//! End-to-end serving driver (DESIGN.md's required e2e validation).
//!
//! Starts the TCP server over the build-time-trained models, fires a
//! batch of concurrent protocol-v2 client requests at it, and reports
//! latency/throughput — then repeats with speculation disabled
//! (autoregressive target-only) to show the speculative speedup, and with
//! the sigmoid method to show the paper's fastest configuration.
//! Finishes with a protocol-v2 showcase: streaming deltas, per-request
//! greedy + stop-sequence + γ-pin overrides, and mid-decode cancellation
//! against the same server.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_demo
//! ```

use std::sync::Arc;

use anyhow::Result;
use specd::engine::{Backend, Engine, EngineConfig, Mode, SamplingParams};
use specd::runtime::Runtime;
use specd::sampling::Method;
use specd::server::service::Client;
use specd::server::{Server, ServerConfig};
use specd::tokenizer::Tokenizer;
use specd::util::stats::Series;

const PROMPTS: &[&str] = &[
    "The scheduler accepts the drafted tokens",
    "A worker thread verifies a probability tile",
    "The request router batches the next request",
    "The profiler tracks the partial sums",
    "The memory pool loads the logits",
    "A streaming client emits the bonus token",
    "The batch planner schedules the decode queue",
    "The verification kernel reduces the residual",
];
const MAX_NEW: usize = 48;
const ROUNDS: usize = 2;

fn run_config(label: &str, method: Method, mode: Mode) -> Result<(f64, f64, f64)> {
    let runtime = Arc::new(Runtime::open_default()?);
    let tokenizer = Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json"))?;
    let engine = Engine::new(
        runtime.clone(),
        EngineConfig {
            method,
            backend: Backend::Hlo,
            mode,
            ..EngineConfig::default()
        },
    )?;
    let server = Arc::new(Server::start(
        engine,
        tokenizer,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
    )?);
    let addr = server.addr().to_string();
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_forever();
        });
    }

    let started = std::time::Instant::now();
    let mut handles = Vec::new();
    for (i, prompt) in PROMPTS.iter().enumerate() {
        let addr = addr.clone();
        let prompt = prompt.to_string();
        handles.push(std::thread::spawn(move || -> Result<Vec<(f64, usize)>> {
            let mut client = Client::connect(&addr)?;
            let params = SamplingParams::default()
                .with_max_new_tokens(MAX_NEW)
                .with_temperature(0.7);
            let mut out = Vec::new();
            for round in 0..ROUNDS {
                let resp =
                    client.request_v2((i * 10 + round) as u64, &prompt, &params)?;
                anyhow::ensure!(resp.get("error").is_none(), "server error: {}", resp.dump());
                out.push((
                    resp.get("latency_ms").unwrap().as_f64().unwrap(),
                    resp.get("tokens").unwrap().as_usize().unwrap(),
                ));
            }
            Ok(out)
        }));
    }
    let mut latency = Series::new();
    let mut tokens = 0usize;
    for h in handles {
        for (lat_ms, toks) in h.join().unwrap()? {
            latency.push(lat_ms);
            tokens += toks;
        }
    }
    let wall = started.elapsed().as_secs_f64();
    let tput = tokens as f64 / wall;
    println!(
        "{label:<28} {:>3} reqs  p50 {:>8.1}ms  p99 {:>8.1}ms  {:>7.1} tok/s  ({} tokens in {:.2}s)",
        latency.len(),
        latency.percentile(50.0),
        latency.percentile(99.0),
        tput,
        tokens,
        wall
    );
    server.shutdown();
    Ok((latency.percentile(50.0), latency.percentile(99.0), tput))
}

/// Protocol-v2 showcase against one running server: streaming deltas,
/// per-request overrides (greedy, stop sequences, pinned γ), and
/// mid-decode cancellation.
fn protocol_v2_demo() -> Result<()> {
    let runtime = Arc::new(Runtime::open_default()?);
    let tokenizer = Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json"))?;
    let engine = Engine::new(runtime, EngineConfig::default())?;
    let server = Arc::new(Server::start(
        engine,
        tokenizer,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
    )?);
    let addr = server.addr().to_string();
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_forever();
        });
    }
    let mut c = Client::connect(&addr)?;

    // 1. stream a sampled request: delta events arrive as tokens commit
    c.send_generate(
        1,
        "The request router batches",
        &SamplingParams::default()
            .with_max_new_tokens(32)
            .with_temperature(0.8)
            .with_top_p(0.9),
        true,
    )?;
    let mut chunks = 0usize;
    loop {
        let ev = c.read_event()?;
        match ev.get("event").and_then(|e| e.as_str()) {
            Some("delta") => chunks += 1,
            _ => {
                println!(
                    "streamed request: {chunks} delta chunks, finish={}",
                    ev.get("finish").and_then(|f| f.as_str()).unwrap_or("?")
                );
                break;
            }
        }
    }

    // 2. per-request overrides: greedy, stop at the first space, γ pinned
    let resp = c.request_v2(
        2,
        "The verification kernel",
        &SamplingParams::default()
            .greedy()
            .with_max_new_tokens(32)
            .with_stop(vec![" ".into()])
            .pin_gamma(2),
    )?;
    println!(
        "greedy + stop + γ-pin: finish={} text={:?}",
        resp.get("finish").and_then(|f| f.as_str()).unwrap_or("?"),
        resp.get("text").and_then(|t| t.as_str()).unwrap_or("?"),
    );

    // 3. cancel mid-decode: the slot is freed and the request finishes
    // with "cancel"
    c.send_generate(
        3,
        "The memory pool loads",
        &SamplingParams::default().with_max_new_tokens(256),
        true,
    )?;
    let _first_delta = c.read_event()?; // decode has started
    c.send_cancel(3)?;
    loop {
        let ev = c.read_event()?;
        if ev.get("event").and_then(|e| e.as_str()) != Some("delta") {
            println!(
                "cancelled request: finish={} after {} tokens",
                ev.get("finish").and_then(|f| f.as_str()).unwrap_or("?"),
                ev.get("tokens").and_then(|t| t.as_usize()).unwrap_or(0),
            );
            break;
        }
    }
    server.shutdown();
    Ok(())
}

fn main() -> Result<()> {
    println!(
        "serve_demo: {} concurrent clients × {} rounds, {} new tokens each\n",
        PROMPTS.len(),
        ROUNDS,
        MAX_NEW
    );
    let (_, _, tput_ar) = run_config(
        "autoregressive (no spec)",
        Method::Exact,
        Mode::Autoregressive,
    )?;
    let (_, _, tput_base) = run_config(
        "speculative baseline",
        Method::Baseline,
        Mode::Speculative,
    )?;
    let (_, _, tput_exact) =
        run_config("speculative exact", Method::Exact, Mode::Speculative)?;
    let (_, _, tput_sig) = run_config(
        "speculative sigmoid",
        Method::sigmoid(-1e3, 1e3),
        Mode::Speculative,
    )?;
    println!(
        "\nspeculative speedup over autoregressive: baseline {:.2}x, exact {:.2}x, sigmoid {:.2}x",
        tput_base / tput_ar,
        tput_exact / tput_ar,
        tput_sig / tput_ar
    );

    println!("\nprotocol v2 showcase (streaming / overrides / cancel):");
    protocol_v2_demo()
}
