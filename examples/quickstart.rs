//! Quickstart: load the AOT artifacts, build an engine, generate text
//! with the paper's exact optimized verification, and print the
//! speculative-decoding statistics.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use anyhow::Result;
use specd::engine::{Backend, Engine, EngineConfig, Mode, SamplingParams};
use specd::runtime::Runtime;
use specd::sampling::Method;
use specd::tokenizer::Tokenizer;

fn main() -> Result<()> {
    // 1. open the artifacts directory (lazy-compiles executables via PJRT)
    let runtime = Arc::new(Runtime::open_default()?);
    println!(
        "loaded manifest: vocab={} seq={} artifacts={}",
        runtime.manifest.vocab_size,
        runtime.manifest.seq_len,
        runtime.manifest.entries.len()
    );

    // 2. tokenizer written by the python build
    let tok = Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json"))?;

    // 3. engine with the paper's exact fused verification kernel
    let mut engine = Engine::new(
        runtime.clone(),
        EngineConfig {
            method: Method::Exact,
            backend: Backend::Hlo,
            mode: Mode::Speculative,
            ..EngineConfig::default()
        },
    )?;

    // 4. generate — per-request policy is a SamplingParams: nucleus
    // sampling at temperature 0.5, stopping at a period
    let prompts = [
        ("The scheduler accepts the drafted tokens", 64usize),
        ("A worker thread verifies", 48usize),
    ];
    let params = SamplingParams::default()
        .with_temperature(0.5)
        .with_top_p(0.95)
        .with_stop(vec![". ".into()]);
    let out = engine.generate_text(&tok, &prompts, &params)?;
    for ((prompt, _), (text, r)) in prompts.iter().zip(&out) {
        println!("\nprompt : {prompt}");
        println!("output : {text}");
        println!(
            "stats  : {} tokens in {} steps ({:.2} tok/step), accept {:.1}%, {:.0}ms",
            r.token_ids.len(),
            r.steps,
            r.tokens_per_step(),
            r.acceptance_rate() * 100.0,
            r.latency * 1e3
        );
    }

    // 5. where the time went (the paper's profiling methodology)
    println!("\nprofile:\n{}", runtime.profiler.render());
    Ok(())
}
