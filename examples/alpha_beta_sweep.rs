//! Table 2/7 driver: the effect of the sigmoid scaling constants (α, β)
//! on accuracy and profiling time — including the ±10⁵ collapse.
//!
//! ```bash
//! cargo run --release --example alpha_beta_sweep -- 8
//! ```

use anyhow::Result;
use specd::engine::{Backend, SamplingParams};
use specd::sampling::Method;
use specd::tables::{run_method, EvalContext};
use specd::util::stats::rel_improvement_pct;
use specd::workload::{make_tasks, TaskKind};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let mut ctx = EvalContext::open_default(n)?;
    // explicit per-request policy (the shared defaults minus temperature)
    ctx.params = SamplingParams::default().with_temperature(0.5);
    for (kind, label) in [
        (TaskKind::Asr, "ASR role (WER ↓, paper uses α,β = ±1e3)"),
        (TaskKind::Summarize, "summarization role (ROUGE-1 ↑, paper ±1e4)"),
    ] {
        println!("\n=== {label} ===");
        let tasks = make_tasks(&ctx.corpus, kind, n, 104);
        let base = run_method(&ctx, &tasks, Method::Baseline, Backend::Hlo, 5, false)?;
        println!(
            "{:<12} {:>8} {:>10} {:>8}",
            "scale", kind.metric_name(), "Δ%prof", "accept"
        );
        println!(
            "{:<12} {:>8.3} {:>10} {:>7.1}%",
            "baseline", base.metric, "-", base.acceptance_rate * 100.0
        );
        for exp in [1i32, 3, 4, 5] {
            let s = 10f32.powi(exp);
            let run = run_method(&ctx, &tasks, Method::sigmoid(-s, s), Backend::Hlo, 5, false)?;
            println!(
                "±1e{exp:<9} {:>8.3} {:>9.1}% {:>7.1}%",
                run.metric,
                rel_improvement_pct(base.profiling_total, run.profiling_total),
                run.acceptance_rate * 100.0
            );
        }
    }
    println!(
        "\nexpected: ±1e3/±1e4 near-baseline accuracy; ±1e5 accepts \
         everything the draft proposes (accuracy collapse, Table 2's \
         WER-29.34 row); ±1e1 over-sharpens the ratio."
    );
    Ok(())
}
