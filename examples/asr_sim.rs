//! ASR-role workload (the LibriSpeech/TED-LIUM/CV16 rows of Table 1):
//! WER for all three verification methods plus the native-oracle backend.
//!
//! ```bash
//! cargo run --release --example asr_sim -- 12
//! ```

use anyhow::Result;
use specd::engine::{Backend, SamplingParams};
use specd::sampling::Method;
use specd::tables::{run_method, EvalContext};
use specd::util::stats::rel_improvement_pct;
use specd::workload::{make_tasks, TaskKind};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let mut ctx = EvalContext::open_default(n)?;
    // transcription wants determinism: greedy per-request policy (the
    // same server can concurrently serve sampled summarization — see
    // examples/summarize.rs)
    ctx.params = SamplingParams::default().greedy();
    let tasks = make_tasks(&ctx.corpus, TaskKind::Asr, n, 103);
    println!("asr_sim: {n} greedy transcription-continuation examples (WER, lower is better)\n");

    let runs = [
        ("baseline/hlo", run_method(&ctx, &tasks, Method::Baseline, Backend::Hlo, 5, false)?),
        ("exact/hlo", run_method(&ctx, &tasks, Method::Exact, Backend::Hlo, 5, false)?),
        ("exact/native", run_method(&ctx, &tasks, Method::Exact, Backend::Native, 5, false)?),
        ("sigmoid/hlo", run_method(&ctx, &tasks, Method::sigmoid(-1e3, 1e3), Backend::Hlo, 5, false)?),
    ];
    let base_prof = runs[0].1.profiling_total;
    println!("{:<14} {:>6} {:>12} {:>10} {:>8}", "method", "WER", "Δ%prof", "tok/step", "accept");
    for (name, run) in &runs {
        println!(
            "{name:<14} {:>6.2} {:>11.1}% {:>10.2} {:>7.1}%",
            run.metric,
            rel_improvement_pct(base_prof, run.profiling_total),
            run.emitted_tokens as f64 / run.steps.max(1) as f64,
            run.acceptance_rate * 100.0,
        );
    }
    assert_eq!(runs[0].1.metric, runs[1].1.metric, "exact must tie baseline");
    println!("\nexact == baseline WER verified ✓");
    Ok(())
}
