//! Summarization-role workload (the Xsum/CNN-DM rows of Table 1):
//! run all three verification methods on the same task set and report
//! ROUGE-1 + Δ% profiling time.
//!
//! ```bash
//! cargo run --release --example summarize -- 12   # examples per method
//! ```

use anyhow::Result;
use specd::engine::{Backend, SamplingParams};
use specd::sampling::Method;
use specd::tables::{run_method, EvalContext};
use specd::util::stats::rel_improvement_pct;
use specd::workload::{make_tasks, TaskKind};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let mut ctx = EvalContext::open_default(n)?;
    // summarization samples with nucleus truncation (per-request policy;
    // the top-p mask applies identically to every verification method,
    // so the exact == baseline tie below still holds)
    ctx.params = SamplingParams::default().with_temperature(0.7).with_top_p(0.95);
    let tasks = make_tasks(&ctx.corpus, TaskKind::Summarize, n, 202);
    println!("summarize: {n} nucleus-sampled examples, 3 methods (same seeds — exact must tie baseline)\n");

    let base = run_method(&ctx, &tasks, Method::Baseline, Backend::Hlo, 5, false)?;
    let exact = run_method(&ctx, &tasks, Method::Exact, Backend::Hlo, 5, false)?;
    let sig = run_method(&ctx, &tasks, Method::sigmoid(-1e4, 1e4), Backend::Hlo, 5, false)?;

    println!("{:<10} {:>8} {:>12} {:>10} {:>8} {:>10}", "method", "ROUGE-1", "Δ%prof", "tok/step", "accept", "steps");
    for (name, run) in [("baseline", &base), ("exact", &exact), ("sigmoid", &sig)] {
        println!(
            "{name:<10} {:>8.3} {:>11.1}% {:>10.2} {:>7.1}% {:>10}",
            run.metric,
            rel_improvement_pct(base.profiling_total, run.profiling_total),
            run.emitted_tokens as f64 / run.steps.max(1) as f64,
            run.acceptance_rate * 100.0,
            run.steps,
        );
    }
    assert_eq!(
        base.metric, exact.metric,
        "exact must reproduce baseline bit-for-bit"
    );
    println!("\nexact == baseline ROUGE verified ✓");
    Ok(())
}
