//! Figure 3/4/5 driver: sweep pinned γ and emit CSV for per-step
//! verification time and peak memory, measured + simulated.
//!
//! ```bash
//! cargo run --release --example gamma_sweep -- 4 > results/gamma_sweep.csv
//! ```

use anyhow::Result;
use specd::engine::{Backend, SamplingParams};
use specd::sampling::Method;
use specd::simulator::{peak_memory_bytes, simulate_step, DeviceProfile, SimConfig};
use specd::tables::{run_method, EvalContext};
use specd::workload::{make_tasks, TaskKind};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let mut ctx = EvalContext::open_default(n)?;
    // the γ pin below is engine-level (run_method's gamma_pinned); the
    // per-request equivalent is SamplingParams::pin_gamma
    ctx.params = SamplingParams::default().with_temperature(0.5);
    let dev = DeviceProfile::by_name("a100").unwrap();
    let tasks = make_tasks(&ctx.corpus, TaskKind::Summarize, n, 202);
    let methods = [
        ("baseline", Method::Baseline),
        ("exact", Method::Exact),
        ("sigmoid", Method::sigmoid(-1e4, 1e4)),
    ];
    println!(
        "gamma,method,meas_verify_ms,meas_peak_mb,sim_step_ms_llama7b,sim_peak_gb_llama7b,accept"
    );
    for gamma in [1usize, 2, 3, 5, 8, 10, 15, 20] {
        for (name, method) in methods {
            let run = run_method(&ctx, &tasks, method, Backend::Hlo, gamma, true)?;
            let sim_cfg = SimConfig {
                batch: 1,
                gamma,
                vocab: 32_000,
                dtype_bytes: 4,
            };
            let sim = simulate_step(dev, sim_cfg, method);
            let sim_mem = peak_memory_bytes(sim_cfg, 7.0e9, 1.3e9, 2.0);
            println!(
                "{gamma},{name},{:.4},{:.2},{:.3},{:.3},{:.3}",
                run.per_step_verify.mean * 1e3,
                run.peak_mem_bytes as f64 / 1e6,
                sim.step_time * 1e3,
                sim_mem / 1e9,
                run.acceptance_rate,
            );
        }
    }
    Ok(())
}
