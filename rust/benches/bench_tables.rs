//! Table-regeneration bench: times the fast (simulator-backed) table
//! generators end-to-end and one representative measured run, so `cargo
//! bench` stays bounded. Full-budget regeneration of every table runs via
//! `specd table --id all --n 8 > results/tables.txt` (see Makefile
//! `tables` target).

use std::time::Instant;

use specd::engine::Backend;
use specd::sampling::Method;
use specd::simulator::DeviceProfile;
use specd::tables::{generate, run_method, EvalContext, TableId};
use specd::workload::{make_tasks, TaskKind};

fn main() {
    let ctx = EvalContext::open_default(2).expect("run `make artifacts` first");
    let dev = DeviceProfile::by_name("a100").unwrap();

    // simulator-backed tables: cheap, deterministic
    for id in [TableId::T3] {
        let t = Instant::now();
        match generate(id, &ctx, dev) {
            Ok(out) => println!(
                "{id:?}: regenerated in {:.3}s ({} lines)",
                t.elapsed().as_secs_f64(),
                out.lines().count()
            ),
            Err(e) => println!("{id:?}: FAILED — {e:#}"),
        }
    }

    // one representative measured harness run per method (the unit of work
    // every measured table is built from)
    let tasks = make_tasks(&ctx.corpus, TaskKind::Summarize, 2, 202);
    for (name, method) in [
        ("baseline", Method::Baseline),
        ("exact", Method::Exact),
        ("sigmoid", Method::sigmoid(-1e4, 1e4)),
    ] {
        let t = Instant::now();
        match run_method(&ctx, &tasks, method, Backend::Hlo, 5, false) {
            Ok(run) => println!(
                "run_method/{name}: {:.2}s wall, {} steps, Σprofiling {:.2}ms, metric {:.3}",
                t.elapsed().as_secs_f64(),
                run.steps,
                run.profiling_total * 1e3,
                run.metric
            ),
            Err(e) => println!("run_method/{name}: FAILED — {e:#}"),
        }
    }
    println!("\nfull regeneration: `specd table --id all --n 8` (see results/)");
}
