//! Substrate micro-benchmarks: the hand-rolled components on the hot
//! path (JSON wire protocol, RNG, softmax, metrics, profiler overhead).
//!
//! `cargo bench --bench bench_substrate`

use specd::metrics::{rouge1_f1, wer};
use specd::sampling;
use specd::util::bench::{bench_report, black_box, BenchConfig};
use specd::util::json;
use specd::util::rng::Pcg32;
use specd::util::timer::Profiler;

fn main() {
    let cfg = BenchConfig::default();

    // JSON: a typical response line
    let line = r#"{"id":42,"text":"the scheduler accepts the drafted tokens in parallel","tokens":64,"steps":17,"accept_rate":0.61,"tokens_per_step":3.76,"latency_ms":12.25,"finish":"length"}"#;
    bench_report("json/parse_response_line", cfg, || {
        black_box(json::parse(line).unwrap());
    });
    let v = json::parse(line).unwrap();
    bench_report("json/dump_response_line", cfg, || {
        black_box(v.dump());
    });

    // RNG: uniform fill of a γ=20 acceptance buffer
    let mut rng = Pcg32::seeded(1);
    let mut buf = [0f32; 20];
    bench_report("rng/fill_uniform_20", cfg, || {
        rng.fill_uniform(&mut buf);
        black_box(buf[0]);
    });

    // softmax + sigmoid over a 32k-vocab row (the oracle hot loop)
    let mut rng = Pcg32::seeded(2);
    let logits: Vec<f32> = (0..32_768).map(|_| rng.gaussian() as f32 * 3.0).collect();
    bench_report("sampling/softmax_32k", cfg, || {
        let mut x = logits.clone();
        let n = x.len();
        sampling::softmax_rows(&mut x, n);
        black_box(x[0]);
    });
    bench_report("sampling/sigmoid_approx_32k", cfg, || {
        let mut x = logits.clone();
        sampling::sigmoid_approx(&mut x, -1e3, 1e3);
        black_box(x[0]);
    });
    let weights: Vec<f32> = logits.iter().map(|x| x.max(0.0)).collect();
    bench_report("sampling/inverse_cdf_32k", cfg, || {
        black_box(sampling::inverse_cdf_sample(&weights, 0.7));
    });

    // metrics on ~40-word strings
    let a = "the scheduler accepts the drafted tokens in parallel and then the batch planner emits the next request once per step while the profiler tracks the partial sums after the reduction with bounded memory on the hot path";
    let b = "the scheduler rejects the drafted tokens in sequence and then the batch planner emits the last request twice per step";
    bench_report("metrics/wer_40w", cfg, || {
        black_box(wer(a, b));
    });
    bench_report("metrics/rouge1_40w", cfg, || {
        black_box(rouge1_f1(a, b));
    });

    // profiler overhead per scope (claimed < 1us in timer.rs docs)
    let p = Profiler::new();
    bench_report("profiler/scope_enter_exit", cfg, || {
        let _g = p.scope("bench");
    });
}
