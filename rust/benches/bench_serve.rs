//! Continuous-batching serve-layer benchmark: hundreds of concurrent
//! socket connections against a ragged-batch sim engine, measuring the
//! SLO quantities the server reports (queue wait, decode latency,
//! queue depth) plus connection-outcome accounting, and a second
//! small-scale backpressure scenario exercising the bounded admission
//! queue (`queue_full` / `shed`).
//!
//! ```text
//! cargo bench --bench bench_serve -- [--json <path>] [--smoke]
//! ```
//!
//! `--json <path>` writes a schema-1 snapshot (committed per-PR as
//! `BENCH_PR<N>.json`, see `docs/PERF.md`); `--smoke` shrinks the
//! connection count for CI and additionally **asserts** zero dropped
//! and zero errored connections — the executability gate for the whole
//! queue/refill/cancel path.
//!
//! No artifacts needed: the engine decodes the simulated model pair.
//! Per-connection γ pins cycle {2, 5, 7} (with adaptive and
//! method-override connections mixed in), so the engine batch is
//! genuinely ragged throughout the run.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use specd::engine::{Backend, Engine, EngineConfig, Mode, PipelineMode, SamplingParams};
use specd::runtime::{Runtime, SimSpec};
use specd::sampling::Method;
use specd::server::{Client, Server, ServerConfig};
use specd::tokenizer::Tokenizer;
use specd::util::bench::{snapshot_envelope, write_json, BenchOpts};
use specd::util::json::{obj, Value};
use specd::util::stats::Series;

const BATCH: usize = 8;

fn sim_engine(seed: u64) -> (Engine, Tokenizer) {
    let spec = SimSpec {
        vocab: 512,
        seq_len: 256,
        gmax: 8,
        batches: vec![BATCH],
        seed: 0xBEEF_CAFE,
        agreement: 0.95,
        model_delay: Duration::from_micros(50),
    };
    let vocab = spec.vocab;
    let rt = Arc::new(Runtime::simulated(spec));
    let engine = Engine::new(
        rt,
        EngineConfig {
            pair: "sim".into(),
            batch: BATCH,
            method: Method::Exact,
            backend: Backend::Native,
            mode: Mode::Speculative,
            gamma_init: 4,
            gamma_pinned: false,
            self_draft: false,
            pipeline: PipelineMode::On,
            pipeline_depth: 2,
            pipeline_salvage: true,
            seed,
        },
    )
    .expect("sim engine");
    let chars: Vec<char> = (' '..='~').collect();
    let keep = chars.len().min(vocab - 3);
    let tok = Tokenizer::from_chars(chars[..keep].to_vec(), vocab).expect("sim tokenizer");
    (engine, tok)
}

fn start_server(seed: u64, queue_limit: usize, shed_after: Option<Duration>) -> Arc<Server> {
    let (engine, tok) = sim_engine(seed);
    Arc::new(
        Server::start(
            engine,
            tok,
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                trace: None,
                queue_limit,
                shed_after,
            },
        )
        .expect("server start"),
    )
}

fn spawn_accept(server: &Arc<Server>) -> std::thread::JoinHandle<()> {
    let server = server.clone();
    std::thread::spawn(move || {
        let _ = server.serve_forever();
    })
}

/// Per-connection sampling params: γ pins cycling {2,5,7} keep the
/// batch ragged; every 4th connection runs the adaptive controller and
/// every 5th overrides the verification method.
fn conn_params(idx: usize) -> SamplingParams {
    let mut p = SamplingParams::default()
        .with_max_new_tokens(12 + idx % 9)
        .with_temperature([0.0f32, 0.7, 1.0][idx % 3])
        .with_seed(9000 + idx as u64);
    if idx % 4 != 3 {
        p = p.pin_gamma([2usize, 5, 7][idx % 3]);
    }
    if idx % 5 == 0 {
        p = p.with_method(Method::Baseline);
    }
    p
}

#[derive(Debug, Default, Clone)]
struct ConnOutcome {
    completed: usize,
    cancelled: usize,
    errors: usize,
    dropped: usize,
    tokens: usize,
    /// client-side wall seconds from send to done
    wall: Vec<f64>,
    /// server-reported queue wait (ms) per done
    queue_ms: Vec<f64>,
    /// server-reported queue depth per done, in completion order
    queue_depth: Vec<usize>,
}

/// One connection's lifecycle: a streaming generate (with a mid-stream
/// cancel on every 5th connection), read to done/error.
fn drive_connection(addr: &str, idx: usize) -> ConnOutcome {
    let mut out = ConnOutcome::default();
    let mut c = match Client::connect(addr) {
        Ok(c) => c,
        Err(_) => {
            out.dropped += 1;
            return out;
        }
    };
    let started = Instant::now();
    if c.send_generate(1, "the scheduler accepts the drafted tokens", &conn_params(idx), true)
        .is_err()
    {
        out.dropped += 1;
        return out;
    }
    // churn: every 5th connection cancels — early enough that many are
    // still in the admission queue, exercising the queued-cancel path
    let cancels = idx % 5 == 2;
    if cancels && c.send_cancel(1).is_err() {
        out.dropped += 1;
        return out;
    }
    loop {
        let ev = match c.read_event() {
            Ok(ev) => ev,
            Err(_) => {
                out.dropped += 1;
                return out;
            }
        };
        match ev.get("event").and_then(Value::as_str) {
            Some("delta") => continue,
            Some("done") => {
                out.wall.push(started.elapsed().as_secs_f64());
                out.tokens += ev.get("tokens").and_then(Value::as_usize).unwrap_or(0);
                if let Some(q) = ev.get("queue_ms").and_then(Value::as_f64) {
                    out.queue_ms.push(q);
                }
                if let Some(d) = ev.get("queue_depth").and_then(Value::as_usize) {
                    out.queue_depth.push(d);
                }
                if ev.get("finish").and_then(Value::as_str) == Some("cancel") {
                    out.cancelled += 1;
                } else {
                    out.completed += 1;
                }
                return out;
            }
            _ => {
                out.errors += 1;
                return out;
            }
        }
    }
}

fn merge(into: &mut ConnOutcome, o: ConnOutcome) {
    into.completed += o.completed;
    into.cancelled += o.cancelled;
    into.errors += o.errors;
    into.dropped += o.dropped;
    into.tokens += o.tokens;
    into.wall.extend(o.wall);
    into.queue_ms.extend(o.queue_ms);
    into.queue_depth.extend(o.queue_depth);
}

/// The headline scenario: `conns` concurrent connections (one thread
/// each) against one server. Returns the aggregate and the wall time.
fn churn_scenario(conns: usize) -> (ConnOutcome, f64) {
    let server = start_server(7, conns.max(16), None);
    let accept = spawn_accept(&server);
    let addr = server.addr().to_string();
    let (tx, rx) = channel::<ConnOutcome>();
    let started = Instant::now();
    let mut handles = Vec::with_capacity(conns);
    for idx in 0..conns {
        let addr = addr.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            let _ = tx.send(drive_connection(&addr, idx));
        }));
    }
    drop(tx);
    let mut agg = ConnOutcome::default();
    for o in rx {
        merge(&mut agg, o);
    }
    let wall = started.elapsed().as_secs_f64();
    for h in handles {
        let _ = h.join();
    }
    server.shutdown();
    let _ = accept.join();
    (agg, wall)
}

/// Backpressure scenario: a tiny admission queue plus an aggressive
/// shed deadline under a connection burst — counts the structured
/// `queue_full` / `shed` rejections the overload produces.
fn backpressure_scenario(conns: usize) -> (usize, usize, usize, usize) {
    let server = start_server(11, 2, Some(Duration::from_millis(250)));
    let accept = spawn_accept(&server);
    let addr = server.addr().to_string();
    let (tx, rx) = channel::<&'static str>();
    let mut handles = Vec::with_capacity(conns);
    for idx in 0..conns {
        let addr = addr.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            let outcome = (|| -> anyhow::Result<&'static str> {
                let mut c = Client::connect(&addr)?;
                let params = SamplingParams::default()
                    .with_max_new_tokens(24)
                    .with_seed(idx as u64);
                c.send_generate(1, "burst", &params, false)?;
                let ev = c.read_event()?;
                Ok(match ev.get("code").and_then(Value::as_str) {
                    Some("queue_full") => "queue_full",
                    Some("shed") => "shed",
                    Some(_) => "error",
                    None => "done",
                })
            })()
            .unwrap_or("dropped");
            let _ = tx.send(outcome);
        }));
    }
    drop(tx);
    let (mut done, mut full, mut shed, mut other) = (0usize, 0usize, 0usize, 0usize);
    for o in rx {
        match o {
            "done" => done += 1,
            "queue_full" => full += 1,
            "shed" => shed += 1,
            _ => other += 1,
        }
    }
    for h in handles {
        let _ = h.join();
    }
    server.shutdown();
    let _ = accept.join();
    (done, full, shed, other)
}

fn percentile_section(name: &str, samples_ms: &[f64]) -> (String, Value) {
    let mut s = Series::new();
    for &x in samples_ms {
        s.push(x);
    }
    let sum = s.summary();
    let line = format!(
        "{name:<24} n={:<5} p50 {:>8.2}ms  p95 {:>8.2}ms  p99 {:>8.2}ms",
        sum.n, sum.p50, sum.p95, sum.p99
    );
    let json = obj(vec![
        ("n", sum.n.into()),
        ("p50_ms", Value::Num(sum.p50)),
        ("p90_ms", Value::Num(sum.p90)),
        ("p95_ms", Value::Num(sum.p95)),
        ("p99_ms", Value::Num(sum.p99)),
        ("mean_ms", Value::Num(sum.mean)),
    ]);
    (line, json)
}

/// Downsample the completion-ordered queue-depth series to at most
/// `cap` points for the snapshot.
fn depth_series(depths: &[usize], cap: usize) -> Vec<Value> {
    let stride = depths.len().div_ceil(cap).max(1);
    depths
        .iter()
        .step_by(stride)
        .map(|&d| (d as i64).into())
        .collect()
}

fn main() {
    let opts = BenchOpts::from_args();
    let conns = if opts.smoke { 32 } else { 240 };

    println!(
        "serve-layer churn: {conns} concurrent connections, batch {BATCH}, \
         ragged γ pins {{2,5,7}} + adaptive, 1-in-5 cancels\n"
    );
    let (agg, wall) = churn_scenario(conns);
    let tps = agg.tokens as f64 / wall;
    println!(
        "connections: {} completed, {} cancelled, {} errors, {} dropped",
        agg.completed, agg.cancelled, agg.errors, agg.dropped
    );
    println!("throughput : {} tokens in {wall:.2}s ({tps:.0} tok/s)\n", agg.tokens);
    let (lat_line, lat_json) =
        percentile_section("decode latency", &agg.wall.iter().map(|s| s * 1e3).collect::<Vec<_>>());
    let (q_line, q_json) = percentile_section("queue wait", &agg.queue_ms);
    println!("{lat_line}\n{q_line}");
    let max_depth = agg.queue_depth.iter().copied().max().unwrap_or(0);
    let mean_depth = if agg.queue_depth.is_empty() {
        0.0
    } else {
        agg.queue_depth.iter().sum::<usize>() as f64 / agg.queue_depth.len() as f64
    };
    println!("queue depth              max {max_depth}  mean {mean_depth:.1}\n");

    assert_eq!(
        agg.completed + agg.cancelled + agg.errors + agg.dropped,
        conns,
        "every connection must be accounted for"
    );
    if opts.smoke {
        assert_eq!(agg.dropped, 0, "smoke gate: no connection may drop");
        assert_eq!(agg.errors, 0, "smoke gate: no connection may error");
        assert!(agg.cancelled > 0, "smoke gate: cancel path must exercise");
    }

    let bconns = if opts.smoke { 16 } else { 64 };
    println!("backpressure burst: {bconns} connections, queue_limit=2, shed-after=250ms\n");
    let (done, full, shed, other) = backpressure_scenario(bconns);
    println!(
        "outcomes: {done} done, {full} queue_full, {shed} shed, {other} other\n"
    );

    if let Some(path) = &opts.json {
        let report = snapshot_envelope(
            "bench_serve",
            opts.smoke,
            vec![
                (
                    "serve",
                    obj(vec![
                        ("batch", BATCH.into()),
                        (
                            "connections",
                            obj(vec![
                                ("total", conns.into()),
                                ("completed", agg.completed.into()),
                                ("cancelled", agg.cancelled.into()),
                                ("errors", agg.errors.into()),
                                ("dropped", agg.dropped.into()),
                            ]),
                        ),
                        ("latency", lat_json),
                        ("queue_wait", q_json),
                        (
                            "queue_depth",
                            obj(vec![
                                ("max", max_depth.into()),
                                ("mean", Value::Num(mean_depth)),
                                ("series", Value::Arr(depth_series(&agg.queue_depth, 64))),
                            ]),
                        ),
                        (
                            "throughput",
                            obj(vec![
                                ("tokens", agg.tokens.into()),
                                ("wall_s", Value::Num(wall)),
                                ("tokens_per_sec", Value::Num(tps)),
                            ]),
                        ),
                    ]),
                ),
                (
                    "backpressure",
                    obj(vec![
                        ("connections", bconns.into()),
                        ("queue_limit", 2i64.into()),
                        ("shed_after_ms", 250i64.into()),
                        ("done", done.into()),
                        ("queue_full", full.into()),
                        ("shed", shed.into()),
                        ("other", other.into()),
                    ]),
                ),
            ],
        );
        write_json(path, &report).expect("writing bench json");
        println!("wrote {}", path.display());
    }
}
