//! Verification micro-benchmark (Table 6's per-step quantity, kernel
//! only): execute the three verify artifacts at the engine vocab and at
//! the paper-scale vocabularies, plus the native oracle for reference.
//!
//! `cargo bench --bench bench_verify`

use std::sync::Arc;

use specd::runtime::{HostTensor, Runtime};
use specd::sampling::kernels::{KernelConfig, VerifyWorkspace};
use specd::sampling::{self, Method};
use specd::util::bench::{bench_report, BenchConfig};
use specd::util::rng::Pcg32;

fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

fn main() {
    let rt = Arc::new(Runtime::open_default().expect("run `make artifacts` first"));
    let cfg = BenchConfig {
        warmup_iters: 3,
        min_iters: 15,
        max_iters: 200,
        max_time: std::time::Duration::from_secs(2),
    };
    let g = 5usize;
    println!("verification step, B=1 γ={g} (HLO artifacts via PJRT-CPU + native oracle)\n");

    let mut vocabs = vec![rt.manifest.vocab_size, 4096];
    if rt.manifest.verify("baseline", 1, g, 32768).is_ok() {
        vocabs.push(32768);
    }
    for v in vocabs {
        let mut rng = Pcg32::seeded(7);
        let z_p = randn(&mut rng, (g + 1) * v, 3.0);
        let z_q = randn(&mut rng, g * v, 3.0);
        let draft: Vec<i32> = (0..g).map(|_| rng.below(v as u32) as i32).collect();
        let u_acc: Vec<f32> = (0..g).map(|_| rng.uniform_f32()).collect();
        let base_inputs = vec![
            HostTensor::f32(&[1, g + 1, v], z_p.clone()),
            HostTensor::f32(&[1, g, v], z_q.clone()),
            HostTensor::i32(&[1, g], draft.clone()),
            HostTensor::f32(&[1, g], u_acc.clone()),
            HostTensor::f32(&[1], vec![0.4]),
            HostTensor::f32(&[1], vec![0.6]),
        ];
        for method in ["baseline", "exact", "sigmoid"] {
            let exe = rt.load_verify(method, 1, g, v).expect(method);
            let mut inputs = base_inputs.clone();
            if method == "sigmoid" {
                inputs.push(HostTensor::f32(&[2], vec![-1e3, 1e3]));
            }
            bench_report(&format!("hlo/{method}/v{v}"), cfg, || {
                let out = exe.run(&inputs).unwrap();
                specd::util::bench::black_box(out);
            });
        }
        // tile-size ablation artifacts (DESIGN §5), V=32768 only
        if v == 32768 {
            for t in [128usize, 256, 512] {
                let name = format!("verify_exact_b1_g{g}_v{v}_t{t}");
                if let Ok(exe) = rt.load(&name) {
                    bench_report(&format!("hlo/exact/v{v}/tile{t}"), cfg, || {
                        let out = exe.run(&base_inputs).unwrap();
                        specd::util::bench::black_box(out);
                    });
                }
            }
        }
        // native scalar oracle for scale
        bench_report(&format!("native/exact/v{v}"), cfg, || {
            let out = sampling::verify::spec_step_batch(
                &z_p, &z_q, 1, g, v, &draft, &u_acc, &[0.4], &[0.6],
                &[Method::Exact], None,
            );
            specd::util::bench::black_box(out);
        });
        bench_report(&format!("native/sigmoid/v{v}"), cfg, || {
            let out = sampling::verify::spec_step_batch(
                &z_p, &z_q, 1, g, v, &draft, &u_acc, &[0.4], &[0.6],
                &[Method::sigmoid(-1e3, 1e3)], None,
            );
            specd::util::bench::black_box(out);
        });
        // segment-parallel kernel layer (zero-alloc workspace reuse; the
        // workspace's persistent pool spawns during warmup, once, so the
        // timed iterations see only the steady-state dispatch cost)
        {
            let kcfg = KernelConfig {
                min_parallel_elems: 0,
                ..KernelConfig::default()
            };
            let threads = kcfg.threads;
            let mut ws = VerifyWorkspace::with_capacity(kcfg, 1, g, v);
            let mut accept = Vec::new();
            let mut tokens = Vec::new();
            bench_report(&format!("kernels/exact/v{v}/t{threads}"), cfg, || {
                sampling::kernels::spec_step_batch_ws(
                    &mut ws, &z_p, &z_q, 1, g, v, &draft, &u_acc, &[0.4], &[0.6],
                    &[Method::Exact], &mut accept, &mut tokens, None,
                );
                specd::util::bench::black_box((&accept, &tokens));
            });
        }
        println!();
    }
}
