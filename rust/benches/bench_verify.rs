//! Verification micro-benchmark (Table 6's per-step quantity, kernel
//! only): execute the three verify artifacts at the engine vocab and at
//! the paper-scale vocabularies, plus the native oracle and the
//! segment-parallel kernel layer for reference.
//!
//! ```text
//! cargo bench --bench bench_verify -- [--json <path>] [--smoke]
//! ```
//!
//! `--json <path>` writes the same `{"schema": 1, "git_rev": …}`
//! snapshot envelope as `bench_e2e` (see `docs/PERF.md`), with one row
//! per benched target. The HLO rows need built artifacts and skip
//! themselves with a notice when the runtime is unavailable; the native
//! oracle and kernel rows always run, so the target is CI-safe.

use std::sync::Arc;

use specd::runtime::{HostTensor, Runtime};
use specd::sampling::kernels::{KernelConfig, VerifyWorkspace};
use specd::sampling::{self, Method, SimdMode};
use specd::util::bench::{bench_report, snapshot_envelope, write_json, BenchOpts, BenchResult};
use specd::util::json::{obj, Value};
use specd::util::rng::Pcg32;

fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

// schema-1 row: `vocab` and `simd` label every timing so the trajectory
// can tell a V=4096 scalar row from a V=32k SIMD row ("n/a" = the lane
// path does not apply, e.g. HLO artifact rows)
fn row_json(vocab: usize, simd: &str, r: &BenchResult) -> Value {
    obj(vec![
        ("vocab", vocab.into()),
        ("simd", simd.into()),
        ("timing", r.to_json()),
    ])
}

fn main() {
    let opts = BenchOpts::from_args();
    let cfg = opts.config();

    // HLO rows need the PJRT runtime + artifacts; everything else is
    // artifact-free, so degrade instead of dying
    let rt: Option<Arc<Runtime>> = match Runtime::open_default() {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            println!("skipping HLO rows: artifacts unavailable ({e:#})\n");
            None
        }
    };

    let g = 5usize;
    println!("verification step, B=1 γ={g} (HLO artifacts via PJRT-CPU + native paths)\n");

    let mut vocabs = vec![4096usize];
    if let Some(rt) = &rt {
        vocabs.insert(0, rt.manifest.vocab_size);
        if rt.manifest.verify("baseline", 1, g, 32768).is_ok() {
            vocabs.push(32768);
        }
    }

    let mut rows: Vec<Value> = Vec::new();
    for v in vocabs {
        let mut rng = Pcg32::seeded(7);
        let z_p = randn(&mut rng, (g + 1) * v, 3.0);
        let z_q = randn(&mut rng, g * v, 3.0);
        let draft: Vec<i32> = (0..g).map(|_| rng.below(v as u32) as i32).collect();
        let u_acc: Vec<f32> = (0..g).map(|_| rng.uniform_f32()).collect();

        if let Some(rt) = &rt {
            let base_inputs = vec![
                HostTensor::f32(&[1, g + 1, v], z_p.clone()),
                HostTensor::f32(&[1, g, v], z_q.clone()),
                HostTensor::i32(&[1, g], draft.clone()),
                HostTensor::f32(&[1, g], u_acc.clone()),
                HostTensor::f32(&[1], vec![0.4]),
                HostTensor::f32(&[1], vec![0.6]),
            ];
            for method in ["baseline", "exact", "sigmoid"] {
                let Ok(exe) = rt.load_verify(method, 1, g, v) else {
                    println!("skipping hlo/{method}/v{v}: no artifact");
                    continue;
                };
                let mut inputs = base_inputs.clone();
                if method == "sigmoid" {
                    inputs.push(HostTensor::f32(&[2], vec![-1e3, 1e3]));
                }
                let r = bench_report(&format!("hlo/{method}/v{v}"), cfg, || {
                    let out = exe.run(&inputs).unwrap();
                    specd::util::bench::black_box(out);
                });
                rows.push(row_json(v, "n/a", &r));
            }
            // tile-size ablation artifacts (DESIGN §5), V=32768 only
            if v == 32768 {
                for t in [128usize, 256, 512] {
                    let name = format!("verify_exact_b1_g{g}_v{v}_t{t}");
                    if let Ok(exe) = rt.load(&name) {
                        let r = bench_report(&format!("hlo/exact/v{v}/tile{t}"), cfg, || {
                            let out = exe.run(&base_inputs).unwrap();
                            specd::util::bench::black_box(out);
                        });
                        rows.push(row_json(v, "n/a", &r));
                    }
                }
            }
        }

        // native scalar oracle for scale
        let r = bench_report(&format!("native/exact/v{v}"), cfg, || {
            let out = sampling::verify::spec_step_batch(
                &z_p, &z_q, 1, g, v, &draft, &u_acc, &[0.4], &[0.6],
                &[Method::Exact], None,
            );
            specd::util::bench::black_box(out);
        });
        rows.push(row_json(v, "off", &r));
        let r = bench_report(&format!("native/sigmoid/v{v}"), cfg, || {
            let out = sampling::verify::spec_step_batch(
                &z_p, &z_q, 1, g, v, &draft, &u_acc, &[0.4], &[0.6],
                &[Method::sigmoid(-1e3, 1e3)], None,
            );
            specd::util::bench::black_box(out);
        });
        rows.push(row_json(v, "off", &r));
        // segment-parallel kernel layer (zero-alloc workspace reuse; the
        // workspace's persistent pool spawns during warmup, once, so the
        // timed iterations see only the steady-state dispatch cost)
        // both lane paths: SimdMode::On degrades to the scalar lane
        // loops off-AVX2 hosts (the row label records what actually ran)
        for mode in [SimdMode::Off, SimdMode::On] {
            let simd_label = if mode.active() { "on" } else { "off" };
            if mode == SimdMode::On && !mode.active() {
                println!("kernels/exact/v{v}: no AVX2, SIMD row measures the scalar path");
            }
            let kcfg = KernelConfig {
                min_parallel_elems: 0,
                simd: mode,
                ..KernelConfig::default()
            };
            let threads = kcfg.threads;
            let mut ws = VerifyWorkspace::with_capacity(kcfg, 1, g, v);
            let mut accept = Vec::new();
            let mut tokens = Vec::new();
            let r = bench_report(
                &format!("kernels/exact/v{v}/t{threads}/simd-{simd_label}"),
                cfg,
                || {
                    sampling::kernels::spec_step_batch_ws(
                        &mut ws, &z_p, &z_q, 1, g, v, &draft, &u_acc, &[0.4], &[0.6],
                        &[Method::Exact], &mut accept, &mut tokens, None,
                    );
                    specd::util::bench::black_box((&accept, &tokens));
                },
            );
            rows.push(row_json(v, simd_label, &r));
        }
        println!();
    }

    if let Some(path) = &opts.json {
        let report = snapshot_envelope(
            "bench_verify",
            opts.smoke,
            vec![
                ("gamma", g.into()),
                ("hlo_available", rt.is_some().into()),
                ("rows", Value::Arr(rows)),
            ],
        );
        write_json(path, &report).expect("writing bench json");
        println!("wrote {}", path.display());
    }
}
