//! Figure 3 (kernel level): verification time vs γ at a paper-scale
//! vocabulary, per method. Prints a CSV series (measured PJRT-CPU) plus
//! the simulated A100 series, and emits the shared schema-1 snapshot
//! envelope on `--json <path>`.
//!
//! `cargo bench --bench bench_gamma_sweep [-- --smoke] [--json out.json]`
//!
//! The measured series needs the AOT verify artifacts (`make
//! artifacts`); without them it skips itself with a notice and only the
//! simulated-A100 series (pure analytical model, no artifacts) is
//! produced — so the CI `--smoke` run works on an artifact-free
//! checkout.

use std::sync::Arc;

use specd::runtime::{HostTensor, Runtime};
use specd::sampling::Method;
use specd::simulator::{simulate_step, DeviceProfile, SimConfig};
use specd::util::bench::{bench, snapshot_envelope, write_json, BenchOpts};
use specd::util::json::{obj, Value};
use specd::util::rng::Pcg32;

const GAMMAS: [usize; 8] = [1, 2, 3, 5, 8, 10, 15, 20];
const METHODS: [&str; 3] = ["baseline", "exact", "sigmoid"];
/// Whisper-scale vocabulary for the simulated-A100 series (paper Fig. 3).
const SIM_VOCAB: usize = 51865;

fn main() {
    let opts = BenchOpts::from_args();
    let cfg = opts.config();
    let dev = DeviceProfile::by_name("a100").unwrap();

    let rt = match Runtime::open_default() {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            println!("skipping measured series: artifacts unavailable ({e:#})");
            None
        }
    };
    // prefer the paper-scale 32k vocab artifacts; fall back to 4096 (quick set)
    let v = match &rt {
        Some(rt) if rt.manifest.verify("baseline", 1, 5, 32768).is_ok() => 32768,
        _ => 4096,
    };

    println!("gamma,method,meas_ms,sim_a100_ms   (V={v}, B=1)");
    let mut rows: Vec<Value> = Vec::new();
    for g in GAMMAS {
        for method in METHODS {
            let meas_ms = rt.as_ref().and_then(|rt| {
                let exe = rt.load_verify(method, 1, g, v).ok()?;
                let mut rng = Pcg32::seeded(g as u64);
                let z_p: Vec<f32> = (0..(g + 1) * v)
                    .map(|_| rng.gaussian() as f32 * 3.0)
                    .collect();
                let z_q: Vec<f32> = (0..g * v).map(|_| rng.gaussian() as f32 * 3.0).collect();
                let mut inputs = vec![
                    HostTensor::f32(&[1, g + 1, v], z_p),
                    HostTensor::f32(&[1, g, v], z_q),
                    HostTensor::i32(&[1, g], (0..g as i32).collect()),
                    HostTensor::f32(&[1, g], vec![0.5; g]),
                    HostTensor::f32(&[1], vec![0.4]),
                    HostTensor::f32(&[1], vec![0.6]),
                ];
                if method == "sigmoid" {
                    inputs.push(HostTensor::f32(&[2], vec![-1e3, 1e3]));
                }
                let r = bench(&format!("{method}/g{g}"), cfg, || {
                    let out = exe.run(&inputs).unwrap();
                    specd::util::bench::black_box(out);
                });
                Some(r.summary.mean * 1e3)
            });
            let m = match method {
                "baseline" => Method::Baseline,
                "exact" => Method::Exact,
                _ => Method::sigmoid(-1e3, 1e3),
            };
            let sim = simulate_step(
                dev,
                SimConfig { batch: 1, gamma: g, vocab: SIM_VOCAB, dtype_bytes: 2 },
                m,
            );
            let sim_ms = sim.step_time * 1e3;
            match meas_ms {
                Some(ms) => println!("{g},{method},{ms:.4},{sim_ms:.3}"),
                None => println!("{g},{method},,{sim_ms:.3}"),
            }
            rows.push(obj(vec![
                ("gamma", (g as i64).into()),
                ("method", method.into()),
                ("meas_ms", meas_ms.map_or(Value::Null, Value::Num)),
                ("sim_a100_ms", Value::Num(sim_ms)),
            ]));
        }
    }

    if let Some(path) = &opts.json {
        let report = snapshot_envelope(
            "bench_gamma_sweep",
            opts.smoke,
            vec![
                ("measured", Value::Bool(rt.is_some())),
                ("vocab", (v as i64).into()),
                ("sim_vocab", (SIM_VOCAB as i64).into()),
                ("sim_device", "a100".into()),
                ("rows", Value::Arr(rows)),
            ],
        );
        write_json(path, &report).expect("writing bench json");
        println!("wrote {}", path.display());
    }
}
