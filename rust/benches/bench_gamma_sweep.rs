//! Figure 3 (kernel level): verification time vs γ at a paper-scale
//! vocabulary, per method. Prints a CSV series (measured PJRT-CPU) plus
//! the simulated A100 series.
//!
//! `cargo bench --bench bench_gamma_sweep`

use std::sync::Arc;

use specd::runtime::{HostTensor, Runtime};
use specd::sampling::Method;
use specd::simulator::{simulate_step, DeviceProfile, SimConfig};
use specd::util::bench::{bench, BenchConfig};
use specd::util::rng::Pcg32;

fn main() {
    let rt = Arc::new(Runtime::open_default().expect("run `make artifacts` first"));
    let dev = DeviceProfile::by_name("a100").unwrap();
    // prefer the paper-scale 32k vocab artifacts; fall back to 4096 (quick set)
    let v = if rt.manifest.verify("baseline", 1, 5, 32768).is_ok() {
        32768
    } else {
        4096
    };
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 10,
        max_iters: 60,
        max_time: std::time::Duration::from_millis(1200),
    };
    println!("gamma,method,meas_ms,sim_a100_ms   (V={v}, B=1)");
    for g in [1usize, 2, 3, 5, 8, 10, 15, 20] {
        for method in ["baseline", "exact", "sigmoid"] {
            let Ok(exe) = rt.load_verify(method, 1, g, v) else {
                continue;
            };
            let mut rng = Pcg32::seeded(g as u64);
            let z_p: Vec<f32> = (0..(g + 1) * v).map(|_| rng.gaussian() as f32 * 3.0).collect();
            let z_q: Vec<f32> = (0..g * v).map(|_| rng.gaussian() as f32 * 3.0).collect();
            let mut inputs = vec![
                HostTensor::f32(&[1, g + 1, v], z_p),
                HostTensor::f32(&[1, g, v], z_q),
                HostTensor::i32(&[1, g], (0..g as i32).collect()),
                HostTensor::f32(&[1, g], vec![0.5; g]),
                HostTensor::f32(&[1], vec![0.4]),
                HostTensor::f32(&[1], vec![0.6]),
            ];
            if method == "sigmoid" {
                inputs.push(HostTensor::f32(&[2], vec![-1e3, 1e3]));
            }
            let r = bench(&format!("{method}/g{g}"), cfg, || {
                let out = exe.run(&inputs).unwrap();
                specd::util::bench::black_box(out);
            });
            let m = match method {
                "baseline" => Method::Baseline,
                "exact" => Method::Exact,
                _ => Method::sigmoid(-1e3, 1e3),
            };
            let sim = simulate_step(
                dev,
                SimConfig { batch: 1, gamma: g, vocab: 51865, dtype_bytes: 2 },
                m,
            );
            println!(
                "{g},{method},{:.4},{:.3}",
                r.summary.mean * 1e3,
                sim.step_time * 1e3
            );
        }
    }
}
