//! End-to-end engine benchmark (Table 5's wall-clock quantity) plus the
//! verify-path kernel comparison (scalar oracle vs the segment-parallel
//! kernel layer), the **pipelined-vs-serial decode comparison** over
//! the simulated model pair, and the **trace record-path overhead**
//! gate (recorder attached vs `NullSink`; must stay under 2%).
//!
//! ```text
//! cargo bench --bench bench_e2e -- [--json <path>] [--smoke]
//! ```
//!
//! `--json <path>` writes a machine-readable report (per-target
//! mean/p50/p95, per-scope profiler totals, tokens/sec, the verify-path
//! speedup and the per-batch pipeline speedups), stamped with
//! `{"schema": 1, "git_rev": …}` so the trajectory tooling described in
//! `docs/PERF.md` can trust the format. Per-PR snapshots are committed
//! as `BENCH_PR<N>.json`; CI's smoke step writes a throwaway
//! `BENCH_CI.json`. `--smoke` runs single-iteration timings (CI
//! executability gate).
//!
//! The verify-path and pipeline sections need no artifacts (the latter
//! decodes over [`specd::runtime::SimSpec`] models); the AOT decode
//! section skips itself with a notice when artifacts are unavailable.

use std::sync::Arc;
use std::time::{Duration, Instant};

use specd::engine::{
    Backend, Engine, EngineConfig, GenRequest, Mode, PipelineMode, SamplingParams,
};
use specd::runtime::{Runtime, SimSpec};
use specd::sampling::kernels::{spec_step_batch_ws, KernelConfig, VerifyWorkspace};
use specd::sampling::{verify, Method};
use specd::tokenizer::Tokenizer;
use specd::trace::{NullSink, TraceRecorder};
use specd::util::bench::{
    bench, black_box, snapshot_envelope, write_json, BenchConfig, BenchOpts, BenchResult,
};
use specd::util::json::{obj, Value};
use specd::util::rng::Pcg32;
use specd::util::stats::rel_improvement_pct;

fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

/// Scalar oracle vs parallel kernels on the native verify path at paper
/// scale (B=4, γ=5, V=4096). Returns the JSON section and the speedup of
/// the widest parallel config over scalar. Each workspace's persistent
/// worker pool spawns during the warmup iterations, outside the timed
/// samples — the timed iterations measure the steady-state dispatch
/// cost the engine sees, not thread spawns.
fn verify_path_section(cfg: BenchConfig) -> (Value, f64) {
    let (b, gamma, v) = (4usize, 5usize, 4096usize);
    let mut rng = Pcg32::seeded(42);
    let z_p = randn(&mut rng, b * (gamma + 1) * v, 3.0);
    let z_q = randn(&mut rng, b * gamma * v, 3.0);
    let draft: Vec<i32> = (0..b * gamma).map(|_| rng.below(v as u32) as i32).collect();
    let u_acc: Vec<f32> = (0..b * gamma).map(|_| rng.uniform_f32()).collect();
    let u_res: Vec<f32> = (0..b).map(|_| rng.uniform_f32()).collect();
    let u_bonus: Vec<f32> = (0..b).map(|_| rng.uniform_f32()).collect();
    let methods = vec![Method::Exact; b];

    println!("native verify path, B={b} γ={gamma} V={v} (scalar oracle vs kernels)\n");
    let scalar = bench("verify/scalar-oracle", cfg, || {
        let out = verify::spec_step_batch(
            &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus, &methods, None,
        );
        black_box(out);
    });
    println!("{}", scalar.row());

    let expect = verify::spec_step_batch(
        &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus, &methods, None,
    );

    let max_threads = KernelConfig::default().threads.max(2);
    let mut thread_counts = vec![1usize, 2];
    if max_threads > 2 {
        thread_counts.push(max_threads);
    }
    let mut rows: Vec<(usize, BenchResult)> = Vec::new();
    for threads in thread_counts {
        let mut kcfg = KernelConfig::with_threads(threads);
        kcfg.min_parallel_elems = 0;
        let mut ws = VerifyWorkspace::with_capacity(kcfg, b, gamma, v);
        let mut accept = Vec::new();
        let mut tokens = Vec::new();
        let r = bench(&format!("verify/kernels-t{threads}"), cfg, || {
            spec_step_batch_ws(
                &mut ws, &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus,
                &methods, &mut accept, &mut tokens, None,
            );
            black_box((&accept, &tokens));
        });
        assert_eq!(
            (accept.clone(), tokens.clone()),
            expect,
            "kernels must stay bit-identical to the scalar oracle"
        );
        println!("{}", r.row());
        rows.push((threads, r));
    }

    // the headline metric counts genuinely parallel configs only — the
    // t1 row measures the zero-alloc workspace rewrite, not parallelism
    let best = rows
        .iter()
        .filter(|(t, _)| *t >= 2)
        .map(|(_, r)| r.mean_secs())
        .fold(f64::INFINITY, f64::min);
    let speedup = scalar.mean_secs() / best;
    println!("\nverify-path speedup (best >=2-thread config vs scalar): {speedup:.2}x\n");

    let section = obj(vec![
        ("batch", b.into()),
        ("gamma", gamma.into()),
        ("vocab", v.into()),
        ("scalar", scalar.to_json()),
        (
            "parallel",
            Value::Arr(
                rows.iter()
                    .map(|(t, r)| {
                        obj(vec![("threads", (*t).into()), ("timing", r.to_json())])
                    })
                    .collect(),
            ),
        ),
        ("speedup", Value::Num(speedup)),
    ]);
    (section, speedup)
}

/// The PR 5 tentpole quantity, generalized by PR 10 to a depth-k
/// speculation window with per-slot partial-hit adoption: the same
/// decode workload through the serial loop and the pipelined scheduler
/// at window depths k ∈ {1,2,3}, over the simulated model pair (no
/// artifacts needed) on the native verify path. Outputs are asserted
/// bit-identical for every (k, salvage) cell before anything is timed;
/// the speedups are pure scheduling.
fn pipeline_section(cfg: BenchConfig) -> (Value, Vec<(usize, f64)>) {
    let spec = SimSpec {
        vocab: 4096,
        seq_len: 512,
        gmax: 10,
        batches: vec![1, 2, 4],
        seed: 0xC0FF_EE11,
        // high draft/target agreement + a short pinned γ keep the
        // all-accept rate (and so the prefetch hit rate) high — the
        // regime speculative decoding is deployed in. A full barrier
        // hit still needs all B·γ drafts accepted, but partial-hit
        // adoption salvages the slots whose prediction held when the
        // barrier misses, so the effective per-slot hit rate sits well
        // above the all-or-nothing block rate at B=4
        agreement: 0.99,
        // emulated device-dispatch latency per model call — the wall
        // time the pipeline exists to hide verification behind
        model_delay: Duration::from_micros(200),
    };
    println!(
        "pipelined vs serial decode (sim models, V={} agreement={} delay={}us)\n",
        spec.vocab,
        spec.agreement,
        spec.model_delay.as_micros()
    );

    let reqs = |b: usize| -> Vec<GenRequest> {
        (0..2 * b as u64)
            .map(|i| {
                GenRequest::new(
                    i,
                    vec![1, 7 + i as i32, 9, 23, 41, 5],
                    SamplingParams::default()
                        .with_max_new_tokens(48)
                        .with_temperature(0.8)
                        .with_seed(1000 + i),
                )
            })
            .collect()
    };
    let engine = |b: usize, pipeline: PipelineMode, depth: usize, salvage: bool| -> Engine {
        let rt = Arc::new(Runtime::simulated(spec.clone()));
        Engine::new(
            rt,
            EngineConfig {
                pair: "sim".into(),
                batch: b,
                method: Method::Exact,
                backend: Backend::Native,
                mode: Mode::Speculative,
                gamma_init: 3,
                gamma_pinned: true,
                self_draft: false,
                pipeline,
                pipeline_depth: depth,
                pipeline_salvage: salvage,
                seed: 7,
            },
        )
        .expect("sim engine")
    };

    // window depths timed per batch; the headline speedup (and the
    // `pipeline_speedups` gate series) uses the default depth
    const DEPTHS: [usize; 3] = [1, 2, 3];
    const HEADLINE_K: usize = 2;

    let mut rows: Vec<Value> = Vec::new();
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for b in [1usize, 2, 4] {
        // correctness first: identical outputs, token for token, for
        // every window depth × salvage mode that gets timed below
        let serial_out = engine(b, PipelineMode::Off, 1, true)
            .generate(reqs(b))
            .unwrap();
        let tokens: usize = serial_out.iter().map(|r| r.token_ids.len()).sum();
        let mut headline_stats = None;
        for depth in DEPTHS {
            for salvage in [true, false] {
                let mut pipe_engine = engine(b, PipelineMode::On, depth, salvage);
                let pipe_out = pipe_engine.generate(reqs(b)).unwrap();
                assert_eq!(serial_out.len(), pipe_out.len());
                for (x, y) in serial_out.iter().zip(&pipe_out) {
                    assert_eq!(
                        x.token_ids, y.token_ids,
                        "pipelined decode must be bit-identical to serial \
                         (B={b} k={depth} salvage={salvage})"
                    );
                }
                if depth == HEADLINE_K && salvage {
                    headline_stats = pipe_engine.pipeline_stats();
                }
            }
        }
        let stats = headline_stats.expect("pipeline enabled");
        let full_hit_rate = if stats.blocks > 0 {
            stats.full_hits as f64 / stats.blocks as f64
        } else {
            0.0
        };
        let effective_hit_rate = stats.effective_hit_rate();

        let mut serial_engine = engine(b, PipelineMode::Off, 1, true);
        let serial = bench(&format!("decode/serial-b{b}"), cfg, || {
            let out = serial_engine.generate(reqs(b)).unwrap();
            black_box(out);
        });
        println!("{}", serial.row());
        let mut depth_rows: Vec<Value> = Vec::new();
        for depth in DEPTHS {
            let mut pipe_engine = engine(b, PipelineMode::On, depth, true);
            let pipelined = bench(&format!("decode/pipelined-b{b}-k{depth}"), cfg, || {
                let out = pipe_engine.generate(reqs(b)).unwrap();
                black_box(out);
            });
            println!("{}", pipelined.row());
            let speedup = serial.mean_secs() / pipelined.mean_secs();
            if depth == HEADLINE_K {
                speedups.push((b, speedup));
            }
            depth_rows.push(obj(vec![
                ("depth", depth.into()),
                ("pipelined", pipelined.to_json()),
                ("speedup", Value::Num(speedup)),
            ]));
        }
        println!(
            "  B={b}: {tokens} tokens/run, full-hit rate {:.0}%, effective \
             (full + salvaged) hit rate {:.0}%, {} slot-rows salvaged / {} \
             redone over {} partial hits\n",
            full_hit_rate * 100.0,
            effective_hit_rate * 100.0,
            stats.slots_salvaged,
            stats.slots_redone,
            stats.partial_hits
        );
        rows.push(obj(vec![
            ("batch", b.into()),
            ("tokens_per_run", tokens.into()),
            ("hit_rate", Value::Num(full_hit_rate)),
            ("effective_hit_rate", Value::Num(effective_hit_rate)),
            ("full_hits", (stats.full_hits as i64).into()),
            ("partial_hits", (stats.partial_hits as i64).into()),
            ("slots_salvaged", (stats.slots_salvaged as i64).into()),
            ("slots_redone", (stats.slots_redone as i64).into()),
            ("serial", serial.to_json()),
            ("depths", Value::Arr(depth_rows)),
        ]));
    }

    let section = obj(vec![
        ("vocab", spec.vocab.into()),
        ("agreement", Value::Num(spec.agreement as f64)),
        (
            "model_delay_us",
            (spec.model_delay.as_micros() as i64).into(),
        ),
        (
            "window_depths",
            Value::Arr(DEPTHS.iter().map(|d| (*d).into()).collect()),
        ),
        ("headline_depth", HEADLINE_K.into()),
        ("rows", Value::Arr(rows)),
    ]);
    (section, speedups)
}

/// The PR 6 gate: the same pipelined sim decode with a live trace
/// recorder attached vs the default [`NullSink`]. Recording must stay
/// near-zero-cost (< 2% wall-clock) — every hook site guards on
/// `enabled()` before building an event, so the off path is one branch
/// and the on path is digests + an in-memory push per step.
fn trace_overhead_section(cfg: BenchConfig) -> (Value, Vec<(usize, f64)>) {
    let spec = SimSpec {
        vocab: 4096,
        seq_len: 512,
        gmax: 10,
        batches: vec![1, 2, 4],
        seed: 0xC0FF_EE11,
        agreement: 0.99,
        // the deployment-like regime the pipeline section measures:
        // model dispatch dominates, as it does against real hardware
        model_delay: Duration::from_micros(200),
    };
    println!(
        "trace record-path overhead (pipelined sim decode, recorder on vs off, \
         V={} delay={}us)\n",
        spec.vocab,
        spec.model_delay.as_micros()
    );
    let reqs = |b: usize| -> Vec<GenRequest> {
        (0..2 * b as u64)
            .map(|i| {
                GenRequest::new(
                    i,
                    vec![1, 7 + i as i32, 9, 23, 41, 5],
                    SamplingParams::default()
                        .with_max_new_tokens(48)
                        .with_temperature(0.8)
                        .with_seed(1000 + i),
                )
            })
            .collect()
    };
    let engine = |b: usize| -> Engine {
        let rt = Arc::new(Runtime::simulated(spec.clone()));
        Engine::new(
            rt,
            EngineConfig {
                pair: "sim".into(),
                batch: b,
                method: Method::Exact,
                backend: Backend::Native,
                mode: Mode::Speculative,
                gamma_init: 3,
                gamma_pinned: true,
                self_draft: false,
                pipeline: PipelineMode::On,
                pipeline_depth: 2,
                pipeline_salvage: true,
                seed: 7,
            },
        )
        .expect("sim engine")
    };

    let mut rows: Vec<Value> = Vec::new();
    let mut overheads: Vec<(usize, f64)> = Vec::new();
    for b in [1usize, 2, 4] {
        let mut e_off = engine(b);
        let off = bench(&format!("decode/trace-off-b{b}"), cfg, || {
            // re-attach the null sink each iteration so both closures
            // pay the same per-run setup
            e_off.set_trace(Arc::new(NullSink));
            let out = e_off.generate(reqs(b)).unwrap();
            black_box(out);
        });
        println!("{}", off.row());

        let mut e_on = engine(b);
        let mut events = 0usize;
        let on = bench(&format!("decode/trace-on-b{b}"), cfg, || {
            let rec = Arc::new(TraceRecorder::buffered(e_on.trace_header()));
            e_on.set_trace(rec.clone());
            let out = e_on.generate(reqs(b)).unwrap();
            black_box(out);
            events = rec.event_count();
        });
        println!("{}", on.row());

        let overhead_pct = (on.mean_secs() / off.mean_secs() - 1.0) * 100.0;
        println!("  B={b}: {events} events/run, record-path overhead {overhead_pct:+.2}%\n");
        rows.push(obj(vec![
            ("batch", b.into()),
            ("events_per_run", events.into()),
            ("trace_off", off.to_json()),
            ("trace_on", on.to_json()),
            ("overhead_pct", Value::Num(overhead_pct)),
        ]));
        overheads.push((b, overhead_pct));
    }
    let section = obj(vec![
        ("vocab", spec.vocab.into()),
        (
            "model_delay_us",
            (spec.model_delay.as_micros() as i64).into(),
        ),
        ("rows", Value::Arr(rows)),
    ]);
    (section, overheads)
}

fn run_decode(
    rt: &Arc<Runtime>,
    tok: &Tokenizer,
    method: Method,
    mode: Mode,
) -> (f64, usize, f64) {
    let mut engine = Engine::new(
        rt.clone(),
        EngineConfig {
            method,
            backend: Backend::Hlo,
            mode,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let reqs: Vec<GenRequest> = (0..6)
        .map(|i| {
            GenRequest::new(
                i,
                tok.encode("The scheduler accepts the drafted tokens"),
                SamplingParams::default()
                    .with_max_new_tokens(40)
                    .with_temperature(0.7)
                    .with_seed(500 + i),
            )
        })
        .collect();
    let t = Instant::now();
    let results = engine.generate(reqs).unwrap();
    let wall = t.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.token_ids.len()).sum();
    (wall, tokens, engine.stats.profiling_time_total())
}

/// End-to-end decode over the AOT artifacts. Returns the JSON section,
/// or `None` (with a notice) when artifacts are unavailable.
fn e2e_section() -> Option<(Value, Value)> {
    let rt = match Runtime::open_default() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("skipping end-to-end decode: artifacts unavailable ({e:#})");
            return None;
        }
    };
    let tok = match Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json")) {
        Ok(tok) => tok,
        Err(e) => {
            println!("skipping end-to-end decode: tokenizer unavailable ({e:#})");
            return None;
        }
    };

    println!("end-to-end decode: 6 requests × 40 tokens (measured, PJRT-CPU)\n");
    let (wall_ar, tok_ar, _) = run_decode(&rt, &tok, Method::Exact, Mode::Autoregressive);
    let (wall_b, tok_b, prof_b) = run_decode(&rt, &tok, Method::Baseline, Mode::Speculative);
    let (wall_e, tok_e, prof_e) = run_decode(&rt, &tok, Method::Exact, Mode::Speculative);
    let (wall_s, tok_s, prof_s) =
        run_decode(&rt, &tok, Method::sigmoid(-1e3, 1e3), Mode::Speculative);

    let mut rows: Vec<Value> = Vec::new();
    let mut row = |name: &str, wall: f64, tokens: usize, prof: f64| {
        let tps = tokens as f64 / wall;
        println!(
            "{name:<26} wall {wall:>7.3}s  {tps:>7.1} tok/s  Σprofiling {:>8.2}ms",
            prof * 1e3
        );
        rows.push(obj(vec![
            ("name", name.into()),
            ("wall_s", Value::Num(wall)),
            ("tokens", tokens.into()),
            ("tokens_per_sec", Value::Num(tps)),
            ("profiling_ms", Value::Num(prof * 1e3)),
        ]));
    };
    row("autoregressive", wall_ar, tok_ar, 0.0);
    row("speculative baseline", wall_b, tok_b, prof_b);
    row("speculative exact", wall_e, tok_e, prof_e);
    row("speculative sigmoid", wall_s, tok_s, prof_s);
    println!(
        "\nΔ% wall-clock vs baseline: exact {:+.1}%, sigmoid {:+.1}%",
        rel_improvement_pct(wall_b, wall_e),
        rel_improvement_pct(wall_b, wall_s)
    );
    println!(
        "Δ% profiling  vs baseline: exact {:+.1}%, sigmoid {:+.1}%",
        rel_improvement_pct(prof_b, prof_e),
        rel_improvement_pct(prof_b, prof_s)
    );
    println!(
        "speculative speedup over autoregressive (exact): {:.2}x",
        (tok_e as f64 / wall_e) / (tok_ar as f64 / wall_ar)
    );

    // per-scope profiler totals (the Δ%-profiling raw material)
    let scopes: Vec<Value> = rt
        .profiler
        .report()
        .into_iter()
        .map(|(name, stat)| {
            let avg_us = if stat.calls > 0 {
                stat.total.as_secs_f64() * 1e6 / stat.calls as f64
            } else {
                0.0
            };
            obj(vec![
                ("scope", name.as_str().into()),
                ("calls", (stat.calls as i64).into()),
                ("total_ms", Value::Num(stat.total.as_secs_f64() * 1e3)),
                ("avg_us", Value::Num(avg_us)),
            ])
        })
        .collect();
    Some((Value::Arr(rows), Value::Arr(scopes)))
}

fn main() {
    let opts = BenchOpts::from_args();
    let cfg = opts.config();

    let (verify_json, speedup) = verify_path_section(cfg);
    let (pipeline_json, pipeline_speedups) = pipeline_section(cfg);
    let (trace_json, trace_overheads) = trace_overhead_section(cfg);
    for (b, pct) in &trace_overheads {
        assert!(
            *pct < 2.0,
            "trace record-path overhead {pct:.2}% at B={b} exceeds the 2% budget"
        );
    }
    let e2e = e2e_section();

    if let Some(path) = &opts.json {
        let (e2e_json, scopes_json) = match e2e {
            Some((rows, scopes)) => (rows, scopes),
            None => (Value::Null, Value::Null),
        };
        let pipeline_speedup_json = Value::Arr(
            pipeline_speedups
                .iter()
                .map(|(b, s)| obj(vec![("batch", (*b).into()), ("speedup", Value::Num(*s))]))
                .collect(),
        );
        let report = snapshot_envelope(
            "bench_e2e",
            opts.smoke,
            vec![
                ("verify_path", verify_json),
                ("verify_speedup", Value::Num(speedup)),
                ("pipeline", pipeline_json),
                ("pipeline_speedups", pipeline_speedup_json),
                ("trace_overhead", trace_json),
                ("e2e", e2e_json),
                ("scopes", scopes_json),
            ],
        );
        write_json(path, &report).expect("writing bench json");
        println!("wrote {}", path.display());
    }
}
