//! End-to-end engine benchmark (Table 5's wall-clock quantity): decode a
//! fixed workload with each method and report wall time, throughput and
//! the Δ% improvements.
//!
//! `cargo bench --bench bench_e2e`

use std::sync::Arc;
use std::time::Instant;

use specd::engine::{Backend, Engine, EngineConfig, GenRequest, Mode, SamplingParams};
use specd::runtime::Runtime;
use specd::sampling::Method;
use specd::tokenizer::Tokenizer;
use specd::util::stats::rel_improvement_pct;

fn run(rt: &Arc<Runtime>, tok: &Tokenizer, method: Method, mode: Mode) -> (f64, usize, f64) {
    let mut engine = Engine::new(
        rt.clone(),
        EngineConfig {
            method,
            backend: Backend::Hlo,
            mode,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let reqs: Vec<GenRequest> = (0..6)
        .map(|i| {
            GenRequest::new(
                i,
                tok.encode("The scheduler accepts the drafted tokens"),
                SamplingParams::default()
                    .with_max_new_tokens(40)
                    .with_temperature(0.7)
                    .with_seed(500 + i),
            )
        })
        .collect();
    let t = Instant::now();
    let results = engine.generate(reqs).unwrap();
    let wall = t.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.token_ids.len()).sum();
    (wall, tokens, engine.stats.profiling_time_total())
}

fn main() {
    let rt = Arc::new(Runtime::open_default().expect("run `make artifacts` first"));
    let tok = Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json")).unwrap();

    println!("end-to-end decode: 6 requests × 40 tokens (measured, PJRT-CPU)\n");
    let (wall_ar, tok_ar, _) = run(&rt, &tok, Method::Exact, Mode::Autoregressive);
    let (wall_b, tok_b, prof_b) = run(&rt, &tok, Method::Baseline, Mode::Speculative);
    let (wall_e, tok_e, prof_e) = run(&rt, &tok, Method::Exact, Mode::Speculative);
    let (wall_s, tok_s, prof_s) =
        run(&rt, &tok, Method::sigmoid(-1e3, 1e3), Mode::Speculative);

    let row = |name: &str, wall: f64, tokens: usize, prof: f64| {
        println!(
            "{name:<26} wall {wall:>7.3}s  {:>7.1} tok/s  Σprofiling {:>8.2}ms",
            tokens as f64 / wall,
            prof * 1e3
        );
    };
    row("autoregressive", wall_ar, tok_ar, 0.0);
    row("speculative baseline", wall_b, tok_b, prof_b);
    row("speculative exact", wall_e, tok_e, prof_e);
    row("speculative sigmoid", wall_s, tok_s, prof_s);
    println!(
        "\nΔ% wall-clock vs baseline: exact {:+.1}%, sigmoid {:+.1}%",
        rel_improvement_pct(wall_b, wall_e),
        rel_improvement_pct(wall_b, wall_s)
    );
    println!(
        "Δ% profiling  vs baseline: exact {:+.1}%, sigmoid {:+.1}%",
        rel_improvement_pct(prof_b, prof_e),
        rel_improvement_pct(prof_b, prof_s)
    );
    println!(
        "speculative speedup over autoregressive (exact): {:.2}x",
        (tok_e as f64 / wall_e) / (tok_ar as f64 / wall_ar)
    );
}
