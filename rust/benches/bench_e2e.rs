//! End-to-end engine benchmark (Table 5's wall-clock quantity) plus the
//! verify-path kernel comparison: scalar oracle vs the segment-parallel
//! kernel layer at batch ≥ 4.
//!
//! ```text
//! cargo bench --bench bench_e2e -- [--json <path>] [--smoke]
//! ```
//!
//! `--json <path>` writes a machine-readable report (per-target
//! mean/p50/p95, per-scope profiler totals, tokens/sec and the
//! verify-path speedup), stamped with `{"schema": 1, "git_rev": …}` so
//! the trajectory tooling described in `docs/PERF.md` can trust the
//! format. Per-PR snapshots are committed as `BENCH_PR<N>.json`
//! (currently `BENCH_PR3.json` → `BENCH_PR4.json`); CI's smoke step
//! writes a throwaway `BENCH_CI.json`. `--smoke` runs single-iteration
//! timings (CI smoke step).
//!
//! The verify-path section needs no artifacts; the decode section skips
//! itself with a notice when the AOT artifacts are unavailable.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use specd::engine::{Backend, Engine, EngineConfig, GenRequest, Mode, SamplingParams};
use specd::runtime::Runtime;
use specd::sampling::kernels::{spec_step_batch_ws, KernelConfig, VerifyWorkspace};
use specd::sampling::{verify, Method};
use specd::tokenizer::Tokenizer;
use specd::util::bench::{bench, black_box, write_json, BenchConfig, BenchResult};
use specd::util::json::{obj, Value};
use specd::util::rng::Pcg32;
use specd::util::stats::rel_improvement_pct;

struct Opts {
    json: Option<PathBuf>,
    smoke: bool,
}

fn parse_opts() -> Opts {
    let mut opts = Opts {
        json: None,
        smoke: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => {
                let path = args.next().expect("--json needs a path");
                opts.json = Some(PathBuf::from(path));
            }
            "--smoke" => opts.smoke = true,
            // cargo bench passes --bench through to the target
            "--bench" => {}
            other => eprintln!("ignoring unknown arg {other:?}"),
        }
    }
    opts
}

fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

/// Short git revision of the working tree, for the JSON stamp
/// (trajectory tooling correlates snapshots with commits). A dirty
/// tree measures code no commit contains, so it is marked with a
/// `-dirty` suffix rather than silently attributed to HEAD.
fn git_rev() -> String {
    let git = |args: &[&str]| {
        std::process::Command::new("git")
            .args(args)
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
    };
    let Some(rev) = git(&["rev-parse", "--short", "HEAD"]) else {
        return "unknown".to_string();
    };
    let dirty = git(&["status", "--porcelain"]).is_some_and(|s| !s.trim().is_empty());
    if dirty {
        format!("{}-dirty", rev.trim())
    } else {
        rev.trim().to_string()
    }
}

/// Scalar oracle vs parallel kernels on the native verify path at paper
/// scale (B=4, γ=5, V=4096). Returns the JSON section and the speedup of
/// the widest parallel config over scalar. Each workspace's persistent
/// worker pool spawns during the warmup iterations, outside the timed
/// samples — the timed iterations measure the steady-state dispatch
/// cost the engine sees, not thread spawns.
fn verify_path_section(cfg: BenchConfig) -> (Value, f64) {
    let (b, gamma, v) = (4usize, 5usize, 4096usize);
    let mut rng = Pcg32::seeded(42);
    let z_p = randn(&mut rng, b * (gamma + 1) * v, 3.0);
    let z_q = randn(&mut rng, b * gamma * v, 3.0);
    let draft: Vec<i32> = (0..b * gamma).map(|_| rng.below(v as u32) as i32).collect();
    let u_acc: Vec<f32> = (0..b * gamma).map(|_| rng.uniform_f32()).collect();
    let u_res: Vec<f32> = (0..b).map(|_| rng.uniform_f32()).collect();
    let u_bonus: Vec<f32> = (0..b).map(|_| rng.uniform_f32()).collect();
    let methods = vec![Method::Exact; b];

    println!("native verify path, B={b} γ={gamma} V={v} (scalar oracle vs kernels)\n");
    let scalar = bench("verify/scalar-oracle", cfg, || {
        let out = verify::spec_step_batch(
            &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus, &methods, None,
        );
        black_box(out);
    });
    println!("{}", scalar.row());

    let expect = verify::spec_step_batch(
        &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus, &methods, None,
    );

    let max_threads = KernelConfig::default().threads.max(2);
    let mut thread_counts = vec![1usize, 2];
    if max_threads > 2 {
        thread_counts.push(max_threads);
    }
    let mut rows: Vec<(usize, BenchResult)> = Vec::new();
    for threads in thread_counts {
        let mut kcfg = KernelConfig::with_threads(threads);
        kcfg.min_parallel_elems = 0;
        let mut ws = VerifyWorkspace::with_capacity(kcfg, b, gamma, v);
        let mut accept = Vec::new();
        let mut tokens = Vec::new();
        let r = bench(&format!("verify/kernels-t{threads}"), cfg, || {
            spec_step_batch_ws(
                &mut ws, &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus,
                &methods, &mut accept, &mut tokens, None,
            );
            black_box((&accept, &tokens));
        });
        assert_eq!(
            (accept.clone(), tokens.clone()),
            expect,
            "kernels must stay bit-identical to the scalar oracle"
        );
        println!("{}", r.row());
        rows.push((threads, r));
    }

    // the headline metric counts genuinely parallel configs only — the
    // t1 row measures the zero-alloc workspace rewrite, not parallelism
    let best = rows
        .iter()
        .filter(|(t, _)| *t >= 2)
        .map(|(_, r)| r.mean_secs())
        .fold(f64::INFINITY, f64::min);
    let speedup = scalar.mean_secs() / best;
    println!("\nverify-path speedup (best >=2-thread config vs scalar): {speedup:.2}x\n");

    let section = obj(vec![
        ("batch", b.into()),
        ("gamma", gamma.into()),
        ("vocab", v.into()),
        ("scalar", scalar.to_json()),
        (
            "parallel",
            Value::Arr(
                rows.iter()
                    .map(|(t, r)| {
                        obj(vec![("threads", (*t).into()), ("timing", r.to_json())])
                    })
                    .collect(),
            ),
        ),
        ("speedup", Value::Num(speedup)),
    ]);
    (section, speedup)
}

fn run_decode(
    rt: &Arc<Runtime>,
    tok: &Tokenizer,
    method: Method,
    mode: Mode,
) -> (f64, usize, f64) {
    let mut engine = Engine::new(
        rt.clone(),
        EngineConfig {
            method,
            backend: Backend::Hlo,
            mode,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let reqs: Vec<GenRequest> = (0..6)
        .map(|i| {
            GenRequest::new(
                i,
                tok.encode("The scheduler accepts the drafted tokens"),
                SamplingParams::default()
                    .with_max_new_tokens(40)
                    .with_temperature(0.7)
                    .with_seed(500 + i),
            )
        })
        .collect();
    let t = Instant::now();
    let results = engine.generate(reqs).unwrap();
    let wall = t.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.token_ids.len()).sum();
    (wall, tokens, engine.stats.profiling_time_total())
}

/// End-to-end decode over the AOT artifacts. Returns the JSON section,
/// or `None` (with a notice) when artifacts are unavailable.
fn e2e_section() -> Option<(Value, Value)> {
    let rt = match Runtime::open_default() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("skipping end-to-end decode: artifacts unavailable ({e:#})");
            return None;
        }
    };
    let tok = match Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json")) {
        Ok(tok) => tok,
        Err(e) => {
            println!("skipping end-to-end decode: tokenizer unavailable ({e:#})");
            return None;
        }
    };

    println!("end-to-end decode: 6 requests × 40 tokens (measured, PJRT-CPU)\n");
    let (wall_ar, tok_ar, _) = run_decode(&rt, &tok, Method::Exact, Mode::Autoregressive);
    let (wall_b, tok_b, prof_b) = run_decode(&rt, &tok, Method::Baseline, Mode::Speculative);
    let (wall_e, tok_e, prof_e) = run_decode(&rt, &tok, Method::Exact, Mode::Speculative);
    let (wall_s, tok_s, prof_s) =
        run_decode(&rt, &tok, Method::sigmoid(-1e3, 1e3), Mode::Speculative);

    let mut rows: Vec<Value> = Vec::new();
    let mut row = |name: &str, wall: f64, tokens: usize, prof: f64| {
        let tps = tokens as f64 / wall;
        println!(
            "{name:<26} wall {wall:>7.3}s  {tps:>7.1} tok/s  Σprofiling {:>8.2}ms",
            prof * 1e3
        );
        rows.push(obj(vec![
            ("name", name.into()),
            ("wall_s", Value::Num(wall)),
            ("tokens", tokens.into()),
            ("tokens_per_sec", Value::Num(tps)),
            ("profiling_ms", Value::Num(prof * 1e3)),
        ]));
    };
    row("autoregressive", wall_ar, tok_ar, 0.0);
    row("speculative baseline", wall_b, tok_b, prof_b);
    row("speculative exact", wall_e, tok_e, prof_e);
    row("speculative sigmoid", wall_s, tok_s, prof_s);
    println!(
        "\nΔ% wall-clock vs baseline: exact {:+.1}%, sigmoid {:+.1}%",
        rel_improvement_pct(wall_b, wall_e),
        rel_improvement_pct(wall_b, wall_s)
    );
    println!(
        "Δ% profiling  vs baseline: exact {:+.1}%, sigmoid {:+.1}%",
        rel_improvement_pct(prof_b, prof_e),
        rel_improvement_pct(prof_b, prof_s)
    );
    println!(
        "speculative speedup over autoregressive (exact): {:.2}x",
        (tok_e as f64 / wall_e) / (tok_ar as f64 / wall_ar)
    );

    // per-scope profiler totals (the Δ%-profiling raw material)
    let scopes: Vec<Value> = rt
        .profiler
        .report()
        .into_iter()
        .map(|(name, stat)| {
            let avg_us = if stat.calls > 0 {
                stat.total.as_secs_f64() * 1e6 / stat.calls as f64
            } else {
                0.0
            };
            obj(vec![
                ("scope", name.as_str().into()),
                ("calls", (stat.calls as i64).into()),
                ("total_ms", Value::Num(stat.total.as_secs_f64() * 1e3)),
                ("avg_us", Value::Num(avg_us)),
            ])
        })
        .collect();
    Some((Value::Arr(rows), Value::Arr(scopes)))
}

fn main() {
    let opts = parse_opts();
    let cfg = if opts.smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 1,
            max_iters: 1,
            max_time: Duration::from_millis(500),
        }
    } else {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 15,
            max_iters: 300,
            max_time: Duration::from_secs(2),
        }
    };

    let (verify_json, speedup) = verify_path_section(cfg);
    let e2e = e2e_section();

    if let Some(path) = opts.json {
        let (e2e_json, scopes_json) = match e2e {
            Some((rows, scopes)) => (rows, scopes),
            None => (Value::Null, Value::Null),
        };
        let report = obj(vec![
            // schema version first: bump it whenever a key changes
            // meaning, so trajectory tooling can refuse formats it does
            // not understand instead of misreading them
            ("schema", 1i64.into()),
            ("git_rev", git_rev().into()),
            ("bench", "bench_e2e".into()),
            ("smoke", opts.smoke.into()),
            ("verify_path", verify_json),
            ("verify_speedup", Value::Num(speedup)),
            ("e2e", e2e_json),
            ("scopes", scopes_json),
        ]);
        write_json(&path, &report).expect("writing bench json");
        println!("wrote {}", path.display());
    }
}
