//! Roofline harness: measured realized bandwidth on the real verify
//! path at production vocab scale, reported against the Table 3 cost
//! model, plus the original analytic sweep over the paper's model
//! combinations on both device profiles.
//!
//! ```text
//! cargo bench --bench bench_bandwidth -- [--json <path>] [--smoke]
//! ```
//!
//! The measured section drives `spec_step_batch_ws` (the serving-path
//! kernels, SIMD off and on) over V ∈ {4k, 32k, 128k} × B ∈ {1, 4} ×
//! method, converts mean wall-clock into realized GB/s with the traffic
//! model below, and sets the cost model's realized bandwidth for the
//! same shape next to it. The fp16-ingestion rows compare fused
//! widen+construct against the f32 construction for one score matrix.
//! `--smoke` restricts to V=32k, B=1 at single-iteration counts so CI
//! can snapshot the schema cheaply; `--json <path>` writes the same
//! `{"schema": 1, …}` envelope as the other benches (see
//! `docs/PERF.md`, "Roofline methodology").

use specd::sampling::kernels::{self, KernelConfig, Logits, VerifyWorkspace};
use specd::sampling::{f32_to_f16_bits, Method, SimdMode};
use specd::simulator::{simulate_step, DeviceProfile, SimConfig};
use specd::util::bench::{bench_report, black_box, snapshot_envelope, write_json, BenchOpts};
use specd::util::json::{obj, Value};
use specd::util::rng::Pcg32;

fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

/// Bytes the verify step actually touches, per the traffic model in
/// docs/PERF.md: read both logit matrices, write both prob matrices,
/// then build + re-scan one residual row per slot.
fn step_bytes(b: usize, g: usize, v: usize) -> f64 {
    let elems = 2 * b * (g + 1) * v  // z_p read + p write
        + 2 * b * g * v              // z_q read + q write
        + 4 * b * v; // residual: read p and q rows, write it, re-read for the CDF scan
    (elems * 4) as f64
}

fn cost_model_tables() {
    use specd::util::bench::Table;
    for dev_name in ["a100", "2080ti"] {
        let dev = DeviceProfile::by_name(dev_name).unwrap();
        println!("== cost model: {} (peak {:.0} GB/s) ==\n", dev.name, dev.peak_bw / 1e9);
        let mut table = Table::new(&[
            "combo",
            "method",
            "step ms",
            "busy ms",
            "bytes MB",
            "realized GB/s",
            "launches",
        ]);
        for (label, v, dt) in [
            ("whisper-small (52k fp16)", 51_865usize, 2usize),
            ("llama2 (32k fp32)", 32_000, 4),
            ("qwen (152k fp32)", 151_936, 4),
            ("gemma (256k fp32)", 256_000, 4),
        ] {
            for (mname, method) in [
                ("baseline", Method::Baseline),
                ("exact", Method::Exact),
                ("sigmoid", Method::sigmoid(-1e4, 1e4)),
            ] {
                let cost = simulate_step(
                    dev,
                    SimConfig { batch: 1, gamma: 5, vocab: v, dtype_bytes: dt },
                    method,
                );
                table.row(vec![
                    label.into(),
                    mname.into(),
                    format!("{:.3}", cost.step_time * 1e3),
                    format!("{:.3}", cost.busy_time * 1e3),
                    format!("{:.2}", cost.bytes_hbm / 1e6),
                    format!("{:.2}", cost.realized_bandwidth() / 1e9),
                    format!("{}", cost.launches),
                ]);
            }
        }
        println!("{}", table.render());
    }
    println!(
        "shape checks: sigmoid realized bandwidth highest per combo; all \
         values far below peak (paper: memory transfer is not the limit).\n"
    );
}

fn main() {
    let opts = BenchOpts::from_args();
    let cfg = opts.config();
    let g = 5usize;

    if !opts.smoke {
        cost_model_tables();
    }

    let vocabs: Vec<usize> =
        if opts.smoke { vec![32_768] } else { vec![4_096, 32_768, 131_072] };
    let batches: Vec<usize> = if opts.smoke { vec![1] } else { vec![1, 4] };
    let dev = DeviceProfile::by_name("a100").unwrap();

    println!("== measured roofline: native verify path, γ={g} (model = a100 cost model) ==\n");
    let mut table = specd::util::bench::Table::new(&[
        "vocab",
        "B",
        "method",
        "simd",
        "mean µs",
        "bytes MB",
        "GB/s",
        "model GB/s",
    ]);
    let mut rows: Vec<Value> = Vec::new();
    let mut rng = Pcg32::seeded(11);

    for &v in &vocabs {
        for &b in &batches {
            let z_p = randn(&mut rng, b * (g + 1) * v, 3.0);
            let z_q = randn(&mut rng, b * g * v, 3.0);
            let draft: Vec<i32> = (0..b * g).map(|i| ((i * 97) % v) as i32).collect();
            let u_acc = vec![0.5f32; b * g];
            let u_res = vec![0.4f32; b];
            let u_bonus = vec![0.6f32; b];
            for (mname, method) in
                [("exact", Method::Exact), ("sigmoid", Method::sigmoid(-1e3, 1e3))]
            {
                let methods = vec![method; b];
                let model_gbs = simulate_step(
                    dev,
                    SimConfig { batch: b, gamma: g, vocab: v, dtype_bytes: 4 },
                    method,
                )
                .realized_bandwidth()
                    / 1e9;
                // both lane paths; SimdMode::On degrades to the scalar
                // lane loops off-AVX2 hosts (the label records reality)
                for mode in [SimdMode::Off, SimdMode::On] {
                    let simd_label = if mode.active() { "on" } else { "off" };
                    let kcfg = KernelConfig {
                        min_parallel_elems: 0,
                        simd: mode,
                        ..KernelConfig::default()
                    };
                    let mut ws = VerifyWorkspace::with_capacity(kcfg, b, g, v);
                    let mut accept = Vec::new();
                    let mut tokens = Vec::new();
                    let r = bench_report(
                        &format!("verify/{mname}/v{v}/b{b}/simd-{simd_label}"),
                        cfg,
                        || {
                            kernels::spec_step_batch_ws(
                                &mut ws, &z_p, &z_q, b, g, v, &draft, &u_acc, &u_res,
                                &u_bonus, &methods, &mut accept, &mut tokens, None,
                            );
                            black_box((&accept, &tokens));
                        },
                    );
                    let bytes = step_bytes(b, g, v);
                    let gbs = bytes / r.mean_secs() / 1e9;
                    table.row(vec![
                        format!("{v}"),
                        format!("{b}"),
                        mname.into(),
                        simd_label.into(),
                        format!("{:.1}", r.mean_secs() * 1e6),
                        format!("{:.2}", bytes / 1e6),
                        format!("{gbs:.2}"),
                        format!("{model_gbs:.2}"),
                    ]);
                    rows.push(obj(vec![
                        ("vocab", v.into()),
                        ("batch", b.into()),
                        ("method", mname.into()),
                        ("simd", simd_label.into()),
                        ("bytes_mb", (bytes / 1e6).into()),
                        ("gbs", gbs.into()),
                        ("model_gbs", model_gbs.into()),
                        ("timing", r.to_json()),
                    ]));
                }
            }
        }

        // fp16 logit ingestion: fused widen+construct vs f32 construct
        // over one (γ+1)-row score matrix (B=1, softmax)
        let nrows = g + 1;
        let logits32 = randn(&mut rng, nrows * v, 3.0);
        let logits16: Vec<u16> = logits32.iter().map(|&x| f32_to_f16_bits(x)).collect();
        let mut dst = vec![0f32; v];
        for (dtype, src_bytes) in [("f32", 4usize), ("f16", 2usize)] {
            let r = bench_report(&format!("ingest/{dtype}/v{v}"), cfg, || {
                for row in 0..nrows {
                    let off = row * v;
                    let src = if dtype == "f16" {
                        Logits::F16(&logits16[off..off + v])
                    } else {
                        Logits::F32(&logits32[off..off + v])
                    };
                    kernels::construct_prob_row_logits(src, &mut dst, Method::Exact);
                    black_box(&dst);
                }
            });
            let bytes = (nrows * v * (src_bytes + 4)) as f64;
            let gbs = bytes / r.mean_secs() / 1e9;
            table.row(vec![
                format!("{v}"),
                "1".into(),
                format!("ingest-{dtype}"),
                "n/a".into(),
                format!("{:.1}", r.mean_secs() * 1e6),
                format!("{:.2}", bytes / 1e6),
                format!("{gbs:.2}"),
                "-".into(),
            ]);
            rows.push(obj(vec![
                ("vocab", v.into()),
                ("batch", 1usize.into()),
                ("method", format!("ingest-{dtype}").into()),
                ("simd", "n/a".into()),
                ("bytes_mb", (bytes / 1e6).into()),
                ("gbs", gbs.into()),
                ("timing", r.to_json()),
            ]));
        }
    }
    println!("{}", table.render());

    if let Some(path) = &opts.json {
        let report = snapshot_envelope(
            "bench_bandwidth",
            opts.smoke,
            vec![
                ("gamma", g.into()),
                ("device_model", "a100".into()),
                ("rows", Value::Arr(rows)),
            ],
        );
        write_json(path, &report).expect("writing bench json");
        println!("wrote {}", path.display());
    }
}
