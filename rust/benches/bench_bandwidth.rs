//! Table 3 driver: realized-bandwidth cost-model sweep over the paper's
//! model combinations on both device profiles, plus the kernel-launch /
//! bytes breakdown per method.
//!
//! `cargo bench --bench bench_bandwidth`

use specd::sampling::Method;
use specd::simulator::{simulate_step, DeviceProfile, SimConfig};
use specd::util::bench::Table;

fn main() {
    for dev_name in ["a100", "2080ti"] {
        let dev = DeviceProfile::by_name(dev_name).unwrap();
        println!("== device: {} (peak {:.0} GB/s) ==\n", dev.name, dev.peak_bw / 1e9);
        let mut table = Table::new(&[
            "combo",
            "method",
            "step ms",
            "busy ms",
            "bytes MB",
            "realized GB/s",
            "launches",
        ]);
        for (label, v, dt) in [
            ("whisper-small (52k fp16)", 51_865usize, 2usize),
            ("llama2 (32k fp32)", 32_000, 4),
            ("qwen (152k fp32)", 151_936, 4),
            ("gemma (256k fp32)", 256_000, 4),
        ] {
            for (mname, method) in [
                ("baseline", Method::Baseline),
                ("exact", Method::Exact),
                ("sigmoid", Method::sigmoid(-1e4, 1e4)),
            ] {
                let cost = simulate_step(
                    dev,
                    SimConfig { batch: 1, gamma: 5, vocab: v, dtype_bytes: dt },
                    method,
                );
                table.row(vec![
                    label.into(),
                    mname.into(),
                    format!("{:.3}", cost.step_time * 1e3),
                    format!("{:.3}", cost.busy_time * 1e3),
                    format!("{:.2}", cost.bytes_hbm / 1e6),
                    format!("{:.2}", cost.realized_bandwidth() / 1e9),
                    format!("{}", cost.launches),
                ]);
            }
        }
        println!("{}", table.render());
    }
    println!(
        "shape checks: sigmoid realized bandwidth highest per combo; all \
         values far below peak (paper: memory transfer is not the limit)."
    );
}
