//! Typed stub of the PJRT/XLA binding surface `specd` uses.
//!
//! The workspace builds hermetically against this crate: every type and
//! signature matches the real `xla` bindings, host-side [`Literal`]
//! handling is functional, but creating a [`PjRtClient`] reports that no
//! native XLA runtime is linked. Integration tests detect that cleanly
//! and skip; swap this path dependency for the real `xla` crate (plus
//! its native library) to execute the AOT artifacts.

use std::fmt;
use std::path::Path;

/// Stub error type (mirrors the binding crate's error).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT runtime unavailable: specd was built against the vendored \
     xla stub crate (rust/vendor/xla-stub); link the real xla bindings to execute artifacts";

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element dtypes of the artifact tensors (subset + placeholders so
/// downstream matches stay non-trivial).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host-side values a native type can be read back as.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_ne_chunk(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_ne_chunk(bytes: [u8; 4]) -> Self {
        f32::from_ne_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_ne_chunk(bytes: [u8; 4]) -> Self {
        i32::from_ne_bytes(bytes)
    }
}

/// Array shape: dtype + dimensions.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Dense host literal (functional in the stub: create / shape / read).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * 4 {
            return Err(Error(format!(
                "literal data is {} bytes but shape {dims:?} needs {}",
                data.len(),
                elems * 4
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            ty: self.ty,
            dims: self.dims.clone(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_ne_chunk([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Raw native-endian element bytes, row-major — the zero-copy read
    /// side of [`Literal::create_from_shape_and_untyped_data`]. Callers
    /// that reuse output buffers across steps (the engine's staging
    /// workspaces) copy straight from this instead of allocating via
    /// [`Literal::to_vec`]. The real bindings expose the same through
    /// the literal's untyped-data accessor.
    pub fn untyped_data(&self) -> &[u8] {
        &self.bytes
    }

    /// Destructure a tuple literal (stub literals are never tuples).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        std::fs::read_to_string(path.as_ref())
            .map(|_| HloModuleProto)
            .map_err(|e| Error(format!("reading {}: {e}", path.as_ref().display())))
    }
}

/// Computation wrapper (opaque in the stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Loaded executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client. [`PjRtClient::cpu`] fails in the stub — the one place
/// callers learn the native runtime is absent.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let data: Vec<f32> = vec![1.0, -2.5, 3.5, 0.0, 7.25, -0.5];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes)
                .unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert_eq!(lit.untyped_data(), &bytes[..]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[3], &[0; 8])
                .is_err()
        );
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
