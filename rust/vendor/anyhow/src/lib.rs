//! Vendored minimal `anyhow` — an offline, API-compatible stand-in for
//! the subset this workspace uses: [`Error`], [`Result`], the
//! [`Context`] trait on `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Error state is a flattened context chain of
//! strings; `{e}` prints the outermost message, `{e:#}` the full chain
//! joined by `": "` — matching the real crate's formatting contract.

use std::error::Error as StdError;
use std::fmt;

/// A flattened error: context chain from outermost to innermost.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    fn wrap(mut self, context: String) -> Self {
        self.chain.insert(0, context);
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// The real anyhow's blanket conversion; sound because `Error` itself
// deliberately does not implement `std::error::Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and missing `Option` values).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("opening manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: missing file");
        let e = Err::<(), Error>(e).with_context(|| "loading runtime").unwrap_err();
        assert_eq!(
            format!("{e:#}"),
            "loading runtime: opening manifest: missing file"
        );
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("id must be an integer").unwrap_err();
        assert_eq!(format!("{e}"), "id must be an integer");
        assert_eq!(Some(5).context("never").unwrap(), 5);
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let n = 3;
        let e = anyhow!("got {n} items");
        assert_eq!(format!("{e}"), "got 3 items");
        let e = anyhow!("{} of {}", 1, 2);
        assert_eq!(format!("{e}"), "1 of 2");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");

        fn fails(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(fails(5).unwrap(), 5);
        assert_eq!(format!("{}", fails(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", fails(200).unwrap_err()), "too big");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
    }
}
