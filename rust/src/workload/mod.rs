//! Evaluation workloads standing in for the paper's datasets (DESIGN.md
//! §3): an ASR-role transcription task scored with WER and a
//! summarization-role continuation task scored with ROUGE-1, both drawn
//! deterministically from the build corpus.

pub mod corpus;
pub mod task;

pub use corpus::Corpus;
pub use task::{make_tasks, Task, TaskKind};
