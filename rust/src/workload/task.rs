//! Task generation + scoring.
//!
//! * [`TaskKind::Asr`] — "transcription": the prompt is a sentence prefix
//!   and the model must continue the (highly regular) corpus text; scored
//!   with WER against the true continuation. Plays the role of the
//!   LibriSpeech/TED-LIUM/CV16 rows of Table 1.
//! * [`TaskKind::Summarize`] — continuation of a paragraph after a
//!   "Summary:"-style cue, scored with ROUGE-1 against the reference
//!   continuation — the Xsum/CNN-DM role.
//!
//! Accuracy differences between verification methods arise exactly as in
//! the paper: `exact` emits the same tokens as `baseline` (same metric to
//! the last digit), while `sigmoid` perturbs acceptance/resampling and
//! degrades the metric — increasingly so for extreme (α, β).

use crate::metrics::{rouge1_f1, wer};
use crate::util::rng::Pcg32;

use super::corpus::Corpus;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Asr,
    Summarize,
}

impl TaskKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "asr" => Some(TaskKind::Asr),
            "summarize" | "sum" => Some(TaskKind::Summarize),
            _ => None,
        }
    }

    pub fn metric_name(&self) -> &'static str {
        match self {
            TaskKind::Asr => "WER",
            TaskKind::Summarize => "ROUGE-1",
        }
    }

    /// true if larger metric values are better (ROUGE) or worse (WER)
    pub fn higher_is_better(&self) -> bool {
        matches!(self, TaskKind::Summarize)
    }
}

/// One evaluation example.
#[derive(Debug, Clone)]
pub struct Task {
    pub kind: TaskKind,
    pub prompt: String,
    pub reference: String,
    pub max_new_tokens: usize,
}

impl Task {
    /// Score a generated continuation against the reference.
    pub fn score(&self, hypothesis: &str) -> f64 {
        match self.kind {
            TaskKind::Asr => wer(&self.reference, hypothesis),
            TaskKind::Summarize => rouge1_f1(&self.reference, hypothesis),
        }
    }
}

/// Deterministically draw `n` tasks from the corpus.
///
/// ASR tasks: pick a sentence, prompt = first ~40% of its characters,
/// reference = remainder (max_new sized to cover it). Summarize tasks:
/// pick a paragraph, prompt = its first sentences, reference = the next
/// chunk.
pub fn make_tasks(corpus: &Corpus, kind: TaskKind, n: usize, seed: u64) -> Vec<Task> {
    let mut rng = Pcg32::new(seed, 77);
    let mut tasks = Vec::with_capacity(n);
    while tasks.len() < n {
        match kind {
            TaskKind::Asr => {
                let s = rng.choice(&corpus.sentences);
                if s.len() < 40 {
                    continue;
                }
                let cut = (s.len() * 2) / 5;
                // cut at a char boundary (corpus is ascii, but be safe)
                let cut = (cut..s.len()).find(|&i| s.is_char_boundary(i)).unwrap();
                let reference = s[cut..].trim().to_string();
                tasks.push(Task {
                    kind,
                    prompt: s[..cut].to_string(),
                    reference: reference.clone(),
                    max_new_tokens: (reference.len() + 8).min(160),
                });
            }
            TaskKind::Summarize => {
                let p = rng.choice(&corpus.paragraphs);
                if p.len() < 160 {
                    continue;
                }
                let cut = (96..p.len()).find(|&i| p.is_char_boundary(i)).unwrap();
                let end = (cut + 100).min(p.len());
                let end = (end..p.len())
                    .find(|&i| p.is_char_boundary(i))
                    .unwrap_or(p.len());
                tasks.push(Task {
                    kind,
                    prompt: p[..cut].to_string(),
                    reference: p[cut..end].trim().to_string(),
                    max_new_tokens: 100,
                });
            }
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        let mut text = String::new();
        for i in 0..20 {
            text.push_str(&format!(
                "The scheduler number {i} accepts the drafted tokens in parallel \
                 and then the batch planner emits the next request once per step. \
                 A worker thread verifies a probability tile with bounded memory. \
                 The profiler tracks the partial sums after the reduction.\n\n"
            ));
        }
        Corpus::from_text(text).unwrap()
    }

    #[test]
    fn asr_tasks_split_sentences() {
        let tasks = make_tasks(&corpus(), TaskKind::Asr, 8, 1);
        assert_eq!(tasks.len(), 8);
        for t in &tasks {
            assert!(!t.prompt.is_empty());
            assert!(!t.reference.is_empty());
            assert!(t.max_new_tokens >= t.reference.len().min(152));
        }
    }

    #[test]
    fn summarize_tasks_have_paragraph_prompts() {
        let tasks = make_tasks(&corpus(), TaskKind::Summarize, 5, 2);
        assert_eq!(tasks.len(), 5);
        for t in &tasks {
            assert!(t.prompt.len() >= 96);
            assert_eq!(t.max_new_tokens, 100);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = make_tasks(&corpus(), TaskKind::Asr, 5, 42);
        let b = make_tasks(&corpus(), TaskKind::Asr, 5, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.reference, y.reference);
        }
        let c = make_tasks(&corpus(), TaskKind::Asr, 5, 43);
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn scoring_uses_the_right_metric() {
        let t = Task {
            kind: TaskKind::Asr,
            prompt: "p".into(),
            reference: "a b c".into(),
            max_new_tokens: 10,
        };
        assert_eq!(t.score("a b c"), 0.0); // perfect WER
        let t = Task {
            kind: TaskKind::Summarize,
            prompt: "p".into(),
            reference: "a b c".into(),
            max_new_tokens: 10,
        };
        assert_eq!(t.score("a b c"), 1.0); // perfect ROUGE
    }
}
