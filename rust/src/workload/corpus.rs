//! Corpus access: the deterministic text the build-time models were
//! trained on (`data/corpus.txt`, emitted by python/compile/gen_corpus.py).

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Loaded corpus split into sentence and paragraph views.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub text: String,
    pub paragraphs: Vec<String>,
    pub sentences: Vec<String>,
}

impl Corpus {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading corpus {}", path.display()))?;
        Self::from_text(text)
    }

    /// Default location (`data/corpus.txt` or `$SPECD_CORPUS`).
    pub fn load_default() -> Result<Self> {
        let path = std::env::var_os("SPECD_CORPUS")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("data/corpus.txt"));
        Self::load(&path)
    }

    pub fn from_text(text: String) -> Result<Self> {
        if text.trim().is_empty() {
            bail!("corpus is empty");
        }
        let paragraphs: Vec<String> = text
            .split("\n\n")
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(String::from)
            .collect();
        let sentences: Vec<String> = paragraphs
            .iter()
            .flat_map(|p| p.split(". "))
            .map(|s| s.trim().trim_end_matches('.').to_string())
            .filter(|s| s.split_whitespace().count() >= 3)
            .collect();
        Ok(Corpus {
            text,
            paragraphs,
            sentences,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "The scheduler accepts the drafted tokens. \
The batch planner emits the next request in parallel.\n\n\
A worker thread verifies a probability tile. The profiler tracks the \
partial sums once per step.";

    #[test]
    fn splits_paragraphs_and_sentences() {
        let c = Corpus::from_text(SAMPLE.to_string()).unwrap();
        assert_eq!(c.paragraphs.len(), 2);
        assert_eq!(c.sentences.len(), 4);
        assert!(c.sentences[0].starts_with("The scheduler"));
        // trailing period stripped
        assert!(!c.sentences[0].ends_with('.'));
    }

    #[test]
    fn rejects_empty() {
        assert!(Corpus::from_text("  \n ".to_string()).is_err());
    }
}
