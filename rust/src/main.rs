//! `specd` CLI — serve, generate, evaluate, and regenerate the paper's
//! tables/figures.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use specd::engine::{Backend, Engine, EngineConfig, Mode, PipelineMode, SamplingParams};
use specd::runtime::{Runtime, SimSpec};
use specd::sampling::Method;
use specd::server::{Server, ServerConfig};
use specd::trace::TraceRecorder;
use specd::simulator::DeviceProfile;
use specd::tables::{self, EvalContext, TableId};
use specd::tokenizer::Tokenizer;
use specd::util::cli::Command;
use specd::util::json::Value;
use specd::workload::{make_tasks, TaskKind};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match argv.split_first() {
        Some((s, rest)) => (s.as_str(), rest.to_vec()),
        None => ("help", Vec::new()),
    };
    let code = match dispatch(sub, &rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(sub: &str, rest: &[String]) -> Result<()> {
    match sub {
        "info" => info(rest),
        "run" => run(rest),
        "serve" => serve(rest),
        "client" => client(rest),
        "eval" => eval(rest),
        "table" | "figure" => table(rest),
        "trace" => trace_cmd(rest),
        "help" | "--help" | "-h" => {
            println!("{}", help_text());
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{}", help_text()),
    }
}

fn help_text() -> &'static str {
    "specd — optimized speculative sampling serving engine (EMNLP 2024 reproduction)\n\
     \n\
     subcommands:\n\
     \x20 info                         artifact/manifest summary\n\
     \x20 run     --prompt <text>      one-off generation\n\
     \x20 serve   --addr <host:port>   TCP JSON-lines server (protocol v2 + v1 shim)\n\
     \x20 client  --prompt <text>      send a request to a running server\n\
     \x20 eval    --task asr|sum       workload evaluation (WER / ROUGE-1)\n\
     \x20 table   --id t1..t8|all      regenerate a paper table\n\
     \x20 figure  --id f3|f4|f5        regenerate a paper figure's data\n\
     \x20 trace   record|check|export|fuzz|corpus   deterministic execution traces:\n\
     \x20         record a pipelined sim decode, replay it offline against\n\
     \x20         the scalar oracle, convert binary<->JSON-lines, or fuzz\n\
     \x20         randomized schedules through record-then-check\n\
     \n\
     sampling params (run/client; every request carries a SamplingParams —\n\
     defaults: 64 new tokens, temperature 0.8, no truncation, no stops):\n\
     \x20 --max-new N, --temperature T, --top-k K, --top-p P,\n\
     \x20 --stop \"a,b\" (comma-separated stop sequences, trimmed from output),\n\
     \x20 --request-gamma G [--pin-gamma] (per-request draft-length override),\n\
     \x20 --request-method baseline|exact|sigmoid|sigmoid16 (per-request\n\
     \x20 verification-method override, dispatched per slot on any batch\n\
     \x20 size; needs verify artifacts sharing a gamma with the engine\n\
     \x20 method); `client` additionally takes a per-request --seed\n\
     \x20 (`run`'s --seed seeds the engine RNG)\n\
     \n\
     wire protocol v2 (one JSON object per line, both directions):\n\
     \x20 -> {\"v\":2,\"op\":\"generate\",\"id\":1,\"prompt\":\"...\",\"stream\":true,\n\
     \x20     \"params\":{\"max_new_tokens\":32,\"top_p\":0.9,\"stop\":[\"\\n\"]}}\n\
     \x20 <- {\"v\":2,\"event\":\"delta\",\"id\":1,\"text\":\"...\",\"tokens\":4}   (stream)\n\
     \x20 <- {\"v\":2,\"event\":\"done\",\"id\":1,\"text\":\"...\",\"finish\":\"length\",...}\n\
     \x20 -> {\"v\":2,\"op\":\"cancel\",\"id\":1}    frees the slot mid-decode\n\
     \x20 <- {\"v\":2,\"event\":\"error\",\"id\":1,\"code\":\"invalid_params\",\"error\":...}\n\
     \x20 done events carry the SLO block: queue_ms, queue_depth, and\n\
     \x20 latency / queue-wait percentiles; overload answers with codes\n\
     \x20 queue_full (admission queue at --queue-limit) or shed (queued\n\
     \x20 past --shed-after-ms); v1 one-shot lines (no \"v\" key) still\n\
     \x20 round-trip unchanged.\n\
     \n\
     common options: --method baseline|exact|sigmoid, --backend hlo|native|sim,\n\
     --pair base|large, --batch N, --alpha/--beta, --n <examples>, --seed,\n\
     --pipeline on|off|auto (overlap next-step model dispatch with CPU\n\
     verification; auto = on for --backend native; bit-identical outputs),\n\
     --pipeline-depth K (speculation window: prefetched step blocks in\n\
     flight, 1-8; partial barrier hits adopt per slot — --no-salvage\n\
     reverts to the all-or-nothing barrier);\n\
     --backend sim runs the artifact-free simulated model pair (native\n\
     verification, synthetic tokenizer — no `make artifacts` needed), and\n\
     SPECD_SIM=1 does the same for subcommands without the flag;\n\
     serve --trace <path> streams a binary execution trace for\n\
     `specd trace check` (toggle at runtime with the v2 `record` op)"
}

fn parse_method(p: &specd::util::cli::Parsed) -> Result<Method> {
    parse_method_str(
        p.str("method"),
        p.f64("alpha").map_err(|e| anyhow!(e))? as f32,
        p.f64("beta").map_err(|e| anyhow!(e))? as f32,
    )
}

fn parse_method_str(name: &str, alpha: f32, beta: f32) -> Result<Method> {
    match name {
        "baseline" => Ok(Method::Baseline),
        "exact" => Ok(Method::Exact),
        "sigmoid" => Ok(Method::sigmoid(alpha, beta)),
        "sigmoid16" => Ok(Method::sigmoid16(alpha, beta)),
        other => bail!("unknown method {other:?}"),
    }
}

fn engine_opts(cmd: Command) -> Command {
    cmd.opt("method", "exact", "verification method")
        .opt(
            "backend",
            "hlo",
            "verifier backend (hlo|native), or sim for the artifact-free simulated pair",
        )
        .opt("pair", "base", "model pair")
        .opt("batch", "1", "engine slots (must match artifacts)")
        .opt("alpha", "-1000", "sigmoid alpha")
        .opt("beta", "1000", "sigmoid beta")
        .opt("gamma", "5", "initial draft length")
        .flag("self-draft", "draft via target-layer skipping (self-speculative)")
        .opt(
            "pipeline",
            "auto",
            "pipelined decode scheduler (on|off|auto; auto = native backend only)",
        )
        .opt(
            "pipeline-depth",
            "2",
            "speculation-window depth k: prefetched step blocks in flight (1-8)",
        )
        .flag(
            "no-salvage",
            "all-or-nothing commit barrier (disable per-slot partial-hit adoption)",
        )
        .opt("seed", "0", "rng seed")
}

/// The per-request SamplingParams flags shared by `run` and `client`.
fn sampling_opts(cmd: Command) -> Command {
    cmd.opt("max-new", "64", "max new tokens")
        .opt("temperature", "0.8", "sampling temperature (0 = greedy)")
        .opt("top-k", "0", "top-k truncation (0 = off)")
        .opt("top-p", "1.0", "nucleus truncation (1.0 = off)")
        .opt("stop", "", "comma-separated stop sequences")
        .opt("request-gamma", "0", "per-request draft-length cap (0 = off)")
        .flag("pin-gamma", "pin γ to --request-gamma (bypass the controller)")
}

fn sampling_params(p: &specd::util::cli::Parsed) -> Result<SamplingParams> {
    let mut params = SamplingParams::default()
        .with_max_new_tokens(p.usize("max-new").map_err(|e| anyhow!(e))?)
        .with_temperature(p.f64("temperature").map_err(|e| anyhow!(e))? as f32)
        .with_top_k(p.usize("top-k").map_err(|e| anyhow!(e))?)
        .with_top_p(p.f64("top-p").map_err(|e| anyhow!(e))? as f32);
    if !p.str("stop").is_empty() {
        params = params.with_stop(
            p.str("stop").split(',').map(String::from).collect(),
        );
    }
    let g = p.usize("request-gamma").map_err(|e| anyhow!(e))?;
    if g > 0 {
        params = if p.flag("pin-gamma") {
            params.pin_gamma(g)
        } else {
            params.with_gamma(g)
        };
    }
    params.validate().map_err(|e| anyhow!(e))?;
    Ok(params)
}

fn build_engine(p: &specd::util::cli::Parsed, mode: Mode) -> Result<(Engine, Tokenizer)> {
    if p.str("backend") == "sim" || p.str("pair") == "sim" {
        return build_sim_engine(p, mode);
    }
    let runtime = Arc::new(Runtime::open_default()?);
    let tokenizer = Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json"))?;
    let config = EngineConfig {
        pair: p.str("pair").to_string(),
        batch: p.usize("batch").map_err(|e| anyhow!(e))?,
        method: parse_method(p)?,
        backend: Backend::parse(p.str("backend"))
            .ok_or_else(|| anyhow!("bad --backend"))?,
        mode,
        gamma_init: p.usize("gamma").map_err(|e| anyhow!(e))?,
        gamma_pinned: false,
        self_draft: p.flag("self-draft"),
        pipeline: PipelineMode::parse(p.str("pipeline"))
            .ok_or_else(|| anyhow!("bad --pipeline (want on|off|auto)"))?,
        pipeline_depth: p.usize("pipeline-depth").map_err(|e| anyhow!(e))?,
        pipeline_salvage: !p.flag("no-salvage"),
        seed: p.u64("seed").map_err(|e| anyhow!(e))?,
    };
    Ok((Engine::new(runtime, config)?, tokenizer))
}

/// `--backend sim` / `--pair sim`: artifact-free engine over the
/// simulated model pair — native verification, synthetic printable-ASCII
/// tokenizer, `SPECD_SIM_DELAY_US` / `SPECD_SIM_AGREEMENT` honored.
fn build_sim_engine(p: &specd::util::cli::Parsed, mode: Mode) -> Result<(Engine, Tokenizer)> {
    if p.flag("self-draft") {
        bail!("--self-draft needs real artifacts (unavailable with --backend sim)");
    }
    let batch = p.usize("batch").map_err(|e| anyhow!(e))?;
    let mut spec = SimSpec::from_env();
    if !spec.batches.contains(&batch) {
        spec.batches.push(batch);
    }
    let vocab = spec.vocab;
    let runtime = Arc::new(Runtime::simulated(spec));
    let tokenizer = sim_tokenizer(vocab)?;
    let config = EngineConfig {
        pair: "sim".into(),
        batch,
        method: parse_method(p)?,
        backend: Backend::Native,
        mode,
        gamma_init: p.usize("gamma").map_err(|e| anyhow!(e))?,
        gamma_pinned: false,
        self_draft: false,
        pipeline: PipelineMode::parse(p.str("pipeline"))
            .ok_or_else(|| anyhow!("bad --pipeline (want on|off|auto)"))?,
        pipeline_depth: p.usize("pipeline-depth").map_err(|e| anyhow!(e))?,
        pipeline_salvage: !p.flag("no-salvage"),
        seed: p.u64("seed").map_err(|e| anyhow!(e))?,
    };
    Ok((Engine::new(runtime, config)?, tokenizer))
}

/// Printable-ASCII char tokenizer sized to the simulated vocab.
fn sim_tokenizer(vocab: usize) -> Result<Tokenizer> {
    let chars: Vec<char> = (' '..='~').collect();
    let keep = chars.len().min(vocab.saturating_sub(3));
    Tokenizer::from_chars(chars[..keep].to_vec(), vocab)
}

fn info(rest: &[String]) -> Result<()> {
    let cmd = Command::new("info", "artifact summary");
    cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let runtime = Runtime::open_default()?;
    let m = &runtime.manifest;
    println!("specd {}", specd::version());
    println!("artifacts dir : {}", m.dir.display());
    println!("vocab         : {}", m.vocab_size);
    println!("seq len       : {}", m.seq_len);
    println!("gmax          : {}", m.gmax);
    for (pair, (t, d)) in &m.pairs {
        println!("pair {pair:<8}: target {t} params, draft {d} params");
    }
    println!("artifacts     : {}", m.entries.len());
    for kind in ["draft_step", "target_step", "target_score", "verify"] {
        let n = m.entries.iter().filter(|e| e.kind == kind).count();
        println!("  {kind:<14} {n}");
    }
    Ok(())
}

fn run(rest: &[String]) -> Result<()> {
    let cmd = sampling_opts(engine_opts(Command::new("run", "one-off generation")))
        .req("prompt", "prompt text")
        .opt(
            "request-method",
            "",
            "per-request verification-method override (any batch size)",
        )
        .flag("autoregressive", "disable speculation (target-only)");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let mode = if p.flag("autoregressive") {
        Mode::Autoregressive
    } else {
        Mode::Speculative
    };
    let mut params = sampling_params(&p)?;
    if !p.str("request-method").is_empty() {
        params = params.with_method(parse_method_str(
            p.str("request-method"),
            p.f64("alpha").map_err(|e| anyhow!(e))? as f32,
            p.f64("beta").map_err(|e| anyhow!(e))? as f32,
        )?);
    }
    if mode == Mode::Autoregressive && (params.top_k != 0 || params.top_p < 1.0) {
        bail!("--top-k/--top-p require the speculative pipeline (drop --autoregressive)");
    }
    let (mut engine, tok) = build_engine(&p, mode)?;
    let out = engine.generate_text(
        &tok,
        &[(p.str("prompt"), params.max_new_tokens)],
        &params,
    )?;
    for (text, r) in out {
        println!("{}{}", p.str("prompt"), text);
        eprintln!(
            "[{} tokens, {} steps, {:.2} tok/step, accept {:.1}%, {:.1}ms]",
            r.token_ids.len(),
            r.steps,
            r.tokens_per_step(),
            r.acceptance_rate() * 100.0,
            r.latency * 1e3
        );
    }
    Ok(())
}

fn serve(rest: &[String]) -> Result<()> {
    let cmd = engine_opts(Command::new("serve", "TCP JSON-lines server"))
        .opt("addr", "127.0.0.1:7077", "bind address")
        .opt(
            "trace",
            "",
            "stream a binary execution trace here (replay with `specd trace check`)",
        )
        .opt(
            "queue-limit",
            "512",
            "admission-queue bound (past it requests get a queue_full error)",
        )
        .opt(
            "shed-after-ms",
            "0",
            "load-shed queued requests waiting longer than this (0 = never)",
        );
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let (engine, tok) = build_engine(&p, Mode::Speculative)?;
    let trace = if p.str("trace").is_empty() {
        None
    } else {
        let path = std::path::PathBuf::from(p.str("trace"));
        let rec = TraceRecorder::to_file(engine.trace_header(), &path).map_err(|e| anyhow!(e))?;
        println!("recording execution trace to {}", path.display());
        Some(Arc::new(rec))
    };
    let shed_ms = p.u64("shed-after-ms").map_err(|e| anyhow!(e))?;
    let server = Server::start(
        engine,
        tok,
        ServerConfig {
            addr: p.str("addr").to_string(),
            trace,
            queue_limit: p.usize("queue-limit").map_err(|e| anyhow!(e))?,
            shed_after: (shed_ms > 0).then(|| std::time::Duration::from_millis(shed_ms)),
        },
    )?;
    println!("listening on {} (ctrl-c to stop)", server.addr());
    server.serve_forever()
}

fn client(rest: &[String]) -> Result<()> {
    let cmd = sampling_opts(Command::new("client", "send one request to a specd server"))
        .opt("addr", "127.0.0.1:7077", "server address")
        .req("prompt", "prompt text")
        .opt("seed", "", "per-request rng seed (empty = derive)")
        .opt("request-method", "", "per-request method override (baseline|exact|sigmoid|sigmoid16)")
        .opt("alpha", "-1000", "sigmoid alpha for --request-method")
        .opt("beta", "1000", "sigmoid beta for --request-method")
        .flag("stream", "stream incremental delta events")
        .flag("v1", "use the legacy v1 one-shot protocol");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let mut params = sampling_params(&p)?;
    if !p.str("seed").is_empty() {
        params = params.with_seed(p.u64("seed").map_err(|e| anyhow!(e))?);
    }
    if !p.str("request-method").is_empty() {
        params = params.with_method(parse_method_str(
            p.str("request-method"),
            p.f64("alpha").map_err(|e| anyhow!(e))? as f32,
            p.f64("beta").map_err(|e| anyhow!(e))? as f32,
        )?);
    }
    let mut c = specd::server::Client::connect(p.str("addr"))?;
    if p.flag("v1") {
        let resp = c.request(1, p.str("prompt"), params.max_new_tokens, params.temperature)?;
        println!("{}", resp.dump());
        return Ok(());
    }
    c.send_generate(1, p.str("prompt"), &params, p.flag("stream"))?;
    loop {
        let ev = c.read_event()?;
        println!("{}", ev.dump());
        match ev.get("event").and_then(Value::as_str) {
            Some("delta") => continue,
            _ => break, // done or error
        }
    }
    Ok(())
}

fn eval(rest: &[String]) -> Result<()> {
    let cmd = engine_opts(Command::new("eval", "workload evaluation"))
        .opt("task", "asr", "asr | summarize")
        .opt("n", "8", "examples")
        .opt("temperature", "0.7", "sampling temperature");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let kind = TaskKind::parse(p.str("task")).ok_or_else(|| anyhow!("bad --task"))?;
    let mut ctx = EvalContext::open_default(p.usize("n").map_err(|e| anyhow!(e))?)?;
    ctx.pair = p.str("pair").to_string();
    ctx.batch = p.usize("batch").map_err(|e| anyhow!(e))?;
    ctx.params = ctx
        .params
        .with_temperature(p.f64("temperature").map_err(|e| anyhow!(e))? as f32);
    let tasks = make_tasks(&ctx.corpus, kind, ctx.n_examples, 42);
    let method = parse_method(&p)?;
    let backend =
        Backend::parse(p.str("backend")).ok_or_else(|| anyhow!("bad --backend"))?;
    let run = tables::run_method(&ctx, &tasks, method, backend, 5, false)?;
    println!(
        "task={:?} method={} n={}",
        kind,
        method.name(),
        ctx.n_examples
    );
    println!("{} = {:.3}", kind.metric_name(), run.metric);
    println!(
        "profiling total = {:.2}ms over {} steps",
        run.profiling_total * 1e3,
        run.steps
    );
    println!("per-step verify = {}ms", run.per_step_verify.mean_std_ms());
    println!(
        "acceptance = {:.1}%  mean γ = {:.2}",
        run.acceptance_rate * 100.0,
        run.gamma_mean
    );
    println!(
        "wallclock = {:.3}s  tokens = {}",
        run.wallclock, run.emitted_tokens
    );
    Ok(())
}

fn table(rest: &[String]) -> Result<()> {
    let cmd = Command::new("table", "regenerate a paper table/figure")
        .req("id", "t1|t2|t3|t4|t5|t6|t8|f3|f4|f5|all")
        .opt("n", "8", "examples per run")
        .opt("pair", "base", "model pair")
        .opt("batch", "1", "engine slots")
        .opt("device", "a100", "simulated device (a100|2080ti)")
        .opt("seed", "1234", "rng seed");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let device = DeviceProfile::by_name(p.str("device"))
        .ok_or_else(|| anyhow!("unknown device {:?}", p.str("device")))?;
    let mut ctx = EvalContext::open_default(p.usize("n").map_err(|e| anyhow!(e))?)?;
    ctx.pair = p.str("pair").to_string();
    ctx.batch = p.usize("batch").map_err(|e| anyhow!(e))?;
    ctx.seed = p.u64("seed").map_err(|e| anyhow!(e))?;
    let ids: Vec<TableId> = if p.str("id") == "all" {
        TableId::ALL.to_vec()
    } else {
        vec![TableId::parse(p.str("id"))
            .ok_or_else(|| anyhow!("unknown table id {:?}", p.str("id")))?]
    };
    for id in ids {
        println!("{}", tables::generate(id, &ctx, device)?);
    }
    Ok(())
}

fn trace_cmd(rest: &[String]) -> Result<()> {
    const USAGE: &str = "usage: specd trace record|check|export|fuzz|corpus [flags]\n\
         \x20 record  --out t.bin [--jsonl --batch N --requests N --max-new N\n\
         \x20         --seed S --agreement A --method M --gamma G --gmax G\n\
         \x20         --gammas \"2,5,7\" --mixed-methods\n\
         \x20         --pipeline on|off --cancel-at step:id[,step:id]]\n\
         \x20 check   --trace t.bin        replay against the scalar oracle\n\
         \x20 export  --trace t.bin --out t.jsonl   binary <-> JSON-lines\n\
         \x20 fuzz    [--cases N --seed S --case K --serve --smoke]\n\
         \x20         randomized record-then-check (--serve: real server +\n\
         \x20         socket client schedules; --case K: re-run one case)\n\
         \x20 corpus  [--dir D --name N --regen]  gate the committed\n\
         \x20         trace regression corpus (rust/tests/corpus)";
    let (sub, rest) = match rest.split_first() {
        Some((s, r)) if !s.starts_with('-') => (s.as_str(), r.to_vec()),
        _ => bail!("{USAGE}"),
    };
    match sub {
        "record" => trace_record(&rest),
        "check" => trace_check(&rest),
        "export" => trace_export(&rest),
        "fuzz" => trace_fuzz(&rest),
        "corpus" => trace_corpus(&rest),
        other => bail!("unknown trace subcommand {other:?}\n{USAGE}"),
    }
}

/// Build the deterministic decode schedule `trace record` drives from
/// the parsed flags.
fn trace_case(p: &specd::util::cli::Parsed) -> Result<specd::trace::fuzz::FuzzCase> {
    let seed = p.u64("seed").map_err(|e| anyhow!(e))?;
    Ok(specd::trace::fuzz::FuzzCase {
        batch: p.usize("batch").map_err(|e| anyhow!(e))?,
        agreement: p.f64("agreement").map_err(|e| anyhow!(e))? as f32,
        engine_seed: seed.wrapping_mul(2).wrapping_add(11),
        method: parse_method(p)?,
        mixed_methods: p.flag("mixed-methods"),
        n_reqs: p.usize("requests").map_err(|e| anyhow!(e))?,
        max_new: p.usize("max-new").map_err(|e| anyhow!(e))?,
        gamma_init: p.usize("gamma").map_err(|e| anyhow!(e))?,
        pipeline: match p.str("pipeline") {
            "on" => PipelineMode::On,
            "off" => PipelineMode::Off,
            other => bail!("bad --pipeline {other:?} (want on|off)"),
        },
        pipeline_depth: p.usize("pipeline-depth").map_err(|e| anyhow!(e))?,
        pipeline_salvage: !p.flag("no-salvage"),
        gmax: p.usize("gmax").map_err(|e| anyhow!(e))?,
        pin_gammas: parse_gammas(p.str("gammas"))?,
        cancels: parse_cancels(p.str("cancel-at"))?,
        seed,
        ..specd::trace::fuzz::FuzzCase::default()
    })
}

/// Parse the `--gammas "2,5,7"` per-request γ-pin cycle.
fn parse_gammas(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| {
            p.parse::<usize>()
                .ok()
                .filter(|&g| g > 0)
                .ok_or_else(|| anyhow!("bad --gammas entry {p:?} (want positive integers)"))
        })
        .collect()
}

/// Parse `"step:id[,step:id...]"` mid-decode cancel schedules.
fn parse_cancels(s: &str) -> Result<Vec<(usize, u64)>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (step, id) = part
            .split_once(':')
            .ok_or_else(|| anyhow!("bad --cancel-at entry {part:?} (want step:id)"))?;
        let step: usize = step
            .trim()
            .parse()
            .map_err(|_| anyhow!("bad --cancel-at step {step:?}"))?;
        let id: u64 = id
            .trim()
            .parse()
            .map_err(|_| anyhow!("bad --cancel-at request id {id:?}"))?;
        out.push((step, id));
    }
    Ok(out)
}

fn trace_record(rest: &[String]) -> Result<()> {
    let cmd = Command::new(
        "trace record",
        "record a pipelined sim decode to a trace file",
    )
    .req("out", "output trace path")
    .flag("jsonl", "write the JSON-lines export instead of binary framing")
    .opt("batch", "2", "engine slots")
    .opt("requests", "4", "requests to decode (queue churn beyond --batch)")
    .opt("max-new", "16", "per-request new-token budget (varied per request)")
    .opt("seed", "1", "schedule derivation seed")
    .opt("agreement", "0.9", "draft/target agreement of the sim pair")
    .opt("method", "exact", "default verification method")
    .opt("alpha", "-1000", "sigmoid alpha")
    .opt("beta", "1000", "sigmoid beta")
    .opt("gamma", "4", "initial draft length")
    .opt("gmax", "6", "sim model-pair draft capacity (per-slot γ ceiling)")
    .opt(
        "gammas",
        "",
        "pin request i's γ to entry i%len, e.g. \"2,5,7\" (ragged mixed-γ batches)",
    )
    .flag("mixed-methods", "sprinkle per-request method overrides")
    .opt("pipeline", "on", "pipelined decode scheduler (on|off)")
    .opt("pipeline-depth", "2", "speculation-window depth k (1-8)")
    .flag("no-salvage", "all-or-nothing barrier (disable partial-hit adoption)")
    .opt("cancel-at", "", "mid-decode cancels, \"step:id[,step:id]\"");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let case = trace_case(&p)?;
    let (trace, _rec) = specd::trace::fuzz::record_case(&case)?;
    let path = std::path::PathBuf::from(p.str("out"));
    if p.flag("jsonl") {
        specd::trace::format::save_jsonl(&trace, &path).map_err(|e| anyhow!(e))?;
    } else {
        specd::trace::format::save_binary(&trace, &path).map_err(|e| anyhow!(e))?;
    }
    println!(
        "recorded {} events ({} requests, batch {}) -> {}",
        trace.events.len(),
        case.n_reqs,
        case.batch,
        path.display()
    );
    Ok(())
}

fn trace_check(rest: &[String]) -> Result<()> {
    let cmd = Command::new(
        "trace check",
        "replay a recorded trace against the scalar oracle",
    )
    .req("trace", "trace file (binary or JSON lines, format sniffed)");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let trace = specd::trace::format::load(std::path::Path::new(p.str("trace")))
        .map_err(|e| anyhow!(e))?;
    let report = specd::trace::check(&trace).map_err(|e| anyhow!("trace unreplayable: {e}"))?;
    println!(
        "replayed {} steps / {} events: {} requests, {} cancels, {} tokens, \
         {} pipeline events, {} verify dispatches, {} adopted blocks \
         ({} slot-rows salvaged)",
        report.steps,
        report.events,
        report.requests,
        report.cancels,
        report.tokens,
        report.pipeline_events,
        report.verify_events,
        report.pipeline_adopts,
        report.pipeline_salvaged
    );
    match report.divergence {
        None => {
            println!("trace check: OK — bit-identical to the scalar oracle");
            Ok(())
        }
        Some(d) => bail!("trace check: DIVERGED — {d}"),
    }
}

fn trace_export(rest: &[String]) -> Result<()> {
    let cmd = Command::new(
        "trace export",
        "convert a trace between binary framing and JSON lines",
    )
    .req("trace", "input trace file (format sniffed)")
    .req("out", "output path (.jsonl/.json -> JSON lines, else binary)");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let trace = specd::trace::format::load(std::path::Path::new(p.str("trace")))
        .map_err(|e| anyhow!(e))?;
    let out = std::path::PathBuf::from(p.str("out"));
    let jsonl = out
        .extension()
        .and_then(|e| e.to_str())
        .is_some_and(|e| e.eq_ignore_ascii_case("jsonl") || e.eq_ignore_ascii_case("json"));
    if jsonl {
        specd::trace::format::save_jsonl(&trace, &out).map_err(|e| anyhow!(e))?;
    } else {
        specd::trace::format::save_binary(&trace, &out).map_err(|e| anyhow!(e))?;
    }
    println!("wrote {} events -> {}", trace.events.len(), out.display());
    Ok(())
}

fn trace_fuzz(rest: &[String]) -> Result<()> {
    let cmd = Command::new(
        "trace fuzz",
        "randomized pipelined schedules through record-then-check",
    )
    .opt("cases", "20", "number of derived cases")
    .opt("seed", "7", "fuzz run seed (a failing case reproduces from it)")
    .opt("case", "", "re-derive and re-run exactly this case index, then exit")
    .flag(
        "serve",
        "fuzz the serve layer: a real server over the sim backend, driven \
         by randomized client schedules through actual sockets",
    )
    .flag("smoke", "quick smoke run for CI");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let seed = p.u64("seed").map_err(|e| anyhow!(e))?;
    let serve = p.flag("serve");

    // reproduction path: exactly one derived case
    if !p.str("case").is_empty() {
        let idx: u64 = p
            .str("case")
            .parse()
            .map_err(|_| anyhow!("bad --case {:?} (want a case index)", p.str("case")))?;
        if serve {
            let rep = specd::trace::serve_fuzz::run_derived_serve_case(seed, idx)?;
            println!(
                "serve case {idx} (seed {seed}) — ok ({} reqs, {} dones, {} overloads, \
                 {} checked steps)",
                rep.reqs,
                rep.dones,
                rep.queue_full + rep.shed,
                rep.checked_steps
            );
        } else {
            let label = specd::trace::fuzz::case_label(seed, idx);
            let report = specd::trace::fuzz::run_derived_case(seed, idx)?;
            if let Some(d) = report.divergence {
                bail!("{label} — DIVERGED: {d}");
            }
            println!("{label} — ok ({} steps, {} tokens)", report.steps, report.tokens);
        }
        return Ok(());
    }

    if serve {
        let cases = if p.flag("smoke") {
            2
        } else {
            p.usize("cases").map_err(|e| anyhow!(e))?
        };
        let report = specd::trace::serve_fuzz::fuzz_serve(cases, seed, |line| println!("{line}"))?;
        if let Some(f) = report.failure {
            bail!("trace fuzz --serve FAILED (seed {seed}): {f}");
        }
        println!(
            "trace fuzz --serve: {} cases clean ({} reqs, {} dones, {} overloads, \
             {} checked steps)",
            report.cases, report.reqs, report.dones, report.overloads, report.checked_steps
        );
        return Ok(());
    }

    let cases = if p.flag("smoke") {
        3
    } else {
        p.usize("cases").map_err(|e| anyhow!(e))?
    };
    let report = specd::trace::fuzz::fuzz(cases, seed, |line| println!("{line}"))?;
    if let Some(f) = report.failure {
        bail!("trace fuzz FAILED (seed {seed}): {f}");
    }
    println!(
        "trace fuzz: {} cases clean ({} steps, {} tokens, {} pipeline events, \
         {} adopted blocks, {} slot-rows salvaged)",
        report.cases,
        report.steps,
        report.tokens,
        report.pipeline_events,
        report.pipeline_adopts,
        report.pipeline_salvaged
    );
    Ok(())
}

fn trace_corpus(rest: &[String]) -> Result<()> {
    let cmd = Command::new(
        "trace corpus",
        "gate the committed trace regression corpus (oracle replay + \
         byte-exact re-record of every entry)",
    )
    .opt(
        "dir",
        "",
        "corpus directory (default: rust/tests/corpus under the crate root)",
    )
    .opt("name", "", "gate only the entry with this name")
    .flag(
        "regen",
        "re-record every selected entry in place (intentional semantic \
         changes only — see docs/TESTING.md)",
    );
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let dir = if p.str("dir").is_empty() {
        specd::trace::corpus::default_dir()
    } else {
        std::path::PathBuf::from(p.str("dir"))
    };
    let name = Some(p.str("name")).filter(|n| !n.is_empty());
    let regen = p.flag("regen");
    let report = specd::trace::corpus::run(&dir, name, regen, |line| println!("{line}"))?;
    if !report.ok() {
        bail!(
            "trace corpus FAILED ({}/{} entries):\n{}",
            report.failures.len(),
            report.failures.len() + report.entries,
            report.failures.join("\n")
        );
    }
    if regen {
        println!(
            "trace corpus: regenerated {} entries -> {}",
            report.entries,
            dir.display()
        );
    } else {
        let seeded = if report.seeded > 0 {
            format!(", {} seeded — commit the new .sptr files", report.seeded)
        } else {
            String::new()
        };
        println!(
            "trace corpus: {} entries clean ({} steps, {} tokens replayed{seeded})",
            report.entries, report.steps, report.tokens
        );
    }
    Ok(())
}
