//! `specd` CLI — serve, generate, evaluate, and regenerate the paper's
//! tables/figures.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use specd::engine::{Backend, Engine, EngineConfig, Mode, PipelineMode, SamplingParams};
use specd::runtime::Runtime;
use specd::sampling::Method;
use specd::server::{Server, ServerConfig};
use specd::simulator::DeviceProfile;
use specd::tables::{self, EvalContext, TableId};
use specd::tokenizer::Tokenizer;
use specd::util::cli::Command;
use specd::util::json::Value;
use specd::workload::{make_tasks, TaskKind};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match argv.split_first() {
        Some((s, rest)) => (s.as_str(), rest.to_vec()),
        None => ("help", Vec::new()),
    };
    let code = match dispatch(sub, &rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(sub: &str, rest: &[String]) -> Result<()> {
    match sub {
        "info" => info(rest),
        "run" => run(rest),
        "serve" => serve(rest),
        "client" => client(rest),
        "eval" => eval(rest),
        "table" | "figure" => table(rest),
        "help" | "--help" | "-h" => {
            println!("{}", help_text());
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{}", help_text()),
    }
}

fn help_text() -> &'static str {
    "specd — optimized speculative sampling serving engine (EMNLP 2024 reproduction)\n\
     \n\
     subcommands:\n\
     \x20 info                         artifact/manifest summary\n\
     \x20 run     --prompt <text>      one-off generation\n\
     \x20 serve   --addr <host:port>   TCP JSON-lines server (protocol v2 + v1 shim)\n\
     \x20 client  --prompt <text>      send a request to a running server\n\
     \x20 eval    --task asr|sum       workload evaluation (WER / ROUGE-1)\n\
     \x20 table   --id t1..t8|all      regenerate a paper table\n\
     \x20 figure  --id f3|f4|f5        regenerate a paper figure's data\n\
     \n\
     sampling params (run/client; every request carries a SamplingParams —\n\
     defaults: 64 new tokens, temperature 0.8, no truncation, no stops):\n\
     \x20 --max-new N, --temperature T, --top-k K, --top-p P,\n\
     \x20 --stop \"a,b\" (comma-separated stop sequences, trimmed from output),\n\
     \x20 --request-gamma G [--pin-gamma] (per-request draft-length override),\n\
     \x20 --request-method baseline|exact|sigmoid|sigmoid16 (per-request\n\
     \x20 verification-method override, dispatched per slot on any batch\n\
     \x20 size; needs verify artifacts sharing a gamma with the engine\n\
     \x20 method); `client` additionally takes a per-request --seed\n\
     \x20 (`run`'s --seed seeds the engine RNG)\n\
     \n\
     wire protocol v2 (one JSON object per line, both directions):\n\
     \x20 -> {\"v\":2,\"op\":\"generate\",\"id\":1,\"prompt\":\"...\",\"stream\":true,\n\
     \x20     \"params\":{\"max_new_tokens\":32,\"top_p\":0.9,\"stop\":[\"\\n\"]}}\n\
     \x20 <- {\"v\":2,\"event\":\"delta\",\"id\":1,\"text\":\"...\",\"tokens\":4}   (stream)\n\
     \x20 <- {\"v\":2,\"event\":\"done\",\"id\":1,\"text\":\"...\",\"finish\":\"length\",...}\n\
     \x20 -> {\"v\":2,\"op\":\"cancel\",\"id\":1}    frees the slot mid-decode\n\
     \x20 <- {\"v\":2,\"event\":\"error\",\"id\":1,\"code\":\"invalid_params\",\"error\":...}\n\
     \x20 v1 one-shot lines (no \"v\" key) still round-trip unchanged.\n\
     \n\
     common options: --method baseline|exact|sigmoid, --backend hlo|native,\n\
     --pair base|large, --batch N, --alpha/--beta, --n <examples>, --seed,\n\
     --pipeline on|off|auto (overlap next-step model dispatch with CPU\n\
     verification; auto = on for --backend native; bit-identical outputs);\n\
     SPECD_SIM=1 serves the artifact-free simulated model pair (--pair sim\n\
     --backend native)"
}

fn parse_method(p: &specd::util::cli::Parsed) -> Result<Method> {
    parse_method_str(
        p.str("method"),
        p.f64("alpha").map_err(|e| anyhow!(e))? as f32,
        p.f64("beta").map_err(|e| anyhow!(e))? as f32,
    )
}

fn parse_method_str(name: &str, alpha: f32, beta: f32) -> Result<Method> {
    match name {
        "baseline" => Ok(Method::Baseline),
        "exact" => Ok(Method::Exact),
        "sigmoid" => Ok(Method::sigmoid(alpha, beta)),
        "sigmoid16" => Ok(Method::sigmoid16(alpha, beta)),
        other => bail!("unknown method {other:?}"),
    }
}

fn engine_opts(cmd: Command) -> Command {
    cmd.opt("method", "exact", "verification method")
        .opt("backend", "hlo", "verifier backend (hlo|native)")
        .opt("pair", "base", "model pair")
        .opt("batch", "1", "engine slots (must match artifacts)")
        .opt("alpha", "-1000", "sigmoid alpha")
        .opt("beta", "1000", "sigmoid beta")
        .opt("gamma", "5", "initial draft length")
        .flag("self-draft", "draft via target-layer skipping (self-speculative)")
        .opt(
            "pipeline",
            "auto",
            "pipelined decode scheduler (on|off|auto; auto = native backend only)",
        )
        .opt("seed", "0", "rng seed")
}

/// The per-request SamplingParams flags shared by `run` and `client`.
fn sampling_opts(cmd: Command) -> Command {
    cmd.opt("max-new", "64", "max new tokens")
        .opt("temperature", "0.8", "sampling temperature (0 = greedy)")
        .opt("top-k", "0", "top-k truncation (0 = off)")
        .opt("top-p", "1.0", "nucleus truncation (1.0 = off)")
        .opt("stop", "", "comma-separated stop sequences")
        .opt("request-gamma", "0", "per-request draft-length cap (0 = off)")
        .flag("pin-gamma", "pin γ to --request-gamma (bypass the controller)")
}

fn sampling_params(p: &specd::util::cli::Parsed) -> Result<SamplingParams> {
    let mut params = SamplingParams::default()
        .with_max_new_tokens(p.usize("max-new").map_err(|e| anyhow!(e))?)
        .with_temperature(p.f64("temperature").map_err(|e| anyhow!(e))? as f32)
        .with_top_k(p.usize("top-k").map_err(|e| anyhow!(e))?)
        .with_top_p(p.f64("top-p").map_err(|e| anyhow!(e))? as f32);
    if !p.str("stop").is_empty() {
        params = params.with_stop(
            p.str("stop").split(',').map(String::from).collect(),
        );
    }
    let g = p.usize("request-gamma").map_err(|e| anyhow!(e))?;
    if g > 0 {
        params = if p.flag("pin-gamma") {
            params.pin_gamma(g)
        } else {
            params.with_gamma(g)
        };
    }
    params.validate().map_err(|e| anyhow!(e))?;
    Ok(params)
}

fn build_engine(p: &specd::util::cli::Parsed, mode: Mode) -> Result<(Engine, Tokenizer)> {
    let runtime = Arc::new(Runtime::open_default()?);
    let tokenizer = Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json"))?;
    let config = EngineConfig {
        pair: p.str("pair").to_string(),
        batch: p.usize("batch").map_err(|e| anyhow!(e))?,
        method: parse_method(p)?,
        backend: Backend::parse(p.str("backend"))
            .ok_or_else(|| anyhow!("bad --backend"))?,
        mode,
        gamma_init: p.usize("gamma").map_err(|e| anyhow!(e))?,
        gamma_pinned: false,
        self_draft: p.flag("self-draft"),
        pipeline: PipelineMode::parse(p.str("pipeline"))
            .ok_or_else(|| anyhow!("bad --pipeline (want on|off|auto)"))?,
        seed: p.u64("seed").map_err(|e| anyhow!(e))?,
    };
    Ok((Engine::new(runtime, config)?, tokenizer))
}

fn info(rest: &[String]) -> Result<()> {
    let cmd = Command::new("info", "artifact summary");
    cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let runtime = Runtime::open_default()?;
    let m = &runtime.manifest;
    println!("specd {}", specd::version());
    println!("artifacts dir : {}", m.dir.display());
    println!("vocab         : {}", m.vocab_size);
    println!("seq len       : {}", m.seq_len);
    println!("gmax          : {}", m.gmax);
    for (pair, (t, d)) in &m.pairs {
        println!("pair {pair:<8}: target {t} params, draft {d} params");
    }
    println!("artifacts     : {}", m.entries.len());
    for kind in ["draft_step", "target_step", "target_score", "verify"] {
        let n = m.entries.iter().filter(|e| e.kind == kind).count();
        println!("  {kind:<14} {n}");
    }
    Ok(())
}

fn run(rest: &[String]) -> Result<()> {
    let cmd = sampling_opts(engine_opts(Command::new("run", "one-off generation")))
        .req("prompt", "prompt text")
        .opt(
            "request-method",
            "",
            "per-request verification-method override (any batch size)",
        )
        .flag("autoregressive", "disable speculation (target-only)");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let mode = if p.flag("autoregressive") {
        Mode::Autoregressive
    } else {
        Mode::Speculative
    };
    let mut params = sampling_params(&p)?;
    if !p.str("request-method").is_empty() {
        params = params.with_method(parse_method_str(
            p.str("request-method"),
            p.f64("alpha").map_err(|e| anyhow!(e))? as f32,
            p.f64("beta").map_err(|e| anyhow!(e))? as f32,
        )?);
    }
    if mode == Mode::Autoregressive && (params.top_k != 0 || params.top_p < 1.0) {
        bail!("--top-k/--top-p require the speculative pipeline (drop --autoregressive)");
    }
    let (mut engine, tok) = build_engine(&p, mode)?;
    let out = engine.generate_text(
        &tok,
        &[(p.str("prompt"), params.max_new_tokens)],
        &params,
    )?;
    for (text, r) in out {
        println!("{}{}", p.str("prompt"), text);
        eprintln!(
            "[{} tokens, {} steps, {:.2} tok/step, accept {:.1}%, {:.1}ms]",
            r.token_ids.len(),
            r.steps,
            r.tokens_per_step(),
            r.acceptance_rate() * 100.0,
            r.latency * 1e3
        );
    }
    Ok(())
}

fn serve(rest: &[String]) -> Result<()> {
    let cmd = engine_opts(Command::new("serve", "TCP JSON-lines server"))
        .opt("addr", "127.0.0.1:7077", "bind address");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let (engine, tok) = build_engine(&p, Mode::Speculative)?;
    let server = Server::start(
        engine,
        tok,
        ServerConfig {
            addr: p.str("addr").to_string(),
        },
    )?;
    println!("listening on {} (ctrl-c to stop)", server.addr());
    server.serve_forever()
}

fn client(rest: &[String]) -> Result<()> {
    let cmd = sampling_opts(Command::new("client", "send one request to a specd server"))
        .opt("addr", "127.0.0.1:7077", "server address")
        .req("prompt", "prompt text")
        .opt("seed", "", "per-request rng seed (empty = derive)")
        .opt("request-method", "", "per-request method override (baseline|exact|sigmoid|sigmoid16)")
        .opt("alpha", "-1000", "sigmoid alpha for --request-method")
        .opt("beta", "1000", "sigmoid beta for --request-method")
        .flag("stream", "stream incremental delta events")
        .flag("v1", "use the legacy v1 one-shot protocol");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let mut params = sampling_params(&p)?;
    if !p.str("seed").is_empty() {
        params = params.with_seed(p.u64("seed").map_err(|e| anyhow!(e))?);
    }
    if !p.str("request-method").is_empty() {
        params = params.with_method(parse_method_str(
            p.str("request-method"),
            p.f64("alpha").map_err(|e| anyhow!(e))? as f32,
            p.f64("beta").map_err(|e| anyhow!(e))? as f32,
        )?);
    }
    let mut c = specd::server::Client::connect(p.str("addr"))?;
    if p.flag("v1") {
        let resp = c.request(1, p.str("prompt"), params.max_new_tokens, params.temperature)?;
        println!("{}", resp.dump());
        return Ok(());
    }
    c.send_generate(1, p.str("prompt"), &params, p.flag("stream"))?;
    loop {
        let ev = c.read_event()?;
        println!("{}", ev.dump());
        match ev.get("event").and_then(Value::as_str) {
            Some("delta") => continue,
            _ => break, // done or error
        }
    }
    Ok(())
}

fn eval(rest: &[String]) -> Result<()> {
    let cmd = engine_opts(Command::new("eval", "workload evaluation"))
        .opt("task", "asr", "asr | summarize")
        .opt("n", "8", "examples")
        .opt("temperature", "0.7", "sampling temperature");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let kind = TaskKind::parse(p.str("task")).ok_or_else(|| anyhow!("bad --task"))?;
    let mut ctx = EvalContext::open_default(p.usize("n").map_err(|e| anyhow!(e))?)?;
    ctx.pair = p.str("pair").to_string();
    ctx.batch = p.usize("batch").map_err(|e| anyhow!(e))?;
    ctx.params = ctx
        .params
        .with_temperature(p.f64("temperature").map_err(|e| anyhow!(e))? as f32);
    let tasks = make_tasks(&ctx.corpus, kind, ctx.n_examples, 42);
    let method = parse_method(&p)?;
    let backend =
        Backend::parse(p.str("backend")).ok_or_else(|| anyhow!("bad --backend"))?;
    let run = tables::run_method(&ctx, &tasks, method, backend, 5, false)?;
    println!(
        "task={:?} method={} n={}",
        kind,
        method.name(),
        ctx.n_examples
    );
    println!("{} = {:.3}", kind.metric_name(), run.metric);
    println!(
        "profiling total = {:.2}ms over {} steps",
        run.profiling_total * 1e3,
        run.steps
    );
    println!("per-step verify = {}ms", run.per_step_verify.mean_std_ms());
    println!(
        "acceptance = {:.1}%  mean γ = {:.2}",
        run.acceptance_rate * 100.0,
        run.gamma_mean
    );
    println!(
        "wallclock = {:.3}s  tokens = {}",
        run.wallclock, run.emitted_tokens
    );
    Ok(())
}

fn table(rest: &[String]) -> Result<()> {
    let cmd = Command::new("table", "regenerate a paper table/figure")
        .req("id", "t1|t2|t3|t4|t5|t6|t8|f3|f4|f5|all")
        .opt("n", "8", "examples per run")
        .opt("pair", "base", "model pair")
        .opt("batch", "1", "engine slots")
        .opt("device", "a100", "simulated device (a100|2080ti)")
        .opt("seed", "1234", "rng seed");
    let p = cmd.parse(rest).map_err(|e| anyhow!(e))?;
    let device = DeviceProfile::by_name(p.str("device"))
        .ok_or_else(|| anyhow!("unknown device {:?}", p.str("device")))?;
    let mut ctx = EvalContext::open_default(p.usize("n").map_err(|e| anyhow!(e))?)?;
    ctx.pair = p.str("pair").to_string();
    ctx.batch = p.usize("batch").map_err(|e| anyhow!(e))?;
    ctx.seed = p.u64("seed").map_err(|e| anyhow!(e))?;
    let ids: Vec<TableId> = if p.str("id") == "all" {
        TableId::ALL.to_vec()
    } else {
        vec![TableId::parse(p.str("id"))
            .ok_or_else(|| anyhow!("unknown table id {:?}", p.str("id")))?]
    };
    for id in ids {
        println!("{}", tables::generate(id, &ctx, device)?);
    }
    Ok(())
}
