//! The pipelined decode scheduler's moving parts: multi-generation step
//! staging, the shared model-block dispatch, and the depth-k speculation
//! window that overlaps CPU verification with the next k steps' model
//! dispatch.
//!
//! ## Why this exists
//!
//! PR 3/4 made the verification *kernels* concurrent; the decode loop
//! around them stayed a strict serial chain: `draft → score → verify →
//! commit`, every phase waiting on the previous one. But the engine's
//! verification is CPU work on the persistent
//! [`crate::sampling::kernels::pool::WorkerPool`], while draft/score are
//! executable dispatches — two different substrates that can genuinely
//! run at the same time. This module overlaps them: once step N's score
//! logits are staged, the engine **speculates that every draft of step N
//! will be accepted**, predicts step N's full commit (the γ drafted
//! tokens plus the bonus token, computed with the *exact* verification
//! arithmetic so a correct prediction is bit-for-bit the verifier's
//! output), and ships a **chain job** onto the [`DispatchLane`]. The
//! chain job computes step N+1's whole model block — γ draft calls plus
//! the score call, reading speculative post-commit state — and then,
//! instead of stopping, **predicts step N+1's commit itself** (same
//! arithmetic, against cloned RNG streams) and keeps going: up to k
//! blocks (N+1 .. N+k) stream back to the engine, each computed while
//! the engine is still verifying earlier steps.
//!
//! ## Per-slot partial-hit adoption
//!
//! The commit barrier is per-slot. Each decode step while a chain is
//! alive, the engine compares every slot's actual verification outcome
//! against the chain's prediction for that slot: full acceptance and a
//! bit-identical emitted row keep the slot **valid**; any mismatch
//! invalidates that slot *only*. When a prefetched block arrives, every
//! valid slot's rows (draft tokens, z_q, z_p, advanced RNG stream) are
//! **salvaged**; only the missed slots' rows are redone, in a reduced
//! model block whose rows are then spliced into the adopted generation
//! at the step's final γ-prefix offsets. This works because the model
//! contract is per-batch-row independent (`rows_are_batch_independent`
//! in `runtime/sim.rs`) and per-slot RNG streams advance independently
//! (PR 7): a slot whose predictions all held has rows that are
//! bit-identical to what a serial step would compute, regardless of
//! what its batch neighbours did.
//!
//! The chain's validity is **cumulative**: a slot salvages rows from
//! block d only if *every* barrier since the chain launched confirmed
//! its predictions — deeper blocks were computed from the shallower
//! predictions, so one miss poisons that slot's whole remaining window
//! (the cascade-cancel invariant). When every slot is invalid the chain
//! is cancelled outright and the lane job abandons its remaining model
//! calls.
//!
//! Observable state is **never** mutated speculatively — predictions
//! live in their own buffer generations and RNG clones, and rows are
//! adopted only after the barrier proves them equal to the serial
//! outcome — so committed tokens, deltas, stats counters, and every
//! per-slot RNG stream are bit-identical to the serial engine for any
//! seed, schedule, and window depth (the `it_pipeline` parity suite
//! asserts this across k × salvage × methods × seeds × batch sizes,
//! including mid-decode cancellation).
//!
//! ## Workspace generations
//!
//! A pool of [`StepBuffers`] generations rotates through the lane: the
//! engine verifies out of the *current* generation while the chain job
//! fills up to k more. Ownership transfers wholesale (boxed moves
//! through the job channel), so there is no sharing to synchronise; a
//! consumed generation parks back in the pool, and the block-slot /
//! chain-info scratch round-trips the same way. Steady-state chains
//! therefore allocate nothing proportional to γ·V — what remains per
//! *launch* (not per step) is O(B) plumbing (the channel, chain-state
//! vectors, per-slot stop-sequence clones).
//!
//! ## The dispatcher-lane invariant
//!
//! Verify regions are only ever dispatched by the engine thread; the
//! lane's chain job runs executable calls against buffers it owns and
//! never touches the worker pool. The pool's single-dispatcher
//! invariant therefore holds with the pipeline on, and the two
//! substrates overlap freely. See `kernels/pool.rs` for the lane's
//! contract.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{HostTensor, LoadedExecutable, TensorView};
use crate::sampling::kernels::pool::DispatchLane;
use crate::sampling::{self, kernels, verify, Method};
use crate::tokenizer;
use crate::trace::{NullSink, PipelineEv, TraceEvent, TraceSink};
use crate::util::rng::Pcg32;
use crate::util::timer::Profiler;

use super::core::Mode;
use super::gamma::GammaController;
use super::request::match_stop_suffix;
use super::verifier::Backend;

/// Whether the engine overlaps model dispatch with CPU verification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// pipeline whenever the engine decodes speculatively
    On,
    /// strict serial decode loop (the pre-PR-5 behaviour)
    Off,
    /// pipeline on the native verify backend only (the default): the
    /// HLO backend's bonus draw may differ from the native prediction
    /// in the last ulp, which the barrier treats as a miss — correct,
    /// but a wasted prefetch, so `auto` keeps HLO serial
    Auto,
}

impl PipelineMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "on" => Some(PipelineMode::On),
            "off" => Some(PipelineMode::Off),
            "auto" => Some(PipelineMode::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PipelineMode::On => "on",
            PipelineMode::Off => "off",
            PipelineMode::Auto => "auto",
        }
    }

    /// Resolve against the engine's decode mode and verify backend.
    pub fn enabled(self, mode: Mode, backend: Backend) -> bool {
        match self {
            PipelineMode::Off => false,
            PipelineMode::On => mode == Mode::Speculative,
            PipelineMode::Auto => mode == Mode::Speculative && backend == Backend::Native,
        }
    }
}

/// One generation of per-step staging: model inputs, staged model
/// outputs, and the verification logit matrices for one speculative
/// block. The engine owns one *current* generation; the pipeline
/// rotates up to k more through the dispatcher lane. Buffers are sized
/// at construction for the engine's fixed `(B, S, GMAX, V)` — those
/// dimensions are engine-constant, which is what lets a parked
/// generation be reused verbatim ([`PipelineCtl::take_spare`]
/// debug-asserts it) — and are refilled in place every block.
///
/// ## Ragged row addressing
///
/// A block runs **per-slot γ**: slot *i* contributes `γᵢ` draft rows and
/// `γᵢ + 1` target rows (zero rows when inactive), packed back-to-back.
/// The γ-prefix tables [`StepBuffers::q_off`] / [`StepBuffers::p_off`]
/// (rebuilt by [`run_model_block`] from the block's slots) give every
/// layer the same row addressing: slot *i*'s draft rows live at
/// `q_off[i]..q_off[i+1]` of `zq`/`draft`, its target rows at
/// `p_off[i]..p_off[i+1]` of `zp`. Capacities stay at the rectangular
/// worst case (`γᵢ ≤ GMAX`), so a ragged block never reallocates.
#[derive(Debug)]
pub struct StepBuffers {
    /// model token input, `B · S` (row i = slot i's context + drafts)
    pub tokens: Vec<i32>,
    /// model length input, `B`
    pub lens: Vec<i32>,
    /// per-call sampling uniforms, `B`
    pub u: Vec<f32>,
    /// per-call sampling temperatures, `B`
    pub temp: Vec<f32>,
    /// draft logits staging, ragged rows (≤ `B · GMAX`) of `V`
    pub zq: Vec<f32>,
    /// target logits staging, ragged rows (≤ `B · (GMAX+1)`) of `V`
    pub zp: Vec<f32>,
    /// drafted token ids, ragged (≤ `B · GMAX`)
    pub draft: Vec<i32>,
    /// draft-row prefix table, `B + 1`: `q_off[i] = Σ_{j<i} γⱼ`
    pub q_off: Vec<usize>,
    /// target-row prefix table, `B + 1`: `p_off[i] = Σ_{j<i} (γⱼ + 1)`
    /// over *active* slots (inactive slots contribute zero rows)
    pub p_off: Vec<usize>,
    /// draft_step output staging (token + logits tensors)
    pub draft_out: Vec<HostTensor>,
    /// target_score / target_step output staging
    pub target_out: Vec<HostTensor>,
}

impl StepBuffers {
    pub fn new(b: usize, s: usize, gmax: usize, v: usize) -> Self {
        StepBuffers {
            tokens: vec![0; b * s],
            lens: vec![1; b],
            u: vec![0.0; b],
            temp: vec![0.0; b],
            zq: vec![0.0; b * gmax * v],
            zp: vec![0.0; b * (gmax + 1) * v],
            draft: vec![0; b * gmax],
            q_off: vec![0; b + 1],
            p_off: vec![0; b + 1],
            draft_out: Vec::new(),
            target_out: Vec::new(),
        }
    }

    /// Total draft rows of the staged block (`q_off[B]`).
    pub fn total_q(&self, b: usize) -> usize {
        self.q_off[b]
    }

    /// Total target rows of the staged block (`p_off[B]`).
    pub fn total_p(&self, b: usize) -> usize {
        self.p_off[b]
    }
}

/// Problem dimensions threaded through a model block.
#[derive(Debug, Clone, Copy)]
pub struct BlockDims {
    pub b: usize,
    pub s: usize,
    pub v: usize,
    pub gmax: usize,
}

/// Per-slot inputs to one model block. The serial path builds these
/// views of live slots; the chain job builds them from speculative
/// post-commit state with **cloned** RNGs (adopted into the live slots
/// only when the barrier proves the slot's predictions correct).
#[derive(Debug)]
pub struct BlockSlot {
    pub active: bool,
    /// committed (or speculatively committed) token count at block start
    pub len: usize,
    pub rng: Pcg32,
    /// effective draft temperature for this slot
    pub draft_temp: f32,
    /// this slot's γ for the block (`0` when inactive)
    pub gamma: usize,
}

impl BlockSlot {
    pub fn inactive() -> Self {
        BlockSlot {
            active: false,
            len: 1,
            rng: Pcg32::seeded(0),
            draft_temp: 1.0,
            gamma: 0,
        }
    }
}

/// Snap a wanted γ down to artifact availability (the γ set common to
/// every active slot's verification method).
pub(crate) fn snap_gamma(avail: &[usize], want: usize) -> usize {
    avail
        .iter()
        .copied()
        .filter(|&g| g <= want)
        .max()
        .unwrap_or_else(|| avail.first().copied().unwrap_or(1))
}

/// γ wanted by one slot for one step: the controller value clamped by
/// context headroom, capped by a non-pinned per-request override,
/// snapped down to the slot method's artifact γ set. One implementation
/// shared by the engine's per-step plan, the launch-time next-step
/// plan, and the chain job's deeper plans — shared by construction so
/// the three cannot drift.
pub(crate) fn plan_gamma(
    avail: &[usize],
    ctl: &GammaController,
    headroom: usize,
    cap: Option<usize>,
) -> usize {
    let mut want = ctl.effective(headroom);
    if let Some(cap) = cap {
        want = want.min(cap).max(1);
    }
    snap_gamma(avail, want)
}

/// Run one speculative block's model dispatch — `max γᵢ` sequential
/// `draft_step` calls and one `target_score` call — staging the draft
/// tokens, the raw draft logits (`zq`), and the sliced raw score window
/// (`zp`) into `bufs` at **ragged per-slot row offsets**. Each slot runs
/// its own γ (from [`BlockSlot::gamma`]): draft call *c* samples for
/// exactly the slots with `c < γᵢ`; a slot done drafting participates in
/// the remaining calls as a PAD row (`len=1`, `u=0`, `temp=1`) and —
/// crucially — **does not consume its RNG stream**, so a slot's draws
/// depend only on its own γ, never on its batch neighbours'. The γ-prefix
/// tables `bufs.q_off` / `bufs.p_off` are rebuilt here from the block's
/// slots, so the serial path, the chain job, and the trace checker all
/// derive identical row addressing from the same code.
///
/// Token rows of `bufs.tokens` must be pre-filled with each slot's
/// context (PAD rows for inactive slots); drafted tokens are appended in
/// place as they are sampled, so the model sees exactly the token stream
/// the serial engine would feed it.
///
/// This is the one implementation both the serial path and the chain
/// job execute — shared by construction so the two cannot drift.
/// Temperature scaling and top-k/top-p filtering of the staged logits
/// deliberately stay on the engine thread (one code path, after
/// adoption), keeping this function a pure function of
/// `(slot contexts, RNG states, executables)`.
///
/// Returns `Ok(false)` when `cancel` was raised between model calls (a
/// barrier miss abandoning the block early); the buffers then hold a
/// partial block and must be discarded by the caller.
///
/// `prefetch` selects the profiler scopes: a speculatively-dispatched
/// block records under `prefetch/draft` / `prefetch/score` instead of
/// `step/draft` / `step/score`, so the serial scopes keep measuring
/// exactly the engine thread's critical path (a missed prefetch plus
/// its serial redo would otherwise double-count; see `docs/PERF.md`).
#[allow(clippy::too_many_arguments)]
pub fn run_model_block(
    draft_step: &LoadedExecutable,
    target_score: &LoadedExecutable,
    profiler: &Profiler,
    bufs: &mut StepBuffers,
    slots: &mut [BlockSlot],
    dims: BlockDims,
    prefetch: bool,
    cancel: Option<&AtomicBool>,
) -> Result<bool> {
    let BlockDims { b, s, v, gmax } = dims;
    debug_assert_eq!(slots.len(), b);
    let shape_bs = [b, s];
    let shape_b = [b];
    let cancelled = || cancel.is_some_and(|c| c.load(Ordering::Relaxed));
    let (draft_scope, score_scope) = if prefetch {
        ("prefetch/draft", "prefetch/score")
    } else {
        ("step/draft", "step/score")
    };

    // --- 0. γ-prefix tables for the block's ragged row layout
    bufs.q_off.clear();
    bufs.p_off.clear();
    let (mut qo, mut po) = (0usize, 0usize);
    let mut max_gamma = 0usize;
    for slot in slots.iter() {
        bufs.q_off.push(qo);
        bufs.p_off.push(po);
        if slot.active {
            debug_assert!(slot.gamma >= 1 && slot.gamma <= gmax);
            qo += slot.gamma;
            po += slot.gamma + 1;
            max_gamma = max_gamma.max(slot.gamma);
        } else {
            debug_assert_eq!(slot.gamma, 0, "inactive slots carry γ = 0");
        }
    }
    bufs.q_off.push(qo);
    bufs.p_off.push(po);

    // --- 1. draft phase: max γᵢ sequential draft_step calls
    {
        let _g = profiler.scope(draft_scope);
        for c in 0..max_gamma {
            if cancelled() {
                return Ok(false);
            }
            for (i, slot) in slots.iter_mut().enumerate() {
                if slot.active && c < slot.gamma {
                    bufs.lens[i] = (slot.len + c) as i32;
                    bufs.u[i] = slot.rng.uniform_f32();
                    bufs.temp[i] = slot.draft_temp;
                } else {
                    bufs.lens[i] = 1;
                    bufs.u[i] = 0.0;
                    bufs.temp[i] = 1.0;
                }
            }
            draft_step.run_views_into(
                &[
                    TensorView::i32(&shape_bs, &bufs.tokens),
                    TensorView::i32(&shape_b, &bufs.lens),
                    TensorView::f32(&shape_b, &bufs.u),
                    TensorView::f32(&shape_b, &bufs.temp),
                ],
                &mut bufs.draft_out,
            )?;
            let toks = bufs.draft_out[0].as_i32()?;
            let logits = bufs.draft_out[1].as_f32()?;
            for (i, slot) in slots.iter().enumerate() {
                if slot.active && c < slot.gamma {
                    let r = bufs.q_off[i] + c;
                    bufs.draft[r] = toks[i];
                    bufs.tokens[i * s + slot.len + c] = toks[i];
                    bufs.zq[r * v..(r + 1) * v].copy_from_slice(&logits[i * v..(i + 1) * v]);
                }
            }
        }
    }

    // --- 2. target scoring: one call, slice each slot's last γᵢ+1
    //        window rows to its ragged zp span
    if cancelled() {
        return Ok(false);
    }
    {
        let _g = profiler.scope(score_scope);
        for (i, slot) in slots.iter().enumerate() {
            bufs.lens[i] = if slot.active {
                (slot.len + slot.gamma) as i32
            } else {
                1
            };
        }
        target_score.run_views_into(
            &[
                TensorView::i32(&shape_bs, &bufs.tokens),
                TensorView::i32(&shape_b, &bufs.lens),
            ],
            &mut bufs.target_out,
        )?;
        let win = bufs.target_out[0].as_f32()?; // (B, GMAX+1, V)
        let w = gmax + 1;
        for (i, slot) in slots.iter().enumerate() {
            if !slot.active {
                continue;
            }
            let g = slot.gamma;
            for j in 0..=g {
                let src = (i * w + (w - (g + 1) + j)) * v;
                let dst = (bufs.p_off[i] + j) * v;
                bufs.zp[dst..dst + v].copy_from_slice(&win[src..src + v]);
            }
        }
    }
    Ok(true)
}

/// Per-slot request/controller snapshot the chain job needs to extend
/// the window past depth 1: everything the engine would consult to
/// predict a commit, check finish conditions, and plan the next γ —
/// captured at launch against the *speculative* post-launch-step state
/// so the job never reads live engine state.
pub(crate) struct ChainSlotInfo {
    pub active: bool,
    pub id: u64,
    /// effective target temperature (engine clamp applied)
    pub temp: f32,
    pub top_k: usize,
    pub top_p: f32,
    pub method: Method,
    pub max_new_tokens: usize,
    /// predicted generated-token count after the launching step's commit
    pub gen_len: usize,
    pub stop_ids: Vec<Vec<i32>>,
    /// rolling tail of the predicted generated stream (last `max_stop-1`
    /// tokens) for stop-suffix matching across predicted step boundaries
    pub tail: Vec<i32>,
    /// controller state after the launching step's predicted all-accept
    pub ctrl: GammaController,
    /// non-pinned per-request γ cap
    pub cap: Option<usize>,
    /// the slot method's artifact γ set
    pub avail: Vec<usize>,
}

impl ChainSlotInfo {
    pub fn inactive() -> Self {
        ChainSlotInfo {
            active: false,
            id: 0,
            temp: 1.0,
            top_k: 0,
            top_p: 1.0,
            method: Method::Exact,
            max_new_tokens: 0,
            gen_len: 0,
            stop_ids: Vec::new(),
            tail: Vec::new(),
            ctrl: GammaController::pinned(1),
            cap: None,
            avail: Vec::new(),
        }
    }
}

/// One prefetched block streaming back from the chain job.
pub(crate) struct ChainBlock {
    /// 1-based position in the window (block d serves step launch+d)
    pub depth: usize,
    pub bufs: Box<StepBuffers>,
    pub slots: Vec<BlockSlot>,
    /// the lane's prediction of the commit of the step this block
    /// serves — ragged rows addressed by this block's `p_off` table —
    /// which gates block `depth+1` at that step's barrier. Empty when
    /// the chain ends at this block (window exhausted, predicted
    /// finish, or cancellation).
    pub predicted_next: Vec<i32>,
}

/// Messages from the chain job to the engine: up to k blocks in depth
/// order, then exactly one `Done` returning the unused scratch.
pub(crate) enum ChainMsg {
    Block(ChainBlock),
    Done {
        gens: Vec<Box<StepBuffers>>,
        slots: Vec<Vec<BlockSlot>>,
        infos: Vec<ChainSlotInfo>,
    },
}

/// The lane-side body of a chain launch: run block 1, then repeatedly
/// predict the just-computed block's commit, build the next block's
/// speculative inputs, ship the finished block to the engine, and run
/// the next one — up to `depth` blocks. The prediction replays the
/// engine's exact bonus arithmetic (scale → filter → prob row →
/// inverse-CDF with the slot's own `u_bonus`) on a scratch copy, and
/// the per-slot RNG bookkeeping mirrors the verify-draw order (γ
/// acceptance, resample, bonus), so every shipped [`BlockSlot::rng`]
/// is exactly the post-draft stream the serial engine would hold.
#[allow(clippy::too_many_arguments)]
fn run_chain_job(
    draft_step: &LoadedExecutable,
    target_score: &LoadedExecutable,
    profiler: &Profiler,
    dims: BlockDims,
    depth: usize,
    mut infos: Vec<ChainSlotInfo>,
    mut bufs: Box<StepBuffers>,
    mut slots: Vec<BlockSlot>,
    mut spares: Vec<Box<StepBuffers>>,
    mut slot_pool: Vec<Vec<BlockSlot>>,
    cancel: &AtomicBool,
    tx: &Sender<ChainMsg>,
) {
    let BlockDims { b, s, v, .. } = dims;
    // prediction scratch (per chain, not per step)
    let mut zrow = vec![0.0f32; v];
    let mut prob = vec![0.0f32; v];
    let mut sims: Vec<Pcg32> = vec![Pcg32::seeded(0); b];
    let mut d = 1usize;
    loop {
        let completed = matches!(
            run_model_block(
                draft_step,
                target_score,
                profiler,
                &mut bufs,
                &mut slots,
                dims,
                true,
                Some(cancel),
            ),
            Ok(true)
        );
        if !completed {
            // cancelled mid-block or a model call failed: the engine's
            // serial redo resurfaces any real failure
            spares.push(bufs);
            slot_pool.push(slots);
            let _ = tx.send(ChainMsg::Done {
                gens: spares,
                slots: slot_pool,
                infos,
            });
            return;
        }
        if d == depth || spares.is_empty() || cancel.load(Ordering::Relaxed) {
            let _ = tx.send(ChainMsg::Block(ChainBlock {
                depth: d,
                bufs,
                slots,
                predicted_next: Vec::new(),
            }));
            let _ = tx.send(ChainMsg::Done {
                gens: spares,
                slots: slot_pool,
                infos,
            });
            return;
        }

        // --- predict this block's step commit, slot by slot
        let total_p = bufs.total_p(b);
        let mut predicted = vec![-1i32; total_p];
        for i in 0..b {
            let info = &infos[i];
            if !info.active || !slots[i].active {
                continue;
            }
            let sl = &slots[i];
            let g = sl.gamma;
            let (q0, p0) = (bufs.q_off[i], bufs.p_off[i]);
            // the slot's verify draws for this step, in draw order:
            // γ acceptance thresholds, one resample, one bonus — the
            // shipped BlockSlot keeps the post-draft stream untouched
            let mut sim = sl.rng.clone();
            for _ in 0..g + 1 {
                let _ = sim.uniform_f32();
            }
            let ubonus = sim.uniform_f32();
            // engine-exact bonus arithmetic on a scratch copy of the
            // raw bonus logit row
            zrow.copy_from_slice(&bufs.zp[(p0 + g) * v..(p0 + g + 1) * v]);
            if (info.temp - 1.0).abs() > 1e-6 {
                let inv = 1.0 / info.temp;
                for x in zrow.iter_mut() {
                    *x *= inv;
                }
            }
            if info.top_k != 0 || info.top_p < 1.0 {
                sampling::filter::mask_logits_top_k_top_p(&mut zrow, info.top_k, info.top_p);
            }
            kernels::construct_prob_row(&zrow, &mut prob, info.method);
            let row = &mut predicted[p0..p0 + g + 1];
            row[..g].copy_from_slice(&bufs.draft[q0..q0 + g]);
            row[g] = verify::inverse_cdf_sample(&prob, ubonus) as i32;
            sims[i] = sim; // post-bonus = the next block's pre-draft stream
        }

        // --- would the predicted commit finish any slot? The window
        // cannot model a slot-set change, so the chain ends here.
        let mut finishes = false;
        'check: for i in 0..b {
            let info = &mut infos[i];
            if !info.active || !slots[i].active {
                continue;
            }
            let sl = &slots[i];
            let g = sl.gamma;
            if s.saturating_sub(sl.len + g + 1) < 2 {
                finishes = true;
                break;
            }
            let max_stop = info.stop_ids.iter().map(Vec::len).max().unwrap_or(0);
            for &tok in &predicted[bufs.p_off[i]..bufs.p_off[i] + g + 1] {
                if tok == tokenizer::EOS {
                    finishes = true;
                    break 'check;
                }
                if max_stop > 0 {
                    info.tail.push(tok);
                    if match_stop_suffix(&info.tail, &info.stop_ids).is_some() {
                        finishes = true;
                        break 'check;
                    }
                }
                info.gen_len += 1;
                if info.gen_len >= info.max_new_tokens {
                    finishes = true;
                    break 'check;
                }
            }
            if max_stop > 1 && info.tail.len() > max_stop - 1 {
                let cut = info.tail.len() - (max_stop - 1);
                info.tail.drain(..cut);
            }
        }
        if finishes {
            let _ = tx.send(ChainMsg::Block(ChainBlock {
                depth: d,
                bufs,
                slots,
                predicted_next: Vec::new(),
            }));
            let _ = tx.send(ChainMsg::Done {
                gens: spares,
                slots: slot_pool,
                infos,
            });
            return;
        }

        // --- plan the next block: γ from the all-accept-updated
        // controller clone, token rows = this block's rows (context +
        // drafts already appended) completed with the predicted bonus
        let mut nbufs = spares.pop().expect("checked non-empty above");
        let mut nslots = slot_pool.pop().unwrap_or_default();
        nslots.clear();
        for i in 0..b {
            let info = &mut infos[i];
            let dst = &mut nbufs.tokens[i * s..(i + 1) * s];
            if !info.active || !slots[i].active {
                dst.fill(tokenizer::PAD);
                nslots.push(BlockSlot::inactive());
                continue;
            }
            let sl = &slots[i];
            let g = sl.gamma;
            let newlen = sl.len + g + 1;
            info.ctrl.update(true);
            let ng = plan_gamma(
                &info.avail,
                &info.ctrl,
                s.saturating_sub(newlen),
                info.cap,
            );
            dst.copy_from_slice(&bufs.tokens[i * s..(i + 1) * s]);
            dst[sl.len + g] = predicted[bufs.p_off[i] + g];
            nslots.push(BlockSlot {
                active: true,
                len: newlen,
                rng: sims[i].clone(),
                draft_temp: sl.draft_temp,
                gamma: ng,
            });
        }
        let _ = tx.send(ChainMsg::Block(ChainBlock {
            depth: d,
            bufs,
            slots,
            predicted_next: predicted,
        }));
        bufs = nbufs;
        slots = nslots;
        d += 1;
    }
}

/// Per-depth slice of [`PipelineStats`], indexed by window depth − 1.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DepthStats {
    /// blocks of this depth consumed at a step start
    pub consumed: u64,
    /// of those, adopted wholesale
    pub full_hits: u64,
    pub slots_salvaged: u64,
    pub slots_redone: u64,
}

/// Pipelined-scheduler counters ([`super::core::Engine::pipeline_stats`]).
///
/// Slot-level counters are the primary signal: `slots_salvaged /
/// (slots_salvaged + slots_redone)` is the **effective hit rate** —
/// the fraction of slot-steps served from prefetched work, counting
/// partial adoptions (the whole-block hit rate of PR 5 under-counted
/// exactly these).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PipelineStats {
    /// chain launches onto the dispatcher lane
    pub chains: u64,
    /// prefetched blocks consumed at a step start
    pub blocks: u64,
    /// blocks adopted wholesale (every active slot salvaged)
    pub full_hits: u64,
    /// blocks spliced: some slots salvaged, the rest redone
    pub partial_hits: u64,
    /// blocks that arrived but contributed nothing (all slots redone)
    pub misses: u64,
    /// slot-steps whose rows were taken from a prefetched block
    pub slots_salvaged: u64,
    /// slot-steps redone serially while a prefetched block was at hand
    pub slots_redone: u64,
    /// draft rows (Σγ) salvaged from prefetched blocks
    pub rows_salvaged: u64,
    /// draft rows recomputed by redo blocks
    pub rows_redone: u64,
    /// chains cancelled by the cascade before exhausting their window
    pub cancelled: u64,
    /// per-depth consumption counters, `per_depth[d-1]` = depth d
    pub per_depth: Vec<DepthStats>,
}

impl PipelineStats {
    /// Fraction of slot-steps served from prefetched work (full +
    /// salvaged) — the bench gate's effective hit rate.
    pub fn effective_hit_rate(&self) -> f64 {
        let total = self.slots_salvaged + self.slots_redone;
        if total == 0 {
            0.0
        } else {
            self.slots_salvaged as f64 / total as f64
        }
    }
}

/// Engine-side state of one live chain: the channel to the lane job,
/// the per-slot cumulative validity, and the prediction gating the next
/// block.
pub(crate) struct ChainState {
    rx: Receiver<ChainMsg>,
    cancel: Arc<AtomicBool>,
    /// depth of the next block to consume (1-based)
    next_depth: usize,
    /// per-slot request id at launch (meaningful where `valid` started true)
    ids: Vec<u64>,
    /// cumulative per-slot prediction validity since launch: ANDed with
    /// every barrier verdict and every salvage outcome; deeper blocks
    /// were computed from shallower predictions, so one miss poisons
    /// the slot's whole remaining window
    valid: Vec<bool>,
    /// prediction gating block `next_depth`: ragged rows plus the
    /// layout (p_off prefix, per-slot γ) of the step it predicts
    pred_rows: Vec<i32>,
    pred_off: Vec<usize>,
    pred_gammas: Vec<usize>,
    /// a prediction is staged and awaits its barrier verdict
    has_pending: bool,
}

/// Pipeline control state owned by the engine (present only when the
/// pipeline is enabled): the dispatcher lane, the generation pool, and
/// the live chain.
pub(crate) struct PipelineCtl {
    lane: DispatchLane,
    /// configured window depth k (≥ 1)
    depth: usize,
    /// parked buffer generations (up to k at steady state)
    spares: Vec<Box<StepBuffers>>,
    /// parked block-slot scratch vectors
    slot_pool: Vec<Vec<BlockSlot>>,
    /// parked chain-info scratch
    info_pool: Vec<ChainSlotInfo>,
    chain: Option<ChainState>,
    /// a cancelled (or exhausted) chain whose lane job may still be
    /// running: the serial redo must not wait for it, so it parks here
    /// and its generations are reclaimed — without blocking — before
    /// the next launch
    draining: Option<(Receiver<ChainMsg>, Arc<AtomicBool>)>,
    /// recycled prediction-row scratch (`B · (γ+1)`) for the engine's
    /// launch-step prediction
    predicted_spare: Vec<i32>,
    pub stats: PipelineStats,
    /// trace hook for scheduler events — [`NullSink`] unless the engine
    /// attached a recorder
    trace: Arc<dyn TraceSink>,
}

impl Drop for PipelineCtl {
    fn drop(&mut self) {
        // engine teardown with work in flight: raise the cancel flags
        // so the lane job abandons its remaining model calls and the
        // lane's own Drop (which joins after the queue drains) returns
        // after at most one in-progress call instead of a whole window
        if let Some(chain) = &self.chain {
            chain.cancel.store(true, Ordering::Relaxed);
        }
        if let Some((_, cancel)) = &self.draining {
            cancel.store(true, Ordering::Relaxed);
        }
    }
}

impl PipelineCtl {
    pub fn new(depth: usize) -> Self {
        let depth = depth.max(1);
        PipelineCtl {
            lane: DispatchLane::new(),
            depth,
            spares: Vec::new(),
            slot_pool: Vec::new(),
            info_pool: Vec::new(),
            chain: None,
            draining: None,
            predicted_spare: Vec::new(),
            stats: PipelineStats {
                per_depth: vec![DepthStats::default(); depth],
                ..PipelineStats::default()
            },
            trace: Arc::new(NullSink),
        }
    }

    /// Attach the engine's trace sink (propagated by
    /// [`super::core::Engine::set_trace`]).
    pub fn set_trace(&mut self, sink: Arc<dyn TraceSink>) {
        self.trace = sink;
    }

    /// Configured window depth k.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Take the prediction-row scratch (cleared; returned via
    /// [`PipelineCtl::recycle_predicted`] or a launch round-trip).
    pub fn take_predicted(&mut self) -> Vec<i32> {
        let mut p = std::mem::take(&mut self.predicted_spare);
        p.clear();
        p
    }

    /// Hand back prediction scratch from an aborted launch attempt.
    pub fn recycle_predicted(&mut self, predicted: Vec<i32>) {
        self.predicted_spare = predicted;
    }

    /// Take the chain-info scratch (cleared) for a launch.
    pub fn take_infos(&mut self) -> Vec<ChainSlotInfo> {
        let mut v = std::mem::take(&mut self.info_pool);
        v.clear();
        v
    }

    /// Hand back chain-info scratch from an aborted launch attempt.
    pub fn recycle_infos(&mut self, infos: Vec<ChainSlotInfo>) {
        self.info_pool = infos;
    }

    pub fn chain_alive(&self) -> bool {
        self.chain.is_some()
    }

    /// Whether slot `i`, currently owned by request `id`, is still
    /// chain-valid: every prediction for it since the launch held, and
    /// the launch snapshot was taken against this same request.
    pub fn chain_slot_ok(&self, i: usize, id: u64) -> bool {
        self.chain
            .as_ref()
            .is_some_and(|c| c.valid[i] && c.ids[i] == id)
    }

    /// The staged prediction awaiting its barrier verdict: ragged rows,
    /// the `p_off` prefix of the step they predict, and that step's
    /// per-slot γ.
    pub fn pending(&self) -> Option<(&[i32], &[usize], &[usize])> {
        let c = self.chain.as_ref()?;
        if !c.has_pending {
            return None;
        }
        Some((&c.pred_rows, &c.pred_off, &c.pred_gammas))
    }

    /// A spare buffer generation (allocating on first use / after a
    /// lost generation). Dimensions are engine-constant, so a parked
    /// generation is reused verbatim.
    pub fn take_spare(&mut self, b: usize, s: usize, gmax: usize, v: usize) -> Box<StepBuffers> {
        match self.spares.pop() {
            Some(bufs) => {
                debug_assert_eq!(bufs.tokens.len(), b * s, "engine dims are constant");
                debug_assert_eq!(bufs.zp.len(), b * (gmax + 1) * v);
                bufs
            }
            None => Box::new(StepBuffers::new(b, s, gmax, v)),
        }
    }

    /// Park a buffer generation for the next launch.
    pub fn park(&mut self, bufs: Box<StepBuffers>) {
        self.spares.push(bufs);
    }

    /// Take a block-slot scratch vector (cleared).
    pub fn take_slots(&mut self) -> Vec<BlockSlot> {
        let mut s = self.slot_pool.pop().unwrap_or_default();
        s.clear();
        s
    }

    /// Hand back block-slot scratch after adoption.
    pub fn park_slots(&mut self, slots: Vec<BlockSlot>) {
        self.slot_pool.push(slots);
    }

    /// Ship a chain job onto the dispatcher lane: block 1's assembled
    /// inputs plus the per-slot snapshots that let the job extend the
    /// window to `depth` blocks. `predicted` / `pred_off` /
    /// `pred_gammas` describe the engine-side prediction of the
    /// *launching* step's commit, which gates block 1.
    #[allow(clippy::too_many_arguments)]
    pub fn launch(
        &mut self,
        draft_step: Arc<LoadedExecutable>,
        target_score: Arc<LoadedExecutable>,
        profiler: Arc<Profiler>,
        bufs: Box<StepBuffers>,
        slots: Vec<BlockSlot>,
        dims: BlockDims,
        infos: Vec<ChainSlotInfo>,
        predicted: Vec<i32>,
        pred_off: &[usize],
        pred_gammas: &[usize],
    ) {
        debug_assert!(self.chain.is_none(), "one chain in flight at a time");
        debug_assert!(self.draining.is_none(), "launch requires a drained lane");
        let depth = self.depth;
        let cancel = Arc::new(AtomicBool::new(false));
        let cancel_job = cancel.clone();
        let (tx, rx) = channel::<ChainMsg>();
        let b = dims.b;
        let mut ids = vec![0u64; b];
        let mut valid = vec![false; b];
        for (i, info) in infos.iter().enumerate() {
            if info.active {
                ids[i] = info.id;
                valid[i] = true;
            }
        }
        // spare generations + slot scratch for blocks 2..k
        let mut gens: Vec<Box<StepBuffers>> = Vec::with_capacity(depth - 1);
        for _ in 1..depth {
            gens.push(self.take_spare(dims.b, dims.s, dims.gmax, dims.v));
        }
        let mut pool: Vec<Vec<BlockSlot>> = Vec::with_capacity(depth - 1);
        for _ in 1..depth {
            pool.push(self.slot_pool.pop().unwrap_or_default());
        }
        // traced launch γ = block 1's largest per-slot γ
        let gamma_max = slots.iter().map(|sl| sl.gamma).max().unwrap_or(0);
        self.lane.submit(Box::new(move || {
            run_chain_job(
                &draft_step,
                &target_score,
                &profiler,
                dims,
                depth,
                infos,
                bufs,
                slots,
                gens,
                pool,
                &cancel_job,
                &tx,
            );
        }));
        self.chain = Some(ChainState {
            rx,
            cancel,
            next_depth: 1,
            ids,
            valid,
            pred_rows: predicted,
            pred_off: pred_off.to_vec(),
            pred_gammas: pred_gammas.to_vec(),
            has_pending: true,
        });
        self.stats.chains += 1;
        if self.trace.enabled() {
            self.trace.record(TraceEvent::Pipeline(PipelineEv::Launch {
                gamma: gamma_max as u32,
                depth: depth as u32,
            }));
        }
    }

    /// Apply a barrier verdict to the live chain: AND the per-slot
    /// verdicts into the cumulative validity, record the trace event
    /// (depth = the block this prediction gates), and cascade-cancel
    /// the chain when no slot remains salvageable. `full` = every
    /// engine-active slot's verdict held.
    pub fn apply_barrier(&mut self, verdicts: &[bool], full: bool) {
        let Some(chain) = &mut self.chain else { return };
        debug_assert!(chain.has_pending, "barrier without a staged prediction");
        chain.has_pending = false;
        for (vi, &v) in chain.valid.iter_mut().zip(verdicts) {
            *vi = *vi && v;
        }
        let depth = chain.next_depth as u32;
        if self.trace.enabled() {
            self.trace.record(TraceEvent::Pipeline(if full {
                PipelineEv::BarrierHit { depth }
            } else {
                PipelineEv::BarrierMiss {
                    depth,
                    slot_hits: chain.valid.clone(),
                }
            }));
        }
        if !chain.valid.iter().any(|&x| x) {
            self.cancel_chain();
        }
    }

    /// A mid-decode request cancellation freed slot `i`: its chain
    /// predictions are void, but its batch neighbours' remain
    /// salvageable — only when *no* slot is left does the chain cancel.
    pub fn invalidate_slot(&mut self, i: usize) {
        let Some(chain) = &mut self.chain else { return };
        chain.valid[i] = false;
        if !chain.valid.iter().any(|&x| x) {
            self.cancel_chain();
        }
    }

    /// Cascade-cancel: raise the job's cancel flag, count it, and move
    /// the channel to the draining slot so remaining blocks are
    /// reclaimed without ever blocking the serial redo.
    fn cancel_chain(&mut self) {
        let Some(chain) = self.chain.take() else { return };
        chain.cancel.store(true, Ordering::Relaxed);
        self.stats.cancelled += 1;
        if self.trace.enabled() {
            self.trace
                .record(TraceEvent::Pipeline(PipelineEv::CancelInflight));
        }
        self.drain_now(chain.rx, chain.cancel);
        self.predicted_spare = chain.pred_rows;
    }

    /// Receive the chain's next block at a step start. Blocks until the
    /// lane hands it over — the wait is the tail of the overlap, and it
    /// only happens when at least one slot is still valid (a fully
    /// invalid chain was cascade-cancelled at the barrier). Returns
    /// `None` when no chain is alive or the job ended early.
    pub fn next_block(&mut self) -> Option<ChainBlock> {
        let chain = self.chain.as_mut()?;
        match chain.rx.recv() {
            Ok(ChainMsg::Block(blk)) => {
                debug_assert_eq!(blk.depth, chain.next_depth, "blocks arrive in depth order");
                Some(blk)
            }
            Ok(ChainMsg::Done { gens, slots, infos }) => {
                // early stop (cancel raced the window, or a model call
                // failed): reclaim and fall back to serial
                self.spares.extend(gens);
                self.slot_pool.extend(slots);
                self.info_pool = infos;
                self.chain = None;
                None
            }
            Err(_) => {
                // job panicked: generations lost (reallocated on the
                // next launch), lane itself survives
                self.chain = None;
                None
            }
        }
    }

    /// Bookkeeping after the engine consumed a block: fold the salvage
    /// outcome into the cumulative validity, account stats, record the
    /// `Adopt` trace event, and stage the lane's prediction of this
    /// step's commit (gating the next block). An empty prediction means
    /// the chain ended at this block — the job's `Done` follows
    /// immediately, so it is reclaimed with a (bounded) blocking recv
    /// to keep the schedule deterministic.
    #[allow(clippy::too_many_arguments)]
    pub fn note_consumed(
        &mut self,
        salv: &[bool],
        full: bool,
        rows_salvaged: u64,
        rows_redone: u64,
        pred_rows: Vec<i32>,
        pred_off: &[usize],
        block_slots: &[BlockSlot],
    ) {
        let n_salv = salv.iter().filter(|&&x| x).count() as u64;
        let Some(chain) = &mut self.chain else { return };
        let d = chain.next_depth;
        self.stats.blocks += 1;
        self.stats.slots_salvaged += n_salv;
        self.stats.rows_salvaged += rows_salvaged;
        self.stats.rows_redone += rows_redone;
        let dstats = &mut self.stats.per_depth[d - 1];
        dstats.consumed += 1;
        dstats.slots_salvaged += n_salv;
        if full {
            self.stats.full_hits += 1;
            dstats.full_hits += 1;
        } else if n_salv > 0 {
            self.stats.partial_hits += 1;
        } else {
            self.stats.misses += 1;
        }
        if self.trace.enabled() {
            self.trace.record(TraceEvent::Pipeline(PipelineEv::Adopt {
                depth: d as u32,
                salvaged: salv.to_vec(),
            }));
        }
        for (vi, &sv) in chain.valid.iter_mut().zip(salv) {
            *vi = *vi && sv;
        }
        if pred_rows.is_empty() {
            // window exhausted at this block: the job sent `Done` right
            // after it — reclaim now (deterministic, near-zero wait)
            let chain = self.chain.take().expect("checked above");
            loop {
                match chain.rx.recv() {
                    Ok(ChainMsg::Done { gens, slots, infos }) => {
                        self.spares.extend(gens);
                        self.slot_pool.extend(slots);
                        self.info_pool = infos;
                        break;
                    }
                    Ok(ChainMsg::Block(blk)) => {
                        // defensive: a deeper block raced the early stop
                        self.spares.push(blk.bufs);
                        self.slot_pool.push(blk.slots);
                    }
                    Err(_) => break,
                }
            }
        } else {
            chain.pred_rows = pred_rows;
            chain.pred_off.clear();
            chain.pred_off.extend_from_slice(pred_off);
            chain.pred_gammas.clear();
            chain
                .pred_gammas
                .extend(block_slots.iter().map(|sl| sl.gamma));
            chain.has_pending = true;
            chain.next_depth += 1;
        }
    }

    /// Count an engine-active slot-step that was redone serially while
    /// a chain block was at hand (the per-slot complement of
    /// `slots_salvaged`, accumulated by the engine at consumption).
    pub fn note_slots_redone(&mut self, depth: usize, n: u64) {
        self.stats.slots_redone += n;
        if depth >= 1 && depth <= self.stats.per_depth.len() {
            self.stats.per_depth[depth - 1].slots_redone += n;
        }
    }

    /// Move a finished-or-cancelled chain's channel to the draining
    /// slot, reclaiming immediately when the job already sent `Done`.
    fn drain_now(&mut self, rx: Receiver<ChainMsg>, cancel: Arc<AtomicBool>) {
        loop {
            match rx.try_recv() {
                Ok(ChainMsg::Block(blk)) => {
                    self.spares.push(blk.bufs);
                    self.slot_pool.push(blk.slots);
                }
                Ok(ChainMsg::Done { gens, slots, infos }) => {
                    self.spares.extend(gens);
                    self.slot_pool.extend(slots);
                    self.info_pool = infos;
                    return;
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    self.draining = Some((rx, cancel));
                    return;
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => return,
            }
        }
    }

    /// Reclaim the draining chain's buffers if its job has finished;
    /// returns whether the lane is free for a new launch (a launch
    /// while the old job still runs would queue behind it and tie up
    /// the buffer generations, so the caller skips that step instead).
    pub fn lane_free(&mut self) -> bool {
        let Some((rx, cancel)) = self.draining.take() else {
            return true;
        };
        self.drain_now(rx, cancel);
        self.draining.is_none()
    }
}

impl std::fmt::Debug for PipelineCtl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PipelineCtl")
            .field("depth", &self.depth)
            .field("chain", &self.chain.is_some())
            .field("chains", &self.stats.chains)
            .field("blocks", &self.stats.blocks)
            .field("full_hits", &self.stats.full_hits)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_mode_parse_and_resolution() {
        assert_eq!(PipelineMode::parse("on"), Some(PipelineMode::On));
        assert_eq!(PipelineMode::parse("off"), Some(PipelineMode::Off));
        assert_eq!(PipelineMode::parse("auto"), Some(PipelineMode::Auto));
        assert_eq!(PipelineMode::parse("x"), None);
        assert_eq!(PipelineMode::Auto.name(), "auto");

        assert!(PipelineMode::On.enabled(Mode::Speculative, Backend::Hlo));
        assert!(PipelineMode::On.enabled(Mode::Speculative, Backend::Native));
        assert!(!PipelineMode::On.enabled(Mode::Autoregressive, Backend::Native));
        assert!(!PipelineMode::Off.enabled(Mode::Speculative, Backend::Native));
        assert!(PipelineMode::Auto.enabled(Mode::Speculative, Backend::Native));
        assert!(!PipelineMode::Auto.enabled(Mode::Speculative, Backend::Hlo));
    }

    #[test]
    fn step_buffers_sized_for_block_shape() {
        let b = StepBuffers::new(2, 8, 3, 16);
        assert_eq!(b.tokens.len(), 16);
        assert_eq!(b.zq.len(), 2 * 3 * 16);
        assert_eq!(b.zp.len(), 2 * 4 * 16);
        assert_eq!(b.draft.len(), 6);
    }

    #[test]
    fn ctl_spares_round_trip_and_reallocate_when_lost() {
        let mut ctl = PipelineCtl::new(2);
        let a = ctl.take_spare(1, 8, 2, 4);
        let ptr = a.tokens.as_ptr();
        ctl.park(a);
        let b = ctl.take_spare(1, 8, 2, 4);
        assert_eq!(b.tokens.as_ptr(), ptr, "parked generation is reused");
        // not parked back: the next take allocates fresh
        drop(b);
        let c = ctl.take_spare(1, 8, 2, 4);
        assert_eq!(c.tokens.len(), 8);
    }

    #[test]
    fn ctl_without_chain_is_inert() {
        let mut ctl = PipelineCtl::new(3);
        assert_eq!(ctl.depth(), 3);
        assert!(ctl.next_block().is_none());
        assert!(ctl.pending().is_none());
        assert!(!ctl.chain_alive());
        assert!(!ctl.chain_slot_ok(0, 7));
        ctl.apply_barrier(&[true, false], false); // no-op without a chain
        ctl.invalidate_slot(0);
        assert!(ctl.lane_free(), "nothing draining on a fresh ctl");
        assert_eq!(ctl.stats, PipelineStats {
            per_depth: vec![DepthStats::default(); 3],
            ..PipelineStats::default()
        });
    }

    #[test]
    fn plan_gamma_snaps_caps_and_clamps() {
        let avail = [1usize, 2, 4, 8];
        let ctl = GammaController::new(5, 1, 8);
        // controller wants 5, snapped down to 4
        assert_eq!(plan_gamma(&avail, &ctl, 100, None), 4);
        // non-pinned cap 3 → snapped to 2
        assert_eq!(plan_gamma(&avail, &ctl, 100, Some(3)), 2);
        // headroom 3 → effective 2
        assert_eq!(plan_gamma(&avail, &ctl, 3, None), 2);
        // nothing small enough → smallest artifact
        assert_eq!(snap_gamma(&[4, 8], 2), 4);
    }

    #[test]
    fn effective_hit_rate_counts_partial_adoptions() {
        let mut st = PipelineStats::default();
        assert_eq!(st.effective_hit_rate(), 0.0);
        st.slots_salvaged = 3;
        st.slots_redone = 1;
        assert!((st.effective_hit_rate() - 0.75).abs() < 1e-12);
    }
}
