//! The pipelined decode scheduler's moving parts: double-buffered step
//! staging, the shared model-block dispatch, and the speculative
//! prefetch control that overlaps step *N*'s CPU verification with step
//! *N+1*'s model dispatch.
//!
//! ## Why this exists
//!
//! PR 3/4 made the verification *kernels* concurrent; the decode loop
//! around them stayed a strict serial chain: `draft → score → verify →
//! commit`, every phase waiting on the previous one. But the engine's
//! verification is CPU work on the persistent
//! [`crate::sampling::kernels::pool::WorkerPool`], while draft/score are
//! executable dispatches — two different substrates that can genuinely
//! run at the same time. This module overlaps them: once step N's score
//! logits are staged, the engine **speculates that every draft of step N
//! will be accepted**, predicts step N's full commit (the γ drafted
//! tokens plus the bonus token, computed with the *exact* verification
//! arithmetic so a correct prediction is bit-for-bit the verifier's
//! output), and ships step N+1's whole model block — γ draft calls plus
//! the score call, reading speculative post-commit state — onto the
//! [`DispatchLane`]. The engine thread then runs step N's verification
//! kernels as usual. At the pipeline barrier (step N's commit):
//!
//! * **hit** — verification accepted everything and emitted exactly the
//!   predicted tokens: step N+1 adopts the prefetched buffers and the
//!   advanced RNG clones, skipping its entire draft/score phase;
//! * **miss** — any rejection, token mismatch, or slot-set change: the
//!   prefetch is cancelled and discarded, and step N+1 dispatches
//!   serially from untouched state.
//!
//! Observable state is **never** mutated speculatively — predictions
//! live in their own buffer generation and RNG clones, and are adopted
//! only after the barrier proves them equal to the serial outcome — so
//! committed tokens, deltas, stats counters, and every per-slot RNG
//! stream are bit-identical to the serial engine for any seed, hit or
//! miss (the `it_pipeline` parity suite asserts this across methods ×
//! seeds × batch sizes, including mid-decode cancellation).
//!
//! ## Workspace generations
//!
//! Two [`StepBuffers`] generations ping-pong: the engine verifies out of
//! the *current* generation while the lane's job fills the *spare* one.
//! Ownership transfers wholesale (boxed moves through the job channel),
//! so there is no sharing to synchronise; a generation is reused every
//! other step, and the prediction-row / block-slot scratch round-trips
//! through [`PipelineCtl`] the same way. Steady-state prefetches
//! therefore allocate nothing proportional to γ·V — what remains per
//! launch is O(1) plumbing (the result channel and the boxed lane
//! job).
//!
//! ## The dispatcher-lane invariant
//!
//! Verify regions are only ever dispatched by the engine thread; the
//! lane's job runs executable calls against buffers it owns and never
//! touches the worker pool. The pool's single-dispatcher invariant
//! therefore holds with the pipeline on, and the two substrates overlap
//! freely. See `kernels/pool.rs` for the lane's contract.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{HostTensor, LoadedExecutable, TensorView};
use crate::sampling::kernels::pool::DispatchLane;
use crate::trace::{NullSink, PipelineEv, TraceEvent, TraceSink};
use crate::util::rng::Pcg32;
use crate::util::timer::Profiler;

use super::core::Mode;
use super::verifier::Backend;

/// Whether the engine overlaps model dispatch with CPU verification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// pipeline whenever the engine decodes speculatively
    On,
    /// strict serial decode loop (the pre-PR-5 behaviour)
    Off,
    /// pipeline on the native verify backend only (the default): the
    /// HLO backend's bonus draw may differ from the native prediction
    /// in the last ulp, which the barrier treats as a miss — correct,
    /// but a wasted prefetch, so `auto` keeps HLO serial
    Auto,
}

impl PipelineMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "on" => Some(PipelineMode::On),
            "off" => Some(PipelineMode::Off),
            "auto" => Some(PipelineMode::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PipelineMode::On => "on",
            PipelineMode::Off => "off",
            PipelineMode::Auto => "auto",
        }
    }

    /// Resolve against the engine's decode mode and verify backend.
    pub fn enabled(self, mode: Mode, backend: Backend) -> bool {
        match self {
            PipelineMode::Off => false,
            PipelineMode::On => mode == Mode::Speculative,
            PipelineMode::Auto => mode == Mode::Speculative && backend == Backend::Native,
        }
    }
}

/// One generation of per-step staging: model inputs, staged model
/// outputs, and the verification logit matrices for one speculative
/// block. The engine owns one *current* generation; the pipeline
/// ping-pongs a second *spare* through the dispatcher lane. Buffers are
/// sized at construction for the engine's fixed `(B, S, GMAX, V)` —
/// those dimensions are engine-constant, which is what lets a parked
/// generation be reused verbatim ([`PipelineCtl::take_spare`]
/// debug-asserts it) — and are refilled in place every block.
///
/// ## Ragged row addressing
///
/// A block runs **per-slot γ**: slot *i* contributes `γᵢ` draft rows and
/// `γᵢ + 1` target rows (zero rows when inactive), packed back-to-back.
/// The γ-prefix tables [`StepBuffers::q_off`] / [`StepBuffers::p_off`]
/// (rebuilt by [`run_model_block`] from the block's slots) give every
/// layer the same row addressing: slot *i*'s draft rows live at
/// `q_off[i]..q_off[i+1]` of `zq`/`draft`, its target rows at
/// `p_off[i]..p_off[i+1]` of `zp`. Capacities stay at the rectangular
/// worst case (`γᵢ ≤ GMAX`), so a ragged block never reallocates.
#[derive(Debug)]
pub struct StepBuffers {
    /// model token input, `B · S` (row i = slot i's context + drafts)
    pub tokens: Vec<i32>,
    /// model length input, `B`
    pub lens: Vec<i32>,
    /// per-call sampling uniforms, `B`
    pub u: Vec<f32>,
    /// per-call sampling temperatures, `B`
    pub temp: Vec<f32>,
    /// draft logits staging, ragged rows (≤ `B · GMAX`) of `V`
    pub zq: Vec<f32>,
    /// target logits staging, ragged rows (≤ `B · (GMAX+1)`) of `V`
    pub zp: Vec<f32>,
    /// drafted token ids, ragged (≤ `B · GMAX`)
    pub draft: Vec<i32>,
    /// draft-row prefix table, `B + 1`: `q_off[i] = Σ_{j<i} γⱼ`
    pub q_off: Vec<usize>,
    /// target-row prefix table, `B + 1`: `p_off[i] = Σ_{j<i} (γⱼ + 1)`
    /// over *active* slots (inactive slots contribute zero rows)
    pub p_off: Vec<usize>,
    /// draft_step output staging (token + logits tensors)
    pub draft_out: Vec<HostTensor>,
    /// target_score / target_step output staging
    pub target_out: Vec<HostTensor>,
}

impl StepBuffers {
    pub fn new(b: usize, s: usize, gmax: usize, v: usize) -> Self {
        StepBuffers {
            tokens: vec![0; b * s],
            lens: vec![1; b],
            u: vec![0.0; b],
            temp: vec![0.0; b],
            zq: vec![0.0; b * gmax * v],
            zp: vec![0.0; b * (gmax + 1) * v],
            draft: vec![0; b * gmax],
            q_off: vec![0; b + 1],
            p_off: vec![0; b + 1],
            draft_out: Vec::new(),
            target_out: Vec::new(),
        }
    }

    /// Total draft rows of the staged block (`q_off[B]`).
    pub fn total_q(&self, b: usize) -> usize {
        self.q_off[b]
    }

    /// Total target rows of the staged block (`p_off[B]`).
    pub fn total_p(&self, b: usize) -> usize {
        self.p_off[b]
    }
}

/// Problem dimensions threaded through a model block.
#[derive(Debug, Clone, Copy)]
pub struct BlockDims {
    pub b: usize,
    pub s: usize,
    pub v: usize,
    pub gmax: usize,
}

/// Per-slot inputs to one model block. The serial path builds these
/// views of live slots; the prefetch path builds them from speculative
/// post-commit state with **cloned** RNGs (adopted into the live slots
/// only on a barrier hit).
#[derive(Debug)]
pub struct BlockSlot {
    pub active: bool,
    /// committed (or speculatively committed) token count at block start
    pub len: usize,
    pub rng: Pcg32,
    /// effective draft temperature for this slot
    pub draft_temp: f32,
    /// this slot's γ for the block (`0` when inactive)
    pub gamma: usize,
}

impl BlockSlot {
    pub fn inactive() -> Self {
        BlockSlot {
            active: false,
            len: 1,
            rng: Pcg32::seeded(0),
            draft_temp: 1.0,
            gamma: 0,
        }
    }
}

/// Run one speculative block's model dispatch — `max γᵢ` sequential
/// `draft_step` calls and one `target_score` call — staging the draft
/// tokens, the raw draft logits (`zq`), and the sliced raw score window
/// (`zp`) into `bufs` at **ragged per-slot row offsets**. Each slot runs
/// its own γ (from [`BlockSlot::gamma`]): draft call *c* samples for
/// exactly the slots with `c < γᵢ`; a slot done drafting participates in
/// the remaining calls as a PAD row (`len=1`, `u=0`, `temp=1`) and —
/// crucially — **does not consume its RNG stream**, so a slot's draws
/// depend only on its own γ, never on its batch neighbours'. The γ-prefix
/// tables `bufs.q_off` / `bufs.p_off` are rebuilt here from the block's
/// slots, so the serial path, the prefetch path, and the trace checker
/// all derive identical row addressing from the same code.
///
/// Token rows of `bufs.tokens` must be pre-filled with each slot's
/// context (PAD rows for inactive slots); drafted tokens are appended in
/// place as they are sampled, so the model sees exactly the token stream
/// the serial engine would feed it.
///
/// This is the one implementation both the serial path and the
/// prefetch job execute — shared by construction so the two cannot
/// drift. Temperature scaling and top-k/top-p filtering of the staged
/// logits deliberately stay on the engine thread (one code path, after
/// adoption), keeping this function a pure function of
/// `(slot contexts, RNG states, executables)`.
///
/// Returns `Ok(false)` when `cancel` was raised between model calls (a
/// barrier miss abandoning the block early); the buffers then hold a
/// partial block and must be discarded by the caller.
///
/// `prefetch` selects the profiler scopes: a speculatively-dispatched
/// block records under `prefetch/draft` / `prefetch/score` instead of
/// `step/draft` / `step/score`, so the serial scopes keep measuring
/// exactly the engine thread's critical path (a missed prefetch plus
/// its serial redo would otherwise double-count; see `docs/PERF.md`).
#[allow(clippy::too_many_arguments)]
pub fn run_model_block(
    draft_step: &LoadedExecutable,
    target_score: &LoadedExecutable,
    profiler: &Profiler,
    bufs: &mut StepBuffers,
    slots: &mut [BlockSlot],
    dims: BlockDims,
    prefetch: bool,
    cancel: Option<&AtomicBool>,
) -> Result<bool> {
    let BlockDims { b, s, v, gmax } = dims;
    debug_assert_eq!(slots.len(), b);
    let shape_bs = [b, s];
    let shape_b = [b];
    let cancelled = || cancel.is_some_and(|c| c.load(Ordering::Relaxed));
    let (draft_scope, score_scope) = if prefetch {
        ("prefetch/draft", "prefetch/score")
    } else {
        ("step/draft", "step/score")
    };

    // --- 0. γ-prefix tables for the block's ragged row layout
    bufs.q_off.clear();
    bufs.p_off.clear();
    let (mut qo, mut po) = (0usize, 0usize);
    let mut max_gamma = 0usize;
    for slot in slots.iter() {
        bufs.q_off.push(qo);
        bufs.p_off.push(po);
        if slot.active {
            debug_assert!(slot.gamma >= 1 && slot.gamma <= gmax);
            qo += slot.gamma;
            po += slot.gamma + 1;
            max_gamma = max_gamma.max(slot.gamma);
        } else {
            debug_assert_eq!(slot.gamma, 0, "inactive slots carry γ = 0");
        }
    }
    bufs.q_off.push(qo);
    bufs.p_off.push(po);

    // --- 1. draft phase: max γᵢ sequential draft_step calls
    {
        let _g = profiler.scope(draft_scope);
        for c in 0..max_gamma {
            if cancelled() {
                return Ok(false);
            }
            for (i, slot) in slots.iter_mut().enumerate() {
                if slot.active && c < slot.gamma {
                    bufs.lens[i] = (slot.len + c) as i32;
                    bufs.u[i] = slot.rng.uniform_f32();
                    bufs.temp[i] = slot.draft_temp;
                } else {
                    bufs.lens[i] = 1;
                    bufs.u[i] = 0.0;
                    bufs.temp[i] = 1.0;
                }
            }
            draft_step.run_views_into(
                &[
                    TensorView::i32(&shape_bs, &bufs.tokens),
                    TensorView::i32(&shape_b, &bufs.lens),
                    TensorView::f32(&shape_b, &bufs.u),
                    TensorView::f32(&shape_b, &bufs.temp),
                ],
                &mut bufs.draft_out,
            )?;
            let toks = bufs.draft_out[0].as_i32()?;
            let logits = bufs.draft_out[1].as_f32()?;
            for (i, slot) in slots.iter().enumerate() {
                if slot.active && c < slot.gamma {
                    let r = bufs.q_off[i] + c;
                    bufs.draft[r] = toks[i];
                    bufs.tokens[i * s + slot.len + c] = toks[i];
                    bufs.zq[r * v..(r + 1) * v].copy_from_slice(&logits[i * v..(i + 1) * v]);
                }
            }
        }
    }

    // --- 2. target scoring: one call, slice each slot's last γᵢ+1
    //        window rows to its ragged zp span
    if cancelled() {
        return Ok(false);
    }
    {
        let _g = profiler.scope(score_scope);
        for (i, slot) in slots.iter().enumerate() {
            bufs.lens[i] = if slot.active {
                (slot.len + slot.gamma) as i32
            } else {
                1
            };
        }
        target_score.run_views_into(
            &[
                TensorView::i32(&shape_bs, &bufs.tokens),
                TensorView::i32(&shape_b, &bufs.lens),
            ],
            &mut bufs.target_out,
        )?;
        let win = bufs.target_out[0].as_f32()?; // (B, GMAX+1, V)
        let w = gmax + 1;
        for (i, slot) in slots.iter().enumerate() {
            if !slot.active {
                continue;
            }
            let g = slot.gamma;
            for j in 0..=g {
                let src = (i * w + (w - (g + 1) + j)) * v;
                let dst = (bufs.p_off[i] + j) * v;
                bufs.zp[dst..dst + v].copy_from_slice(&win[src..src + v]);
            }
        }
    }
    Ok(true)
}

/// What the lane's prefetch job sends back at the barrier.
pub(crate) struct PrefetchResult {
    pub bufs: Box<StepBuffers>,
    pub slots: Vec<BlockSlot>,
    /// `Ok(true)` = full block staged; `Ok(false)` = cancelled early;
    /// `Err` = a model call failed (the serial redo will resurface it)
    pub outcome: Result<bool>,
}

/// A prefetch in flight on the dispatcher lane.
pub(crate) struct InFlight {
    rx: Receiver<PrefetchResult>,
    cancel: Arc<AtomicBool>,
    /// slot-set epoch at launch: any admit/cancel/finish invalidates
    epoch: u64,
    /// predicted commit rows of the *launching* step, ragged per-slot
    /// spans addressed by that step's `p_off` table
    pub predicted: Vec<i32>,
    /// barrier verdict, set by the launching step's commit
    resolved: Option<bool>,
}

/// Pipeline control state owned by the engine (present only when the
/// pipeline is enabled): the dispatcher lane, the spare buffer
/// generation, and the in-flight prefetch.
pub(crate) struct PipelineCtl {
    lane: DispatchLane,
    spare: Option<Box<StepBuffers>>,
    inflight: Option<InFlight>,
    /// a discarded prefetch whose lane job had not finished when the
    /// barrier resolved: the serial redo must not wait for it, so it
    /// parks here (cancel flag raised) and its buffers are reclaimed —
    /// without blocking — before the next launch
    draining: Option<InFlight>,
    /// recycled prediction-row scratch (`B · (γ+1)`), round-tripped
    /// through [`InFlight`] so steady-state launches allocate nothing
    predicted_spare: Vec<i32>,
    /// recycled block-slot scratch, round-tripped through the job
    slots_spare: Vec<BlockSlot>,
    /// prefetches launched / adopted (observability + tests)
    pub launched: u64,
    pub hits: u64,
    /// trace hook for scheduler events (launch / hit / miss / discard /
    /// lane cancel) — [`NullSink`] unless the engine attached a recorder
    trace: Arc<dyn TraceSink>,
}

impl Drop for PipelineCtl {
    fn drop(&mut self) {
        // engine teardown with work in flight: raise the cancel flags
        // so the lane job abandons its remaining model calls and the
        // lane's own Drop (which joins after the queue drains) returns
        // after at most one in-progress call instead of a whole block
        self.cancel_inflight();
        if let Some(d) = &self.draining {
            d.cancel.store(true, Ordering::Relaxed);
        }
    }
}

impl PipelineCtl {
    pub fn new() -> Self {
        PipelineCtl {
            lane: DispatchLane::new(),
            spare: None,
            inflight: None,
            draining: None,
            predicted_spare: Vec::new(),
            slots_spare: Vec::new(),
            launched: 0,
            hits: 0,
            trace: Arc::new(NullSink),
        }
    }

    /// Attach the engine's trace sink (propagated by
    /// [`super::core::Engine::set_trace`]).
    pub fn set_trace(&mut self, sink: Arc<dyn TraceSink>) {
        self.trace = sink;
    }

    /// Take the prediction-row scratch (cleared; returned via
    /// [`PipelineCtl::recycle_predicted`] or a launch + barrier
    /// round-trip).
    pub fn take_predicted(&mut self) -> Vec<i32> {
        let mut p = std::mem::take(&mut self.predicted_spare);
        p.clear();
        p
    }

    /// Hand back prediction scratch from an aborted launch attempt.
    pub fn recycle_predicted(&mut self, predicted: Vec<i32>) {
        self.predicted_spare = predicted;
    }

    /// Take the block-slot scratch (cleared; round-trips through the
    /// lane job and back via [`PipelineCtl::resolve`] /
    /// [`PipelineCtl::park_slots`]).
    pub fn take_slots(&mut self) -> Vec<BlockSlot> {
        let mut s = std::mem::take(&mut self.slots_spare);
        s.clear();
        s
    }

    /// Hand back the block-slot scratch after a hit adoption.
    pub fn park_slots(&mut self, slots: Vec<BlockSlot>) {
        self.slots_spare = slots;
    }

    pub fn has_inflight(&self) -> bool {
        self.inflight.is_some()
    }

    /// Predicted commit rows of the in-flight prefetch (barrier
    /// compare; ragged spans addressed by the launching step's `p_off`).
    pub fn inflight_predicted(&self) -> Option<&[i32]> {
        self.inflight.as_ref().map(|inf| inf.predicted.as_slice())
    }

    /// The spare buffer generation (allocating on first use / after a
    /// lost generation). Dimensions are engine-constant, so a parked
    /// generation is reused verbatim.
    pub fn take_spare(&mut self, b: usize, s: usize, gmax: usize, v: usize) -> Box<StepBuffers> {
        match self.spare.take() {
            Some(bufs) => {
                debug_assert_eq!(bufs.tokens.len(), b * s, "engine dims are constant");
                debug_assert_eq!(bufs.zp.len(), b * (gmax + 1) * v);
                bufs
            }
            None => Box::new(StepBuffers::new(b, s, gmax, v)),
        }
    }

    /// Park a buffer generation for the next prefetch.
    pub fn park(&mut self, bufs: Box<StepBuffers>) {
        self.spare = Some(bufs);
    }

    /// Ship a speculative model block onto the dispatcher lane.
    #[allow(clippy::too_many_arguments)]
    pub fn launch(
        &mut self,
        draft_step: Arc<LoadedExecutable>,
        target_score: Arc<LoadedExecutable>,
        profiler: Arc<Profiler>,
        mut bufs: Box<StepBuffers>,
        mut slots: Vec<BlockSlot>,
        dims: BlockDims,
        predicted: Vec<i32>,
        epoch: u64,
    ) {
        debug_assert!(self.inflight.is_none(), "one prefetch in flight at a time");
        let cancel = Arc::new(AtomicBool::new(false));
        let cancel_job = cancel.clone();
        let (tx, rx) = channel::<PrefetchResult>();
        // traced launch γ = the block's largest per-slot γ (the number
        // of draft calls the lane job will make)
        let gamma_max = slots.iter().map(|sl| sl.gamma).max().unwrap_or(0);
        self.lane.submit(Box::new(move || {
            let outcome = run_model_block(
                &draft_step,
                &target_score,
                &profiler,
                &mut bufs,
                &mut slots,
                dims,
                true,
                Some(&cancel_job),
            );
            let _ = tx.send(PrefetchResult {
                bufs,
                slots,
                outcome,
            });
        }));
        self.inflight = Some(InFlight {
            rx,
            cancel,
            epoch,
            predicted,
            resolved: None,
        });
        self.launched += 1;
        if self.trace.enabled() {
            self.trace
                .record(TraceEvent::Pipeline(PipelineEv::Launch {
                    gamma: gamma_max as u32,
                }));
        }
    }

    /// Record the barrier verdict for the in-flight prefetch (called by
    /// the launching step's commit). A miss raises the cancel flag so
    /// the job abandons remaining model calls.
    pub fn note_outcome(&mut self, hit: bool) {
        if let Some(inf) = &mut self.inflight {
            inf.resolved = Some(hit);
            if !hit {
                inf.cancel.store(true, Ordering::Relaxed);
            }
            if self.trace.enabled() {
                self.trace.record(TraceEvent::Pipeline(if hit {
                    PipelineEv::BarrierHit
                } else {
                    PipelineEv::BarrierMiss
                }));
            }
        }
    }

    /// Raise the cancel flag on any in-flight prefetch (slot-set
    /// changes between steps; the epoch check would discard it anyway —
    /// this just stops it burning model time).
    pub fn cancel_inflight(&self) {
        if let Some(inf) = &self.inflight {
            inf.cancel.store(true, Ordering::Relaxed);
            if self.trace.enabled() {
                self.trace
                    .record(TraceEvent::Pipeline(PipelineEv::CancelInflight));
            }
        }
    }

    /// Barrier reclaim at the next step's start. For a recorded **hit**
    /// with an unchanged slot set, blocks until the lane job hands its
    /// buffers back (the step needs that block anyway — the wait *is*
    /// the tail of the overlap) and returns them for adoption iff the
    /// block completed cleanly. For a **miss** (or stale epoch, or
    /// unresolved error path), raises the cancel flag and reclaims
    /// **without blocking**: a still-running job parks in the draining
    /// slot so the serial redo starts immediately — misses never wait
    /// on the lane.
    pub fn resolve(&mut self, current_epoch: u64) -> Option<(Box<StepBuffers>, Vec<BlockSlot>)> {
        let inf = self.inflight.take()?;
        let adopt = inf.resolved == Some(true) && inf.epoch == current_epoch;
        if !adopt {
            inf.cancel.store(true, Ordering::Relaxed);
            // a barrier miss was already recorded at the verdict; this
            // distinguishes the verdict-hit-but-stale-epoch discard
            if inf.resolved != Some(false) && self.trace.enabled() {
                self.trace.record(TraceEvent::Pipeline(PipelineEv::Discard));
            }
            self.stash_draining(inf);
            return None;
        }
        let InFlight { rx, predicted, .. } = inf;
        self.predicted_spare = predicted;
        match rx.recv() {
            Ok(r) => {
                if matches!(r.outcome, Ok(true)) {
                    // counted at the adoption point (not the verdict),
                    // so a verdict-hit discarded by a slot-set change
                    // between steps never inflates the hit rate
                    self.hits += 1;
                    Some((r.bufs, r.slots))
                } else {
                    // model error / cancelled: the serial redo will
                    // resurface any real failure
                    self.spare = Some(r.bufs);
                    self.slots_spare = r.slots;
                    None
                }
            }
            // the job panicked: the lane survives, this generation's
            // buffers are lost (reallocated on the next launch)
            Err(_) => None,
        }
    }

    /// Move a discarded in-flight prefetch to the draining slot,
    /// reclaiming its buffers right away when the job already finished.
    fn stash_draining(&mut self, inf: InFlight) {
        debug_assert!(self.draining.is_none(), "at most one draining prefetch");
        match inf.rx.try_recv() {
            Ok(r) => {
                self.predicted_spare = inf.predicted;
                self.spare = Some(r.bufs);
                self.slots_spare = r.slots;
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => self.draining = Some(inf),
            // job panicked: buffers lost, scratch still reclaimable
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                self.predicted_spare = inf.predicted;
            }
        }
    }

    /// Reclaim the draining prefetch's buffers if its job has finished;
    /// returns whether the lane is free for a new launch (a launch
    /// while the old job still runs would queue behind it and tie up
    /// both buffer generations, so the caller skips that step instead).
    pub fn lane_free(&mut self) -> bool {
        let Some(d) = self.draining.take() else {
            return true;
        };
        match d.rx.try_recv() {
            Ok(r) => {
                self.predicted_spare = d.predicted;
                self.spare = Some(r.bufs);
                self.slots_spare = r.slots;
                true
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => {
                self.draining = Some(d);
                false
            }
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                self.predicted_spare = d.predicted;
                true
            }
        }
    }
}

impl std::fmt::Debug for PipelineCtl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PipelineCtl")
            .field("inflight", &self.inflight.is_some())
            .field("launched", &self.launched)
            .field("hits", &self.hits)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_mode_parse_and_resolution() {
        assert_eq!(PipelineMode::parse("on"), Some(PipelineMode::On));
        assert_eq!(PipelineMode::parse("off"), Some(PipelineMode::Off));
        assert_eq!(PipelineMode::parse("auto"), Some(PipelineMode::Auto));
        assert_eq!(PipelineMode::parse("x"), None);
        assert_eq!(PipelineMode::Auto.name(), "auto");

        assert!(PipelineMode::On.enabled(Mode::Speculative, Backend::Hlo));
        assert!(PipelineMode::On.enabled(Mode::Speculative, Backend::Native));
        assert!(!PipelineMode::On.enabled(Mode::Autoregressive, Backend::Native));
        assert!(!PipelineMode::Off.enabled(Mode::Speculative, Backend::Native));
        assert!(PipelineMode::Auto.enabled(Mode::Speculative, Backend::Native));
        assert!(!PipelineMode::Auto.enabled(Mode::Speculative, Backend::Hlo));
    }

    #[test]
    fn step_buffers_sized_for_block_shape() {
        let b = StepBuffers::new(2, 8, 3, 16);
        assert_eq!(b.tokens.len(), 16);
        assert_eq!(b.zq.len(), 2 * 3 * 16);
        assert_eq!(b.zp.len(), 2 * 4 * 16);
        assert_eq!(b.draft.len(), 6);
    }

    #[test]
    fn ctl_spare_ping_pongs_and_reallocates_when_lost() {
        let mut ctl = PipelineCtl::new();
        let a = ctl.take_spare(1, 8, 2, 4);
        let ptr = a.tokens.as_ptr();
        ctl.park(a);
        let b = ctl.take_spare(1, 8, 2, 4);
        assert_eq!(b.tokens.as_ptr(), ptr, "parked generation is reused");
        // not parked back: the next take allocates fresh
        drop(b);
        let c = ctl.take_spare(1, 8, 2, 4);
        assert_eq!(c.tokens.len(), 8);
    }

    #[test]
    fn resolve_without_inflight_is_none() {
        let mut ctl = PipelineCtl::new();
        assert!(ctl.resolve(0).is_none());
        ctl.note_outcome(true); // no-op without an in-flight prefetch
        assert!(!ctl.has_inflight());
        assert!(ctl.lane_free(), "nothing draining on a fresh ctl");
    }
}
