//! The continuous-batching speculative decode loop.
//!
//! One decode step over B slots (inactive slots padded, masked by
//! `lens`):
//!
//! 1. **draft**: γ sequential `draft_step` calls — each samples one token
//!    for every slot and returns the raw draft logits (collected into
//!    z_q);
//! 2. **score**: one `target_score` call returning the target logits at
//!    the last `GMAX+1` positions; the engine slices the (γ+1) rows the
//!    verification needs;
//! 3. **verify**: one fused verification call per decode step — the HLO
//!    artifact, or the native segment-parallel kernel layer
//!    ([`crate::sampling::kernels`]) — producing per-slot accepted
//!    lengths and emitted tokens. Verification is slot-parallel with
//!    **per-slot method dispatch**: each row is verified under its own
//!    [`crate::sampling::Method`] (the engine default or a per-request
//!    override, on any batch size);
//! 4. **commit**: slot state update, finish detection (EOS, stop
//!    sequences, length, context), refill from the admission queue,
//!    adaptive-γ update (+2 on all-accept / −1).
//!
//! Per-request policy lives in [`SamplingParams`] and is honored
//! per-slot: target/draft temperatures, top-k/top-p truncation of the
//! target distribution (logit masking shared with the sampling oracle),
//! stop sequences at commit, γ caps/pins, and verification-method
//! overrides (a heterogeneous batch resolves γ to the values common to
//! every method's artifact set). Committed tokens are additionally
//! surfaced through [`Engine::take_deltas`] so the server can stream
//! incremental output, and [`Engine::cancel`] frees a slot mid-decode.
//!
//! The heavy per-step allocations are gone at steady state: model
//! inputs are borrowed from preallocated step buffers as
//! [`crate::runtime::TensorView`]s (no per-step logit/token clones),
//! model *outputs* are staged into engine-owned reusable buffers via
//! [`crate::runtime::LoadedExecutable::run_views_into`] (no per-step
//! `to_vec` of the draft/score logits), and the verification path
//! writes into the engine-owned reusable [`VerifyOutput`] / kernel
//! workspace, whose persistent worker pool also removes the per-step
//! thread spawns. (Small bookkeeping allocations remain — the
//! γ-availability set built per step, streaming deltas — all O(batch),
//! none proportional to γ·V.)
//!
//! Every uniform consumed anywhere in the stack comes from per-request
//! PCG32 streams, so generation is deterministic given request seeds.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{HostTensor, LoadedExecutable, Runtime, TensorView};
use crate::sampling::{self, Method};
use crate::tokenizer;
use crate::util::rng::Pcg32;

use super::gamma::GammaController;
use super::request::{
    match_stop_suffix, FinishReason, GenRequest, GenResult, SamplingParams,
};
use super::stats::EngineStats;
use super::verifier::{Backend, Verifier, VerifyInputs, VerifyOutput};

/// Decoding mode: the speculative pipeline or plain target-only
/// autoregression (the non-speculative reference used by the serve demo).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Speculative,
    Autoregressive,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// model pair from the manifest ("base" / "large")
    pub pair: String,
    /// slot count; must match an artifact batch size
    pub batch: usize,
    pub method: Method,
    pub backend: Backend,
    pub mode: Mode,
    pub gamma_init: usize,
    /// pin γ (disables the adaptive controller) — used by the sweeps
    pub gamma_pinned: bool,
    /// self-speculative drafting (§A.7): draft with the first half of the
    /// *target* model's layers instead of the separate draft network
    pub self_draft: bool,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            pair: "base".into(),
            batch: 1,
            method: Method::Exact,
            backend: Backend::Hlo,
            mode: Mode::Speculative,
            gamma_init: 5,
            gamma_pinned: false,
            self_draft: false,
            seed: 0,
        }
    }
}

/// Per-slot decoding state.
struct Slot {
    req: GenRequest,
    /// token buffer of length S (prompt + generated + in-flight drafts)
    tokens: Vec<i32>,
    /// valid committed length (prompt + generated)
    len: usize,
    generated: Vec<i32>,
    rng: Pcg32,
    steps: usize,
    drafted: usize,
    accepted: usize,
    started: Instant,
}

impl Slot {
    fn headroom(&self, s: usize) -> usize {
        s.saturating_sub(self.len)
    }
}

/// The speculative-decoding serving engine.
pub struct Engine {
    pub runtime: Arc<Runtime>,
    pub config: EngineConfig,
    pub stats: EngineStats,
    verifier: Verifier,
    gamma: GammaController,
    draft_step: Arc<LoadedExecutable>,
    target_step: Arc<LoadedExecutable>,
    target_score: Arc<LoadedExecutable>,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<GenRequest>,
    results: Vec<GenResult>,
    /// tokens committed since the last [`Engine::take_deltas`] call
    deltas: Vec<(u64, Vec<i32>)>,
    // model dims
    seq_len: usize,
    vocab: usize,
    gmax: usize,
    // preallocated step buffers (hot path, no per-step allocation)
    tokens_buf: Vec<i32>,
    lens_buf: Vec<i32>,
    u_buf: Vec<f32>,
    temp_buf: Vec<f32>,
    zq_buf: Vec<f32>,
    zp_buf: Vec<f32>,
    draft_buf: Vec<i32>,
    uacc_buf: Vec<f32>,
    ures_buf: Vec<f32>,
    ubonus_buf: Vec<f32>,
    /// per-slot verification method for the current step (engine default
    /// unless the slot's request carries an override)
    methods_buf: Vec<Method>,
    /// reusable verification output buffers (accept lengths + emitted
    /// tokens), filled in place by the verifier each step
    verify_out: VerifyOutput,
    /// reusable model-output staging buffers, refilled in place by
    /// [`crate::runtime::LoadedExecutable::run_views_into`] — the
    /// workspace pattern extended to the draft/score model calls, so
    /// their per-step output `to_vec`s are gone too
    draft_out: Vec<HostTensor>,
    target_out: Vec<HostTensor>,
}

impl Engine {
    pub fn new(runtime: Arc<Runtime>, config: EngineConfig) -> Result<Self> {
        let m = &runtime.manifest;
        let (seq_len, vocab, gmax) = (m.seq_len, m.vocab_size, m.gmax);
        if !m.model_batches(&config.pair).contains(&config.batch) {
            bail!(
                "no artifacts for pair {:?} at batch {} (available: {:?})",
                config.pair,
                config.batch,
                m.model_batches(&config.pair)
            );
        }
        let draft_kind = if config.self_draft {
            "draft_self_step"
        } else {
            "draft_step"
        };
        let draft_step = runtime.load_model(draft_kind, &config.pair, config.batch)?;
        let target_step = runtime.load_model("target_step", &config.pair, config.batch)?;
        let target_score = runtime.load_model("target_score", &config.pair, config.batch)?;
        let verifier = Verifier::new(
            runtime.clone(),
            config.method,
            config.backend,
            config.batch,
            vocab,
        );
        let avail = verifier.available_gammas();
        if avail.is_empty() && config.mode == Mode::Speculative {
            bail!(
                "no verify artifacts for method {:?} b={} v={}",
                config.method.name(),
                config.batch,
                vocab
            );
        }
        let max_gamma = avail.iter().copied().max().unwrap_or(1).min(gmax);
        let gamma = if config.gamma_pinned {
            GammaController::pinned(config.gamma_init.min(max_gamma))
        } else {
            GammaController::new(config.gamma_init, 1, max_gamma)
        };
        let b = config.batch;
        Ok(Engine {
            verifier,
            gamma,
            draft_step,
            target_step,
            target_score,
            slots: (0..b).map(|_| None).collect(),
            queue: VecDeque::new(),
            results: Vec::new(),
            deltas: Vec::new(),
            stats: EngineStats::default(),
            seq_len,
            vocab,
            gmax,
            tokens_buf: vec![0; b * seq_len],
            lens_buf: vec![1; b],
            u_buf: vec![0.0; b],
            temp_buf: vec![0.0; b],
            zq_buf: vec![0.0; b * gmax * vocab],
            zp_buf: vec![0.0; b * (gmax + 1) * vocab],
            draft_buf: vec![0; b * gmax],
            uacc_buf: vec![0.0; b * gmax],
            ures_buf: vec![0.0; b],
            ubonus_buf: vec![0.0; b],
            methods_buf: vec![config.method; b],
            verify_out: VerifyOutput::default(),
            draft_out: Vec::new(),
            target_out: Vec::new(),
            runtime,
            config,
        })
    }

    /// Enqueue a request (admitted into a slot on the next step).
    ///
    /// In-process callers are trusted: over-long prompts are truncated at
    /// admission. Wire-facing layers should check [`Engine::admissible`]
    /// first and reject instead.
    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    /// Validate a request against the params rules and the loaded model
    /// (the wire-facing admission check).
    pub fn admissible(&self, req: &GenRequest) -> Result<(), String> {
        req.params.validate()?;
        if req.prompt_ids.len() > self.seq_len {
            return Err(format!(
                "prompt is {} tokens but model context is {}",
                req.prompt_ids.len(),
                self.seq_len
            ));
        }
        if self.config.mode == Mode::Autoregressive
            && (req.params.top_k != 0 || req.params.top_p < 1.0)
        {
            // the autoregressive path samples inside the target_step
            // artifact, where the filter cannot be applied — reject
            // rather than silently ignore the knobs
            return Err(
                "top_k/top_p filtering requires the speculative pipeline".into()
            );
        }
        if let Some(m) = req.params.method {
            if self.config.mode == Mode::Speculative {
                // per-slot dispatch serves overrides on any batch size;
                // the requirements are artifact availability and — since
                // a batched step runs one γ for every slot — at least
                // one γ shared with the engine method AND every method
                // already admitted (active slots + queue). Admitting a
                // request that zeroes the intersection would make a
                // later batch unrunnable and fail *other* clients'
                // requests, so it is rejected here instead.
                let avail = self.verifier.available_gammas_for(m);
                if avail.is_empty() {
                    return Err(format!(
                        "no verify artifacts for method {:?}",
                        m.name()
                    ));
                }
                let mut in_play: Vec<Method> = vec![self.config.method];
                for s in self.slots.iter().flatten() {
                    in_play.push(s.req.params.method.unwrap_or(self.config.method));
                }
                for r in &self.queue {
                    in_play.push(r.params.method.unwrap_or(self.config.method));
                }
                let common = self.verifier.available_gammas_common(&in_play);
                if !common.iter().any(|g| avail.contains(g)) {
                    return Err(format!(
                        "method {:?} shares no verify artifact gamma with \
                         the engine method and currently admitted requests",
                        m.name()
                    ));
                }
            }
        }
        if let Some(g) = req.params.gamma {
            if g > self.gmax {
                return Err(format!("gamma {} exceeds model gmax {}", g, self.gmax));
            }
            if self.config.mode == Mode::Speculative {
                let m = req.params.method.unwrap_or(self.config.method);
                if !self
                    .verifier
                    .available_gammas_for(m)
                    .iter()
                    .any(|&x| x <= g)
                {
                    return Err(format!(
                        "no verify artifact with gamma <= {g} for method {:?}",
                        m.name()
                    ));
                }
            }
        }
        Ok(())
    }

    /// Cancel a request by id: drop it from the queue, or free its slot
    /// mid-decode. Emits a [`GenResult`] with [`FinishReason::Cancelled`]
    /// carrying whatever was generated so far. Returns false when the id
    /// is unknown (never submitted, or already finished).
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            let _ = self.queue.remove(pos);
            self.results.push(GenResult {
                id,
                token_ids: Vec::new(),
                finish: FinishReason::Cancelled,
                steps: 0,
                drafted: 0,
                accepted: 0,
                latency: 0.0,
            });
            self.stats.finished += 1;
            return true;
        }
        for slot in self.slots.iter_mut() {
            if slot.as_ref().is_some_and(|s| s.req.id == id) {
                let s = slot.take().unwrap();
                self.results.push(GenResult {
                    id,
                    token_ids: s.generated,
                    finish: FinishReason::Cancelled,
                    steps: s.steps,
                    drafted: s.drafted,
                    accepted: s.accepted,
                    latency: s.started.elapsed().as_secs_f64(),
                });
                self.stats.finished += 1;
                return true;
            }
        }
        false
    }

    /// Requests currently being decoded.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn gamma(&self) -> usize {
        self.gamma.gamma()
    }

    /// Submit-all + run-to-completion convenience.
    pub fn generate(&mut self, reqs: Vec<GenRequest>) -> Result<Vec<GenResult>> {
        for r in reqs {
            self.submit(r);
        }
        self.run_until_done()?;
        Ok(self.take_results())
    }

    pub fn run_until_done(&mut self) -> Result<()> {
        self.admit();
        while self.active() > 0 {
            self.step()?;
            // batch path: nobody streams, don't let deltas accumulate
            self.deltas.clear();
        }
        Ok(())
    }

    pub fn take_results(&mut self) -> Vec<GenResult> {
        let mut out = std::mem::take(&mut self.results);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Tokens committed since the last call, in commit order:
    /// `(request id, newly committed token ids)`. Streaming note: a stop
    /// sequence that spans a step boundary may retract up to its length
    /// from previously delivered deltas — the final [`GenResult`] (and
    /// the wire `done` event) is authoritative.
    pub fn take_deltas(&mut self) -> Vec<(u64, Vec<i32>)> {
        std::mem::take(&mut self.deltas)
    }

    fn admit(&mut self) {
        for slot in self.slots.iter_mut() {
            if slot.is_none() {
                if let Some(req) = self.queue.pop_front() {
                    let mut tokens = vec![tokenizer::PAD; self.seq_len];
                    let prompt: Vec<i32> = if req.prompt_ids.is_empty() {
                        vec![tokenizer::BOS]
                    } else {
                        let keep = req.prompt_ids.len().min(self.seq_len / 2);
                        req.prompt_ids[req.prompt_ids.len() - keep..].to_vec()
                    };
                    tokens[..prompt.len()].copy_from_slice(&prompt);
                    let len = prompt.len();
                    let seed = req.params.seed_or(req.id);
                    let rng = Pcg32::derive(self.config.seed ^ seed, req.id);
                    *slot = Some(Slot {
                        req,
                        tokens,
                        len,
                        generated: Vec::new(),
                        rng,
                        steps: 0,
                        drafted: 0,
                        accepted: 0,
                        started: Instant::now(),
                    });
                }
            }
        }
    }

    /// Speculative-mode clamp: rejection sampling needs q to be the real
    /// proposal distribution, so fully-greedy temps are nudged positive.
    fn effective_temp(t: f32) -> f32 {
        t.max(0.05)
    }

    /// Fill the per-slot verification methods for this step: the engine
    /// default unless the slot's request carries an override. Inactive
    /// slots pad with the first *active* slot's method (their rows are
    /// masked at commit, so any method is semantically fine) — padding
    /// with an in-use method keeps a fully-overridden batch down to one
    /// HLO artifact dispatch and keeps the γ intersection from being
    /// constrained by a method nobody is using.
    fn fill_methods(&mut self) {
        let pad = self
            .slots
            .iter()
            .flatten()
            .next()
            .map(|s| s.req.params.method.unwrap_or(self.config.method))
            .unwrap_or(self.config.method);
        for i in 0..self.config.batch {
            self.methods_buf[i] = match &self.slots[i] {
                Some(s) => s.req.params.method.unwrap_or(self.config.method),
                None => pad,
            };
        }
    }

    /// γ wanted this step: the adaptive controller clamped by slot
    /// headroom, then by per-request overrides — pinned slots bypass the
    /// controller, plain overrides cap it; a heterogeneous batch resolves
    /// to the most conservative value since γ is one per batched step.
    /// The result is then snapped down to artifact availability — for a
    /// heterogeneous batch, to the γ set common to every active slot's
    /// verification method, so a γ pin can be served below its pinned
    /// value when it shares the batch with method overrides (admission
    /// guarantees an artifact with γ ≤ the override exists; trusted
    /// in-process callers fall back to the smallest artifact).
    fn step_gamma_want(&self, min_headroom: usize) -> usize {
        let mut cap: Option<usize> = None;
        let mut pinned: Option<usize> = None;
        for sl in self.slots.iter().flatten() {
            if let Some(g) = sl.req.params.gamma {
                if sl.req.params.gamma_pinned {
                    pinned = Some(pinned.map_or(g, |p| p.min(g)));
                } else {
                    cap = Some(cap.map_or(g, |c| c.min(g)));
                }
            }
        }
        // a pin replaces the controller value, not the other slots' caps
        let mut want = match pinned {
            Some(g) => g,
            None => self.gamma.effective(min_headroom),
        };
        if let Some(c) = cap {
            want = want.min(c);
        }
        want.min(min_headroom.saturating_sub(1)).max(1)
    }

    /// Execute one decode step across all active slots.
    pub fn step(&mut self) -> Result<()> {
        self.admit();
        if self.active() == 0 {
            return Ok(());
        }
        let step_started = Instant::now();
        match self.config.mode {
            Mode::Speculative => self.step_speculative(step_started),
            Mode::Autoregressive => self.step_autoregressive(step_started),
        }
    }

    fn fill_model_inputs(&mut self, extra: usize) {
        let (b, s) = (self.config.batch, self.seq_len);
        for i in 0..b {
            match &self.slots[i] {
                Some(slot) => {
                    self.tokens_buf[i * s..(i + 1) * s].copy_from_slice(&slot.tokens);
                    self.lens_buf[i] = (slot.len + extra) as i32;
                }
                None => {
                    self.tokens_buf[i * s..(i + 1) * s].fill(tokenizer::PAD);
                    self.lens_buf[i] = 1;
                }
            }
        }
    }

    fn step_speculative(&mut self, step_started: Instant) -> Result<()> {
        let (b, s, v) = (self.config.batch, self.seq_len, self.vocab);

        // γ for this step: controller value clamped by slot headroom and
        // per-request overrides, snapped to artifact availability.
        let min_headroom = self
            .slots
            .iter()
            .flatten()
            .map(|sl| sl.headroom(s))
            .min()
            .unwrap_or(2);
        let want = self.step_gamma_want(min_headroom);
        self.fill_methods();
        // a batched step runs one γ across all slots, so a heterogeneous
        // batch snaps to the γ values every slot's method can serve.
        // Admission checks each override pairwise against the engine
        // method, so the intersection can only go empty when two
        // *different* overrides have disjoint artifact γ sets — fail the
        // step with a real message rather than limping into a γ no
        // method can load.
        let avail = self.verifier.available_gammas_common(&self.methods_buf);
        if avail.is_empty() {
            bail!(
                "active requests' verification methods share no verify \
                 artifact gamma (methods in play: {:?})",
                self.methods_buf.iter().map(|m| m.name()).collect::<Vec<_>>()
            );
        }
        let gamma = avail
            .iter()
            .copied()
            .filter(|&g| g <= want)
            .max()
            .unwrap_or_else(|| avail.first().copied().unwrap_or(1));

        // model input shapes (inputs are borrowed views over the
        // preallocated step buffers — no per-step clones)
        let shape_bs = [b, s];
        let shape_b = [b];

        // --- 1. draft phase: γ sequential draft_step calls
        {
            let prof = self.runtime.profiler.clone();
            let _g = prof.scope("step/draft");
            for c in 0..gamma {
                self.fill_model_inputs(c);
                for i in 0..b {
                    let (u, t) = match &mut self.slots[i] {
                        Some(slot) => (
                            slot.rng.uniform_f32(),
                            Self::effective_temp(slot.req.params.draft_temp()),
                        ),
                        None => (0.0, 1.0),
                    };
                    self.u_buf[i] = u;
                    self.temp_buf[i] = t;
                }
                self.draft_step.run_views_into(
                    &[
                        TensorView::i32(&shape_bs, &self.tokens_buf),
                        TensorView::i32(&shape_b, &self.lens_buf),
                        TensorView::f32(&shape_b, &self.u_buf),
                        TensorView::f32(&shape_b, &self.temp_buf),
                    ],
                    &mut self.draft_out,
                )?;
                let toks = self.draft_out[0].as_i32()?;
                let logits = self.draft_out[1].as_f32()?;
                for i in 0..b {
                    if let Some(slot) = &mut self.slots[i] {
                        slot.tokens[slot.len + c] = toks[i];
                        self.draft_buf[i * gamma + c] = toks[i];
                    }
                    self.zq_buf[(i * gamma + c) * v..(i * gamma + c + 1) * v]
                        .copy_from_slice(&logits[i * v..(i + 1) * v]);
                }
            }
        }

        // --- 2. target scoring: one call, slice the last γ+1 positions
        {
            let prof = self.runtime.profiler.clone();
            let _g = prof.scope("step/score");
            self.fill_model_inputs(gamma);
            self.target_score.run_views_into(
                &[
                    TensorView::i32(&shape_bs, &self.tokens_buf),
                    TensorView::i32(&shape_b, &self.lens_buf),
                ],
                &mut self.target_out,
            )?;
            let win = self.target_out[0].as_f32()?; // (B, GMAX+1, V)
            let w = self.gmax + 1;
            for i in 0..b {
                for j in 0..=gamma {
                    let src = (i * w + (w - (gamma + 1) + j)) * v;
                    let dst = (i * (gamma + 1) + j) * v;
                    self.zp_buf[dst..dst + v].copy_from_slice(&win[src..src + v]);
                }
            }
        }

        // --- temperature scaling (verification distributions must match
        // the sampling temperature; see effective_temp)
        for i in 0..b {
            let t = match &self.slots[i] {
                Some(slot) => Self::effective_temp(slot.req.params.temperature),
                None => 1.0,
            };
            if (t - 1.0).abs() > 1e-6 {
                let inv = 1.0 / t;
                for x in &mut self.zp_buf[i * (gamma + 1) * v..(i + 1) * (gamma + 1) * v] {
                    *x *= inv;
                }
                for x in &mut self.zq_buf[i * gamma * v..(i + 1) * gamma * v] {
                    *x *= inv;
                }
            }
        }

        // --- per-request top-k/top-p truncation of the target
        // distribution (q is left untouched: it must remain the true
        // proposal the drafts were sampled from; rejection sampling then
        // yields the truncated target regardless of q's support)
        for i in 0..b {
            let (k, p) = match &self.slots[i] {
                Some(slot) => (slot.req.params.top_k, slot.req.params.top_p),
                None => (0, 1.0),
            };
            if k == 0 && p >= 1.0 {
                continue;
            }
            for j in 0..=gamma {
                let off = (i * (gamma + 1) + j) * v;
                sampling::filter::mask_logits_top_k_top_p(
                    &mut self.zp_buf[off..off + v],
                    k,
                    p,
                );
            }
        }

        // --- 3. verification (the paper's kernel, one fused call)
        for i in 0..b {
            let (ua, ur, ub2) = match &mut self.slots[i] {
                Some(slot) => {
                    for c in 0..gamma {
                        self.uacc_buf[i * gamma + c] = slot.rng.uniform_f32();
                    }
                    (true, slot.rng.uniform_f32(), slot.rng.uniform_f32())
                }
                None => (false, 0.0, 0.0),
            };
            if !ua {
                self.uacc_buf[i * gamma..(i + 1) * gamma].fill(1.0);
            }
            self.ures_buf[i] = ur;
            self.ubonus_buf[i] = ub2;
        }
        let ins = VerifyInputs {
            z_p: &self.zp_buf[..b * (gamma + 1) * v],
            z_q: &self.zq_buf[..b * gamma * v],
            draft: &self.draft_buf[..b * gamma],
            u_acc: &self.uacc_buf[..b * gamma],
            u_res: &self.ures_buf,
            u_bonus: &self.ubonus_buf,
        };
        let verify_secs = self.verifier.verify_into(
            gamma,
            &self.methods_buf,
            &ins,
            &mut self.verify_out,
        )?;

        // --- 4. commit
        let mut all_accepted = true;
        let mut drafted_total = 0usize;
        let mut accepted_total = 0usize;
        let mut emitted_total = 0usize;
        for i in 0..b {
            let Some(slot) = &mut self.slots[i] else { continue };
            let alen = self.verify_out.accept_len[i] as usize;
            slot.steps += 1;
            slot.drafted += gamma;
            slot.accepted += alen;
            drafted_total += gamma;
            accepted_total += alen;
            if alen < gamma {
                all_accepted = false;
            }

            let row =
                &self.verify_out.out_tokens[i * (gamma + 1)..(i + 1) * (gamma + 1)];
            let gen_before = slot.generated.len();
            let mut finish: Option<FinishReason> = None;
            for &tok in row.iter().take(alen + 1) {
                debug_assert!(tok >= 0);
                slot.tokens[slot.len] = tok;
                slot.len += 1;
                slot.generated.push(tok);
                if tok == tokenizer::EOS {
                    finish = Some(FinishReason::Stop);
                    break;
                }
                if let Some(m) = match_stop_suffix(&slot.generated, &slot.req.stop_ids)
                {
                    slot.generated.truncate(slot.generated.len() - m);
                    finish = Some(FinishReason::StopSeq);
                    break;
                }
                if slot.generated.len() >= slot.req.params.max_new_tokens {
                    finish = Some(FinishReason::Length);
                    break;
                }
            }
            // newly committed tokens (a stop-sequence trim can retract
            // below gen_before when the match spans a step boundary)
            let from = gen_before.min(slot.generated.len());
            let delta: Vec<i32> = slot.generated[from..].to_vec();
            emitted_total += delta.len();
            if !delta.is_empty() {
                self.deltas.push((slot.req.id, delta));
            }
            if finish.is_none() && slot.headroom(s) < 2 {
                finish = Some(FinishReason::Context);
            }
            if let Some(reason) = finish {
                let slot = self.slots[i].take().unwrap();
                self.results.push(GenResult {
                    id: slot.req.id,
                    token_ids: slot.generated,
                    finish: reason,
                    steps: slot.steps,
                    drafted: slot.drafted,
                    accepted: slot.accepted,
                    latency: slot.started.elapsed().as_secs_f64(),
                });
                self.stats.finished += 1;
            }
        }

        self.gamma.update(all_accepted);
        self.stats.record_step(
            gamma,
            drafted_total,
            accepted_total,
            emitted_total,
            step_started.elapsed().as_secs_f64(),
            verify_secs,
        );
        self.admit();
        Ok(())
    }

    fn step_autoregressive(&mut self, step_started: Instant) -> Result<()> {
        let (b, s) = (self.config.batch, self.seq_len);
        self.fill_model_inputs(0);
        for i in 0..b {
            let (u, t) = match &mut self.slots[i] {
                Some(slot) => (slot.rng.uniform_f32(), slot.req.params.temperature),
                None => (0.0, 1.0),
            };
            self.u_buf[i] = u;
            self.temp_buf[i] = t;
        }
        let shape_bs = [b, s];
        let shape_b = [b];
        {
            let prof = self.runtime.profiler.clone();
            let _g = prof.scope("step/target_step");
            self.target_step.run_views_into(
                &[
                    TensorView::i32(&shape_bs, &self.tokens_buf),
                    TensorView::i32(&shape_b, &self.lens_buf),
                    TensorView::f32(&shape_b, &self.u_buf),
                    TensorView::f32(&shape_b, &self.temp_buf),
                ],
                &mut self.target_out,
            )?;
        }
        let toks = self.target_out[0].as_i32()?;
        let mut emitted = 0usize;
        for i in 0..b {
            let Some(slot) = &mut self.slots[i] else { continue };
            slot.steps += 1;
            slot.tokens[slot.len] = toks[i];
            slot.len += 1;
            let gen_before = slot.generated.len();
            slot.generated.push(toks[i]);
            let finish = if toks[i] == tokenizer::EOS {
                Some(FinishReason::Stop)
            } else if let Some(m) =
                match_stop_suffix(&slot.generated, &slot.req.stop_ids)
            {
                slot.generated.truncate(slot.generated.len() - m);
                Some(FinishReason::StopSeq)
            } else if slot.generated.len() >= slot.req.params.max_new_tokens {
                Some(FinishReason::Length)
            } else if slot.headroom(s) < 2 {
                Some(FinishReason::Context)
            } else {
                None
            };
            let from = gen_before.min(slot.generated.len());
            let delta: Vec<i32> = slot.generated[from..].to_vec();
            emitted += delta.len();
            if !delta.is_empty() {
                self.deltas.push((slot.req.id, delta));
            }
            if let Some(reason) = finish {
                let slot = self.slots[i].take().unwrap();
                self.results.push(GenResult {
                    id: slot.req.id,
                    token_ids: slot.generated,
                    finish: reason,
                    steps: slot.steps,
                    drafted: 0,
                    accepted: 0,
                    latency: slot.started.elapsed().as_secs_f64(),
                });
                self.stats.finished += 1;
            }
        }
        self.stats
            .record_step(0, 0, 0, emitted, step_started.elapsed().as_secs_f64(), 0.0);
        self.admit();
        Ok(())
    }

    /// Generate text end-to-end with a tokenizer (server/example helper).
    /// `params` applies to every prompt; the per-prompt `usize` overrides
    /// `max_new_tokens`.
    pub fn generate_text(
        &mut self,
        tok: &tokenizer::Tokenizer,
        prompts: &[(&str, usize)],
        params: &SamplingParams,
    ) -> Result<Vec<(String, GenResult)>> {
        let reqs: Vec<GenRequest> = prompts
            .iter()
            .enumerate()
            .map(|(i, (p, max_new))| {
                let rp = params.clone().with_max_new_tokens(*max_new);
                GenRequest::new(i as u64, tok.encode(p), rp).tokenize_stops(tok)
            })
            .collect();
        let results = self.generate(reqs)?;
        Ok(results
            .into_iter()
            .map(|r| (tok.decode_until_stop(&r.token_ids), r))
            .collect())
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("pair", &self.config.pair)
            .field("batch", &self.config.batch)
            .field("method", &self.config.method.name())
            .field("active", &self.active())
            .field("pending", &self.pending())
            .finish()
    }
}

// Engine construction/decode tests need artifacts: rust/tests/it_engine.rs.
