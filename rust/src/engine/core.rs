//! The continuous-batching speculative decode loop.
//!
//! One decode step over B slots (inactive slots padded, masked by
//! `lens`), each active slot running its **own speculation depth γᵢ**
//! (ragged batch):
//!
//! 1. **draft**: max(γᵢ) sequential `draft_step` calls — slot i
//!    participates in the first γᵢ of them, sampling one token per call
//!    and staging the raw draft logits into its ragged z_q span;
//! 2. **score**: one `target_score` call returning the target logits at
//!    the last `GMAX+1` positions; the engine slices the (γᵢ+1) rows
//!    each slot's verification needs into its ragged z_p span;
//! 3. **verify**: one fused verification call per decode step — the HLO
//!    artifact, or the native segment-parallel kernel layer
//!    ([`crate::sampling::kernels`]) — producing per-slot accepted
//!    lengths and emitted tokens. Verification is slot-parallel with
//!    **per-slot method dispatch**: each row is verified under its own
//!    [`crate::sampling::Method`] (the engine default or a per-request
//!    override, on any batch size);
//! 4. **commit**: slot state update, finish detection (EOS, stop
//!    sequences, length, context), mid-flight refill from the admission
//!    queue, per-slot adaptive-γ update (+2 on all-accept / −1).
//!
//! ## Ragged batches (per-slot γ)
//!
//! Every slot owns a [`GammaController`]; each step plans a per-slot γ
//! from that controller, the slot's context headroom, and its request's
//! γ cap/pin, snapped to the slot method's artifact set. Row addressing
//! uses the γ-prefix tables in [`StepBuffers`] (`q_off`/`p_off`):
//! slot i's draft rows live at `q_off[i]..q_off[i]+γᵢ` and its target
//! rows at `p_off[i]..p_off[i]+γᵢ+1`; inactive slots contribute zero
//! rows. The native verify path consumes the ragged spans directly; the
//! **HLO backend collapses the plan to one shared γ** before dispatch
//! (its verify programs are rectangular `(method, B, γ)` artifacts), so
//! genuinely ragged batches are native-only.
//!
//! ## The pipelined scheduler
//!
//! With [`PipelineMode`] enabled (the default on the native verify
//! backend), model dispatch of the next up-to-k steps runs
//! **concurrently** with this step's CPU verification: after step N's
//! logits are staged and its verification uniforms drawn, the engine
//! predicts step N's commit under the all-accept assumption (the γ
//! drafts plus a bonus token computed with the verifier's exact
//! arithmetic) and ships a **chain job** to a dedicated dispatcher
//! lane, which computes the model blocks of steps N+1..N+k against
//! successively deeper predictions (`--pipeline-depth`, default 2).
//! Each step's commit is a **per-slot** pipeline barrier: a slot whose
//! prediction held adopts its prefetched rows and RNG stream; a missed
//! slot is redone in a reduced serial block whose rows are spliced
//! into the adopted generation at the final γ-prefix offsets, and its
//! chain predictions are invalidated through every deeper block
//! (cascade-cancel when no slot survives). Either way the observable
//! outputs — committed tokens, streaming deltas, stats counters,
//! per-slot RNG streams — are **bit-identical** to the serial loop for
//! any seed, schedule, and depth (asserted by the `it_pipeline` parity
//! suite). The machinery lives in [`crate::engine::pipeline`].
//!
//! Per-request policy lives in [`SamplingParams`] and is honored
//! per-slot: target/draft temperatures, top-k/top-p truncation of the
//! target distribution (logit masking shared with the sampling oracle),
//! stop sequences at commit, γ caps/pins (applied to the slot's own
//! controller, not the batch), and verification-method overrides (each
//! slot's γ snaps to its own method's artifact set; only the HLO
//! backend intersects across methods). Committed tokens are additionally
//! surfaced through [`Engine::take_deltas`] so the server can stream
//! incremental output, and [`Engine::cancel`] frees a slot mid-decode.
//!
//! The heavy per-step allocations are gone at steady state: model
//! inputs are borrowed from the preallocated [`StepBuffers`] generation
//! as [`crate::runtime::TensorView`]s, model *outputs* are staged into
//! the generation's reusable buffers via
//! [`crate::runtime::LoadedExecutable::run_views_into`], and the
//! verification path writes into the engine-owned reusable
//! [`VerifyOutput`] / kernel workspace, whose persistent worker pool
//! also removes the per-step thread spawns. The pipeline adds a second
//! [`StepBuffers`] generation that ping-pongs with the first — still no
//! allocation proportional to γ·V in the loop.
//!
//! Every uniform consumed anywhere in the stack comes from per-request
//! PCG32 streams, so generation is deterministic given request seeds.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{LoadedExecutable, Runtime, TensorView};
use crate::sampling::{self, kernels, verify, Method};
use crate::tokenizer;
use crate::trace::{
    digest_f32, params_digest, AdmitEvent, NullSink, SimHeader, SlotStep, StepEvent,
    TraceEvent, TraceHeader, TraceSink, TRACE_VERSION,
};
use crate::util::rng::Pcg32;

use super::gamma::GammaController;
use super::pipeline::{
    self, run_model_block, BlockDims, BlockSlot, ChainBlock, ChainSlotInfo, PipelineCtl,
    PipelineMode, PipelineStats, StepBuffers,
};
use super::request::{
    match_stop_suffix, FinishReason, GenRequest, GenResult, SamplingParams,
};
use super::stats::EngineStats;
use super::verifier::{Backend, Verifier, VerifyInputs, VerifyOutput};

/// Decoding mode: the speculative pipeline or plain target-only
/// autoregression (the non-speculative reference used by the serve demo).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Speculative,
    Autoregressive,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// model pair from the manifest ("base" / "large" / "sim")
    pub pair: String,
    /// slot count; must match an artifact batch size
    pub batch: usize,
    pub method: Method,
    pub backend: Backend,
    pub mode: Mode,
    pub gamma_init: usize,
    /// pin γ (disables the adaptive controller) — used by the sweeps
    pub gamma_pinned: bool,
    /// self-speculative drafting (§A.7): draft with the first half of the
    /// *target* model's layers instead of the separate draft network
    pub self_draft: bool,
    /// overlap next-step model dispatch with CPU verification
    /// (`auto` = on for [`Backend::Native`] speculative decoding)
    pub pipeline: PipelineMode,
    /// speculation-window depth k: how many future steps' model blocks
    /// the chain job may run ahead of the commit barrier (clamped to
    /// 1..=8; forced to 1 on the HLO backend, whose rectangular verify
    /// programs the lane-side γ planner does not model)
    pub pipeline_depth: usize,
    /// per-slot partial-hit adoption: on a barrier miss, keep the
    /// prefetched rows of every slot whose prediction held and redo
    /// only the missed slots. `false` restores the all-or-nothing
    /// barrier (one missed slot discards the whole window)
    pub pipeline_salvage: bool,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            pair: "base".into(),
            batch: 1,
            method: Method::Exact,
            backend: Backend::Hlo,
            mode: Mode::Speculative,
            gamma_init: 5,
            gamma_pinned: false,
            self_draft: false,
            pipeline: PipelineMode::Auto,
            pipeline_depth: 2,
            pipeline_salvage: true,
            seed: 0,
        }
    }
}

/// A structured admission rejection: a stable machine-readable `code`
/// (surfaced verbatim as the wire-protocol error code by the server)
/// plus a human-readable message. Generic parameter/model-limit
/// violations carry the code `"rejected"`; conflicts that are specific
/// enough to act on get their own code (e.g.
/// `"method_gamma_conflict"`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdmitError {
    pub code: &'static str,
    pub msg: String,
}

impl AdmitError {
    fn rejected(msg: impl Into<String>) -> Self {
        AdmitError {
            code: "rejected",
            msg: msg.into(),
        }
    }
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for AdmitError {}

/// Per-slot decoding state.
struct Slot {
    req: GenRequest,
    /// token buffer of length S (prompt + generated)
    tokens: Vec<i32>,
    /// valid committed length (prompt + generated)
    len: usize,
    generated: Vec<i32>,
    rng: Pcg32,
    /// this slot's adaptive speculation-depth controller (pinned when
    /// the request or the engine config pins γ)
    gamma: GammaController,
    steps: usize,
    drafted: usize,
    accepted: usize,
    started: Instant,
}

impl Slot {
    fn headroom(&self, s: usize) -> usize {
        s.saturating_sub(self.len)
    }
}

/// The speculative-decoding serving engine.
pub struct Engine {
    pub runtime: Arc<Runtime>,
    pub config: EngineConfig,
    pub stats: EngineStats,
    verifier: Verifier,
    draft_step: Arc<LoadedExecutable>,
    target_step: Arc<LoadedExecutable>,
    target_score: Arc<LoadedExecutable>,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<GenRequest>,
    results: Vec<GenResult>,
    /// tokens committed since the last [`Engine::take_deltas`] call
    deltas: Vec<(u64, Vec<i32>)>,
    // model dims
    seq_len: usize,
    vocab: usize,
    gmax: usize,
    /// current staging generation (model inputs/outputs + logit
    /// matrices); the pipeline ping-pongs a second generation through
    /// the dispatcher lane
    bufs: StepBuffers,
    /// per-slot block views for the serial dispatch path (reused)
    block_slots: Vec<BlockSlot>,
    /// per-slot γ planned for the current step (0 = inactive slot);
    /// the authoritative ragged shape every phase of the step shares
    gammas_buf: Vec<usize>,
    /// per-slot γ planned for the *next* step by the prefetch path
    /// (scratch, same encoding)
    gnext_buf: Vec<usize>,
    // verification uniforms (drawn on the engine thread each step)
    uacc_buf: Vec<f32>,
    ures_buf: Vec<f32>,
    ubonus_buf: Vec<f32>,
    /// per-slot verification method for the current step (engine default
    /// unless the slot's request carries an override)
    methods_buf: Vec<Method>,
    /// reusable verification output buffers (accept lengths + emitted
    /// tokens), filled in place by the verifier each step
    verify_out: VerifyOutput,
    /// pipelined-scheduler state; `None` = strict serial loop
    pipeline: Option<PipelineCtl>,
    /// scratch: per-slot barrier verdicts for the pending chain
    /// prediction of this step
    verdict_buf: Vec<bool>,
    /// scratch: per-slot salvage decisions when consuming a prefetched
    /// chain block
    salv_buf: Vec<bool>,
    /// scratch: the reduced redo block's packed γ-prefix offsets, saved
    /// before the final ragged layout is rebuilt for splicing
    redo_q: Vec<usize>,
    redo_p: Vec<usize>,
    /// scratch row for the bonus-token prediction (V elements)
    bonus_row: Vec<f32>,
    /// scratch tail for predicted stop-sequence matching
    stop_scratch: Vec<i32>,
    /// trace capture hook ([`NullSink`] unless a recorder is attached
    /// via [`Engine::set_trace`]) — disabled cost is one branch per
    /// recording site
    trace: Arc<dyn TraceSink>,
}

impl Engine {
    pub fn new(runtime: Arc<Runtime>, config: EngineConfig) -> Result<Self> {
        let m = &runtime.manifest;
        let (seq_len, vocab, gmax) = (m.seq_len, m.vocab_size, m.gmax);
        if !m.model_batches(&config.pair).contains(&config.batch) {
            bail!(
                "no artifacts for pair {:?} at batch {} (available: {:?})",
                config.pair,
                config.batch,
                m.model_batches(&config.pair)
            );
        }
        let draft_kind = if config.self_draft {
            "draft_self_step"
        } else {
            "draft_step"
        };
        let draft_step = runtime.load_model(draft_kind, &config.pair, config.batch)?;
        let target_step = runtime.load_model("target_step", &config.pair, config.batch)?;
        let target_score = runtime.load_model("target_score", &config.pair, config.batch)?;
        let verifier = Verifier::new(
            runtime.clone(),
            config.method,
            config.backend,
            config.batch,
            vocab,
        );
        let avail = verifier.available_gammas();
        if avail.is_empty() && config.mode == Mode::Speculative {
            bail!(
                "no verify artifacts for method {:?} b={} v={}",
                config.method.name(),
                config.batch,
                vocab
            );
        }
        let b = config.batch;
        // effective speculation-window depth: the HLO backend's
        // rectangular verify programs are not modelled by the lane-side
        // γ planner, so the chain never runs deeper than one block there
        let depth = if config.backend == Backend::Hlo {
            1
        } else {
            config.pipeline_depth.clamp(1, 8)
        };
        let pipeline = if config.pipeline.enabled(config.mode, config.backend) {
            Some(PipelineCtl::new(depth))
        } else {
            None
        };
        Ok(Engine {
            verifier,
            draft_step,
            target_step,
            target_score,
            slots: (0..b).map(|_| None).collect(),
            queue: VecDeque::new(),
            results: Vec::new(),
            deltas: Vec::new(),
            stats: EngineStats::default(),
            seq_len,
            vocab,
            gmax,
            bufs: StepBuffers::new(b, seq_len, gmax, vocab),
            block_slots: Vec::with_capacity(b),
            gammas_buf: vec![0; b],
            gnext_buf: vec![0; b],
            uacc_buf: vec![0.0; b * gmax],
            ures_buf: vec![0.0; b],
            ubonus_buf: vec![0.0; b],
            methods_buf: vec![config.method; b],
            verify_out: VerifyOutput::default(),
            pipeline,
            verdict_buf: Vec::with_capacity(b),
            salv_buf: Vec::with_capacity(b),
            redo_q: Vec::with_capacity(b + 1),
            redo_p: Vec::with_capacity(b + 1),
            bonus_row: vec![0.0; vocab],
            stop_scratch: Vec::new(),
            trace: Arc::new(NullSink),
            runtime,
            config,
        })
    }

    /// Attach a trace sink (e.g. a [`crate::trace::TraceRecorder`]),
    /// propagating it into the verifier and the pipelined scheduler. A
    /// replay-checkable trace must be attached before any request is
    /// submitted — the admit events carry the initial RNG positions.
    pub fn set_trace(&mut self, sink: Arc<dyn TraceSink>) {
        self.trace = sink.clone();
        self.verifier.set_trace(sink.clone());
        if let Some(ctl) = &mut self.pipeline {
            ctl.set_trace(sink);
        }
    }

    /// Replace the verifier's kernel scheduling config (threads, chunk
    /// size, SIMD mode). A test/bench knob: every config is bit-identical
    /// by contract, and setting it explicitly avoids racing on the
    /// `SPECD_SIMD` / `SPECD_VERIFY_*` env vars from parallel tests.
    pub fn set_kernel_config(&mut self, cfg: kernels::KernelConfig) {
        self.verifier.set_kernel_config(cfg);
    }

    /// The trace header describing this engine's exact configuration —
    /// what a [`crate::trace::TraceRecorder`] is constructed with.
    pub fn trace_header(&self) -> TraceHeader {
        TraceHeader {
            version: TRACE_VERSION,
            pair: self.config.pair.clone(),
            batch: self.config.batch as u32,
            seq_len: self.seq_len as u32,
            vocab: self.vocab as u32,
            gmax: self.gmax as u32,
            engine_seed: self.config.seed,
            method: self.config.method,
            backend: match self.config.backend {
                Backend::Hlo => "hlo",
                Backend::Native => "native",
            }
            .into(),
            mode: match self.config.mode {
                Mode::Speculative => "speculative",
                Mode::Autoregressive => "autoregressive",
            }
            .into(),
            pipeline: self.config.pipeline.name().into(),
            pipeline_depth: self.pipeline.as_ref().map_or(1, |ctl| ctl.depth() as u32),
            gamma_init: self.config.gamma_init as u32,
            gamma_pinned: self.config.gamma_pinned,
            self_draft: self.config.self_draft,
            sim: self.runtime.sim_spec().map(|s| SimHeader {
                seed: s.seed,
                agreement: s.agreement,
            }),
        }
    }

    /// Enqueue a request (admitted into a slot on the next step).
    ///
    /// In-process callers are trusted: over-long prompts are truncated at
    /// admission. Wire-facing layers should check [`Engine::admissible`]
    /// first and reject instead.
    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    /// Validate a request against the params rules and the loaded model
    /// (the wire-facing admission check). Errors are structured
    /// [`AdmitError`]s: the server forwards the code on the wire.
    pub fn admissible(&self, req: &GenRequest) -> Result<(), AdmitError> {
        req.params.validate().map_err(AdmitError::rejected)?;
        if req.prompt_ids.len() > self.seq_len {
            return Err(AdmitError::rejected(format!(
                "prompt is {} tokens but model context is {}",
                req.prompt_ids.len(),
                self.seq_len
            )));
        }
        if self.config.mode == Mode::Autoregressive
            && (req.params.top_k != 0 || req.params.top_p < 1.0)
        {
            // the autoregressive path samples inside the target_step
            // artifact, where the filter cannot be applied — reject
            // rather than silently ignore the knobs
            return Err(AdmitError::rejected(
                "top_k/top_p filtering requires the speculative pipeline",
            ));
        }
        if let Some(m) = req.params.method {
            if self.config.mode == Mode::Speculative {
                let avail = self.verifier.available_gammas_for(m);
                if avail.is_empty() {
                    return Err(AdmitError::rejected(format!(
                        "no verify artifacts for method {:?}",
                        m.name()
                    )));
                }
                // The native backend runs each slot's γ under its own
                // method — mixed-method batches need no shared γ. Only
                // the HLO backend (rectangular verify programs, one γ
                // per dispatch) must keep a non-empty γ intersection
                // across every method in play (active slots + queue):
                // admitting a request that zeroes it would make a later
                // batch unrunnable and fail *other* clients' requests.
                if self.config.backend == Backend::Hlo {
                    let mut in_play: Vec<Method> = vec![self.config.method];
                    for s in self.slots.iter().flatten() {
                        in_play.push(s.req.params.method.unwrap_or(self.config.method));
                    }
                    for r in &self.queue {
                        in_play.push(r.params.method.unwrap_or(self.config.method));
                    }
                    let common = self.verifier.available_gammas_common(&in_play);
                    if !common.iter().any(|g| avail.contains(g)) {
                        return Err(AdmitError {
                            code: "method_gamma_conflict",
                            msg: format!(
                                "method {:?} (artifact gamma set {:?}) shares no \
                                 verify artifact gamma with the engine method and \
                                 currently admitted requests (common gamma set {:?})",
                                m.name(),
                                avail,
                                common
                            ),
                        });
                    }
                }
            }
        }
        if let Some(g) = req.params.gamma {
            if g > self.gmax {
                return Err(AdmitError::rejected(format!(
                    "gamma {} exceeds model gmax {}",
                    g, self.gmax
                )));
            }
            if self.config.mode == Mode::Speculative {
                let m = req.params.method.unwrap_or(self.config.method);
                if !self
                    .verifier
                    .available_gammas_for(m)
                    .iter()
                    .any(|&x| x <= g)
                {
                    return Err(AdmitError::rejected(format!(
                        "no verify artifact with gamma <= {g} for method {:?}",
                        m.name()
                    )));
                }
            }
        }
        Ok(())
    }

    /// Cancel a request by id: drop it from the queue, or free its slot
    /// mid-decode. Emits a [`GenResult`] with [`FinishReason::Cancelled`]
    /// carrying whatever was generated so far. Returns false when the id
    /// is unknown (never submitted, or already finished).
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            let _ = self.queue.remove(pos);
            self.results.push(GenResult {
                id,
                token_ids: Vec::new(),
                finish: FinishReason::Cancelled,
                steps: 0,
                drafted: 0,
                accepted: 0,
                latency: 0.0,
            });
            self.stats.finished += 1;
            if self.trace.enabled() {
                self.trace.record(TraceEvent::Cancel { id, slot: None });
            }
            return true;
        }
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if slot.as_ref().is_some_and(|s| s.req.id == id) {
                let s = slot.take().unwrap();
                self.results.push(GenResult {
                    id,
                    token_ids: s.generated,
                    finish: FinishReason::Cancelled,
                    steps: s.steps,
                    drafted: s.drafted,
                    accepted: s.accepted,
                    latency: s.started.elapsed().as_secs_f64(),
                });
                self.stats.finished += 1;
                // the slot's chain predictions were built against the
                // cancelled request — invalidate them through every
                // in-flight generation (cascade-cancels when it was the
                // last valid slot)
                if let Some(ctl) = &mut self.pipeline {
                    ctl.invalidate_slot(i);
                }
                if self.trace.enabled() {
                    self.trace.record(TraceEvent::Cancel {
                        id,
                        slot: Some(i as u32),
                    });
                }
                return true;
            }
        }
        false
    }

    /// Requests currently being decoded.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Batch slots not yet claimed by an active or engine-queued
    /// request. The serve layer submits from its bounded admission
    /// queue only while this is nonzero, so a freed slot is refilled
    /// on the very next loop pass (mid-flight refill) and the engine's
    /// own queue never grows beyond the batch.
    pub fn free_slots(&self) -> usize {
        self.slots
            .len()
            .saturating_sub(self.active() + self.queue.len())
    }

    /// Per-slot γ controller values (0 = free slot) — observability
    /// only; the per-step plan additionally clamps by headroom/caps.
    pub fn slot_gammas(&self) -> Vec<usize> {
        self.slots
            .iter()
            .map(|s| s.as_ref().map_or(0, |sl| sl.gamma.gamma()))
            .collect()
    }

    /// Pipelined-scheduler counters (chains launched, blocks consumed,
    /// full/partial barrier hits, per-slot salvage totals, per-depth
    /// breakdown); `None` when the pipeline is disabled.
    pub fn pipeline_stats(&self) -> Option<PipelineStats> {
        self.pipeline.as_ref().map(|ctl| ctl.stats.clone())
    }

    /// Submit-all + run-to-completion convenience.
    pub fn generate(&mut self, reqs: Vec<GenRequest>) -> Result<Vec<GenResult>> {
        for r in reqs {
            self.submit(r);
        }
        self.run_until_done()?;
        Ok(self.take_results())
    }

    pub fn run_until_done(&mut self) -> Result<()> {
        self.admit();
        while self.active() > 0 {
            self.step()?;
            // batch path: nobody streams, don't let deltas accumulate
            self.deltas.clear();
        }
        Ok(())
    }

    pub fn take_results(&mut self) -> Vec<GenResult> {
        let mut out = std::mem::take(&mut self.results);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Tokens committed since the last call, in commit order:
    /// `(request id, newly committed token ids)`. Streaming note: a stop
    /// sequence that spans a step boundary may retract up to its length
    /// from previously delivered deltas — the final [`GenResult`] (and
    /// the wire `done` event) is authoritative.
    pub fn take_deltas(&mut self) -> Vec<(u64, Vec<i32>)> {
        std::mem::take(&mut self.deltas)
    }

    /// The largest γ a slot running `method` can verify, clamped to the
    /// model's GMAX — the upper bound of that slot's controller.
    fn max_gamma_for(&self, method: Method) -> usize {
        self.verifier
            .available_gammas_for(method)
            .iter()
            .copied()
            .max()
            .unwrap_or(1)
            .min(self.gmax)
            .max(1)
    }

    fn admit(&mut self) {
        for i in 0..self.config.batch {
            if self.slots[i].is_some() {
                continue;
            }
            let Some(req) = self.queue.pop_front() else { return };
            // mid-flight refill: this admission lands while other slots
            // are still decoding (recorded in the trace so the checker
            // replays ragged admission timing faithfully)
            let refill = self.slots.iter().any(Option::is_some);
            let mut tokens = vec![tokenizer::PAD; self.seq_len];
            let prompt: Vec<i32> = if req.prompt_ids.is_empty() {
                vec![tokenizer::BOS]
            } else {
                let keep = req.prompt_ids.len().min(self.seq_len / 2);
                req.prompt_ids[req.prompt_ids.len() - keep..].to_vec()
            };
            tokens[..prompt.len()].copy_from_slice(&prompt);
            let len = prompt.len();
            let seed = req.params.seed_or(req.id);
            let rng = Pcg32::derive(self.config.seed ^ seed, req.id);
            let method = req.params.method.unwrap_or(self.config.method);
            let max_g = self.max_gamma_for(method);
            let init = self.config.gamma_init.clamp(1, max_g);
            let gamma = if req.params.gamma_pinned {
                GammaController::pinned(
                    req.params.gamma.unwrap_or(init).clamp(1, max_g),
                )
            } else if self.config.gamma_pinned {
                GammaController::pinned(init)
            } else {
                GammaController::new(self.config.gamma_init, 1, max_g)
            };
            if self.trace.enabled() {
                let (rng_state, rng_inc) = rng.state();
                let p = &req.params;
                self.trace.record(TraceEvent::Admit(AdmitEvent {
                    slot: i as u32,
                    id: req.id,
                    prompt: prompt.clone(),
                    stop_ids: req.stop_ids.clone(),
                    max_new_tokens: p.max_new_tokens as u32,
                    temperature: p.temperature,
                    draft_temperature: p.draft_temperature,
                    top_k: p.top_k as u32,
                    top_p: p.top_p,
                    gamma: p.gamma.unwrap_or(0) as u32,
                    gamma_pinned: p.gamma_pinned,
                    method: p.method,
                    seed,
                    params_digest: params_digest(p),
                    rng_state,
                    rng_inc,
                    refill,
                }));
            }
            self.slots[i] = Some(Slot {
                req,
                tokens,
                len,
                generated: Vec::new(),
                rng,
                gamma,
                steps: 0,
                drafted: 0,
                accepted: 0,
                started: Instant::now(),
            });
            // note: no chain invalidation here — a prefetched chain only
            // ever covers slots that were active at launch, and request
            // ids are assumed unique per engine lifetime, so a refilled
            // slot can never alias a chain prediction (the per-slot
            // `chain_slot_ok` id check enforces it)
        }
    }

    /// Speculative-mode clamp: rejection sampling needs q to be the real
    /// proposal distribution, so fully-greedy temps are nudged positive.
    /// `pub(crate)` because the trace replay checker must apply the
    /// exact same clamp.
    pub(crate) fn effective_temp(t: f32) -> f32 {
        t.max(0.05)
    }

    /// Fill the per-slot verification methods for this step: the engine
    /// default unless the slot's request carries an override. Inactive
    /// slots pad with the first *active* slot's method (their rows are
    /// masked at commit, so any method is semantically fine) — padding
    /// with an in-use method keeps a fully-overridden batch down to one
    /// HLO artifact dispatch and keeps the γ intersection from being
    /// constrained by a method nobody is using.
    fn fill_methods(&mut self) {
        let pad = self
            .slots
            .iter()
            .flatten()
            .next()
            .map(|s| s.req.params.method.unwrap_or(self.config.method))
            .unwrap_or(self.config.method);
        for i in 0..self.config.batch {
            self.methods_buf[i] = match &self.slots[i] {
                Some(s) => s.req.params.method.unwrap_or(self.config.method),
                None => pad,
            };
        }
    }

    /// γ wanted by one slot this step: its controller value clamped by
    /// the slot's own context headroom (pinned controllers bypass the
    /// adaptive value but still clamp), capped by a non-pinned
    /// per-request γ override, snapped down to the slot method's
    /// artifact γ set. Static so the pipeline's next-step planning can
    /// evaluate it against a *cloned* controller.
    fn plan_slot_gamma(
        verifier: &Verifier,
        slot: &Slot,
        ctl: &GammaController,
        headroom: usize,
        method: Method,
    ) -> usize {
        let cap = if slot.req.params.gamma_pinned {
            None
        } else {
            slot.req.params.gamma
        };
        pipeline::plan_gamma(&verifier.available_gammas_for(method), ctl, headroom, cap)
    }

    /// HLO verify artifacts are rectangular `(method, B, γ)` programs —
    /// one shared γ per dispatch. Collapse a per-slot plan (`0` =
    /// inactive) to the most conservative active want, snapped to the γ
    /// set common to every method in play. Errs when the active
    /// methods' artifact γ sets have an empty intersection (admission
    /// guards this; it can still surface on engine-default/override
    /// combinations submitted in-process).
    fn collapse_hlo_plan(
        verifier: &Verifier,
        methods: &[Method],
        plan: &mut [usize],
    ) -> Result<()> {
        let avail = verifier.available_gammas_common(methods);
        if avail.is_empty() {
            bail!(
                "active requests' verification methods share no verify \
                 artifact gamma (methods in play: {:?})",
                methods.iter().map(|m| m.name()).collect::<Vec<_>>()
            );
        }
        if let Some(w) = plan.iter().copied().filter(|&g| g > 0).min() {
            let g = pipeline::snap_gamma(&avail, w);
            for x in plan.iter_mut() {
                if *x > 0 {
                    *x = g;
                }
            }
        }
        Ok(())
    }

    /// Execute one decode step across all active slots.
    pub fn step(&mut self) -> Result<()> {
        self.admit();
        if self.active() == 0 {
            return Ok(());
        }
        let step_started = Instant::now();
        match self.config.mode {
            Mode::Speculative => self.step_speculative(step_started),
            Mode::Autoregressive => self.step_autoregressive(step_started),
        }
    }

    fn fill_model_inputs(&mut self, extra: usize) {
        let (b, s) = (self.config.batch, self.seq_len);
        for i in 0..b {
            match &self.slots[i] {
                Some(slot) => {
                    self.bufs.tokens[i * s..(i + 1) * s].copy_from_slice(&slot.tokens);
                    self.bufs.lens[i] = (slot.len + extra) as i32;
                }
                None => {
                    self.bufs.tokens[i * s..(i + 1) * s].fill(tokenizer::PAD);
                    self.bufs.lens[i] = 1;
                }
            }
        }
    }

    /// Dispatch this step's model block (max-γ draft calls + score) on
    /// the engine thread — the serial path, also the miss fallback. The
    /// per-slot γ plan rides in on each [`BlockSlot`].
    fn dispatch_block_serial(&mut self) -> Result<()> {
        let b = self.config.batch;
        // token rows from committed slot state (lens is refilled per
        // model call inside the block, so `extra` is irrelevant here)
        self.fill_model_inputs(0);
        self.block_slots.clear();
        for i in 0..b {
            match &self.slots[i] {
                Some(slot) => {
                    self.block_slots.push(BlockSlot {
                        active: true,
                        len: slot.len,
                        rng: slot.rng.clone(),
                        draft_temp: Self::effective_temp(slot.req.params.draft_temp()),
                        gamma: self.gammas_buf[i],
                    });
                }
                None => {
                    self.block_slots.push(BlockSlot::inactive());
                }
            }
        }
        let dims = BlockDims {
            b,
            s: self.seq_len,
            v: self.vocab,
            gmax: self.gmax,
        };
        let res = run_model_block(
            &self.draft_step,
            &self.target_score,
            &self.runtime.profiler,
            &mut self.bufs,
            &mut self.block_slots,
            dims,
            false,
            None,
        );
        // the block consumed per-slot uniforms: persist the advanced RNG
        // streams (even on error — matching the old partial-step
        // semantics where draws happened directly on the live slots)
        for i in 0..b {
            if let Some(slot) = &mut self.slots[i] {
                slot.rng = self.block_slots[i].rng.clone();
            }
        }
        res.map(|_| ())
    }

    /// Consume one prefetched chain block as this step's model block.
    /// Per-slot salvage decision: a slot adopts its prefetched rows iff
    /// its chain predictions have held at every barrier so far
    /// (`chain_slot_ok`) and the block's shape matches this step's
    /// replan (same request id, committed length, and γ — on the native
    /// backend these are implied by chain validity; the explicit guards
    /// make adoption fail safe rather than fail wrong). A full hit
    /// swaps the whole generation in; a partial hit redoes the missed
    /// slots in a reduced serial block and splices; zero salvageable
    /// slots fall back to the plain serial dispatch.
    fn consume_chain_block(&mut self, block: ChainBlock) -> Result<()> {
        let b = self.config.batch;
        let ChainBlock {
            depth,
            bufs: bbufs,
            slots: bslots,
            predicted_next,
        } = block;
        let mut salv = std::mem::take(&mut self.salv_buf);
        salv.clear();
        let mut full = true;
        let mut any_active = false;
        let (mut rows_salv, mut rows_redo, mut n_redo) = (0u64, 0u64, 0u64);
        for i in 0..b {
            let ok = match &self.slots[i] {
                Some(slot) => {
                    any_active = true;
                    let ok = bslots[i].active
                        && self
                            .pipeline
                            .as_ref()
                            .is_some_and(|ctl| ctl.chain_slot_ok(i, slot.req.id))
                        && bslots[i].len == slot.len
                        && bslots[i].gamma == self.gammas_buf[i];
                    if ok {
                        rows_salv += self.gammas_buf[i] as u64;
                    } else {
                        rows_redo += self.gammas_buf[i] as u64;
                        n_redo += 1;
                        full = false;
                    }
                    ok
                }
                None => {
                    if bslots[i].active {
                        full = false;
                    }
                    false
                }
            };
            salv.push(ok);
        }
        full = full && any_active;
        let any_salvaged = salv.iter().any(|&x| x);
        if let Some(ctl) = &mut self.pipeline {
            ctl.note_consumed(
                &salv,
                full,
                rows_salv,
                rows_redo,
                predicted_next,
                &bbufs.p_off,
                &bslots,
            );
            ctl.note_slots_redone(depth, n_redo);
        }
        if full {
            // wholesale adoption: the block's drafts ARE this step's
            // drafts and its RNG clones ARE the post-draft streams
            for (i, bs) in bslots.iter().enumerate() {
                if let Some(slot) = &mut self.slots[i] {
                    slot.rng = bs.rng.clone();
                }
            }
            let old = std::mem::replace(&mut self.bufs, *bbufs);
            if let Some(ctl) = &mut self.pipeline {
                ctl.park(Box::new(old));
                ctl.park_slots(bslots);
            }
        } else if !any_salvaged {
            if let Some(ctl) = &mut self.pipeline {
                ctl.park(bbufs);
                ctl.park_slots(bslots);
            }
            self.dispatch_block_serial()?;
        } else {
            self.splice_block(&bbufs, &bslots, &salv)?;
            if let Some(ctl) = &mut self.pipeline {
                ctl.park(bbufs);
                ctl.park_slots(bslots);
            }
        }
        self.salv_buf = salv;
        Ok(())
    }

    /// Partial-hit adoption: redo the missed slots' draft/score rows in
    /// a reduced model block, then assemble this step's generation by
    /// splicing the salvaged slots' prefetched rows and the redone rows
    /// into the final γ-prefix-table layout in `self.bufs`.
    fn splice_block(
        &mut self,
        bbufs: &StepBuffers,
        bslots: &[BlockSlot],
        salv: &[bool],
    ) -> Result<()> {
        let (b, v) = (self.config.batch, self.vocab);
        let any_missed = (0..b).any(|i| self.slots[i].is_some() && !salv[i]);
        if any_missed {
            // --- 1. reduced redo block: only the missed slots run model
            // calls (salvaged slots are marked inactive — per-batch-row
            // independence of the model artifacts makes their rows
            // identical either way, which is what licenses the splice)
            self.fill_model_inputs(0);
            self.block_slots.clear();
            for i in 0..b {
                match &self.slots[i] {
                    Some(slot) if !salv[i] => self.block_slots.push(BlockSlot {
                        active: true,
                        len: slot.len,
                        rng: slot.rng.clone(),
                        draft_temp: Self::effective_temp(slot.req.params.draft_temp()),
                        gamma: self.gammas_buf[i],
                    }),
                    _ => self.block_slots.push(BlockSlot::inactive()),
                }
            }
            let dims = BlockDims {
                b,
                s: self.seq_len,
                v,
                gmax: self.gmax,
            };
            run_model_block(
                &self.draft_step,
                &self.target_score,
                &self.runtime.profiler,
                &mut self.bufs,
                &mut self.block_slots,
                dims,
                false,
                None,
            )?;
            // persist ONLY the missed slots' advanced RNG streams — the
            // salvaged slots adopt the chain's post-draft clones below
            // (the redo block never drew for them)
            for i in 0..b {
                if !salv[i] {
                    if let Some(slot) = &mut self.slots[i] {
                        slot.rng = self.block_slots[i].rng.clone();
                    }
                }
            }
            // the redo block's packed offsets, before the final layout
            self.redo_q.clear();
            self.redo_q.extend_from_slice(&self.bufs.q_off);
            self.redo_p.clear();
            self.redo_p.extend_from_slice(&self.bufs.p_off);
        }
        // --- 2. the final ragged layout of the full step (salvaged +
        // redone slots share one γ-prefix table)
        let (mut qo, mut po) = (0usize, 0usize);
        self.bufs.q_off.clear();
        self.bufs.p_off.clear();
        for i in 0..b {
            self.bufs.q_off.push(qo);
            self.bufs.p_off.push(po);
            if self.slots[i].is_some() {
                qo += self.gammas_buf[i];
                po += self.gammas_buf[i] + 1;
            }
        }
        self.bufs.q_off.push(qo);
        self.bufs.p_off.push(po);
        // --- 3. shift the redone rows up to their final offsets,
        // highest slot first: the final layout also reserves room for
        // the salvaged slots, so dst ≥ src for every missed slot and
        // reverse order never clobbers a not-yet-moved source
        // (copy_within handles residual self-overlap)
        if any_missed {
            for i in (0..b).rev() {
                if salv[i] || self.slots[i].is_none() {
                    continue;
                }
                let g = self.gammas_buf[i];
                let (sq, dq) = (self.redo_q[i], self.bufs.q_off[i]);
                debug_assert!(dq >= sq);
                if sq != dq {
                    self.bufs.zq.copy_within(sq * v..(sq + g) * v, dq * v);
                    self.bufs.draft.copy_within(sq..sq + g, dq);
                }
                let (sp, dp) = (self.redo_p[i], self.bufs.p_off[i]);
                if sp != dp {
                    self.bufs.zp.copy_within(sp * v..(sp + g + 1) * v, dp * v);
                }
            }
        }
        // --- 4. splice the salvaged rows in from the prefetched
        // generation and adopt those slots' post-draft RNG streams
        for i in 0..b {
            if !salv[i] {
                continue;
            }
            let g = self.gammas_buf[i];
            let (sq, dq) = (bbufs.q_off[i], self.bufs.q_off[i]);
            self.bufs.zq[dq * v..(dq + g) * v]
                .copy_from_slice(&bbufs.zq[sq * v..(sq + g) * v]);
            self.bufs.draft[dq..dq + g].copy_from_slice(&bbufs.draft[sq..sq + g]);
            let (sp, dp) = (bbufs.p_off[i], self.bufs.p_off[i]);
            self.bufs.zp[dp * v..(dp + g + 1) * v]
                .copy_from_slice(&bbufs.zp[sp * v..(sp + g + 1) * v]);
            if let Some(slot) = &mut self.slots[i] {
                slot.rng = bslots[i].rng.clone();
            }
        }
        Ok(())
    }

    /// Per-request temperature scaling + top-k/top-p truncation of the
    /// staged logits (verification distributions must match the sampling
    /// temperature; q is left untruncated — it must remain the true
    /// proposal the drafts were sampled from; rejection sampling then
    /// yields the truncated target regardless of q's support).
    fn scale_and_filter(&mut self) {
        let (b, v) = (self.config.batch, self.vocab);
        for i in 0..b {
            let Some(slot) = &self.slots[i] else { continue };
            let g = self.gammas_buf[i];
            let (q0, p0) = (self.bufs.q_off[i], self.bufs.p_off[i]);
            let t = Self::effective_temp(slot.req.params.temperature);
            if (t - 1.0).abs() > 1e-6 {
                let inv = 1.0 / t;
                for x in &mut self.bufs.zp[p0 * v..(p0 + g + 1) * v] {
                    *x *= inv;
                }
                for x in &mut self.bufs.zq[q0 * v..(q0 + g) * v] {
                    *x *= inv;
                }
            }
            let (k, p) = (slot.req.params.top_k, slot.req.params.top_p);
            if k == 0 && p >= 1.0 {
                continue;
            }
            for j in 0..=g {
                let off = (p0 + j) * v;
                sampling::filter::mask_logits_top_k_top_p(
                    &mut self.bufs.zp[off..off + v],
                    k,
                    p,
                );
            }
        }
    }

    /// Draw this step's verification uniforms (γᵢ acceptance
    /// thresholds, resample, bonus) from each slot's RNG stream, staged
    /// at the slot's ragged `q_off` span. Inactive slots own no rows
    /// and consume no draws.
    fn draw_verify_uniforms(&mut self) {
        let b = self.config.batch;
        for i in 0..b {
            let g = self.gammas_buf[i];
            let q0 = self.bufs.q_off[i];
            let (ur, ub2) = match &mut self.slots[i] {
                Some(slot) => {
                    for c in 0..g {
                        self.uacc_buf[q0 + c] = slot.rng.uniform_f32();
                    }
                    (slot.rng.uniform_f32(), slot.rng.uniform_f32())
                }
                None => (0.0, 0.0),
            };
            self.ures_buf[i] = ur;
            self.ubonus_buf[i] = ub2;
        }
    }

    /// Whether the predicted commit rows leave every active slot still
    /// decoding — the prefetch launch condition. Replays the commit
    /// loop's exact finish checks (EOS, stop-sequence suffix across the
    /// step boundary, length, context headroom) against the prediction
    /// without touching live state.
    fn prediction_keeps_all_slots(&mut self, predicted: &[i32]) -> bool {
        let (b, s) = (self.config.batch, self.seq_len);
        for i in 0..b {
            let Some(slot) = &self.slots[i] else { continue };
            let g = self.gammas_buf[i];
            let p0 = self.bufs.p_off[i];
            let row = &predicted[p0..p0 + g + 1];
            // context: the next step needs ≥ 2 tokens of headroom
            if s.saturating_sub(slot.len + g + 1) < 2 {
                return false;
            }
            let max_stop = slot.req.stop_ids.iter().map(Vec::len).max().unwrap_or(0);
            self.stop_scratch.clear();
            if max_stop > 1 {
                let from = slot.generated.len().saturating_sub(max_stop - 1);
                self.stop_scratch.extend_from_slice(&slot.generated[from..]);
            }
            let mut gen_len = slot.generated.len();
            for &tok in row {
                if tok == tokenizer::EOS {
                    return false;
                }
                if max_stop > 0 {
                    self.stop_scratch.push(tok);
                    if match_stop_suffix(&self.stop_scratch, &slot.req.stop_ids).is_some() {
                        return false;
                    }
                }
                gen_len += 1;
                if gen_len >= slot.req.params.max_new_tokens {
                    return false;
                }
            }
        }
        true
    }

    /// Predict this step's commit under the all-accept assumption and,
    /// when every active slot would keep decoding, ship a depth-k
    /// speculation chain to the dispatcher lane against the speculative
    /// state: the lane job runs the next step's model block, then
    /// predicts *that* step's commit itself (from per-slot snapshots,
    /// never live engine state) and keeps extending up to
    /// `pipeline_depth` blocks ahead of the commit barrier.
    ///
    /// The bonus token is computed with the verifier's exact arithmetic
    /// ([`kernels::construct_prob_row`] + [`verify::inverse_cdf_sample`]
    /// over the scaled/filtered bonus row), so on the native backend a
    /// fully-accepted step emits *bit-for-bit* the predicted row and the
    /// barrier can adopt the prefetch. Refuses to launch when any
    /// predicted token would finish a slot (EOS / stop sequence / length
    /// / context), when γ would hit slot headroom, or while a chain is
    /// already live.
    fn maybe_launch_prefetch(&mut self) {
        let (b, s, v) = (self.config.batch, self.seq_len, self.vocab);
        {
            let Some(ctl) = &mut self.pipeline else { return };
            // lane_free also reclaims a cancelled chain's buffers; a
            // lane still draining means no spare generation — skip this
            // step's launch rather than queue behind it
            if ctl.chain_alive() || !ctl.lane_free() {
                return;
            }
        }
        let total_p = self.bufs.total_p(b);
        let mut predicted = self
            .pipeline
            .as_mut()
            .expect("pipeline checked above")
            .take_predicted();
        predicted.resize(total_p, -1);

        // --- predict the commit row of every active slot (ragged rows:
        // every element of predicted[..total_p] belongs to exactly one
        // active slot, so this loop overwrites the whole buffer)
        for i in 0..b {
            if self.slots[i].is_none() {
                continue;
            }
            let g = self.gammas_buf[i];
            let (q0, p0) = (self.bufs.q_off[i], self.bufs.p_off[i]);
            let row = &mut predicted[p0..p0 + g + 1];
            row[..g].copy_from_slice(&self.bufs.draft[q0..q0 + g]);
            let zrow = &self.bufs.zp[(p0 + g) * v..][..v];
            kernels::construct_prob_row(zrow, &mut self.bonus_row[..v], self.methods_buf[i]);
            row[g] = verify::inverse_cdf_sample(&self.bonus_row[..v], self.ubonus_buf[i])
                as i32;
        }

        // --- refuse when the predicted commit would finish any slot
        if !self.prediction_keeps_all_slots(&predicted) {
            self.pipeline
                .as_mut()
                .expect("pipeline checked above")
                .recycle_predicted(predicted);
            return;
        }

        // --- plan each slot's next-step γ against the speculative
        // state (its controller after an all-accept update, its
        // headroom after the predicted (γᵢ+1)-token commit) and build
        // the per-slot chain snapshot the lane job extends deeper
        // blocks from: everything prediction needs — sampling knobs,
        // finish-check state, the γ planner's controller/caps — frozen
        // at launch so the job never reads live engine state
        let mut infos = self
            .pipeline
            .as_mut()
            .expect("pipeline checked above")
            .take_infos();
        for i in 0..b {
            match &self.slots[i] {
                Some(slot) => {
                    let g = self.gammas_buf[i];
                    let committed = g + 1;
                    let mut ctl2 = slot.gamma.clone();
                    ctl2.update(true);
                    self.gnext_buf[i] = Self::plan_slot_gamma(
                        &self.verifier,
                        slot,
                        &ctl2,
                        s.saturating_sub(slot.len + committed),
                        self.methods_buf[i],
                    );
                    let p0 = self.bufs.p_off[i];
                    let row = &predicted[p0..p0 + g + 1];
                    // stop-matching tail: the last max_stop−1 tokens of
                    // (generated + predicted commit), mirroring the
                    // engine's own cross-step suffix window
                    let max_stop =
                        slot.req.stop_ids.iter().map(Vec::len).max().unwrap_or(0);
                    let keep = max_stop.saturating_sub(1);
                    let mut tail = Vec::with_capacity(keep);
                    if keep > 0 {
                        if row.len() >= keep {
                            tail.extend_from_slice(&row[row.len() - keep..]);
                        } else {
                            let need = keep - row.len();
                            let from = slot.generated.len().saturating_sub(need);
                            tail.extend_from_slice(&slot.generated[from..]);
                            tail.extend_from_slice(row);
                        }
                    }
                    infos.push(ChainSlotInfo {
                        active: true,
                        id: slot.req.id,
                        temp: Self::effective_temp(slot.req.params.temperature),
                        top_k: slot.req.params.top_k,
                        top_p: slot.req.params.top_p,
                        method: self.methods_buf[i],
                        max_new_tokens: slot.req.params.max_new_tokens,
                        gen_len: slot.generated.len() + committed,
                        stop_ids: slot.req.stop_ids.clone(),
                        tail,
                        ctrl: ctl2,
                        cap: if slot.req.params.gamma_pinned {
                            None
                        } else {
                            slot.req.params.gamma
                        },
                        avail: self.verifier.available_gammas_for(self.methods_buf[i]),
                    });
                }
                None => {
                    self.gnext_buf[i] = 0;
                    infos.push(ChainSlotInfo::inactive());
                }
            }
        }
        if self.config.backend == Backend::Hlo
            && Self::collapse_hlo_plan(&self.verifier, &self.methods_buf, &mut self.gnext_buf)
                .is_err()
        {
            // no runnable shared γ next step — don't prefetch; the next
            // step's own plan reports the conflict
            let ctl = self.pipeline.as_mut().expect("pipeline checked above");
            ctl.recycle_predicted(predicted);
            ctl.recycle_infos(infos);
            return;
        }

        // --- assemble the speculative block state (cloned RNGs, token
        // rows = committed context + predicted commit; live slots are
        // never touched)
        let ctl = self.pipeline.as_mut().expect("pipeline checked above");
        let mut bufs = ctl.take_spare(b, s, self.gmax, v);
        let mut bslots = ctl.take_slots();
        for i in 0..b {
            let row = &mut bufs.tokens[i * s..(i + 1) * s];
            match &self.slots[i] {
                Some(slot) => {
                    let g = self.gammas_buf[i];
                    let p0 = self.bufs.p_off[i];
                    row.copy_from_slice(&slot.tokens);
                    for (k, &tok) in predicted[p0..p0 + g + 1].iter().enumerate() {
                        row[slot.len + k] = tok;
                    }
                    bslots.push(BlockSlot {
                        active: true,
                        len: slot.len + g + 1,
                        rng: slot.rng.clone(),
                        draft_temp: Self::effective_temp(slot.req.params.draft_temp()),
                        gamma: self.gnext_buf[i],
                    });
                }
                None => {
                    row.fill(tokenizer::PAD);
                    bslots.push(BlockSlot::inactive());
                }
            }
        }
        let dims = BlockDims {
            b,
            s,
            v,
            gmax: self.gmax,
        };
        ctl.launch(
            self.draft_step.clone(),
            self.target_score.clone(),
            self.runtime.profiler.clone(),
            bufs,
            bslots,
            dims,
            infos,
            predicted,
            &self.bufs.p_off,
            &self.gammas_buf,
        );
    }

    fn step_speculative(&mut self, step_started: Instant) -> Result<()> {
        let (b, s, v) = (self.config.batch, self.seq_len, self.vocab);

        // --- 0. chain handoff: a live speculation chain hands this
        // step its next prefetched model block (blocking recv — the
        // lane job streams blocks ahead of the barrier, so on a hit
        // this only waits out the overlap tail)
        let chain_block = match &mut self.pipeline {
            Some(ctl) => ctl.next_block(),
            None => None,
        };

        // --- 1. plan this step's per-slot γ: each slot's own
        // controller clamped by its own headroom and request overrides,
        // snapped to its method's artifact set. The HLO backend then
        // collapses the ragged plan to one shared γ (rectangular verify
        // programs); native takes it as-is.
        self.fill_methods();
        for i in 0..b {
            let g = match &self.slots[i] {
                Some(slot) => Self::plan_slot_gamma(
                    &self.verifier,
                    slot,
                    &slot.gamma,
                    slot.headroom(s),
                    self.methods_buf[i],
                ),
                None => 0,
            };
            self.gammas_buf[i] = g;
        }
        if self.config.backend == Backend::Hlo {
            Self::collapse_hlo_plan(&self.verifier, &self.methods_buf, &mut self.gammas_buf)?;
        }

        // --- trace: snapshot each active slot's RNG stream position
        // *before* the draft draws. In pipelined mode the live slot RNG
        // at this point is still the pre-draft state (a hit prefetch
        // advanced clones; adoption replaces the streams below), so the
        // recorded position is identical in serial and pipelined runs —
        // the trace is schedule-independent by construction.
        let tracing = self.trace.enabled();
        let mut tr_slots: Vec<SlotStep> = Vec::new();
        if tracing {
            for (i, slot) in self.slots.iter().enumerate() {
                let Some(slot) = slot else { continue };
                let (rng_state, rng_inc) = slot.rng.state();
                tr_slots.push(SlotStep {
                    slot: i as u32,
                    id: slot.req.id,
                    len_before: slot.len as u32,
                    gamma: self.gammas_buf[i] as u32,
                    method: self.methods_buf[i],
                    rng_state,
                    rng_inc,
                    draft: Vec::new(),
                    zq_digest: 0,
                    zp_digest: 0,
                    accept_len: 0,
                    out_row: Vec::new(),
                    committed: Vec::new(),
                    finish: None,
                });
            }
        }

        // --- 2. model block: consume the prefetched chain block
        // (wholesale on a full hit, per-slot splice on a partial hit,
        // serial fallback when nothing is salvageable), or dispatch
        // serially when no chain is live
        match chain_block {
            Some(block) => self.consume_chain_block(block)?,
            None => self.dispatch_block_serial()?,
        }

        // --- temperature scaling + per-request filtering, then this
        // step's verification uniforms
        self.scale_and_filter();
        self.draw_verify_uniforms();

        // --- trace: drafted tokens + digests of the exact logit
        // tensors verification will consume (post scale/filter),
        // sliced from each slot's ragged spans
        if tracing {
            for ts in &mut tr_slots {
                let i = ts.slot as usize;
                let g = self.gammas_buf[i];
                let (q0, p0) = (self.bufs.q_off[i], self.bufs.p_off[i]);
                ts.draft.extend_from_slice(&self.bufs.draft[q0..q0 + g]);
                ts.zq_digest = digest_f32(&self.bufs.zq[q0 * v..(q0 + g) * v]);
                ts.zp_digest = digest_f32(&self.bufs.zp[p0 * v..(p0 + g + 1) * v]);
            }
        }

        // --- overlap window: ship the next step's model block to the
        // dispatcher lane before running this step's verification
        self.maybe_launch_prefetch();

        // --- 3. verification (the paper's kernel, one fused ragged call)
        let total_q = self.bufs.total_q(b);
        let total_p = self.bufs.total_p(b);
        let ins = VerifyInputs {
            z_p: &self.bufs.zp[..total_p * v],
            z_q: &self.bufs.zq[..total_q * v],
            draft: &self.bufs.draft[..total_q],
            u_acc: &self.uacc_buf[..total_q],
            u_res: &self.ures_buf,
            u_bonus: &self.ubonus_buf,
        };
        let verify_secs = self.verifier.verify_ragged_into(
            &self.gammas_buf,
            &self.bufs.q_off,
            &self.bufs.p_off,
            &self.methods_buf,
            &ins,
            &mut self.verify_out,
        )?;

        // --- pipeline barrier verdict (computed before the commit loop
        // mutates slot state): a slot's chain prediction of this step
        // held iff the slot is still chain-valid, the chain planned the
        // same γ this step's replan chose, verification accepted every
        // draft, and the emitted row is bit-identical to the predicted
        // row (native: guaranteed equal on all-accept; HLO: the bonus
        // draw may differ in the last ulp — a per-slot miss)
        let mut vb = std::mem::take(&mut self.verdict_buf);
        vb.clear();
        let barrier = match self.pipeline.as_ref().and_then(PipelineCtl::pending) {
            Some((prows, poff, pgam)) => {
                let ctl = self.pipeline.as_ref().expect("pending implies pipeline");
                let mut full = true;
                let mut any_active = false;
                for i in 0..b {
                    let ok = match &self.slots[i] {
                        Some(slot) => {
                            any_active = true;
                            let g = self.gammas_buf[i];
                            let p0 = self.bufs.p_off[i];
                            let ok = ctl.chain_slot_ok(i, slot.req.id)
                                && pgam[i] == g
                                && self.verify_out.accept_len[i] as usize == g
                                && prows[poff[i]..poff[i] + g + 1]
                                    == self.verify_out.out_tokens[p0..p0 + g + 1];
                            if !ok {
                                full = false;
                            }
                            ok
                        }
                        None => false,
                    };
                    vb.push(ok);
                }
                full = full && any_active;
                if !self.config.pipeline_salvage && !full {
                    // all-or-nothing barrier: without partial adoption a
                    // single missed slot discards the whole window
                    vb.fill(false);
                }
                Some(full)
            }
            None => None,
        };

        // --- 4. commit (per-slot ragged rows; each slot's controller
        // updates on its own all-accept outcome)
        let mut drafted_total = 0usize;
        let mut accepted_total = 0usize;
        let mut emitted_total = 0usize;
        let mut ti = 0usize; // cursor into tr_slots (same active-slot order)
        for i in 0..b {
            let Some(slot) = &mut self.slots[i] else { continue };
            let g = self.gammas_buf[i];
            let alen = self.verify_out.accept_len[i] as usize;
            slot.steps += 1;
            slot.drafted += g;
            slot.accepted += alen;
            slot.gamma.update(alen == g);
            drafted_total += g;
            accepted_total += alen;

            let p0 = self.bufs.p_off[i];
            let row = &self.verify_out.out_tokens[p0..p0 + g + 1];
            let gen_before = slot.generated.len();
            let mut finish: Option<FinishReason> = None;
            for &tok in row.iter().take(alen + 1) {
                debug_assert!(tok >= 0);
                slot.tokens[slot.len] = tok;
                slot.len += 1;
                slot.generated.push(tok);
                if tok == tokenizer::EOS {
                    finish = Some(FinishReason::Stop);
                    break;
                }
                if let Some(m) = match_stop_suffix(&slot.generated, &slot.req.stop_ids)
                {
                    slot.generated.truncate(slot.generated.len() - m);
                    finish = Some(FinishReason::StopSeq);
                    break;
                }
                if slot.generated.len() >= slot.req.params.max_new_tokens {
                    finish = Some(FinishReason::Length);
                    break;
                }
            }
            // newly committed tokens (a stop-sequence trim can retract
            // below gen_before when the match spans a step boundary)
            let from = gen_before.min(slot.generated.len());
            let delta: Vec<i32> = slot.generated[from..].to_vec();
            emitted_total += delta.len();
            if finish.is_none() && slot.headroom(s) < 2 {
                finish = Some(FinishReason::Context);
            }
            if tracing {
                let ts = &mut tr_slots[ti];
                debug_assert_eq!(ts.slot as usize, i);
                ts.accept_len = alen as u32;
                ts.out_row.extend_from_slice(row);
                ts.committed.extend_from_slice(&delta);
                ts.finish = finish;
                ti += 1;
            }
            if !delta.is_empty() {
                self.deltas.push((slot.req.id, delta));
            }
            if let Some(reason) = finish {
                let slot = self.slots[i].take().unwrap();
                self.results.push(GenResult {
                    id: slot.req.id,
                    token_ids: slot.generated,
                    finish: reason,
                    steps: slot.steps,
                    drafted: slot.drafted,
                    accepted: slot.accepted,
                    latency: slot.started.elapsed().as_secs_f64(),
                });
                self.stats.finished += 1;
            }
        }

        // apply the barrier verdicts: AND them into the chain's
        // cumulative per-slot validity (a fully-missed window raises
        // the chain's cancel flag so the lane job abandons its
        // remaining model calls)
        if let (Some(ctl), Some(full)) = (&mut self.pipeline, barrier) {
            ctl.apply_barrier(&vb, full);
        }
        self.verdict_buf = vb;

        if tracing {
            self.trace.record(TraceEvent::Step(StepEvent { slots: tr_slots }));
        }

        // ragged step: record the deepest active speculation as the
        // step's representative γ
        let gamma_max = self.gammas_buf.iter().copied().max().unwrap_or(0);
        self.stats.record_step(
            gamma_max,
            drafted_total,
            accepted_total,
            emitted_total,
            step_started.elapsed().as_secs_f64(),
            verify_secs,
        );
        self.admit();
        Ok(())
    }

    fn step_autoregressive(&mut self, step_started: Instant) -> Result<()> {
        let (b, s) = (self.config.batch, self.seq_len);
        self.fill_model_inputs(0);
        for i in 0..b {
            let (u, t) = match &mut self.slots[i] {
                Some(slot) => (slot.rng.uniform_f32(), slot.req.params.temperature),
                None => (0.0, 1.0),
            };
            self.bufs.u[i] = u;
            self.bufs.temp[i] = t;
        }
        let shape_bs = [b, s];
        let shape_b = [b];
        {
            let prof = self.runtime.profiler.clone();
            let _g = prof.scope("step/target_step");
            self.target_step.run_views_into(
                &[
                    TensorView::i32(&shape_bs, &self.bufs.tokens),
                    TensorView::i32(&shape_b, &self.bufs.lens),
                    TensorView::f32(&shape_b, &self.bufs.u),
                    TensorView::f32(&shape_b, &self.bufs.temp),
                ],
                &mut self.bufs.target_out,
            )?;
        }
        let toks = self.bufs.target_out[0].as_i32()?;
        let mut emitted = 0usize;
        for i in 0..b {
            let Some(slot) = &mut self.slots[i] else { continue };
            slot.steps += 1;
            slot.tokens[slot.len] = toks[i];
            slot.len += 1;
            let gen_before = slot.generated.len();
            slot.generated.push(toks[i]);
            let finish = if toks[i] == tokenizer::EOS {
                Some(FinishReason::Stop)
            } else if let Some(m) =
                match_stop_suffix(&slot.generated, &slot.req.stop_ids)
            {
                slot.generated.truncate(slot.generated.len() - m);
                Some(FinishReason::StopSeq)
            } else if slot.generated.len() >= slot.req.params.max_new_tokens {
                Some(FinishReason::Length)
            } else if slot.headroom(s) < 2 {
                Some(FinishReason::Context)
            } else {
                None
            };
            let from = gen_before.min(slot.generated.len());
            let delta: Vec<i32> = slot.generated[from..].to_vec();
            emitted += delta.len();
            if !delta.is_empty() {
                self.deltas.push((slot.req.id, delta));
            }
            if let Some(reason) = finish {
                let slot = self.slots[i].take().unwrap();
                self.results.push(GenResult {
                    id: slot.req.id,
                    token_ids: slot.generated,
                    finish: reason,
                    steps: slot.steps,
                    drafted: 0,
                    accepted: 0,
                    latency: slot.started.elapsed().as_secs_f64(),
                });
                self.stats.finished += 1;
            }
        }
        self.stats
            .record_step(0, 0, 0, emitted, step_started.elapsed().as_secs_f64(), 0.0);
        self.admit();
        Ok(())
    }

    /// Generate text end-to-end with a tokenizer (server/example helper).
    /// `params` applies to every prompt; the per-prompt `usize` overrides
    /// `max_new_tokens`.
    pub fn generate_text(
        &mut self,
        tok: &tokenizer::Tokenizer,
        prompts: &[(&str, usize)],
        params: &SamplingParams,
    ) -> Result<Vec<(String, GenResult)>> {
        let reqs: Vec<GenRequest> = prompts
            .iter()
            .enumerate()
            .map(|(i, (p, max_new))| {
                let rp = params.clone().with_max_new_tokens(*max_new);
                GenRequest::new(i as u64, tok.encode(p), rp).tokenize_stops(tok)
            })
            .collect();
        let results = self.generate(reqs)?;
        Ok(results
            .into_iter()
            .map(|r| (tok.decode_until_stop(&r.token_ids), r))
            .collect())
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("pair", &self.config.pair)
            .field("batch", &self.config.batch)
            .field("method", &self.config.method.name())
            .field("pipeline", &self.pipeline.is_some())
            .field("active", &self.active())
            .field("pending", &self.pending())
            .finish()
    }
}

// Engine construction/decode tests need artifacts (rust/tests/it_engine.rs)
// or the simulated runtime (rust/tests/it_pipeline.rs, which also asserts
// the pipelined scheduler bit-identical to this serial loop).
