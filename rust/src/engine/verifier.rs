//! Verification backends.
//!
//! [`Backend::Hlo`] runs the fused AOT artifact for each method present
//! in the batch — the paper's kernel path. A heterogeneous batch needs
//! one artifact execution per **distinct** method; those executions are
//! independent (each consumes the same borrowed inputs and fills its
//! own staging generation), so they run as a **parallel slot-level
//! schedule** on the workspace's worker pool instead of the old serial
//! `for` loop: each pool lane executes one method group, and the rows
//! each method owns are gathered into the caller's [`VerifyOutput`]
//! afterwards in deterministic first-occurrence order. A single-method
//! batch (the common case) degenerates to one inline call — no pool
//! region, no workers spawned. [`Backend::Native`] runs the
//! segment-parallel kernel layer ([`crate::sampling::kernels`]):
//! slot-parallel with per-row method dispatch, zero steady-state
//! allocation via the verifier-owned [`VerifyWorkspace`], and
//! bit-identical to the scalar oracle used as the cross-check in
//! integration tests.
//!
//! The verifier owns the workspace's persistent worker pool: workers
//! spawn lazily on the first parallel verify region (at most once per
//! engine) and are parked, reused by every subsequent decode step, and
//! joined when the verifier drops. A verifier that never runs a
//! parallel region — single-method HLO batches, autoregressive mode,
//! small matrices — never spawns any.
//!
//! ## Worked example
//!
//! Drive one native verification step directly (the engine does exactly
//! this inside its decode loop, with `ins` borrowing its step buffers):
//!
//! ```no_run
//! use std::sync::Arc;
//! use specd::engine::{Backend, Verifier, VerifyInputs, VerifyOutput};
//! use specd::runtime::Runtime;
//! use specd::sampling::Method;
//!
//! # fn main() -> anyhow::Result<()> {
//! let rt = Arc::new(Runtime::open_default()?);
//! let (b, gamma, v) = (1, 2, rt.manifest.vocab_size);
//! let mut verifier = Verifier::new(rt, Method::Exact, Backend::Native, b, v);
//!
//! let z_p = vec![0.0f32; b * (gamma + 1) * v]; // target logits (B, γ+1, V)
//! let z_q = vec![0.0f32; b * gamma * v];       // draft logits  (B, γ, V)
//! let ins = VerifyInputs {
//!     z_p: &z_p,
//!     z_q: &z_q,
//!     draft: &[3, 5],
//!     u_acc: &[0.4, 0.6],
//!     u_res: &[0.5],
//!     u_bonus: &[0.5],
//! };
//! let mut out = VerifyOutput::default(); // reuse across steps
//! let secs = verifier.verify_into(gamma, &[Method::Exact; 1], &ins, &mut out)?;
//! println!("accepted {} drafts in {secs:.6}s", out.accept_len[0]);
//! # Ok(())
//! # }
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{HostTensor, LoadedExecutable, Runtime, TensorView};
use crate::sampling::kernels::{self, pool, KernelConfig, VerifyWorkspace};
use crate::sampling::Method;
use crate::trace::{NullSink, TraceEvent, TraceSink};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Hlo,
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "hlo" => Some(Backend::Hlo),
            "native" => Some(Backend::Native),
            _ => None,
        }
    }
}

/// Inputs to one verification step, laid out like the AOT artifacts.
pub struct VerifyInputs<'a> {
    /// target logits (B, γ+1, V) row-major
    pub z_p: &'a [f32],
    /// draft logits (B, γ, V)
    pub z_q: &'a [f32],
    /// drafted tokens (B, γ)
    pub draft: &'a [i32],
    pub u_acc: &'a [f32],
    pub u_res: &'a [f32],
    pub u_bonus: &'a [f32],
}

/// Output buffers of one verification step. Owned by the engine and
/// reused across steps (cleared + refilled in place), so the commit path
/// performs no per-step allocation.
#[derive(Debug, Clone, Default)]
pub struct VerifyOutput {
    /// accepted draft count per row (B,)
    pub accept_len: Vec<i32>,
    /// emitted tokens per row (B, γ+1), −1 padded
    pub out_tokens: Vec<i32>,
}

/// Methods in first-occurrence order — the one dedup rule shared by the
/// γ-intersection and the HLO dispatch loop, so the γ a step picks and
/// the order artifacts execute in stay deterministic together.
fn distinct_methods(methods: &[Method]) -> Vec<Method> {
    let mut out: Vec<Method> = Vec::with_capacity(4);
    for m in methods {
        if !out.contains(m) {
            out.push(*m);
        }
    }
    out
}

/// One method group of a parallel HLO dispatch: the group's executable,
/// its α/β constants (sigmoid methods), its output staging generation,
/// and the error slot its pool task reports through. Built per step
/// over borrows of the verifier-owned staging generations, executed as
/// one pool task each, then drained serially for the row gather.
struct GroupRun<'a> {
    exe: Arc<LoadedExecutable>,
    ab: Option<[f32; 2]>,
    out: &'a mut Vec<HostTensor>,
    err: Option<anyhow::Error>,
}

/// Dense staging for ragged→rectangular HLO dispatch: the AOT verify
/// artifacts are compiled for a rectangular `(B, γ, V)` block, so a
/// ragged step on the HLO backend scatters its row spans into this
/// dense block (padding absent slots with reject-all uniforms), runs
/// the normal grouped dispatch, and gathers the ragged rows back out.
#[derive(Debug, Default)]
struct HloStage {
    z_p: Vec<f32>,
    z_q: Vec<f32>,
    draft: Vec<i32>,
    u_acc: Vec<f32>,
    dense: VerifyOutput,
}

/// Method + backend dispatcher, loading per-γ executables lazily. Owns
/// the kernel workspace (buffers + persistent worker pool) for the
/// native backend and the per-method-group output staging generations
/// for the HLO backend.
pub struct Verifier {
    runtime: Arc<Runtime>,
    pub method: Method,
    pub backend: Backend,
    batch: usize,
    vocab: usize,
    ws: VerifyWorkspace,
    /// reusable HLO artifact output staging (accept + tokens tensors),
    /// one generation per distinct method in the step's batch, refilled
    /// in place each dispatch — generation count grows to the
    /// high-water distinct-method count and is then stable
    hlo_out: Vec<Vec<HostTensor>>,
    /// reusable dense staging for ragged HLO dispatch (the artifacts are
    /// rectangular, so ragged rows scatter into a dense block and gather
    /// back; see [`Verifier::verify_ragged_into`])
    hlo_stage: HloStage,
    /// trace hook for verify-dispatch markers ([`NullSink`] unless the
    /// engine attached a recorder)
    trace: Arc<dyn TraceSink>,
}

impl Verifier {
    pub fn new(
        runtime: Arc<Runtime>,
        method: Method,
        backend: Backend,
        batch: usize,
        vocab: usize,
    ) -> Self {
        Verifier {
            runtime,
            method,
            backend,
            batch,
            vocab,
            // the pool inside spawns lazily, so an HLO-backend or
            // autoregressive engine never pays for idle worker threads
            ws: VerifyWorkspace::new(KernelConfig::from_env()),
            hlo_out: Vec::new(),
            hlo_stage: HloStage::default(),
            trace: Arc::new(NullSink),
        }
    }

    /// Attach the engine's trace sink (propagated by
    /// [`crate::engine::Engine::set_trace`]).
    pub fn set_trace(&mut self, sink: Arc<dyn TraceSink>) {
        self.trace = sink;
    }

    /// Replace the kernel scheduling config (bench/test knob; results
    /// are identical for every config).
    pub fn with_kernel_config(mut self, cfg: KernelConfig) -> Self {
        self.set_kernel_config(cfg);
        self
    }

    /// In-place variant of [`Verifier::with_kernel_config`] for callers
    /// that only hold the verifier through an engine (e.g. SIMD on/off
    /// parity tests that must not race on `SPECD_SIMD`).
    pub fn set_kernel_config(&mut self, cfg: KernelConfig) {
        self.ws = VerifyWorkspace::new(cfg);
    }

    /// γ values this verifier can serve for its default method.
    pub fn available_gammas(&self) -> Vec<usize> {
        self.available_gammas_for(self.method)
    }

    /// γ values this verifier can serve for `method` (artifact
    /// availability) — per-request method overrides are admitted only
    /// when this is non-empty.
    pub fn available_gammas_for(&self, method: Method) -> Vec<usize> {
        match self.backend {
            Backend::Native => (1..=64).collect(),
            Backend::Hlo => self
                .runtime
                .manifest
                .verify_gammas(method.name(), self.batch, self.vocab),
        }
    }

    /// γ values every method in `methods` can serve (set intersection).
    /// The **HLO backend** executes one rectangular artifact per step, so
    /// its heterogeneous batches are limited to the γ values common to
    /// their methods (the native backend runs genuinely ragged per-slot γ
    /// and never needs the intersection). Falls back to the default
    /// method's set when `methods` is empty.
    pub fn available_gammas_common(&self, methods: &[Method]) -> Vec<usize> {
        let mut acc: Option<Vec<usize>> = None;
        for m in distinct_methods(methods) {
            let avail = self.available_gammas_for(m);
            acc = Some(match acc {
                None => avail,
                Some(prev) => prev.into_iter().filter(|g| avail.contains(g)).collect(),
            });
        }
        acc.unwrap_or_else(|| self.available_gammas())
    }

    /// Run verification for `gamma` draft positions, writing accept
    /// lengths and emitted tokens into `out` (buffers reused across
    /// steps). `methods` carries one verification method per batch row —
    /// the engine default, or a per-request override on the slot.
    ///
    /// Returns the *execution* seconds — artifact compilation (lazy,
    /// first touch per γ) is deliberately excluded so Δ%-profiling
    /// comparisons between methods are not biased by which method ran
    /// first (the paper's timings are steady-state too).
    pub fn verify_into(
        &mut self,
        gamma: usize,
        methods: &[Method],
        ins: &VerifyInputs<'_>,
        out: &mut VerifyOutput,
    ) -> Result<f64> {
        let (b, v) = (self.batch, self.vocab);
        debug_assert_eq!(ins.z_p.len(), b * (gamma + 1) * v);
        debug_assert_eq!(ins.z_q.len(), b * gamma * v);
        assert_eq!(methods.len(), b, "one method per batch row");
        if self.trace.enabled() {
            self.trace.record(TraceEvent::Verify {
                rows: (b * gamma) as u32,
                groups: distinct_methods(methods).len() as u32,
            });
        }
        match self.backend {
            Backend::Native => {
                let started = Instant::now();
                let _scope = self.runtime.profiler.scope("verify");
                kernels::spec_step_batch_ws(
                    &mut self.ws,
                    ins.z_p,
                    ins.z_q,
                    b,
                    gamma,
                    v,
                    ins.draft,
                    ins.u_acc,
                    ins.u_res,
                    ins.u_bonus,
                    methods,
                    &mut out.accept_len,
                    &mut out.out_tokens,
                    Some(&self.runtime.profiler),
                );
                Ok(started.elapsed().as_secs_f64())
            }
            Backend::Hlo => {
                out.accept_len.clear();
                out.accept_len.resize(b, 0);
                out.out_tokens.clear();
                out.out_tokens.resize(b * (gamma + 1), -1);
                // one artifact per distinct method, compiled outside the
                // timed region
                let distinct = distinct_methods(methods);
                let exes = distinct
                    .iter()
                    .map(|m| self.runtime.load_verify(m.name(), b, gamma, v))
                    .collect::<Result<Vec<_>>>()?;
                while self.hlo_out.len() < distinct.len() {
                    self.hlo_out.push(Vec::new());
                }

                let started = Instant::now();
                let _scope = self.runtime.profiler.scope("verify");
                let shape_p = [b, gamma + 1, v];
                let shape_q = [b, gamma, v];
                let shape_g = [b, gamma];
                let shape_b = [b];
                let shape_ab = [2usize];

                // parallel slot-level schedule: every distinct method's
                // artifact executes as its own pool task against its own
                // staging generation (disjoint &mut via the span
                // partition, unit = one group). A single-method batch
                // degenerates to one inline call — no pool region.
                let mut groups: Vec<GroupRun<'_>> = distinct
                    .iter()
                    .zip(&exes)
                    .zip(self.hlo_out.iter_mut())
                    .map(|((m, exe), staging)| GroupRun {
                        exe: exe.clone(),
                        ab: m.alpha_beta().map(|(alpha, beta)| [alpha, beta]),
                        out: staging,
                        err: None,
                    })
                    .collect();
                let lanes = self.ws.cfg.threads.min(groups.len());
                pool::for_each_span(self.ws.pool(), lanes, &mut groups, 1, |_, span| {
                    for g in span.iter_mut() {
                        let mut inputs = vec![
                            TensorView::f32(&shape_p, ins.z_p),
                            TensorView::f32(&shape_q, ins.z_q),
                            TensorView::i32(&shape_g, ins.draft),
                            TensorView::f32(&shape_g, ins.u_acc),
                            TensorView::f32(&shape_b, ins.u_res),
                            TensorView::f32(&shape_b, ins.u_bonus),
                        ];
                        if let Some(pair) = &g.ab {
                            inputs.push(TensorView::f32(&shape_ab, pair));
                        }
                        if let Err(e) = g.exe.run_views_into(&inputs, g.out) {
                            g.err = Some(e);
                        }
                    }
                });
                for g in groups.iter_mut() {
                    if let Some(e) = g.err.take() {
                        return Err(e);
                    }
                }
                drop(groups);

                // deterministic gather: each row takes its own method's
                // group output, in first-occurrence method order
                for (gi, m) in distinct.iter().enumerate() {
                    let accept = self.hlo_out[gi][0].as_i32()?;
                    let tokens = self.hlo_out[gi][1].as_i32()?;
                    for row in 0..b {
                        if methods[row] == *m {
                            out.accept_len[row] = accept[row];
                            out.out_tokens[row * (gamma + 1)..(row + 1) * (gamma + 1)]
                                .copy_from_slice(
                                    &tokens[row * (gamma + 1)..(row + 1) * (gamma + 1)],
                                );
                        }
                    }
                }
                Ok(started.elapsed().as_secs_f64())
            }
        }
    }

    /// Run verification over **ragged per-slot γ** row spans — the
    /// engine's decode-loop entry point since the ragged-batch refactor.
    ///
    /// `gammas[i]` is slot *i*'s draft count (`0` = empty slot, no
    /// rows); `q_off`/`p_off` are the γ-prefix tables addressing `ins`'s
    /// packed rows (draft-side `Σ γᵢ` rows, target-side `Σ (γᵢ+1)`
    /// rows). `out.accept_len` gets one entry per slot and
    /// `out.out_tokens` the ragged `p_off`-addressed token rows.
    ///
    /// * **Native** runs [`kernels::spec_step_ragged_ws`] — genuinely
    ///   ragged, any γ mix (uniform layouts delegate to the rectangular
    ///   schedules unchanged).
    /// * **HLO** artifacts are rectangular `(B, γ, V)` blocks, so this
    ///   path requires every non-empty slot to share one γ (the engine
    ///   guarantees it by collapsing per-slot γ wants on the HLO
    ///   backend); the rows scatter into the dense staging block with
    ///   reject-all pads for absent slots, run the normal grouped
    ///   dispatch, and gather back.
    pub fn verify_ragged_into(
        &mut self,
        gammas: &[usize],
        q_off: &[usize],
        p_off: &[usize],
        methods: &[Method],
        ins: &VerifyInputs<'_>,
        out: &mut VerifyOutput,
    ) -> Result<f64> {
        let (b, v) = (self.batch, self.vocab);
        assert_eq!(gammas.len(), b, "one γ per batch slot");
        assert_eq!(methods.len(), b, "one method per batch slot");
        debug_assert_eq!(q_off.len(), b + 1);
        debug_assert_eq!(p_off.len(), b + 1);
        let total_q = q_off[b];
        let total_p = p_off[b];
        debug_assert_eq!(ins.z_p.len(), total_p * v);
        debug_assert_eq!(ins.z_q.len(), total_q * v);

        match self.backend {
            Backend::Native => {
                if self.trace.enabled() {
                    self.trace.record(TraceEvent::Verify {
                        rows: total_q as u32,
                        groups: distinct_methods(methods).len() as u32,
                    });
                }
                let started = Instant::now();
                let _scope = self.runtime.profiler.scope("verify");
                kernels::spec_step_ragged_ws(
                    &mut self.ws,
                    ins.z_p,
                    ins.z_q,
                    b,
                    gammas,
                    q_off,
                    p_off,
                    v,
                    ins.draft,
                    ins.u_acc,
                    ins.u_res,
                    ins.u_bonus,
                    methods,
                    &mut out.accept_len,
                    &mut out.out_tokens,
                    Some(&self.runtime.profiler),
                );
                Ok(started.elapsed().as_secs_f64())
            }
            Backend::Hlo => {
                let g = gammas.iter().copied().find(|&g| g > 0).unwrap_or(0);
                if g == 0 {
                    out.accept_len.clear();
                    out.accept_len.resize(b, 0);
                    out.out_tokens.clear();
                    return Ok(0.0);
                }
                if let Some(&bad) = gammas.iter().find(|&&gi| gi != 0 && gi != g) {
                    anyhow::bail!(
                        "HLO verify artifacts are rectangular: per-slot γ must agree \
                         (saw γ={bad} alongside γ={g})"
                    );
                }
                // ragged layout happens to be dense already (every slot
                // occupied at the same γ): no staging copy needed
                if total_q == b * g {
                    let secs = self.verify_into(g, methods, ins, out)?;
                    return Ok(secs);
                }
                // scatter into the dense block; absent slots get
                // reject-all uniforms (u_acc = 1.0 never accepts) and
                // zero logits, and their outputs are dropped at gather
                let mut st = std::mem::take(&mut self.hlo_stage);
                st.z_p.clear();
                st.z_p.resize(b * (g + 1) * v, 0.0);
                st.z_q.clear();
                st.z_q.resize(b * g * v, 0.0);
                st.draft.clear();
                st.draft.resize(b * g, 0);
                st.u_acc.clear();
                st.u_acc.resize(b * g, 1.0);
                for i in 0..b {
                    if gammas[i] != g {
                        continue;
                    }
                    let (q0, p0) = (q_off[i], p_off[i]);
                    st.z_p[i * (g + 1) * v..(i + 1) * (g + 1) * v]
                        .copy_from_slice(&ins.z_p[p0 * v..(p0 + g + 1) * v]);
                    st.z_q[i * g * v..(i + 1) * g * v]
                        .copy_from_slice(&ins.z_q[q0 * v..(q0 + g) * v]);
                    st.draft[i * g..(i + 1) * g].copy_from_slice(&ins.draft[q0..q0 + g]);
                    st.u_acc[i * g..(i + 1) * g].copy_from_slice(&ins.u_acc[q0..q0 + g]);
                }
                let dense_ins = VerifyInputs {
                    z_p: &st.z_p,
                    z_q: &st.z_q,
                    draft: &st.draft,
                    u_acc: &st.u_acc,
                    u_res: ins.u_res,
                    u_bonus: ins.u_bonus,
                };
                let mut dense = std::mem::take(&mut st.dense);
                let res = self.verify_into(g, methods, &dense_ins, &mut dense);
                // gather the ragged rows back out
                if res.is_ok() {
                    out.accept_len.clear();
                    out.accept_len.resize(b, 0);
                    out.out_tokens.clear();
                    out.out_tokens.resize(total_p, -1);
                    for i in 0..b {
                        if gammas[i] != g {
                            continue;
                        }
                        out.accept_len[i] = dense.accept_len[i];
                        out.out_tokens[p_off[i]..p_off[i] + g + 1]
                            .copy_from_slice(&dense.out_tokens[i * (g + 1)..(i + 1) * (g + 1)]);
                    }
                }
                st.dense = dense;
                self.hlo_stage = st;
                res
            }
        }
    }

    /// Convenience wrapper returning an owned [`VerifyOutput`]
    /// (tests/benches; the engine hot path uses [`Verifier::verify_into`]).
    pub fn verify(
        &mut self,
        gamma: usize,
        methods: &[Method],
        ins: &VerifyInputs<'_>,
    ) -> Result<(VerifyOutput, f64)> {
        let mut out = VerifyOutput::default();
        let secs = self.verify_into(gamma, methods, ins, &mut out)?;
        Ok((out, secs))
    }
}

#[cfg(test)]
mod tests {
    // Backend parsing is trivial; HLO-vs-native equivalence is covered by
    // rust/tests/it_runtime.rs (needs built artifacts), and the native
    // kernel layer is parity-tested in crate::sampling::kernels.
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("hlo"), Some(Backend::Hlo));
        assert_eq!(Backend::parse("native"), Some(Backend::Native));
        assert_eq!(Backend::parse("x"), None);
    }

    #[test]
    fn verify_output_buffers_default_empty() {
        let out = VerifyOutput::default();
        assert!(out.accept_len.is_empty());
        assert!(out.out_tokens.is_empty());
    }
}
