//! Verification backends.
//!
//! [`Backend::Hlo`] runs the fused AOT artifact for the configured method
//! (one PJRT call per decode step — the paper's kernel path);
//! [`Backend::Native`] runs the pure-rust oracle (identical semantics,
//! useful when V is small enough that PJRT dispatch dominates, and as the
//! cross-check in integration tests).

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{HostTensor, Runtime};
use crate::sampling::{self, Method};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Hlo,
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "hlo" => Some(Backend::Hlo),
            "native" => Some(Backend::Native),
            _ => None,
        }
    }
}

/// Inputs to one verification step, laid out like the AOT artifacts.
pub struct VerifyInputs<'a> {
    /// target logits (B, γ+1, V) row-major
    pub z_p: &'a [f32],
    /// draft logits (B, γ, V)
    pub z_q: &'a [f32],
    /// drafted tokens (B, γ)
    pub draft: &'a [i32],
    pub u_acc: &'a [f32],
    pub u_res: &'a [f32],
    pub u_bonus: &'a [f32],
}

/// Output of one verification step.
#[derive(Debug, Clone)]
pub struct VerifyOutput {
    /// accepted draft count per row (B,)
    pub accept_len: Vec<i32>,
    /// emitted tokens per row (B, γ+1), −1 padded
    pub out_tokens: Vec<i32>,
}

/// Method + backend dispatcher, loading per-γ executables lazily.
pub struct Verifier {
    runtime: Arc<Runtime>,
    pub method: Method,
    pub backend: Backend,
    batch: usize,
    vocab: usize,
}

impl Verifier {
    pub fn new(
        runtime: Arc<Runtime>,
        method: Method,
        backend: Backend,
        batch: usize,
        vocab: usize,
    ) -> Self {
        Verifier {
            runtime,
            method,
            backend,
            batch,
            vocab,
        }
    }

    /// γ values this verifier can serve for its default method.
    pub fn available_gammas(&self) -> Vec<usize> {
        self.available_gammas_for(self.method)
    }

    /// γ values this verifier can serve for `method` (artifact
    /// availability) — per-request method overrides are admitted only
    /// when this is non-empty.
    pub fn available_gammas_for(&self, method: Method) -> Vec<usize> {
        match self.backend {
            Backend::Native => (1..=64).collect(),
            Backend::Hlo => self
                .runtime
                .manifest
                .verify_gammas(method.name(), self.batch, self.vocab),
        }
    }

    /// Run verification for `gamma` draft positions with `method` (the
    /// engine default, or a per-request override).
    ///
    /// Returns the output plus the *execution* seconds — artifact
    /// compilation (lazy, first touch per γ) is deliberately excluded so
    /// Δ%-profiling comparisons between methods are not biased by which
    /// method ran first (the paper's timings are steady-state too).
    pub fn verify(
        &self,
        gamma: usize,
        method: Method,
        ins: &VerifyInputs<'_>,
    ) -> Result<(VerifyOutput, f64)> {
        let (b, v) = (self.batch, self.vocab);
        debug_assert_eq!(ins.z_p.len(), b * (gamma + 1) * v);
        debug_assert_eq!(ins.z_q.len(), b * gamma * v);
        match self.backend {
            Backend::Native => {
                let started = std::time::Instant::now();
                let _scope = self.runtime.profiler.scope("verify");
                let (accept_len, out_tokens) = sampling::verify::spec_step_batch(
                    ins.z_p,
                    ins.z_q,
                    b,
                    gamma,
                    v,
                    ins.draft,
                    ins.u_acc,
                    ins.u_res,
                    ins.u_bonus,
                    method,
                    Some(&self.runtime.profiler),
                );
                Ok((
                    VerifyOutput {
                        accept_len,
                        out_tokens,
                    },
                    started.elapsed().as_secs_f64(),
                ))
            }
            Backend::Hlo => {
                // compile outside the timed region
                let exe = self.runtime.load_verify(method.name(), b, gamma, v)?;
                let started = std::time::Instant::now();
                let _scope = self.runtime.profiler.scope("verify");
                let mut inputs = vec![
                    HostTensor::f32(&[b, gamma + 1, v], ins.z_p.to_vec()),
                    HostTensor::f32(&[b, gamma, v], ins.z_q.to_vec()),
                    HostTensor::i32(&[b, gamma], ins.draft.to_vec()),
                    HostTensor::f32(&[b, gamma], ins.u_acc.to_vec()),
                    HostTensor::f32(&[b], ins.u_res.to_vec()),
                    HostTensor::f32(&[b], ins.u_bonus.to_vec()),
                ];
                if let Some((alpha, beta)) = method.alpha_beta() {
                    inputs.push(HostTensor::f32(&[2], vec![alpha, beta]));
                }
                let out = exe.run(&inputs)?;
                let result = VerifyOutput {
                    accept_len: out[0].as_i32()?.to_vec(),
                    out_tokens: out[1].as_i32()?.to_vec(),
                };
                Ok((result, started.elapsed().as_secs_f64()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Backend parsing is trivial; HLO-vs-native equivalence is covered by
    // rust/tests/it_runtime.rs (needs built artifacts).
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("hlo"), Some(Backend::Hlo));
        assert_eq!(Backend::parse("native"), Some(Backend::Native));
        assert_eq!(Backend::parse("x"), None);
    }
}
