//! Adaptive draft-length (γ) controller.
//!
//! §4.1: *"Initially, γ is set to 5 and increases by 2 if all speculative
//! tokens sampled from the draft model are accepted; otherwise, it
//! decreases by 1."* — the heuristic of HF transformers' assisted
//! generation, reimplemented here with explicit bounds so the engine can
//! only request γ values that exist as AOT artifacts.

#[derive(Debug, Clone)]
pub struct GammaController {
    gamma: usize,
    min: usize,
    max: usize,
    /// when pinned, update() is a no-op (used by the γ-sweep experiments)
    pinned: bool,
}

impl GammaController {
    pub fn new(init: usize, min: usize, max: usize) -> Self {
        assert!(min >= 1 && min <= max, "bad gamma bounds [{min}, {max}]");
        GammaController {
            gamma: init.clamp(min, max),
            min,
            max,
            pinned: false,
        }
    }

    /// Fixed γ (figures 3-5 sweep a pinned initial value).
    pub fn pinned(gamma: usize) -> Self {
        GammaController {
            gamma,
            min: gamma,
            max: gamma,
            pinned: true,
        }
    }

    pub fn gamma(&self) -> usize {
        self.gamma
    }

    /// Apply the +2/−1 rule after a verification step.
    pub fn update(&mut self, all_accepted: bool) {
        if self.pinned {
            return;
        }
        self.gamma = if all_accepted {
            (self.gamma + 2).min(self.max)
        } else {
            self.gamma.saturating_sub(1).max(self.min)
        };
    }

    /// γ to actually use this step given per-slot context headroom
    /// (each slot needs room for γ drafts + 1 emitted token).
    pub fn effective(&self, min_headroom: usize) -> usize {
        self.gamma.min(min_headroom.saturating_sub(1)).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, Config};

    #[test]
    fn follows_paper_heuristic() {
        let mut c = GammaController::new(5, 1, 20);
        c.update(true);
        assert_eq!(c.gamma(), 7);
        c.update(true);
        assert_eq!(c.gamma(), 9);
        c.update(false);
        assert_eq!(c.gamma(), 8);
    }

    #[test]
    fn clamps_to_bounds() {
        let mut c = GammaController::new(19, 1, 20);
        c.update(true);
        assert_eq!(c.gamma(), 20);
        let mut c = GammaController::new(1, 1, 20);
        c.update(false);
        assert_eq!(c.gamma(), 1);
    }

    #[test]
    fn pinned_never_moves() {
        let mut c = GammaController::pinned(3);
        c.update(true);
        c.update(false);
        assert_eq!(c.gamma(), 3);
    }

    #[test]
    fn effective_respects_headroom() {
        let c = GammaController::new(5, 1, 20);
        assert_eq!(c.effective(100), 5);
        assert_eq!(c.effective(4), 3); // room for 3 drafts + 1 emit
        assert_eq!(c.effective(1), 1); // never below 1
    }

    #[test]
    fn prop_gamma_always_in_bounds() {
        forall("gamma bounds", Config { cases: 100, ..Config::default() }, |rng, _| {
            let mut c = GammaController::new(5, 1, 20);
            for _ in 0..200 {
                c.update(rng.below(2) == 1);
                if !(1..=20).contains(&c.gamma()) {
                    return Err(format!("gamma {} out of bounds", c.gamma()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_update_law() {
        forall("gamma +2/-1", Config { cases: 60, ..Config::default() }, |rng, _| {
            let mut c = GammaController::new(5, 1, 20);
            for _ in 0..50 {
                let before = c.gamma();
                let ok = rng.below(2) == 1;
                c.update(ok);
                let expect = if ok { (before + 2).min(20) } else { (before - 1).max(1) };
                if c.gamma() != expect {
                    return Err(format!("{before} -{ok}-> {} != {expect}", c.gamma()));
                }
            }
            Ok(())
        });
    }
}
