//! Request/response types for the serving engine.

/// A generation request (token-id level; the server layer handles text).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt_ids: Vec<i32>,
    /// raw prompt text, encoded by whichever layer owns the tokenizer
    /// (the TCP server's engine thread); ignored when `prompt_ids` is set
    pub prompt_text: Option<String>,
    pub max_new_tokens: usize,
    /// target-model sampling temperature; `0.0` = greedy
    pub temperature: f32,
    /// draft-model sampling temperature (the draft usually samples at the
    /// same temperature; exposed because greedy drafting raises acceptance)
    pub draft_temperature: f32,
    /// per-request RNG stream seed
    pub seed: u64,
}

impl GenRequest {
    pub fn new(id: u64, prompt_ids: Vec<i32>, max_new_tokens: usize) -> Self {
        GenRequest {
            id,
            prompt_ids,
            prompt_text: None,
            max_new_tokens,
            temperature: 0.8,
            draft_temperature: 0.8,
            seed: id,
        }
    }

    pub fn greedy(mut self) -> Self {
        self.temperature = 0.0;
        self.draft_temperature = 0.0;
        self
    }

    pub fn with_temperature(mut self, t: f32) -> Self {
        self.temperature = t;
        self.draft_temperature = t;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// hit `max_new_tokens`
    Length,
    /// generated EOS
    Stop,
    /// ran out of model context (S)
    Context,
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    /// newly generated token ids (prompt excluded)
    pub token_ids: Vec<i32>,
    pub finish: FinishReason,
    /// decode steps this request was live for
    pub steps: usize,
    /// draft tokens proposed / accepted while this request was live
    pub drafted: usize,
    pub accepted: usize,
    /// request wall latency, seconds
    pub latency: f64,
}

impl GenResult {
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }

    /// mean tokens emitted per decode step (the speculative speedup proxy)
    pub fn tokens_per_step(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.token_ids.len() as f64 / self.steps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chain() {
        let r = GenRequest::new(7, vec![1, 2, 3], 40).greedy().with_seed(9);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.seed, 9);
        let r = GenRequest::new(8, vec![1], 10).with_temperature(1.3);
        assert_eq!(r.draft_temperature, 1.3);
    }

    #[test]
    fn result_rates() {
        let r = GenResult {
            id: 1,
            token_ids: vec![5; 30],
            finish: FinishReason::Length,
            steps: 10,
            drafted: 50,
            accepted: 20,
            latency: 0.5,
        };
        assert!((r.acceptance_rate() - 0.4).abs() < 1e-12);
        assert!((r.tokens_per_step() - 3.0).abs() < 1e-12);
    }
}
