//! Request/response types for the serving engine.
//!
//! [`SamplingParams`] is the single source of per-request policy: every
//! layer (wire protocol, CLI, evaluation harness, engine) builds requests
//! from `SamplingParams::default()` plus explicit overrides, and
//! [`SamplingParams::validate`] is the one place admission rules live.

use crate::sampling::Method;
use crate::tokenizer::Tokenizer;

/// Per-request sampling and decoding policy.
///
/// Defaults (one source of truth — the wire protocol, the CLI and
/// `GenRequest` all derive from it): `max_new_tokens` 64, `temperature`
/// 0.8, draft follows the target temperature, no top-k/top-p truncation,
/// no stop sequences, seed derived from the request id.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    pub max_new_tokens: usize,
    /// target-model sampling temperature; `0.0` = greedy
    pub temperature: f32,
    /// draft-model sampling temperature; `None` follows `temperature`
    /// (exposed because greedy drafting raises acceptance)
    pub draft_temperature: Option<f32>,
    /// keep only the k most probable target tokens (`0` = disabled).
    /// Honored by the speculative pipeline; autoregressive engines
    /// reject filtered requests at admission (sampling happens inside
    /// the target_step artifact there).
    pub top_k: usize,
    /// nucleus truncation of the target distribution (`1.0` = disabled;
    /// same speculative-only caveat as `top_k`)
    pub top_p: f32,
    /// stop sequences (text level; tokenized at admission by whichever
    /// layer owns the tokenizer). The matched sequence is trimmed from
    /// the output.
    pub stop: Vec<String>,
    /// per-request RNG stream seed; `None` derives from the request id
    pub seed: Option<u64>,
    /// per-request draft-length override: caps this slot's adaptive
    /// controller while the request is active. γ is per-slot — batches
    /// are ragged, so other requests' γ values are unaffected (on the
    /// HLO backend, whose artifacts are rectangular, the step still
    /// collapses the per-slot plan to a shared γ)
    pub gamma: Option<usize>,
    /// with `gamma`, bypass this slot's adaptive controller entirely
    /// (pin). A pin replaces the controller's value, not artifact
    /// reality: the per-slot plan still snaps γ down to the largest
    /// value the slot's verification method has artifacts for, and
    /// clamps by the model pair's draft capacity and the request's
    /// remaining sequence headroom.
    pub gamma_pinned: bool,
    /// per-request verification-method override, honored per-slot on any
    /// batch size (the verifier dispatches each batch row under its own
    /// method). On the HLO backend, admission requires verify artifacts
    /// for the method that share at least one γ with the engine's
    /// default method (`method_gamma_conflict` otherwise); the native
    /// backend accepts any method at any γ.
    pub method: Option<Method>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            max_new_tokens: 64,
            temperature: 0.8,
            draft_temperature: None,
            top_k: 0,
            top_p: 1.0,
            stop: Vec::new(),
            seed: None,
            gamma: None,
            gamma_pinned: false,
            method: None,
        }
    }
}

impl SamplingParams {
    pub fn with_max_new_tokens(mut self, n: usize) -> Self {
        self.max_new_tokens = n;
        self
    }

    /// Set the target temperature (draft keeps following it).
    pub fn with_temperature(mut self, t: f32) -> Self {
        self.temperature = t;
        self
    }

    pub fn with_draft_temperature(mut self, t: f32) -> Self {
        self.draft_temperature = Some(t);
        self
    }

    /// Greedy decoding: temperature 0 for target and draft.
    pub fn greedy(mut self) -> Self {
        self.temperature = 0.0;
        self.draft_temperature = None;
        self
    }

    pub fn with_top_k(mut self, k: usize) -> Self {
        self.top_k = k;
        self
    }

    pub fn with_top_p(mut self, p: f32) -> Self {
        self.top_p = p;
        self
    }

    pub fn with_stop(mut self, stop: Vec<String>) -> Self {
        self.stop = stop;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Cap the adaptive γ controller at `g` while this request is active.
    pub fn with_gamma(mut self, g: usize) -> Self {
        self.gamma = Some(g);
        self
    }

    /// Pin γ to exactly `g` for this request (bypasses the controller).
    pub fn pin_gamma(mut self, g: usize) -> Self {
        self.gamma = Some(g);
        self.gamma_pinned = true;
        self
    }

    pub fn with_method(mut self, m: Method) -> Self {
        self.method = Some(m);
        self
    }

    /// Effective draft temperature (follows `temperature` unless set).
    pub fn draft_temp(&self) -> f32 {
        self.draft_temperature.unwrap_or(self.temperature)
    }

    /// Effective RNG seed for a request with id `id`.
    pub fn seed_or(&self, id: u64) -> u64 {
        self.seed.unwrap_or(id)
    }

    /// Admission validation — the one place request policy rules live.
    /// Model-dependent checks (prompt length, artifact availability) are
    /// in [`crate::engine::Engine::admissible`].
    pub fn validate(&self) -> Result<(), String> {
        if self.max_new_tokens == 0 {
            return Err("max_new_tokens must be >= 1".into());
        }
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            return Err(format!(
                "temperature must be finite and >= 0, got {}",
                self.temperature
            ));
        }
        if let Some(t) = self.draft_temperature {
            if !t.is_finite() || t < 0.0 {
                return Err(format!(
                    "draft_temperature must be finite and >= 0, got {t}"
                ));
            }
        }
        if !self.top_p.is_finite() || self.top_p <= 0.0 || self.top_p > 1.0 {
            return Err(format!(
                "top_p must be in (0, 1], got {}",
                self.top_p
            ));
        }
        if self.stop.len() > 16 {
            return Err(format!(
                "at most 16 stop sequences, got {}",
                self.stop.len()
            ));
        }
        if self.stop.iter().any(String::is_empty) {
            return Err("stop sequences must be non-empty".into());
        }
        if self.gamma == Some(0) {
            return Err("gamma override must be >= 1".into());
        }
        if self.gamma_pinned && self.gamma.is_none() {
            return Err("gamma_pinned requires gamma".into());
        }
        Ok(())
    }
}

/// A generation request (token-id level; the server layer handles text).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt_ids: Vec<i32>,
    /// raw prompt text, encoded by whichever layer owns the tokenizer
    /// (the TCP server's engine thread); ignored when `prompt_ids` is set
    pub prompt_text: Option<String>,
    /// sampling policy — the request's single source of decode knobs
    pub params: SamplingParams,
    /// `params.stop` tokenized (filled by whichever layer owns the
    /// tokenizer); empty when no stop sequences apply
    pub stop_ids: Vec<Vec<i32>>,
}

impl GenRequest {
    pub fn new(id: u64, prompt_ids: Vec<i32>, params: SamplingParams) -> Self {
        GenRequest {
            id,
            prompt_ids,
            prompt_text: None,
            params,
            stop_ids: Vec::new(),
        }
    }

    /// Text-prompt request; `prompt_ids` is filled at admission by the
    /// layer that owns the tokenizer.
    pub fn from_text(id: u64, prompt: String, params: SamplingParams) -> Self {
        GenRequest {
            id,
            prompt_ids: Vec::new(),
            prompt_text: Some(prompt),
            params,
            stop_ids: Vec::new(),
        }
    }

    /// Tokenize `params.stop` into `stop_ids` (char-level tokenizer, so
    /// text-level and token-level matching coincide).
    pub fn tokenize_stops(mut self, tok: &Tokenizer) -> Self {
        self.stop_ids = self.params.stop.iter().map(|s| tok.encode(s)).collect();
        self
    }

    // Thin conveniences over `params` (the common test/bench idioms).

    pub fn greedy(mut self) -> Self {
        self.params = self.params.greedy();
        self
    }

    pub fn with_temperature(mut self, t: f32) -> Self {
        self.params = self.params.with_temperature(t);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.params = self.params.with_seed(seed);
        self
    }
}

/// If `generated` ends with one of `stops`, return the matched length
/// (longest match wins so the whole sequence can be trimmed).
pub fn match_stop_suffix(generated: &[i32], stops: &[Vec<i32>]) -> Option<usize> {
    stops
        .iter()
        .filter(|s| !s.is_empty() && s.len() <= generated.len())
        .filter(|s| &generated[generated.len() - s.len()..] == s.as_slice())
        .map(Vec::len)
        .max()
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// hit `max_new_tokens`
    Length,
    /// generated EOS
    Stop,
    /// matched a per-request stop sequence
    StopSeq,
    /// ran out of model context (S)
    Context,
    /// cancelled by the client (wire `{"op":"cancel"}` or
    /// [`crate::engine::Engine::cancel`])
    Cancelled,
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    /// newly generated token ids (prompt excluded)
    pub token_ids: Vec<i32>,
    pub finish: FinishReason,
    /// decode steps this request was live for
    pub steps: usize,
    /// draft tokens proposed / accepted while this request was live
    pub drafted: usize,
    pub accepted: usize,
    /// request wall latency, seconds
    pub latency: f64,
}

impl GenResult {
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }

    /// mean tokens emitted per decode step (the speculative speedup proxy)
    pub fn tokens_per_step(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.token_ids.len() as f64 / self.steps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_defaults_are_the_single_source() {
        let p = SamplingParams::default();
        assert_eq!(p.max_new_tokens, 64);
        assert!((p.temperature - 0.8).abs() < 1e-6);
        assert_eq!(p.draft_temperature, None);
        assert!((p.draft_temp() - 0.8).abs() < 1e-6);
        assert_eq!(p.top_k, 0);
        assert!((p.top_p - 1.0).abs() < 1e-6);
        assert!(p.stop.is_empty());
        assert_eq!(p.seed, None);
        assert_eq!(p.seed_or(42), 42);
        assert_eq!(p.gamma, None);
        assert_eq!(p.method, None);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn params_builder_chain() {
        let p = SamplingParams::default()
            .with_temperature(0.7)
            .with_top_k(40)
            .with_top_p(0.9)
            .with_seed(9)
            .with_stop(vec!["\n".into()])
            .pin_gamma(3);
        assert!((p.temperature - 0.7).abs() < 1e-6);
        assert!((p.draft_temp() - 0.7).abs() < 1e-6);
        assert_eq!(p.top_k, 40);
        assert_eq!(p.seed_or(1), 9);
        assert_eq!(p.gamma, Some(3));
        assert!(p.gamma_pinned);
        assert!(p.validate().is_ok());

        let g = SamplingParams::default().with_draft_temperature(0.2).greedy();
        assert_eq!(g.temperature, 0.0);
        assert_eq!(g.draft_temp(), 0.0);
    }

    #[test]
    fn params_validation_rejects_bad_values() {
        let bad = [
            SamplingParams::default().with_max_new_tokens(0),
            SamplingParams::default().with_temperature(-0.1),
            SamplingParams::default().with_temperature(f32::NAN),
            SamplingParams::default().with_draft_temperature(-1.0),
            SamplingParams::default().with_top_p(0.0),
            SamplingParams::default().with_top_p(1.5),
            SamplingParams::default().with_stop(vec!["".into()]),
            SamplingParams::default().with_gamma(0),
        ];
        for p in bad {
            assert!(p.validate().is_err(), "{p:?} should be rejected");
        }
        let mut pinned_without_gamma = SamplingParams::default();
        pinned_without_gamma.gamma_pinned = true;
        assert!(pinned_without_gamma.validate().is_err());
    }

    #[test]
    fn request_builder_chain() {
        let r = GenRequest::new(
            7,
            vec![1, 2, 3],
            SamplingParams::default().with_max_new_tokens(40),
        )
        .greedy()
        .with_seed(9);
        assert_eq!(r.params.temperature, 0.0);
        assert_eq!(r.params.seed_or(7), 9);
        assert_eq!(r.params.max_new_tokens, 40);
        let r = GenRequest::new(8, vec![1], SamplingParams::default())
            .with_temperature(1.3);
        assert!((r.params.draft_temp() - 1.3).abs() < 1e-6);
    }

    #[test]
    fn stop_suffix_matching() {
        let stops = vec![vec![5, 6], vec![9], vec![4, 5, 6]];
        assert_eq!(match_stop_suffix(&[1, 2, 9], &stops), Some(1));
        // longest match wins
        assert_eq!(match_stop_suffix(&[1, 4, 5, 6], &stops), Some(3));
        assert_eq!(match_stop_suffix(&[1, 2, 5, 6], &stops), Some(2));
        assert_eq!(match_stop_suffix(&[1, 2, 3], &stops), None);
        assert_eq!(match_stop_suffix(&[], &stops), None);
        // empty stop entries are ignored
        assert_eq!(match_stop_suffix(&[1], &[vec![]]), None);
    }

    #[test]
    fn result_rates() {
        let r = GenResult {
            id: 1,
            token_ids: vec![5; 30],
            finish: FinishReason::Length,
            steps: 10,
            drafted: 50,
            accepted: 20,
            latency: 0.5,
        };
        assert!((r.acceptance_rate() - 0.4).abs() < 1e-12);
        assert!((r.tokens_per_step() - 3.0).abs() < 1e-12);
    }
}
