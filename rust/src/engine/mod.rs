//! L3 coordinator: the speculative-decoding serving engine.
//!
//! The paper's contribution is the verification kernel; the system around
//! it here is a vLLM-style serving loop specialised for speculative
//! sampling:
//!
//! * [`request`] — request/result types and [`SamplingParams`], the
//!   single source of per-request policy (defaults + validation)
//! * [`gamma`] — the adaptive draft-length controller (the HF heuristic
//!   the paper uses in §4.1: start at 5, +2 on all-accept, −1 otherwise)
//! * [`verifier`] — pluggable verification backends: the three AOT HLO
//!   methods (`baseline` / `exact` / `sigmoid`) plus a pure-rust `native`
//!   oracle backend
//! * [`core`] — continuous-batching decode loop over the PJRT artifacts
//! * [`pipeline`] — the pipelined decode scheduler: a depth-k chain of
//!   speculatively prefetched step blocks with per-slot partial-hit
//!   adoption at the commit barrier (bit-identical to the serial loop)
//! * [`stats`] — acceptance/time accounting for the paper's tables

pub mod core;
pub mod gamma;
pub mod pipeline;
pub mod request;
pub mod stats;
pub mod verifier;

pub use core::{AdmitError, Engine, EngineConfig, Mode};
pub use gamma::GammaController;
pub use pipeline::{PipelineMode, PipelineStats};
pub use request::{
    match_stop_suffix, FinishReason, GenRequest, GenResult, SamplingParams,
};
pub use stats::EngineStats;
pub use verifier::{Backend, Verifier, VerifyInputs, VerifyOutput};
