//! Engine-level accounting for the paper's evaluation tables.

use crate::util::stats::Series;

/// Counters + per-step series collected while the engine runs.
#[derive(Debug, Default)]
pub struct EngineStats {
    /// decode steps executed
    pub steps: usize,
    /// draft tokens proposed / accepted across all steps
    pub drafted: usize,
    pub accepted: usize,
    /// tokens emitted (accepted + resampled/bonus)
    pub emitted: usize,
    /// wall time of each decode step (seconds)
    pub step_time: Series,
    /// time inside the verification call stack per step (seconds) — the
    /// paper's "profiling time" series
    pub verify_time: Series,
    /// γ used at each step
    pub gamma_series: Series,
    /// completed requests
    pub finished: usize,
}

impl EngineStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }

    /// Σ verification time over all steps — the quantity Table 1 compares.
    pub fn profiling_time_total(&self) -> f64 {
        self.verify_time.sum()
    }

    pub fn tokens_per_step(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.emitted as f64 / self.steps as f64
    }

    pub fn record_step(
        &mut self,
        gamma: usize,
        drafted: usize,
        accepted: usize,
        emitted: usize,
        step_secs: f64,
        verify_secs: f64,
    ) {
        self.steps += 1;
        self.drafted += drafted;
        self.accepted += accepted;
        self.emitted += emitted;
        self.step_time.push(step_secs);
        self.verify_time.push(verify_secs);
        self.gamma_series.push(gamma as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut s = EngineStats::default();
        s.record_step(5, 5, 3, 4, 0.010, 0.004);
        s.record_step(4, 4, 4, 5, 0.008, 0.003);
        assert_eq!(s.steps, 2);
        assert!((s.acceptance_rate() - 7.0 / 9.0).abs() < 1e-12);
        assert!((s.profiling_time_total() - 0.007).abs() < 1e-12);
        assert!((s.tokens_per_step() - 4.5).abs() < 1e-12);
        assert!((s.gamma_series.mean() - 4.5).abs() < 1e-12);
    }

    #[test]
    fn zero_safe() {
        let s = EngineStats::default();
        assert_eq!(s.acceptance_rate(), 0.0);
        assert_eq!(s.tokens_per_step(), 0.0);
    }
}
