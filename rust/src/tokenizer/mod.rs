//! Char-level tokenizer — loads the table written by the python build
//! (`artifacts/tokenizer.json`) so L3 encodes/decodes exactly like L2
//! trained.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
const N_SPECIALS: i32 = 3;

/// Char-level tokenizer with pad/bos/eos specials and a padded vocab.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    chars: Vec<char>,
    /// char -> id lookup (ids start at N_SPECIALS)
    index: std::collections::HashMap<char, i32>,
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn from_chars(chars: Vec<char>, vocab_size: usize) -> Result<Self> {
        if vocab_size < chars.len() + N_SPECIALS as usize {
            bail!(
                "vocab_size {} too small for {} chars + specials",
                vocab_size,
                chars.len()
            );
        }
        let index = chars
            .iter()
            .enumerate()
            .map(|(i, c)| (*c, i as i32 + N_SPECIALS))
            .collect();
        Ok(Tokenizer {
            chars,
            index,
            vocab_size,
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tokenizer {}", path.display()))?;
        Self::from_json(&text)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let vocab_size = v
            .req("vocab_size")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_usize()
            .context("vocab_size not an int")?;
        let chars: Vec<char> = v
            .req("chars")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .context("chars not an array")?
            .iter()
            .map(|c| {
                c.as_str()
                    .and_then(|s| s.chars().next())
                    .context("bad char entry")
            })
            .collect::<Result<_>>()?;
        Self::from_chars(chars, vocab_size)
    }

    /// Encode text; unknown characters are skipped (the build corpus
    /// defines the closed character set).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.chars()
            .filter_map(|c| self.index.get(&c).copied())
            .collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter_map(|&id| {
                let idx = id - N_SPECIALS;
                if idx >= 0 && (idx as usize) < self.chars.len() {
                    Some(self.chars[idx as usize])
                } else {
                    None
                }
            })
            .collect()
    }

    /// Decode stopping at the first EOS / PAD.
    pub fn decode_until_stop(&self, ids: &[i32]) -> String {
        let end = ids
            .iter()
            .position(|&t| t == EOS || t == PAD)
            .unwrap_or(ids.len());
        self.decode(&ids[..end])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::from_json(
            r#"{"type":"char","vocab_size":128,
                "specials":{"pad":0,"bos":1,"eos":2},
                "chars":[" ",".","a","b","c","d","e"]}"#,
        )
        .unwrap()
    }

    #[test]
    fn encode_decode_round_trip() {
        let t = tok();
        let text = "abc de.";
        assert_eq!(t.decode(&t.encode(text)), text);
    }

    #[test]
    fn ids_start_after_specials() {
        let t = tok();
        assert!(t.encode("a").iter().all(|&id| id >= 3));
    }

    #[test]
    fn unknown_chars_skipped() {
        let t = tok();
        assert_eq!(t.decode(&t.encode("aXb")), "ab");
    }

    #[test]
    fn decode_stops_at_eos() {
        let t = tok();
        let mut ids = t.encode("abc");
        ids.push(EOS);
        ids.extend(t.encode("dd"));
        assert_eq!(t.decode_until_stop(&ids), "abc");
    }

    #[test]
    fn decode_ignores_out_of_range() {
        let t = tok();
        // 'a' = chars[2] -> id 5, 'b' = chars[3] -> id 6
        assert_eq!(t.decode(&[-1, 5, 999, 6]), "ab");
    }

    #[test]
    fn vocab_too_small_rejected() {
        let r = Tokenizer::from_chars(vec!['a', 'b'], 4);
        assert!(r.is_err());
    }
}
