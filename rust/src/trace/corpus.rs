//! The committed trace regression corpus (`specd trace corpus`).
//!
//! A fixed registry of named [`FuzzCase`]s spanning the feature matrix
//! — mixed methods, ragged γ with mid-flight refill, pipelined on/off,
//! depth-3 windows with per-slot partial adoption, mid-decode cancels,
//! the fp16-overflow sigmoid τ — each with a recording committed at
//! `rust/tests/corpus/<name>.sptr`. For every
//! entry the gate does two independent checks:
//!
//! 1. **oracle replay** — [`super::check`] re-executes the *committed*
//!    trace against the scalar oracle; a change to the sampling
//!    kernels, the verifier or the commit state machine that would
//!    alter a historical run is flagged at the exact step/slot/field;
//! 2. **re-record compare** — the same case is recorded fresh on
//!    today's engine and diffed against the committed bytes
//!    ([`super::format::first_difference`]); a change to the engine,
//!    scheduler or trace layer that perturbs the event stream — an RNG
//!    stream position, a refill flag, an accept length — is flagged at
//!    the first differing event.
//!
//! Recordings are byte-deterministic for a fixed case (the CI SIMD gate
//! `cmp`s recordings from independent processes), and the SIMD lane
//! paths are bit-identical by contract — so one committed file covers
//! `SPECD_SIMD` on and off. Regeneration (`--regen`) is for
//! *intentional* semantic changes only and should be called out in
//! review; see `docs/TESTING.md`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::engine::PipelineMode;
use crate::sampling::Method;

use super::checker::check;
use super::format::{self, first_difference};
use super::fuzz::{record_case, FuzzCase};

/// One named corpus recording.
pub struct CorpusEntry {
    /// file stem of the committed recording (`<name>.sptr`)
    pub name: &'static str,
    /// one-line description of what the entry pins down
    pub what: &'static str,
    /// the deterministic schedule that produced (and reproduces) it
    pub case: FuzzCase,
}

/// The corpus registry. Append-only by convention: new feature axes get
/// new entries; existing entries change only with an intentional
/// `--regen` (a semantic change to historical runs).
pub fn entries() -> Vec<CorpusEntry> {
    vec![
        CorpusEntry {
            name: "mixed_methods_pipelined",
            what: "pipelined batch-2 decode, per-request method overrides, queue churn",
            case: FuzzCase {
                batch: 2,
                n_reqs: 4,
                mixed_methods: true,
                seed: 7,
                ..FuzzCase::default()
            },
        },
        CorpusEntry {
            name: "ragged_gamma_refill",
            what: "genuinely ragged γ pins {2,5,7} over 3 slots with mid-flight refill",
            case: FuzzCase {
                batch: 3,
                n_reqs: 6,
                gmax: 8,
                pin_gammas: vec![2, 5, 7],
                mixed_methods: true,
                seed: 9,
                ..FuzzCase::default()
            },
        },
        CorpusEntry {
            name: "serial_baseline",
            what: "same shape as mixed_methods_pipelined with the pipeline off",
            case: FuzzCase {
                batch: 2,
                n_reqs: 4,
                mixed_methods: true,
                pipeline: PipelineMode::Off,
                seed: 7,
                ..FuzzCase::default()
            },
        },
        CorpusEntry {
            name: "cancel_churn",
            what: "mid-decode cancels landing on live slots during queue churn",
            case: FuzzCase {
                batch: 2,
                n_reqs: 6,
                mixed_methods: true,
                cancels: vec![(1, 0), (3, 2)],
                seed: 21,
                ..FuzzCase::default()
            },
        },
        CorpusEntry {
            name: "single_slot_stops",
            what: "batch-1 decode with token-level stop sequences and γ overrides",
            case: FuzzCase {
                batch: 1,
                n_reqs: 3,
                max_new: 24,
                seed: 33,
                ..FuzzCase::default()
            },
        },
        CorpusEntry {
            name: "sigmoid16_tau_overflow",
            what: "fp16-overflow sigmoid τ (NaN rejects every draft) as the engine default",
            case: FuzzCase {
                batch: 2,
                n_reqs: 4,
                method: Method::sigmoid16(-1e5, 1e5),
                seed: 12,
                ..FuzzCase::default()
            },
        },
        CorpusEntry {
            name: "partial_adoption_depth3",
            what: "depth-3 window at low agreement: per-slot salvage, cascade cancels, churn",
            case: FuzzCase {
                batch: 3,
                n_reqs: 5,
                agreement: 0.7,
                pipeline_depth: 3,
                mixed_methods: true,
                seed: 17,
                ..FuzzCase::default()
            },
        },
    ]
}

/// Where the committed corpus lives in this repository. The CLI default
/// resolves relative to the crate root at build time; checkouts running
/// an installed binary pass `--dir`.
pub fn default_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/corpus")
}

/// One entry's gate outcome.
#[derive(Debug, Clone, Default)]
pub struct EntryOutcome {
    pub name: String,
    /// decode steps oracle-replayed from the committed trace
    pub steps: usize,
    /// committed tokens verified during replay
    pub tokens: usize,
    /// the committed file was absent and has been seeded from a fresh
    /// (determinism-checked, oracle-replayed) recording
    pub bootstrapped: bool,
    /// why the entry failed, pinned to the exact step/field (replay
    /// divergence) or first differing event (re-record mismatch)
    pub failure: Option<String>,
}

/// Seed a missing committed file. Snapshot-test bootstrap semantics:
/// record the case twice (proving the byte-compare gate is sound for
/// this case), oracle-replay the recording, then write it. Every later
/// run byte-compares against the seeded file.
fn bootstrap_entry(entry: &CorpusEntry, dir: &Path, out: &mut EntryOutcome) {
    out.bootstrapped = true;
    let fresh = match record_case(&entry.case) {
        Ok((t, _rec)) => t,
        Err(e) => {
            out.failure = Some(format!("seed recording failed: {e:#}"));
            return;
        }
    };
    let again = match record_case(&entry.case) {
        Ok((t, _rec)) => t,
        Err(e) => {
            out.failure = Some(format!("seed re-recording failed: {e:#}"));
            return;
        }
    };
    if let Some(diff) = first_difference(&fresh, &again) {
        out.failure = Some(format!("case is not record-deterministic: {diff}"));
        return;
    }
    match check(&fresh) {
        Ok(report) => {
            out.steps = report.steps;
            out.tokens = report.tokens;
            if let Some(d) = report.divergence {
                out.failure = Some(format!("oracle replay of seed recording: {d}"));
                return;
            }
        }
        Err(e) => {
            out.failure = Some(format!("seed recording unreplayable: {e}"));
            return;
        }
    }
    if let Err(e) = std::fs::create_dir_all(dir) {
        out.failure = Some(format!("creating {}: {e}", dir.display()));
        return;
    }
    let path = dir.join(format!("{}.sptr", entry.name));
    if let Err(e) = format::save_binary(&fresh, &path) {
        out.failure = Some(format!("writing {}: {e}", path.display()));
    }
}

/// Gate one entry: oracle-replay the committed recording, then
/// re-record the case and diff. A missing committed file is seeded
/// (see [`bootstrap_entry`]) rather than failed, so a fresh checkout
/// converges to a pinned corpus on first run.
pub fn verify_entry(entry: &CorpusEntry, dir: &Path) -> EntryOutcome {
    let mut out = EntryOutcome {
        name: entry.name.to_string(),
        ..EntryOutcome::default()
    };
    let path = dir.join(format!("{}.sptr", entry.name));
    if !path.exists() {
        bootstrap_entry(entry, dir, &mut out);
        return out;
    }
    let committed = match format::load(&path) {
        Ok(t) => t,
        Err(e) => {
            out.failure = Some(format!("cannot load {}: {e}", path.display()));
            return out;
        }
    };

    // 1. the committed historical run must still replay bit-identically
    match check(&committed) {
        Ok(report) => {
            out.steps = report.steps;
            out.tokens = report.tokens;
            if let Some(d) = report.divergence {
                out.failure = Some(format!("oracle replay of committed trace: {d}"));
                return out;
            }
        }
        Err(e) => {
            out.failure = Some(format!("committed trace unreplayable: {e}"));
            return out;
        }
    }

    // 2. today's engine must still produce the identical recording
    let fresh = match record_case(&entry.case) {
        Ok((t, _rec)) => t,
        Err(e) => {
            out.failure = Some(format!("re-recording failed: {e:#}"));
            return out;
        }
    };
    if let Some(diff) = first_difference(&committed, &fresh) {
        out.failure = Some(format!("re-record differs from committed trace: {diff}"));
    }
    out
}

/// (Re)record one entry's committed file.
pub fn regen_entry(entry: &CorpusEntry, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let (trace, _rec) = record_case(&entry.case)
        .with_context(|| format!("recording corpus entry {}", entry.name))?;
    let path = dir.join(format!("{}.sptr", entry.name));
    format::save_binary(&trace, &path).map_err(|e| anyhow::anyhow!(e))?;
    Ok(())
}

/// Corpus-gate summary.
#[derive(Debug, Clone, Default)]
pub struct CorpusReport {
    pub entries: usize,
    pub steps: usize,
    pub tokens: usize,
    /// entries whose committed file was absent and has been seeded
    pub seeded: usize,
    /// every failing entry (the gate checks all entries before failing)
    pub failures: Vec<String>,
}

impl CorpusReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Run the corpus gate (or `--regen` it). `name` filters to a single
/// entry; `log` receives one line per entry.
pub fn run(
    dir: &Path,
    name: Option<&str>,
    regen: bool,
    mut log: impl FnMut(String),
) -> Result<CorpusReport> {
    let all = entries();
    let selected: Vec<&CorpusEntry> = match name {
        Some(n) => {
            let found: Vec<_> = all.iter().filter(|e| e.name == n).collect();
            if found.is_empty() {
                bail!(
                    "no corpus entry named {n:?} (have: {})",
                    all.iter().map(|e| e.name).collect::<Vec<_>>().join(", ")
                );
            }
            found
        }
        None => all.iter().collect(),
    };
    let mut report = CorpusReport::default();
    for entry in selected {
        if regen {
            regen_entry(entry, dir)?;
            log(format!("{} — regenerated ({})", entry.name, entry.what));
            report.entries += 1;
            continue;
        }
        let out = verify_entry(entry, dir);
        match out.failure {
            None => {
                let verb = if out.bootstrapped { "seeded" } else { "ok" };
                log(format!(
                    "{} — {verb} ({} steps, {} tokens): {}",
                    out.name, out.steps, out.tokens, entry.what
                ));
                report.entries += 1;
                report.steps += out.steps;
                report.tokens += out.tokens;
                report.seeded += usize::from(out.bootstrapped);
            }
            Some(f) => {
                let line = format!("{} — FAILED: {f}", out.name);
                log(line.clone());
                report.failures.push(line);
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_cases_deterministic() {
        let a = entries();
        let b = entries();
        assert_eq!(a.len(), b.len());
        let mut names: Vec<_> = a.iter().map(|e| e.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), a.len(), "duplicate corpus entry names");
        for (ea, eb) in a.iter().zip(b.iter()) {
            assert_eq!(format!("{:?}", ea.case), format!("{:?}", eb.case));
        }
    }

    #[test]
    fn entries_record_deterministically() {
        // the byte-compare gate is sound only if the same case records
        // the identical event stream twice — pipeline markers included
        let entry = &entries()[0];
        let (t1, _) = record_case(&entry.case).unwrap();
        let (t2, _) = record_case(&entry.case).unwrap();
        let diff = first_difference(&t1, &t2);
        assert_eq!(diff, None, "corpus case is not record-deterministic");
    }
}
