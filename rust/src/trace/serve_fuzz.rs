//! Serve-path fuzzing (`specd trace fuzz --serve`): randomized client
//! schedules through the real socket stack.
//!
//! Where [`super::fuzz`] drives the engine API directly, each
//! [`ServeFuzzCase`] here spins up the real [`crate::server::Server`]
//! over the simulated model pair and attacks it through actual TCP
//! connections — concurrent connects, streaming reads, mid-stream and
//! queued cancels, `queue_full`/`shed` bursts, mid-flight refill churn,
//! live `record` toggles — while a shared [`TraceRecorder`] records on
//! the server side. Afterwards the recording is replayed through the
//! offline oracle checker ([`super::check`]) and the serve-layer
//! invariants the engine checker cannot see are validated:
//!
//! - every request a client sent reaches **exactly one** terminal event
//!   (a `done` or a structured overload error), and the connection
//!   stays usable after it;
//! - `shed` errors honor the configured deadline (the server's own
//!   wait accounting, parsed back from the error message);
//! - SLO percentile blocks on every `done` are internally monotone
//!   (p50 ≤ p90 ≤ p95 ≤ p99, non-negative waits);
//! - in the trace, every admitted request reaches exactly one terminal
//!   (a finishing step or an in-slot cancel), admissions land in free
//!   slots, and refill flags match occupancy ([`super::serve_check`]).
//!
//! Case *parameters* are deterministic from the fuzz seed (a reported
//! failure reproduces the same schedule via `--seed N --case K`), but
//! socket interleavings are genuinely concurrent — the invariants above
//! are exactly the properties that must hold for *any* interleaving.
//! Cases that exercise the live `record` toggle produce traces with
//! gaps, which the offline checker by design refuses; those cases
//! validate the client-visible contract (acks, terminals, health) and
//! skip the oracle replay.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::engine::{Backend, Engine, EngineConfig, Mode, PipelineMode, SamplingParams};
use crate::runtime::{Runtime, SimSpec};
use crate::sampling::Method;
use crate::server::{Client, Server, ServerConfig};
use crate::tokenizer::Tokenizer;
use crate::util::json::Value;
use crate::util::rng::Pcg32;

use super::checker::{check, serve_check};
use super::recorder::TraceRecorder;

/// What a connection does with one request after sending it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqAction {
    /// drain to the terminal event
    Normal,
    /// send the cancel immediately after the generate — races admission:
    /// lands on a queued entry (queued-cancel), a live slot, or a
    /// request that already finished (no-op)
    CancelImmediately,
    /// read one event first, then cancel — usually a mid-decode cancel
    CancelAfterFirstEvent,
}

/// One planned request on one connection.
#[derive(Debug, Clone)]
pub struct ReqPlan {
    pub prompt: String,
    pub params: SamplingParams,
    pub streaming: bool,
    pub action: ReqAction,
}

/// One deterministic serve-path schedule.
#[derive(Debug, Clone)]
pub struct ServeFuzzCase {
    pub batch: usize,
    pub vocab: usize,
    /// draft/target agreement of the sim pair
    pub agreement: f32,
    /// sim model-pair seed
    pub model_seed: u64,
    /// engine RNG base seed
    pub engine_seed: u64,
    pub gamma_init: usize,
    pub gmax: usize,
    /// emulated per-model-call latency — makes queue/cancel races real
    pub model_delay_us: u64,
    /// server admission-queue bound (small values force `queue_full`)
    pub queue_limit: usize,
    /// load-shedding deadline for queued requests
    pub shed_after_ms: Option<u64>,
    /// concurrent client connections
    pub conns: usize,
    /// requests per connection
    pub reqs_per_conn: usize,
    /// send every generate up front, then drain (maximum queue
    /// pressure) — otherwise request-by-request
    pub burst: bool,
    /// connection 0 flips the live `record` gate between its requests;
    /// such traces have gaps and skip the oracle replay
    pub toggles: bool,
    /// derivation seed for the per-connection schedules
    pub seed: u64,
}

impl Default for ServeFuzzCase {
    fn default() -> Self {
        ServeFuzzCase {
            batch: 2,
            vocab: 96,
            agreement: 0.9,
            model_seed: 0xC0FFEE,
            engine_seed: 13,
            gamma_init: 4,
            gmax: 8,
            model_delay_us: 200,
            queue_limit: 4,
            shed_after_ms: None,
            conns: 3,
            reqs_per_conn: 2,
            burst: false,
            toggles: false,
            seed: 1,
        }
    }
}

impl ServeFuzzCase {
    fn sim_spec(&self) -> SimSpec {
        SimSpec {
            vocab: self.vocab,
            seq_len: 192,
            gmax: self.gmax,
            batches: vec![self.batch],
            seed: self.model_seed,
            agreement: self.agreement,
            model_delay: Duration::from_micros(self.model_delay_us),
        }
    }

    fn engine(&self) -> Result<Engine> {
        let rt = Arc::new(Runtime::simulated(self.sim_spec()));
        Engine::new(
            rt,
            EngineConfig {
                pair: "sim".into(),
                batch: self.batch,
                method: Method::Exact,
                backend: Backend::Native,
                mode: Mode::Speculative,
                gamma_init: self.gamma_init,
                gamma_pinned: false,
                self_draft: false,
                pipeline: PipelineMode::On,
                pipeline_depth: 2,
                pipeline_salvage: true,
                seed: self.engine_seed,
            },
        )
    }

    fn tokenizer(&self) -> Result<Tokenizer> {
        let chars: Vec<char> = (' '..='~').collect();
        let keep = chars.len().min(self.vocab - 3);
        Tokenizer::from_chars(chars[..keep].to_vec(), self.vocab)
    }

    /// Connection `conn`'s request schedule, derived deterministically
    /// from `self.seed`.
    pub fn schedule(&self, conn: usize) -> Vec<ReqPlan> {
        let mut rng = Pcg32::derive(self.seed, 0x5345_5256 + conn as u64); // "SERV"
        (0..self.reqs_per_conn)
            .map(|r| {
                let words = ["draft", "verify", "commit", "queue", "slot", "spec"];
                let mut prompt = String::new();
                for w in 0..1 + rng.below(3) {
                    if w > 0 {
                        prompt.push(' ');
                    }
                    prompt.push_str(words[rng.below(words.len() as u32) as usize]);
                }
                let mut p = SamplingParams::default()
                    .with_max_new_tokens(4 + rng.below(16) as usize)
                    .with_temperature([0.0, 0.5, 0.9, 1.1][rng.below(4) as usize])
                    .with_seed(self.seed.wrapping_mul(257).wrapping_add((conn * 31 + r) as u64));
                match rng.below(5) {
                    0 => p = p.with_top_k(12),
                    1 => p = p.with_top_p(0.9),
                    2 => p = p.pin_gamma(1 + rng.below(self.gmax as u32 - 1) as usize),
                    _ => {}
                }
                let action = match rng.below(5) {
                    0 => ReqAction::CancelImmediately,
                    1 => ReqAction::CancelAfterFirstEvent,
                    _ => ReqAction::Normal,
                };
                ReqPlan {
                    prompt,
                    params: p,
                    // cancels need an open stream to cancel into
                    streaming: action != ReqAction::Normal || rng.below(2) == 0,
                    action,
                }
            })
            .collect()
    }
}

/// Per-connection outcome counts plus every invariant violation seen.
#[derive(Debug, Clone, Default)]
pub struct ConnReport {
    pub reqs: usize,
    pub dones: usize,
    pub cancels: usize,
    pub queue_full: usize,
    pub shed: usize,
    pub deltas: usize,
    pub record_acks: usize,
    pub violations: Vec<String>,
}

/// One serve-fuzz case's aggregate outcome.
#[derive(Debug, Clone, Default)]
pub struct ServeCaseReport {
    pub reqs: usize,
    pub dones: usize,
    pub cancels: usize,
    pub queue_full: usize,
    pub shed: usize,
    pub deltas: usize,
    /// engine admissions observed in the trace
    pub admits: usize,
    /// mid-flight refill admissions observed in the trace
    pub refills: usize,
    /// decode steps replayed by the oracle checker (0 for toggle cases)
    pub checked_steps: usize,
    /// first invariant violation / divergence, if any
    pub failure: Option<String>,
}

impl ServeCaseReport {
    pub fn ok(&self) -> bool {
        self.failure.is_none()
    }
}

/// Assert p50 ≤ p90 ≤ p95 ≤ p99 (within float-printing slack) on one
/// `*_percentiles_ms` block.
fn percentiles_monotone(block: &Value, what: &str, out: &mut Vec<String>) {
    let p = |k: &str| block.get(k).and_then(Value::as_f64);
    match (p("p50"), p("p90"), p("p95"), p("p99")) {
        (Some(p50), Some(p90), Some(p95), Some(p99)) => {
            let eps = 1e-9;
            if !(p50 <= p90 + eps && p90 <= p95 + eps && p95 <= p99 + eps) {
                out.push(format!(
                    "{what} percentiles not monotone: p50={p50} p90={p90} p95={p95} p99={p99}"
                ));
            }
            if p50 < 0.0 {
                out.push(format!("{what} p50 negative: {p50}"));
            }
        }
        _ => out.push(format!("{what} percentile block incomplete: {}", block.dump())),
    }
}

/// Validate the SLO block on a v2 `done` event.
fn validate_slo(done: &Value, out: &mut Vec<String>) {
    match done.get("queue_ms").and_then(Value::as_f64) {
        Some(q) if q >= 0.0 => {}
        Some(q) => out.push(format!("negative queue_ms {q}")),
        None => out.push(format!("done without queue_ms: {}", done.dump())),
    }
    if done.get("queue_depth").and_then(Value::as_usize).is_none() {
        out.push(format!("done without queue_depth: {}", done.dump()));
    }
    for key in ["latency_percentiles_ms", "queue_wait_percentiles_ms"] {
        match done.get(key) {
            Some(block) => percentiles_monotone(block, key, out),
            None => out.push(format!("done without {key}: {}", done.dump())),
        }
    }
}

/// Validate a `shed` error honors the deadline, parsing the server's
/// own wait accounting out of the message:
/// `load shed after {waited} ms in queue (deadline {deadline} ms)`.
fn validate_shed(msg: &str, out: &mut Vec<String>) {
    let nums: Vec<u64> = msg
        .split(|c: char| !c.is_ascii_digit())
        .filter(|s| !s.is_empty())
        .filter_map(|s| s.parse().ok())
        .collect();
    match nums.as_slice() {
        [waited, deadline] if waited >= deadline => {}
        [waited, deadline] => out.push(format!(
            "shed before the deadline: waited {waited} ms < deadline {deadline} ms"
        )),
        _ => out.push(format!("unparseable shed message: {msg:?}")),
    }
}

/// Drive one connection through its schedule, validating the
/// exactly-one-terminal contract and every SLO block along the way.
fn drive_connection(addr: &str, case: &ServeFuzzCase, conn: usize) -> Result<ConnReport> {
    use crate::server::protocol::render_record;

    let plans = case.schedule(conn);
    let mut c = Client::connect(addr)?;
    // a violated invariant must fail the case, not hang it
    c.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut rep = ConnReport {
        reqs: plans.len(),
        ..ConnReport::default()
    };
    // terminal state per wire id: None = open, Some(kind) = terminated
    let mut terminal: Vec<Option<&'static str>> = vec![None; plans.len()];
    let toggler = case.toggles && conn == 0;

    // send phase: burst mode fires everything up front
    let send = |c: &mut Client, id: usize, plan: &ReqPlan| -> Result<()> {
        c.send_generate(id as u64 + 1, &plan.prompt, &plan.params, plan.streaming)
    };
    if case.burst {
        for (i, plan) in plans.iter().enumerate() {
            send(&mut c, i, plan)?;
            if plan.action != ReqAction::Normal {
                c.send_cancel(i as u64 + 1)?;
            }
        }
    }

    let mut expect_ack_enabled: Vec<bool> = Vec::new();
    let mut open = 0usize;
    for (i, plan) in plans.iter().enumerate() {
        if !case.burst {
            if toggler && i == 1 {
                // flip the live record gate off and back on between
                // requests — the recorder must ack each flip and the
                // stream must stay coherent
                c.send_line(&render_record(900, false))?;
                expect_ack_enabled.push(false);
                c.send_line(&render_record(901, true))?;
                expect_ack_enabled.push(true);
            }
            send(&mut c, i, plan)?;
            if plan.action == ReqAction::CancelImmediately {
                c.send_cancel(i as u64 + 1)?;
            }
        }
        open += 1;

        // drain phase: in burst mode only the final iteration drains
        // (everything is already in flight); otherwise drain up to this
        // request's terminal, executing CancelAfterFirstEvent
        let drain_all = !case.burst || i + 1 == plans.len();
        if case.burst && !drain_all {
            continue;
        }
        let mut awaiting_first = plan.action == ReqAction::CancelAfterFirstEvent && !case.burst;
        while open > 0 {
            let ev = c.read_event().context("reading event")?;
            let id = ev.get("id").and_then(Value::as_i64).unwrap_or(-1);
            let idx = (id - 1) as usize;
            let kind = ev.get("event").and_then(Value::as_str).unwrap_or("");
            match kind {
                "record" => {
                    rep.record_acks += 1;
                    let enabled = ev.get("enabled").and_then(Value::as_bool);
                    let want = expect_ack_enabled.first().copied();
                    if want.is_some() && enabled == want {
                        expect_ack_enabled.remove(0);
                    } else {
                        rep.violations
                            .push(format!("unexpected record ack: {}", ev.dump()));
                    }
                    continue;
                }
                "delta" => {
                    rep.deltas += 1;
                    if terminal.get(idx).is_some_and(Option::is_some) {
                        rep.violations
                            .push(format!("delta after terminal for id {id}"));
                    }
                    if awaiting_first && idx == i {
                        awaiting_first = false;
                        c.send_cancel(i as u64 + 1)?;
                    }
                    continue;
                }
                "done" | "error" => {}
                other => {
                    rep.violations
                        .push(format!("unexpected event {other:?}: {}", ev.dump()));
                    continue;
                }
            }
            // a terminal event
            let Some(slot) = terminal.get_mut(idx) else {
                rep.violations
                    .push(format!("terminal for unknown id {id}: {}", ev.dump()));
                continue;
            };
            if let Some(prev) = slot {
                rep.violations.push(format!(
                    "second terminal for id {id}: already {prev}, now {}",
                    ev.dump()
                ));
                continue;
            }
            if kind == "done" {
                rep.dones += 1;
                *slot = Some("done");
                validate_slo(&ev, &mut rep.violations);
                let finish = ev.get("finish").and_then(Value::as_str).unwrap_or("");
                match finish {
                    "cancel" => rep.cancels += 1,
                    "length" | "stop" | "stop_seq" | "context" => {}
                    other => rep
                        .violations
                        .push(format!("unexpected finish {other:?}: {}", ev.dump())),
                }
            } else {
                let code = ev.get("code").and_then(Value::as_str).unwrap_or("");
                let msg = ev.get("error").and_then(Value::as_str).unwrap_or("");
                match code {
                    "queue_full" => {
                        rep.queue_full += 1;
                        *slot = Some("queue_full");
                    }
                    "shed" => {
                        rep.shed += 1;
                        *slot = Some("shed");
                        validate_shed(msg, &mut rep.violations);
                    }
                    other => {
                        *slot = Some("error");
                        rep.violations.push(format!(
                            "unexpected error code {other:?} for id {id}: {}",
                            ev.dump()
                        ));
                    }
                }
            }
            open -= 1;
            if awaiting_first && idx == i {
                // the request terminated before its first delta (e.g.
                // shed while queued) — nothing left to cancel
                awaiting_first = false;
            }
            if !drain_all {
                break;
            }
        }
    }
    for (i, t) in terminal.iter().enumerate() {
        if t.is_none() {
            rep.violations
                .push(format!("request id {} never reached a terminal", i + 1));
        }
    }
    if !expect_ack_enabled.is_empty() {
        rep.violations.push(format!(
            "{} record toggles were never acked",
            expect_ack_enabled.len()
        ));
    }
    Ok(rep)
}

/// Run one serve-fuzz case end to end: server up, schedules through
/// real sockets, shutdown, then replay + invariant validation.
pub fn run_serve_case(case: &ServeFuzzCase) -> Result<ServeCaseReport> {
    let engine = case.engine()?;
    let rec = Arc::new(TraceRecorder::buffered(engine.trace_header()));
    let server = Arc::new(
        Server::start(
            engine,
            case.tokenizer()?,
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                trace: Some(rec.clone()),
                queue_limit: case.queue_limit,
                shed_after: case.shed_after_ms.map(Duration::from_millis),
            },
        )
        .context("starting fuzz server")?,
    );
    let addr = server.addr().to_string();
    let accept = {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_forever();
        })
    };

    let handles: Vec<_> = (0..case.conns)
        .map(|conn| {
            let addr = addr.clone();
            let case = case.clone();
            std::thread::spawn(move || drive_connection(&addr, &case, conn))
        })
        .collect();
    let mut report = ServeCaseReport::default();
    let mut violations: Vec<String> = Vec::new();
    for (conn, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(Ok(cr)) => {
                report.reqs += cr.reqs;
                report.dones += cr.dones;
                report.cancels += cr.cancels;
                report.queue_full += cr.queue_full;
                report.shed += cr.shed;
                report.deltas += cr.deltas;
                violations.extend(cr.violations.into_iter().map(|v| format!("conn {conn}: {v}")));
            }
            Ok(Err(e)) => violations.push(format!("conn {conn}: client error: {e:#}")),
            Err(_) => violations.push(format!("conn {conn}: driver panicked")),
        }
    }
    // shutdown joins the engine thread: the snapshot below is complete
    server.shutdown();
    let _ = accept.join();
    let trace = rec.snapshot();

    if report.dones + report.queue_full + report.shed != report.reqs && violations.is_empty() {
        violations.push(format!(
            "terminal accounting off: {} dones + {} queue_full + {} shed != {} requests",
            report.dones, report.queue_full, report.shed, report.reqs
        ));
    }

    if case.toggles {
        // the gate was flipped mid-run: the trace has gaps, so the
        // offline checker (which replays from engine start) is out of
        // scope — the client-side contract above is the assertion
        report.admits = trace
            .events
            .iter()
            .filter(|ev| matches!(ev, super::TraceEvent::Admit(_)))
            .count();
    } else {
        match serve_check(&trace) {
            Ok(sr) => {
                report.admits = sr.admits;
                report.refills = sr.refills;
                let max_admitted = report.reqs - report.queue_full - report.shed;
                if sr.admits > max_admitted {
                    violations.push(format!(
                        "trace has {} admits but at most {max_admitted} requests reached the engine",
                        sr.admits
                    ));
                }
            }
            Err(e) => violations.push(format!("serve invariants: {e}")),
        }
        match check(&trace) {
            Ok(cr) => {
                report.checked_steps = cr.steps;
                if let Some(d) = cr.divergence {
                    violations.push(format!("oracle replay diverged: {d}"));
                }
            }
            Err(e) => violations.push(format!("trace unreplayable: {e}")),
        }
    }

    report.failure = violations.first().map(|v| {
        if violations.len() > 1 {
            format!("{v} (+{} more)", violations.len() - 1)
        } else {
            v.clone()
        }
    });
    Ok(report)
}

/// Derive serve case `idx` of a fuzz run from the run seed.
pub fn derive_serve_case(run_seed: u64, idx: u64) -> ServeFuzzCase {
    let mut rng = Pcg32::derive(run_seed, 0x5346 ^ idx.wrapping_add(1)); // "SF"
    let batch = 1 + rng.below(3) as usize;
    let pressure = rng.below(3) == 0; // a third of cases force overload
    ServeFuzzCase {
        batch,
        vocab: 64 + 32 * rng.below(2) as usize,
        agreement: [0.5, 0.9, 0.97][rng.below(3) as usize],
        model_seed: 0xC0FFEE ^ (rng.next_u32() as u64),
        engine_seed: rng.next_u32() as u64,
        gamma_init: 3 + rng.below(3) as usize,
        gmax: 8,
        model_delay_us: [0, 200, 500][rng.below(3) as usize],
        queue_limit: if pressure { 1 } else { 4 + rng.below(4) as usize },
        shed_after_ms: if pressure && rng.below(2) == 0 {
            Some(40)
        } else {
            None
        },
        conns: 2 + rng.below(3) as usize,
        reqs_per_conn: 1 + rng.below(3) as usize,
        burst: rng.below(2) == 0,
        toggles: rng.below(4) == 0,
        seed: run_seed ^ idx.wrapping_mul(0x9E37_79B9),
    }
}

/// Serve-fuzz run summary.
#[derive(Debug, Clone, Default)]
pub struct ServeFuzzReport {
    pub cases: usize,
    pub reqs: usize,
    pub dones: usize,
    pub overloads: usize,
    pub checked_steps: usize,
    /// description of the first failing case, if any — includes the
    /// `--seed N --case K` reproduction line
    pub failure: Option<String>,
}

impl ServeFuzzReport {
    pub fn ok(&self) -> bool {
        self.failure.is_none()
    }
}

/// Run `n_cases` derived serve schedules; stops at the first failure.
pub fn fuzz_serve(
    n_cases: usize,
    run_seed: u64,
    mut log: impl FnMut(String),
) -> Result<ServeFuzzReport> {
    let mut report = ServeFuzzReport::default();
    for idx in 0..n_cases as u64 {
        let case = derive_serve_case(run_seed, idx);
        let label = format!(
            "serve case {idx}: b={} conns={} reqs/conn={} qlimit={} shed={:?} burst={} toggles={}",
            case.batch,
            case.conns,
            case.reqs_per_conn,
            case.queue_limit,
            case.shed_after_ms,
            case.burst,
            case.toggles,
        );
        let failed = |what: String| {
            format!(
                "{label} — {what}\n  reproduce: specd trace fuzz --serve --seed {run_seed} --case {idx}"
            )
        };
        match run_serve_case(&case) {
            Ok(cr) if cr.ok() => {
                log(format!(
                    "{label} — ok ({} reqs, {} dones, {} overloads, {} checked steps)",
                    cr.reqs,
                    cr.dones,
                    cr.queue_full + cr.shed,
                    cr.checked_steps
                ));
                report.cases += 1;
                report.reqs += cr.reqs;
                report.dones += cr.dones;
                report.overloads += cr.queue_full + cr.shed;
                report.checked_steps += cr.checked_steps;
            }
            Ok(cr) => {
                report.failure = Some(failed(format!("FAILED: {}", cr.failure.unwrap())));
                log(report.failure.clone().unwrap());
                return Ok(report);
            }
            Err(e) => {
                report.failure = Some(failed(format!("ERROR: {e:#}")));
                log(report.failure.clone().unwrap());
                return Ok(report);
            }
        }
    }
    Ok(report)
}

/// Re-run exactly one derived case (the `--seed N --case K` repro path).
pub fn run_derived_serve_case(run_seed: u64, idx: u64) -> Result<ServeCaseReport> {
    let case = derive_serve_case(run_seed, idx);
    let rep = run_serve_case(&case)?;
    if let Some(f) = &rep.failure {
        bail!("serve case {idx} (seed {run_seed}) failed: {f}");
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_case_is_clean_end_to_end() {
        let rep = run_serve_case(&ServeFuzzCase::default()).expect("case runs");
        assert!(rep.ok(), "{}", rep.failure.unwrap());
        assert_eq!(rep.reqs, 6);
        assert!(rep.admits > 0, "no request reached the engine");
        assert!(rep.checked_steps > 0, "oracle replay saw no steps");
    }

    #[test]
    fn toggle_case_acks_and_stays_healthy() {
        let case = ServeFuzzCase {
            toggles: true,
            conns: 2,
            reqs_per_conn: 3,
            ..ServeFuzzCase::default()
        };
        let rep = run_serve_case(&case).expect("case runs");
        assert!(rep.ok(), "{}", rep.failure.unwrap());
        // the trace has gaps (gate off between conn 0's requests), so
        // no oracle replay — but the server must have admitted work
        assert_eq!(rep.checked_steps, 0);
        assert!(rep.admits > 0);
    }

    #[test]
    fn overload_case_sheds_within_contract() {
        let case = ServeFuzzCase {
            queue_limit: 1,
            shed_after_ms: Some(30),
            model_delay_us: 500,
            conns: 4,
            reqs_per_conn: 2,
            burst: true,
            ..ServeFuzzCase::default()
        };
        let rep = run_serve_case(&case).expect("case runs");
        assert!(rep.ok(), "{}", rep.failure.unwrap());
        assert_eq!(rep.dones + rep.queue_full + rep.shed, rep.reqs);
    }

    #[test]
    fn derived_serve_cases_are_deterministic() {
        let a = derive_serve_case(7, 2);
        let b = derive_serve_case(7, 2);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        // schedules derive deterministically too
        assert_eq!(
            format!("{:?}", a.schedule(1)),
            format!("{:?}", b.schedule(1))
        );
    }
}
