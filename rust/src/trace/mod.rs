//! Deterministic trace record/replay.
//!
//! Bit-identical output against the scalar oracle is this stack's core
//! invariant; until now it was only asserted by in-process parity
//! tests. This subsystem makes any run — including real server load —
//! checkable *after the fact*:
//!
//! * [`TraceSink`] — a near-zero-cost hook threaded through the engine
//!   ([`crate::engine::core`]), the pipelined scheduler
//!   ([`crate::engine::pipeline`]) and the verifier
//!   ([`crate::engine::verifier`]). The default [`NullSink`] costs one
//!   predictable branch per recording site.
//! * [`format`] — the versioned event model with a binary-framed
//!   on-disk encoding plus a JSON-lines export, round-tripping
//!   losslessly.
//! * [`recorder`] — a [`TraceRecorder`] sink that buffers in memory
//!   (tests, fuzz) or streams frames to disk append-only (serving).
//! * [`checker`] — the offline replay checker behind
//!   `specd trace check`: re-executes a sim-recorded trace step by
//!   step against the scalar `sampling/verify` oracle and reports the
//!   first divergent step with full context.
//! * [`fuzz`] — randomized record-then-check schedules
//!   (methods × γ × batch × cancel/churn) behind `specd trace fuzz`.
//! * [`serve_fuzz`] — randomized *client* schedules driven through the
//!   real socket stack ([`crate::server`]) with server-side recording,
//!   behind `specd trace fuzz --serve`; validates serve-layer
//!   invariants ([`serve_check`]) on top of the oracle replay.
//! * [`corpus`] — the committed trace regression corpus
//!   (`rust/tests/corpus/*.sptr`) behind `specd trace corpus`: named
//!   recordings spanning the feature matrix, each oracle-replayed and
//!   byte-compared against a fresh re-record so any change to a
//!   historical run is caught at the exact step/slot/field.
//!
//! The key trick that keeps traces compact and exact: uniforms are
//! recorded as **RNG stream positions** (`(state, inc)` of the
//! per-request PCG32), not floats — the checker re-draws them
//! bit-for-bit in the engine's draw order.

pub mod checker;
pub mod corpus;
pub mod format;
pub mod fuzz;
pub mod recorder;
pub mod serve_fuzz;

pub use checker::{check, serve_check, CheckReport, Divergence, ServeCheckReport};
pub use format::{
    digest_f32, digest_i32, params_digest, AdmitEvent, PipelineEv, SimHeader, SlotStep,
    StepEvent, Trace, TraceEvent, TraceHeader, TRACE_VERSION,
};
pub use recorder::TraceRecorder;

/// Engine-side hook for trace capture. `&self` so one sink can be
/// shared (`Arc<dyn TraceSink>`) by the engine, the pipeline
/// controller and the verifier; implementations serialize internally.
///
/// Recording sites guard on [`TraceSink::enabled`] before building an
/// event, so the disabled path does no allocation and no digesting.
pub trait TraceSink: Send + Sync {
    /// Whether recording sites should build and deliver events at all.
    fn enabled(&self) -> bool;
    /// Deliver one event. Must be cheap relative to a model step.
    fn record(&self, ev: TraceEvent);
}

/// The default sink: recording off, every site reduced to one branch.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }
    fn record(&self, _ev: TraceEvent) {}
}
