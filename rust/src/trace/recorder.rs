//! The [`TraceRecorder`] sink: in-memory buffering or append-only
//! streaming to disk.
//!
//! Streaming mode writes the binary prelude at open and one frame per
//! event (flushed per record), so a crash or kill mid-run leaves every
//! completed frame readable — exactly what you want from a trace that
//! exists to debug incidents. The `enabled` gate is an `AtomicBool` so
//! the server's `record` knob can flip it without pausing the engine;
//! events between toggles are simply dropped, which is safe because
//! the checker only requires traces recorded from engine start (the
//! header + admit events carry all state).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use super::format::{self, Trace, TraceEvent, TraceHeader};
use super::TraceSink;

enum Store {
    Memory(Vec<TraceEvent>),
    File(BufWriter<File>),
}

/// A [`TraceSink`] that records.
pub struct TraceRecorder {
    header: TraceHeader,
    enabled: AtomicBool,
    store: Mutex<Store>,
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder")
            .field("enabled", &self.is_enabled())
            .field("pair", &self.header.pair)
            .finish()
    }
}

impl TraceRecorder {
    /// In-memory recorder (tests, fuzz, benches). Snapshot with
    /// [`TraceRecorder::snapshot`].
    pub fn buffered(header: TraceHeader) -> Self {
        TraceRecorder {
            header,
            enabled: AtomicBool::new(true),
            store: Mutex::new(Store::Memory(Vec::new())),
        }
    }

    /// Streaming recorder: writes the binary prelude now, then appends
    /// one frame per recorded event.
    pub fn to_file(header: TraceHeader, path: &Path) -> Result<Self, String> {
        let file = File::create(path)
            .map_err(|e| format!("cannot create trace file {}: {e}", path.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(&format::encode_prelude(&header))
            .and_then(|_| w.flush())
            .map_err(|e| format!("cannot write trace header to {}: {e}", path.display()))?;
        Ok(TraceRecorder {
            header,
            enabled: AtomicBool::new(true),
            store: Mutex::new(Store::File(w)),
        })
    }

    /// Flip the recording gate (the server `record` knob).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Release);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    /// Events recorded so far (0 for streaming recorders, which do not
    /// retain events in memory).
    pub fn event_count(&self) -> usize {
        match &*self.store.lock().unwrap() {
            Store::Memory(evs) => evs.len(),
            Store::File(_) => 0,
        }
    }

    /// Clone out the recorded trace (in-memory recorders).
    pub fn snapshot(&self) -> Trace {
        let events = match &*self.store.lock().unwrap() {
            Store::Memory(evs) => evs.clone(),
            Store::File(_) => Vec::new(),
        };
        Trace {
            header: self.header.clone(),
            events,
        }
    }

    /// Flush buffered frames to disk (no-op for in-memory recorders).
    pub fn flush(&self) -> Result<(), String> {
        match &mut *self.store.lock().unwrap() {
            Store::Memory(_) => Ok(()),
            Store::File(w) => w.flush().map_err(|e| format!("trace flush failed: {e}")),
        }
    }
}

impl TraceSink for TraceRecorder {
    fn enabled(&self) -> bool {
        self.is_enabled()
    }

    fn record(&self, ev: TraceEvent) {
        if !self.is_enabled() {
            return;
        }
        match &mut *self.store.lock().unwrap() {
            Store::Memory(evs) => evs.push(ev),
            Store::File(w) => {
                // per-event flush: an incident trace must survive a kill
                let frame = format::encode_event(&ev);
                let _ = w.write_all(&frame).and_then(|_| w.flush());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::Method;
    use crate::trace::format::{PipelineEv, TRACE_VERSION};

    fn header() -> TraceHeader {
        TraceHeader {
            version: TRACE_VERSION,
            pair: "sim".into(),
            batch: 1,
            seq_len: 8,
            vocab: 16,
            gmax: 4,
            engine_seed: 1,
            method: Method::Exact,
            backend: "native".into(),
            mode: "speculative".into(),
            pipeline: "off".into(),
            pipeline_depth: 1,
            gamma_init: 2,
            gamma_pinned: false,
            self_draft: false,
            sim: None,
        }
    }

    #[test]
    fn buffered_records_and_gates() {
        let r = TraceRecorder::buffered(header());
        r.record(TraceEvent::Pipeline(PipelineEv::BarrierHit { depth: 1 }));
        r.set_enabled(false);
        r.record(TraceEvent::Pipeline(PipelineEv::BarrierMiss {
            depth: 1,
            slot_hits: vec![false],
        }));
        r.set_enabled(true);
        r.record(TraceEvent::Cancel { id: 3, slot: None });
        let t = r.snapshot();
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[1], TraceEvent::Cancel { id: 3, slot: None });
    }

    #[test]
    fn streaming_file_round_trips() {
        let dir = std::env::temp_dir().join("specd_trace_rec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let r = TraceRecorder::to_file(header(), &path).unwrap();
        r.record(TraceEvent::Pipeline(PipelineEv::Launch { gamma: 3, depth: 2 }));
        r.record(TraceEvent::Cancel { id: 9, slot: Some(0) });
        drop(r);
        let t = format::load(&path).unwrap();
        assert_eq!(t.header, header());
        assert_eq!(t.events.len(), 2);
        std::fs::remove_file(&path).ok();
    }
}
