//! Offline trace replay against the scalar oracle.
//!
//! [`check`] re-executes a sim-recorded trace step by step: it rebuilds
//! the deterministic simulated runtime from the header, reconstructs
//! every slot's RNG stream from the recorded positions, re-runs the
//! model block + scale/filter + the scalar `sampling/verify` oracle,
//! and replays the engine's commit loop — diffing the trace at every
//! stage. The first mismatch is reported as a [`Divergence`] with the
//! step, slot, field and both values; a clean replay proves the
//! recorded run (serial *or* pipelined) was bit-identical to the
//! oracle.
//!
//! Traces are ragged (format v2+): every recorded slot carries its own
//! γ, so replay rebuilds the step's γ-prefix tables exactly as the
//! engine does and addresses draft/logit rows through them. A slot's
//! uniforms depend only on its own RNG stream and its own γ, which is
//! what lets the per-slot scalar oracle stand in for the batched
//! ragged kernel.
//!
//! Pipelined recordings (format v3) additionally carry the scheduler's
//! chain bookkeeping — launch / barrier / adopt events with per-slot
//! validity and salvage flags. The checker replays a [`ChainModel`]
//! alongside the oracle and re-derives every per-slot verdict: a
//! recorded barrier hit or salvage flag the oracle refutes is a
//! divergence (the scheduler adopted a row the serial engine would
//! have recomputed differently), while a conservatively dropped slot
//! (salvage disabled, cascade cancel) is accepted.
//!
//! What is recorded vs re-derived:
//!
//! * **recorded**: per-slot γ and RNG positions, drafted tokens, logit
//!   digests, accept lengths, emitted rows, committed deltas, finish
//!   reasons, per-slot methods, admission params (incl. the mid-flight
//!   refill flag);
//! * **re-derived**: every uniform (re-drawn from the recorded RNG
//!   positions in the engine's draw order), the logit tensors (the sim
//!   models are pure functions of the token context), the oracle's
//!   accept/emit decisions, and the commit/finish state machine.
//!
//! Replay needs the model to be reproducible offline, so only traces
//! recorded against [`Runtime::simulated`] (`sim` header present) are
//! checkable; real-hardware traces still round-trip and diff
//! structurally, they just can't be re-executed here.

use std::sync::Arc;
use std::time::Duration;

use crate::engine::core::Engine;
use crate::engine::pipeline::{run_model_block, BlockDims, BlockSlot, StepBuffers};
use crate::engine::{match_stop_suffix, FinishReason};
use crate::runtime::{Runtime, SimSpec};
use crate::sampling::{self, verify};
use crate::tokenizer;
use crate::util::rng::Pcg32;

use super::format::{digest_f32, finish_name, PipelineEv, SlotStep, Trace, TraceEvent};

/// First point where the trace and the oracle replay disagree.
#[derive(Debug, Clone, PartialEq)]
pub struct Divergence {
    /// 1-based decode-step index (counting `Step` events)
    pub step: usize,
    /// slot index (engine batch row)
    pub slot: u32,
    /// request id occupying the slot
    pub id: u64,
    /// which recorded field disagreed ("draft", "zq_digest", ...)
    pub field: &'static str,
    /// human-readable recorded-vs-replayed values
    pub detail: String,
}

impl std::fmt::Display for Divergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "step {} slot {} (request {}): {} diverged — {}",
            self.step, self.slot, self.id, self.field, self.detail
        )
    }
}

/// Replay summary; `divergence = None` means the whole trace replayed
/// bit-identically against the oracle.
#[derive(Debug, Clone, Default)]
pub struct CheckReport {
    /// decode steps replayed
    pub steps: usize,
    /// events consumed (all kinds)
    pub events: usize,
    /// requests admitted
    pub requests: usize,
    /// mid-flight refill admissions (admitted while other slots decode)
    pub refills: usize,
    /// cancel events seen
    pub cancels: usize,
    /// committed tokens verified
    pub tokens: usize,
    /// pipeline scheduler events seen (launch/hit/miss/adopt/cancel)
    pub pipeline_events: usize,
    /// prefetched blocks adopted (fully or partially) at a step start
    pub pipeline_adopts: usize,
    /// slot-rows salvaged across all adopt events (partial-hit wins)
    pub pipeline_salvaged: usize,
    /// verifier dispatch markers seen
    pub verify_events: usize,
    pub divergence: Option<Divergence>,
}

impl CheckReport {
    pub fn ok(&self) -> bool {
        self.divergence.is_none()
    }
}

/// Replay-side slot state (the checker's `Slot`).
struct ReplaySlot {
    id: u64,
    tokens: Vec<i32>,
    len: usize,
    generated: Vec<i32>,
    /// the live RNG stream, advanced in lockstep with the engine's
    rng: Pcg32,
    // admission params
    max_new_tokens: usize,
    temperature: f32,
    draft_temp: f32,
    top_k: usize,
    top_p: f32,
    stop_ids: Vec<Vec<i32>>,
    method: Option<crate::sampling::Method>,
}

fn finish_str(f: Option<FinishReason>) -> &'static str {
    f.map(finish_name).unwrap_or("-")
}

/// Replay-side model of the in-flight speculation chain. The scheduler
/// records which slots it believed salvageable ([`PipelineEv`] events);
/// the checker re-derives the same per-slot verdicts from the oracle
/// replay and refuses a trace whose scheduler adopted a row the serial
/// engine would have recomputed differently.
struct ChainModel {
    /// request id per slot at launch (0 = slot was empty)
    ids: Vec<u64>,
    /// cumulative per-slot validity — false once any barrier missed
    /// for the slot (or the slot was empty at launch)
    valid: Vec<bool>,
    /// 1-based depth of the next block to adopt / barrier to judge
    next_depth: u32,
    /// configured window k recorded at launch
    window: u32,
}

/// A barrier event stashed until its step arrives: barrier events are
/// recorded after verification but *before* the step event, so the
/// oracle outcome they must be judged against is the next `Step` in
/// the stream.
struct PendingBarrier {
    /// `None` = recorded full hit; `Some` = recorded per-slot survivors
    slot_hits: Option<Vec<bool>>,
    depth: u32,
    /// chain validity / ids snapshot when the barrier fired
    valid: Vec<bool>,
    ids: Vec<u64>,
}

/// Replay `trace` against the scalar oracle. `Err` means the trace is
/// structurally unreplayable (not sim-recorded, malformed slot refs);
/// a semantic mismatch comes back as `report.divergence`.
pub fn check(trace: &Trace) -> Result<CheckReport, String> {
    let h = &trace.header;
    let sim = h.sim.as_ref().ok_or_else(|| {
        "replay requires a sim-recorded trace (header has no sim section); \
         real-hardware traces can be exported/diffed but not re-executed"
            .to_string()
    })?;
    if h.mode != "speculative" {
        return Err(format!(
            "replay supports speculative traces only (mode is {:?})",
            h.mode
        ));
    }
    if h.self_draft {
        return Err("replay does not support self-draft traces".into());
    }
    if h.backend != "native" {
        return Err(format!(
            "sim traces verify on the native backend; header says {:?}",
            h.backend
        ));
    }
    let (b, s, v, gmax) = (
        h.batch as usize,
        h.seq_len as usize,
        h.vocab as usize,
        h.gmax as usize,
    );
    if b == 0 || v == 0 || gmax == 0 || s == 0 {
        return Err("trace header has zero dims".into());
    }

    // --- rebuild the deterministic model pair the trace was recorded
    // against (model_delay is performance-only — irrelevant to outputs)
    let runtime = Arc::new(Runtime::simulated(SimSpec {
        vocab: v,
        seq_len: s,
        gmax,
        batches: vec![b],
        seed: sim.seed,
        agreement: sim.agreement,
        model_delay: Duration::ZERO,
    }));
    let draft_step = runtime
        .load_model("draft_step", &h.pair, b)
        .map_err(|e| format!("cannot rebuild sim draft model: {e}"))?;
    let target_score = runtime
        .load_model("target_score", &h.pair, b)
        .map_err(|e| format!("cannot rebuild sim score model: {e}"))?;
    let dims = BlockDims { b, s, v, gmax };

    let mut bufs = StepBuffers::new(b, s, gmax, v);
    let mut bslots: Vec<BlockSlot> = Vec::with_capacity(b);

    let mut slots: Vec<Option<ReplaySlot>> = (0..b).map(|_| None).collect();
    let mut report = CheckReport::default();
    let mut last_verify_rows: Option<u32> = None;
    let mut chain: Option<ChainModel> = None;
    let mut barrier: Option<PendingBarrier> = None;

    for ev in &trace.events {
        report.events += 1;
        match ev {
            TraceEvent::Admit(a) => {
                let i = a.slot as usize;
                if i >= b {
                    return Err(format!("admit event slot {i} out of range (batch {b})"));
                }
                if slots[i].is_some() {
                    return Err(format!(
                        "admit event for occupied slot {i} (request {})",
                        a.id
                    ));
                }
                if a.prompt.is_empty() || a.prompt.len() > s {
                    return Err(format!(
                        "admit event prompt length {} invalid for seq_len {s}",
                        a.prompt.len()
                    ));
                }
                // the engine stamps `refill` when the admission lands
                // while other slots are still mid-decode; replay sees
                // the same slot occupancy, so the flag must agree
                let mid_flight = slots.iter().any(Option::is_some);
                if a.refill != mid_flight {
                    report.divergence = Some(Divergence {
                        step: report.steps,
                        slot: a.slot,
                        id: a.id,
                        field: "refill",
                        detail: format!(
                            "recorded {}, replay occupancy implies {}",
                            a.refill, mid_flight
                        ),
                    });
                    return Ok(report);
                }
                if a.refill {
                    report.refills += 1;
                }
                let mut tokens = vec![tokenizer::PAD; s];
                tokens[..a.prompt.len()].copy_from_slice(&a.prompt);
                slots[i] = Some(ReplaySlot {
                    id: a.id,
                    len: a.prompt.len(),
                    tokens,
                    generated: Vec::new(),
                    rng: Pcg32::from_state(a.rng_state, a.rng_inc),
                    max_new_tokens: a.max_new_tokens as usize,
                    temperature: a.temperature,
                    draft_temp: a.draft_temperature.unwrap_or(a.temperature),
                    top_k: a.top_k as usize,
                    top_p: a.top_p,
                    stop_ids: a.stop_ids.clone(),
                    method: a.method,
                });
                report.requests += 1;
            }
            TraceEvent::Cancel { id, slot } => {
                report.cancels += 1;
                if let Some(i) = slot {
                    let i = *i as usize;
                    if i >= b {
                        return Err(format!("cancel event slot {i} out of range"));
                    }
                    match slots[i].take() {
                        Some(sl) if sl.id == *id => {}
                        Some(sl) => {
                            return Err(format!(
                                "cancel event says slot {i} holds request {id}, \
                                 replay has request {}",
                                sl.id
                            ));
                        }
                        None => {
                            return Err(format!(
                                "cancel event for empty slot {i} (request {id})"
                            ));
                        }
                    }
                }
                // queue-side cancels never reached a slot: nothing to do
            }
            TraceEvent::Pipeline(p) => {
                report.pipeline_events += 1;
                match p {
                    PipelineEv::Launch { depth, .. } => {
                        if *depth != h.pipeline_depth {
                            return Err(format!(
                                "pipeline launch records window depth {depth} but the \
                                 header says {}",
                                h.pipeline_depth
                            ));
                        }
                        // v2 traces launch a fresh single-block chain every
                        // step with no adopt events, so a live model here is
                        // legitimate and simply replaced
                        chain = Some(ChainModel {
                            ids: slots
                                .iter()
                                .map(|sl| sl.as_ref().map_or(0, |sl| sl.id))
                                .collect(),
                            valid: slots.iter().map(Option::is_some).collect(),
                            next_depth: 1,
                            window: *depth,
                        });
                    }
                    PipelineEv::BarrierHit { depth }
                    | PipelineEv::BarrierMiss { depth, .. } => {
                        let Some(c) = &chain else {
                            return Err(format!(
                                "step {}: barrier event with no chain in flight",
                                report.steps + 1
                            ));
                        };
                        if barrier.is_some() {
                            return Err(format!(
                                "step {}: two barrier events before the step",
                                report.steps + 1
                            ));
                        }
                        if *depth != c.next_depth {
                            return Err(format!(
                                "step {}: barrier at depth {depth} but the chain \
                                 gates block {}",
                                report.steps + 1,
                                c.next_depth
                            ));
                        }
                        let slot_hits = match p {
                            PipelineEv::BarrierMiss { slot_hits, .. } => {
                                if slot_hits.is_empty() {
                                    // v2 misses carry no per-slot vector: the
                                    // whole window was discarded
                                    Some(vec![false; b])
                                } else if slot_hits.len() != b {
                                    return Err(format!(
                                        "step {}: barrier miss carries {} slot \
                                         flags for batch {b}",
                                        report.steps + 1,
                                        slot_hits.len()
                                    ));
                                } else {
                                    Some(slot_hits.clone())
                                }
                            }
                            _ => None,
                        };
                        barrier = Some(PendingBarrier {
                            slot_hits,
                            depth: *depth,
                            valid: c.valid.clone(),
                            ids: c.ids.clone(),
                        });
                    }
                    PipelineEv::Adopt { depth, salvaged } => {
                        let Some(c) = &mut chain else {
                            return Err(format!(
                                "step {}: adopt event with no chain in flight",
                                report.steps + 1
                            ));
                        };
                        if *depth != c.next_depth {
                            return Err(format!(
                                "step {}: adopt of block depth {depth} but the \
                                 chain is at block {}",
                                report.steps + 1,
                                c.next_depth
                            ));
                        }
                        if salvaged.len() != b {
                            return Err(format!(
                                "step {}: adopt carries {} slot flags for batch {b}",
                                report.steps + 1,
                                salvaged.len()
                            ));
                        }
                        for (i, &sv) in salvaged.iter().enumerate() {
                            // a slot's prefetched rows are salvageable iff
                            // every barrier so far held for it and the same
                            // request still occupies it
                            let expect = c.valid[i]
                                && slots[i].as_ref().is_some_and(|sl| sl.id == c.ids[i]);
                            if sv != expect {
                                report.divergence = Some(Divergence {
                                    step: report.steps + 1,
                                    slot: i as u32,
                                    id: if c.ids[i] != 0 {
                                        c.ids[i]
                                    } else {
                                        slots[i].as_ref().map_or(0, |sl| sl.id)
                                    },
                                    field: "salvaged",
                                    detail: format!(
                                        "adopt at depth {depth} records {sv}, oracle \
                                         chain replay expects {expect}"
                                    ),
                                });
                                return Ok(report);
                            }
                        }
                        report.pipeline_adopts += 1;
                        report.pipeline_salvaged +=
                            salvaged.iter().filter(|&&x| x).count();
                        for (v, &sv) in c.valid.iter_mut().zip(salvaged) {
                            *v = *v && sv;
                        }
                        c.next_depth += 1;
                        if c.next_depth > c.window {
                            chain = None;
                        }
                    }
                    PipelineEv::Discard | PipelineEv::CancelInflight => chain = None,
                }
            }
            TraceEvent::Verify { rows, .. } => {
                report.verify_events += 1;
                last_verify_rows = Some(*rows);
            }
            TraceEvent::Step(step) => {
                report.steps += 1;
                let diverged = replay_step(
                    &mut slots,
                    step,
                    ReplayCtx {
                        step_idx: report.steps,
                        dims,
                        draft_step: &draft_step,
                        target_score: &target_score,
                        profiler: &runtime.profiler,
                        header_method: h.method,
                        last_verify_rows: last_verify_rows.take(),
                    },
                    &mut bufs,
                    &mut bslots,
                    &mut report.tokens,
                )?;
                if let Some(d) = diverged {
                    report.divergence = Some(d);
                    return Ok(report);
                }
                if let Some(pb) = barrier.take() {
                    // judge the stashed barrier against the step the oracle
                    // just replayed: a slot's prediction held iff the chain
                    // still tracked it, the same request occupied it, and
                    // every draft row was accepted (full acceptance is what
                    // makes the predicted bonus token exact)
                    let mut expected = vec![false; b];
                    let mut active = vec![false; b];
                    for ts in &step.slots {
                        let i = ts.slot as usize;
                        active[i] = true;
                        expected[i] = pb.valid[i]
                            && pb.ids[i] == ts.id
                            && ts.accept_len == ts.gamma;
                    }
                    match &pb.slot_hits {
                        None => {
                            // recorded full hit: every engine-active slot
                            // must have proven out
                            for ts in &step.slots {
                                if !expected[ts.slot as usize] {
                                    report.divergence = Some(div(
                                        report.steps,
                                        ts,
                                        "barrier",
                                        format!(
                                            "recorded a full hit at depth {}, oracle \
                                             replay shows this slot missed",
                                            pb.depth
                                        ),
                                    ));
                                    return Ok(report);
                                }
                            }
                        }
                        Some(hits) => {
                            // one-sided: a recorded hit the oracle refutes
                            // means the scheduler adopted a wrong row; a
                            // recorded miss where the oracle would have hit
                            // is merely conservative (the all-or-nothing
                            // collapse with salvage disabled)
                            for (i, &hit) in hits.iter().enumerate() {
                                if hit && !(active[i] && expected[i]) {
                                    report.divergence = Some(Divergence {
                                        step: report.steps,
                                        slot: i as u32,
                                        id: pb.ids[i],
                                        field: "slot_hits",
                                        detail: format!(
                                            "barrier miss at depth {} keeps slot \
                                             {i}, oracle replay refutes the \
                                             prediction",
                                            pb.depth
                                        ),
                                    });
                                    return Ok(report);
                                }
                            }
                        }
                    }
                    if let Some(c) = &mut chain {
                        // mirror the engine: the barrier ANDs the verdict
                        // into the cumulative validity (recorded misses are
                        // authoritative — the scheduler may conservatively
                        // drop more than the oracle requires)
                        let verdict = pb.slot_hits.as_deref().unwrap_or(&expected);
                        for (i, v) in c.valid.iter_mut().enumerate() {
                            *v = *v && verdict[i] && active[i];
                        }
                    }
                }
            }
        }
    }
    Ok(report)
}

struct ReplayCtx<'a> {
    step_idx: usize,
    dims: BlockDims,
    draft_step: &'a crate::runtime::LoadedExecutable,
    target_score: &'a crate::runtime::LoadedExecutable,
    profiler: &'a crate::util::timer::Profiler,
    header_method: crate::sampling::Method,
    /// row count of the Verify marker recorded just before this step
    last_verify_rows: Option<u32>,
}

/// Replay one recorded decode step. Returns `Ok(Some(divergence))` on
/// the first mismatch, `Ok(None)` on a bit-identical step.
fn replay_step(
    slots: &mut [Option<ReplaySlot>],
    step: &super::format::StepEvent,
    ctx: ReplayCtx<'_>,
    bufs: &mut StepBuffers,
    bslots: &mut Vec<BlockSlot>,
    tokens_verified: &mut usize,
) -> Result<Option<Divergence>, String> {
    let BlockDims { b, s, v, gmax } = ctx.dims;
    let sn = ctx.step_idx;

    // --- structural pass: the recorded slot set must be exactly the
    // replay-active set, in slot order, with matching ids / lengths /
    // methods / RNG positions; each slot carries its own γ
    let mut gammas = vec![0usize; b];
    let mut expect = slots
        .iter()
        .enumerate()
        .filter_map(|(i, sl)| sl.as_ref().map(|sl| (i, sl.id)));
    for ts in &step.slots {
        let i = ts.slot as usize;
        if i >= b {
            return Err(format!("step {sn}: slot {i} out of range (batch {b})"));
        }
        let gamma = ts.gamma as usize;
        if gamma == 0 || gamma > gmax {
            return Err(format!(
                "step {sn}: slot {i} gamma {gamma} outside 1..={gmax}"
            ));
        }
        gammas[i] = gamma;
        match expect.next() {
            Some((ei, eid)) if ei == i && eid == ts.id => {}
            other => {
                return Err(format!(
                    "step {sn}: recorded slot {} (request {}) does not match \
                     replay-active slot {:?}",
                    i, ts.id, other
                ));
            }
        }
        let sl = slots[i].as_ref().expect("matched above");
        if sl.len != ts.len_before as usize {
            return Ok(Some(div(
                sn,
                ts,
                "len_before",
                format!("recorded {}, replay has {}", ts.len_before, sl.len),
            )));
        }
        if sl.len + gamma + 1 > s {
            return Err(format!(
                "step {sn}: slot {i} len {} + gamma {gamma} + 1 overflows seq_len {s}",
                sl.len
            ));
        }
        let want = sl.method.unwrap_or(ctx.header_method);
        if ts.method != want {
            return Ok(Some(div(
                sn,
                ts,
                "method",
                format!(
                    "recorded {:?}, admission params imply {:?}",
                    ts.method.name(),
                    want.name()
                ),
            )));
        }
        let (st, inc) = sl.rng.state();
        if (st, inc) != (ts.rng_state, ts.rng_inc) {
            return Ok(Some(div(
                sn,
                ts,
                "rng",
                format!(
                    "recorded position ({:#x}, {:#x}), replay stream is at \
                     ({st:#x}, {inc:#x}) — uniforms out of sync",
                    ts.rng_state, ts.rng_inc
                ),
            )));
        }
        if ts.draft.len() != gamma || ts.out_row.len() != gamma + 1 {
            return Err(format!(
                "step {sn}: slot {i} rows sized {}/{} for gamma {gamma}",
                ts.draft.len(),
                ts.out_row.len()
            ));
        }
    }
    if let Some((ei, eid)) = expect.next() {
        return Err(format!(
            "step {sn}: replay-active slot {ei} (request {eid}) missing from \
             the recorded step"
        ));
    }
    let total_rows: usize = gammas.iter().sum();
    if let Some(vr) = ctx.last_verify_rows {
        if vr as usize != total_rows {
            return Err(format!(
                "step {sn}: verify marker dispatched {vr} draft rows but the \
                 step's per-slot gammas sum to {total_rows}"
            ));
        }
    }

    // --- model block from the recorded RNG positions (the engine's
    // serial dispatch; a pipelined recording replays through here
    // because the positions are schedule-independent). Each slot
    // participates in exactly its own γ draft sub-steps, so the
    // γ-prefix tables `run_model_block` leaves in `bufs` match the
    // engine's row addressing.
    bslots.clear();
    for i in 0..b {
        match &slots[i] {
            Some(sl) => {
                bufs.tokens[i * s..(i + 1) * s].copy_from_slice(&sl.tokens);
                bslots.push(BlockSlot {
                    active: true,
                    len: sl.len,
                    rng: sl.rng.clone(),
                    draft_temp: Engine::effective_temp(sl.draft_temp),
                    gamma: gammas[i],
                });
            }
            None => {
                bufs.tokens[i * s..(i + 1) * s].fill(tokenizer::PAD);
                bslots.push(BlockSlot::inactive());
            }
        }
    }
    run_model_block(
        ctx.draft_step,
        ctx.target_score,
        ctx.profiler,
        bufs,
        bslots,
        ctx.dims,
        false,
        None,
    )
    .map_err(|e| format!("step {sn}: sim model block failed: {e}"))?;

    for ts in &step.slots {
        let i = ts.slot as usize;
        let q0 = bufs.q_off[i];
        let got = &bufs.draft[q0..q0 + gammas[i]];
        if got != ts.draft.as_slice() {
            return Ok(Some(div(
                sn,
                ts,
                "draft",
                format!("recorded {:?}, replay drafted {:?}", ts.draft, got),
            )));
        }
    }

    // --- scale/filter exactly as the engine does over the ragged row
    // spans, then digest-compare the tensors verification consumed
    for i in 0..b {
        let Some(sl) = &slots[i] else { continue };
        let g = gammas[i];
        let (q0, p0) = (bufs.q_off[i], bufs.p_off[i]);
        let t = Engine::effective_temp(sl.temperature);
        if (t - 1.0).abs() > 1e-6 {
            let inv = 1.0 / t;
            for x in &mut bufs.zp[p0 * v..(p0 + g + 1) * v] {
                *x *= inv;
            }
            for x in &mut bufs.zq[q0 * v..(q0 + g) * v] {
                *x *= inv;
            }
        }
        let (k, p) = (sl.top_k, sl.top_p);
        if k == 0 && p >= 1.0 {
            continue;
        }
        for j in 0..=g {
            let off = (p0 + j) * v;
            sampling::filter::mask_logits_top_k_top_p(&mut bufs.zp[off..off + v], k, p);
        }
    }
    for ts in &step.slots {
        let i = ts.slot as usize;
        let g = gammas[i];
        let (q0, p0) = (bufs.q_off[i], bufs.p_off[i]);
        let zq = digest_f32(&bufs.zq[q0 * v..(q0 + g) * v]);
        if zq != ts.zq_digest {
            return Ok(Some(div(
                sn,
                ts,
                "zq_digest",
                format!("recorded {:#x}, replay computed {zq:#x}", ts.zq_digest),
            )));
        }
        let zp = digest_f32(&bufs.zp[p0 * v..(p0 + g + 1) * v]);
        if zp != ts.zp_digest {
            return Ok(Some(div(
                sn,
                ts,
                "zp_digest",
                format!("recorded {:#x}, replay computed {zp:#x}", ts.zp_digest),
            )));
        }
    }

    // --- verification uniforms in the engine's draw order (per slot:
    // γᵢ acceptance draws, one residual, one bonus; inactive slots
    // consume nothing), then the per-slot scalar oracle — the ground
    // truth every batched backend must match row for row
    for ts in &step.slots {
        let i = ts.slot as usize;
        let g = gammas[i];
        let (q0, p0) = (bufs.q_off[i], bufs.p_off[i]);
        let uacc: Vec<f32> = (0..g).map(|_| bslots[i].rng.uniform_f32()).collect();
        let ures = bslots[i].rng.uniform_f32();
        let ubonus = bslots[i].rng.uniform_f32();
        let out = verify::spec_step(
            &bufs.zp[p0 * v..(p0 + g + 1) * v],
            &bufs.zq[q0 * v..(q0 + g) * v],
            v,
            &bufs.draft[q0..q0 + g],
            &uacc,
            ures,
            ubonus,
            ts.method,
            None,
        );

        // --- commit replay: the engine's exact finish state machine
        let alen = out.accept_len;
        if alen != ts.accept_len as usize {
            return Ok(Some(div(
                sn,
                ts,
                "accept_len",
                format!("recorded {}, oracle accepted {alen}", ts.accept_len),
            )));
        }
        if out.tokens != ts.out_row.as_slice() {
            return Ok(Some(div(
                sn,
                ts,
                "out_tokens",
                format!("recorded {:?}, oracle emitted {:?}", ts.out_row, out.tokens),
            )));
        }
        let sl = slots[i].as_mut().expect("validated above");
        let gen_before = sl.generated.len();
        let mut finish: Option<FinishReason> = None;
        for &tok in out.tokens.iter().take(alen + 1) {
            sl.tokens[sl.len] = tok;
            sl.len += 1;
            sl.generated.push(tok);
            if tok == tokenizer::EOS {
                finish = Some(FinishReason::Stop);
                break;
            }
            if let Some(m) = match_stop_suffix(&sl.generated, &sl.stop_ids) {
                sl.generated.truncate(sl.generated.len() - m);
                finish = Some(FinishReason::StopSeq);
                break;
            }
            if sl.generated.len() >= sl.max_new_tokens {
                finish = Some(FinishReason::Length);
                break;
            }
        }
        let from = gen_before.min(sl.generated.len());
        let delta = &sl.generated[from..];
        if finish.is_none() && s.saturating_sub(sl.len) < 2 {
            finish = Some(FinishReason::Context);
        }
        if delta != ts.committed.as_slice() {
            return Ok(Some(div(
                sn,
                ts,
                "committed",
                format!("recorded {:?}, replay committed {:?}", ts.committed, delta),
            )));
        }
        if finish != ts.finish {
            return Ok(Some(div(
                sn,
                ts,
                "finish",
                format!(
                    "recorded {}, replay decided {}",
                    finish_str(ts.finish),
                    finish_str(finish)
                ),
            )));
        }
        *tokens_verified += delta.len();
        // carry the advanced stream into the next step (or free the slot)
        sl.rng = bslots[i].rng.clone();
        if finish.is_some() {
            slots[i] = None;
        }
    }
    Ok(None)
}

fn div(step: usize, ts: &SlotStep, field: &'static str, detail: String) -> Divergence {
    Divergence {
        step,
        slot: ts.slot,
        id: ts.id,
        field,
        detail,
    }
}

/// Summary of a structural serve-layer validation ([`serve_check`]).
#[derive(Debug, Clone, Default)]
pub struct ServeCheckReport {
    /// requests admitted into slots
    pub admits: usize,
    /// admissions stamped as mid-flight refills
    pub refills: usize,
    /// decode steps seen
    pub steps: usize,
    /// terminal events (finishing steps + in-slot cancels)
    pub terminals: usize,
    /// queue-side cancels (request cancelled before reaching a slot)
    pub queue_cancels: usize,
}

/// Validate the serve-layer invariants of a **complete** trace — the
/// properties the oracle replay ([`check`]) asserts only as a side
/// effect, plus the lifecycle coverage it cannot: every admitted
/// request reaches **exactly one** terminal (a finishing step or an
/// in-slot cancel), admissions land in free slots, refill flags match
/// slot occupancy, and no slot is still occupied at end of trace.
///
/// Purely structural (no model replay), so it works on any backend's
/// trace — and unlike [`check`] it does not need the `sim` header.
/// "Complete" means recorded from engine start to quiesce: a trace with
/// a live `record`-toggle gap will legitimately fail here.
pub fn serve_check(trace: &Trace) -> Result<ServeCheckReport, String> {
    let b = trace.header.batch as usize;
    let mut slots: Vec<Option<u64>> = vec![None; b];
    // admission order preserved for the end-of-trace sweep
    let mut admitted: Vec<u64> = Vec::new();
    let mut terminals: std::collections::HashMap<u64, usize> =
        std::collections::HashMap::new();
    let mut report = ServeCheckReport::default();

    for (ev_idx, ev) in trace.events.iter().enumerate() {
        match ev {
            TraceEvent::Admit(a) => {
                let i = a.slot as usize;
                if i >= b {
                    return Err(format!(
                        "event {ev_idx}: admit slot {i} out of range (batch {b})"
                    ));
                }
                if let Some(occ) = slots[i] {
                    return Err(format!(
                        "event {ev_idx}: request {} admitted into slot {i} \
                         still occupied by request {occ}",
                        a.id
                    ));
                }
                if terminals.contains_key(&a.id) {
                    return Err(format!(
                        "event {ev_idx}: request {} admitted twice",
                        a.id
                    ));
                }
                let mid_flight = slots.iter().any(Option::is_some);
                if a.refill != mid_flight {
                    return Err(format!(
                        "event {ev_idx}: admit of request {} has refill={} but {} \
                         other slot(s) are occupied",
                        a.id,
                        a.refill,
                        slots.iter().flatten().count()
                    ));
                }
                slots[i] = Some(a.id);
                admitted.push(a.id);
                terminals.insert(a.id, 0);
                report.admits += 1;
                if a.refill {
                    report.refills += 1;
                }
            }
            TraceEvent::Step(step) => {
                report.steps += 1;
                for ts in &step.slots {
                    let i = ts.slot as usize;
                    if i >= b {
                        return Err(format!(
                            "event {ev_idx}: step slot {i} out of range"
                        ));
                    }
                    match slots[i] {
                        Some(id) if id == ts.id => {}
                        Some(id) => {
                            return Err(format!(
                                "event {ev_idx} (step {}): slot {i} steps request {} \
                                 but holds request {id}",
                                report.steps, ts.id
                            ));
                        }
                        None => {
                            return Err(format!(
                                "event {ev_idx} (step {}): step for request {} in \
                                 empty slot {i} — the request already terminated",
                                report.steps, ts.id
                            ));
                        }
                    }
                    if ts.finish.is_some() {
                        slots[i] = None;
                        *terminals.get_mut(&ts.id).expect("admitted above") += 1;
                        report.terminals += 1;
                    }
                }
            }
            TraceEvent::Cancel { id, slot: Some(i) } => {
                let i = *i as usize;
                if i >= b {
                    return Err(format!("event {ev_idx}: cancel slot {i} out of range"));
                }
                match slots[i].take() {
                    Some(occ) if occ == *id => {}
                    Some(occ) => {
                        return Err(format!(
                            "event {ev_idx}: cancel says slot {i} holds request {id}, \
                             trace has request {occ}"
                        ));
                    }
                    None => {
                        return Err(format!(
                            "event {ev_idx}: cancel of request {id} in empty slot {i} \
                             — a second terminal"
                        ));
                    }
                }
                *terminals.get_mut(id).ok_or_else(|| {
                    format!("event {ev_idx}: in-slot cancel of never-admitted request {id}")
                })? += 1;
                report.terminals += 1;
            }
            TraceEvent::Cancel { id, slot: None } => {
                if slots.contains(&Some(*id)) {
                    return Err(format!(
                        "event {ev_idx}: queue-side cancel of request {id} which \
                         occupies a slot"
                    ));
                }
                report.queue_cancels += 1;
            }
            TraceEvent::Pipeline(_) | TraceEvent::Verify { .. } => {}
        }
    }

    for id in &admitted {
        match terminals[id] {
            1 => {}
            0 => {
                return Err(format!(
                    "request {id} was admitted but never reached a terminal \
                     (no finishing step, no cancel)"
                ));
            }
            n => {
                return Err(format!("request {id} reached {n} terminals"));
            }
        }
    }
    if let Some(i) = slots.iter().position(Option::is_some) {
        return Err(format!(
            "slot {i} still occupied by request {} at end of trace",
            slots[i].unwrap()
        ));
    }
    Ok(report)
}
