//! Trace event model + the two interchangeable encodings.
//!
//! A trace is a header followed by an ordered event stream. The design
//! rule for what goes in an event: record exactly what the offline
//! checker cannot re-derive from the oracle — RNG stream *positions*
//! (never raw uniforms: a `(state, inc)` pair replays every draw
//! bit-for-bit), committed tokens, finish decisions — plus cheap
//! digests of what it *can* re-derive (draft tokens, logit tensors) so
//! corruption is localised to the first divergent step instead of
//! cascading.
//!
//! Two encodings round-trip losslessly:
//!
//! * **binary** — `SPTR` magic, `u32` version, then length-prefixed
//!   frames (`tag:u8, len:u32, payload`). This is the on-disk format
//!   the recorder streams, append-only so a crash mid-run leaves every
//!   completed frame readable.
//! * **JSON-lines** — one header line then one object per event, for
//!   `jq`-style inspection and for shipping traces in bug reports.
//!   `u64` fields (RNG states, digests, seeds, ids) are hex *strings*
//!   because JSON numbers are f64 and would silently truncate them.
//!
//! Versioning rule: any change to recorded semantics (field meaning,
//! draw order, digest function) bumps [`TRACE_VERSION`]; the checker
//! refuses versions it does not know rather than guessing.
//!
//! Version history: **v1** — rectangular batches, one γ per step event.
//! **v2** — ragged per-slot γ: each [`SlotStep`] carries its own
//! `gamma` (the step event has no shared γ), admit events record
//! whether the admission was a mid-flight `refill`, and the verify
//! marker counts ragged `rows` (Σ γᵢ) instead of a γ.
//! **v3** — depth-k pipeline window with per-slot partial-hit
//! adoption: the header records the configured `pipeline_depth`,
//! launch/barrier events carry the window depth, barrier misses carry
//! the surviving per-slot validity, and a new `adopt` event records
//! which slots salvaged rows from each consumed prefetched block. v2
//! traces still load: their pipeline events map onto the v3 shapes at
//! depth 1 (the loader normalizes the header version in memory, so a
//! re-save round-trips as v3).

use std::path::Path;

use crate::engine::FinishReason;
use crate::sampling::Method;
use crate::util::json::{self, obj, Value};

/// On-disk magic for binary traces.
pub const TRACE_MAGIC: [u8; 4] = *b"SPTR";
/// Current trace format version (see module docs for the bump rule and
/// version history).
pub const TRACE_VERSION: u32 = 3;

/// Oldest trace version the loader still accepts (older versions are
/// mapped onto the current event shapes at load time).
pub const TRACE_VERSION_MIN: u32 = 2;

/// FNV-1a over the raw bit patterns of an f32 slice, mixed 8 bytes at a
/// time. One shared digest for recorder and checker — the exact hash is
/// irrelevant as long as both sides agree and it is cheap enough to run
/// over `B·γ·V` logits per step without showing up in the bench.
pub fn digest_f32(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut chunks = xs.chunks_exact(2);
    for pair in &mut chunks {
        let w = (pair[0].to_bits() as u64) | ((pair[1].to_bits() as u64) << 32);
        h = (h ^ w).wrapping_mul(0x100000001b3);
    }
    for x in chunks.remainder() {
        h = (h ^ x.to_bits() as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a over an i32 slice (token rows).
pub fn digest_i32(xs: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in xs {
        h = (h ^ (*x as u32 as u64)).wrapping_mul(0x100000001b3);
    }
    h
}

fn mix(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x100000001b3)
}

/// Digest over every [`crate::engine::SamplingParams`] field. The admit
/// event also records the fields replay consumes directly; the digest
/// is the change detector for everything else (and for fields added
/// later without a format bump).
pub fn params_digest(p: &crate::engine::SamplingParams) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    h = mix(h, p.max_new_tokens as u64);
    h = mix(h, p.temperature.to_bits() as u64);
    h = mix(
        h,
        p.draft_temperature
            .map(|t| t.to_bits() as u64 | (1 << 60))
            .unwrap_or(0),
    );
    h = mix(h, p.top_k as u64);
    h = mix(h, p.top_p.to_bits() as u64);
    for s in &p.stop {
        for b in s.as_bytes() {
            h = mix(h, *b as u64);
        }
        h = mix(h, 0x1FF);
    }
    h = mix(h, p.seed.map(|s| s ^ (1 << 63)).unwrap_or(1));
    h = mix(h, p.gamma.map(|g| g as u64 | (1 << 60)).unwrap_or(0));
    h = mix(h, p.gamma_pinned as u64);
    match &p.method {
        None => h = mix(h, 0xFE),
        Some(m) => {
            let (k, a, b) = method_parts(m);
            h = mix(h, k as u64);
            h = mix(h, a as u64);
            h = mix(h, b as u64);
        }
    }
    h
}

/// Simulator identity embedded in the header: together with the shape
/// fields it is enough to rebuild the exact model pair offline.
#[derive(Debug, Clone, PartialEq)]
pub struct SimHeader {
    pub seed: u64,
    pub agreement: f32,
}

/// Engine + model configuration at recording time. Everything the
/// checker needs to reconstruct the run environment (shapes, seeds,
/// policy), and nothing it can re-derive.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceHeader {
    pub version: u32,
    pub pair: String,
    pub batch: u32,
    pub seq_len: u32,
    pub vocab: u32,
    pub gmax: u32,
    pub engine_seed: u64,
    pub method: Method,
    /// verify backend name (`hlo` / `native`)
    pub backend: String,
    /// `speculative` / `autoregressive` — steps are only recorded for
    /// speculative mode (the AR loop has no verify step to check)
    pub mode: String,
    /// pipeline mode name (`on` / `off` / `auto`)
    pub pipeline: String,
    /// configured speculation-window depth k (1 = single-block
    /// prefetch; v2 traces load as depth 1)
    pub pipeline_depth: u32,
    pub gamma_init: u32,
    pub gamma_pinned: bool,
    pub self_draft: bool,
    /// `Some` iff recorded against [`crate::runtime::Runtime::simulated`];
    /// replay-checking requires it
    pub sim: Option<SimHeader>,
}

/// A request entering a slot, with the exact sampling policy and the
/// derived per-request RNG stream position before any draw.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmitEvent {
    pub slot: u32,
    pub id: u64,
    /// post-truncation prompt tokens actually placed in the slot row
    pub prompt: Vec<i32>,
    pub stop_ids: Vec<Vec<i32>>,
    pub max_new_tokens: u32,
    pub temperature: f32,
    pub draft_temperature: Option<f32>,
    pub top_k: u32,
    pub top_p: f32,
    /// per-request γ cap (0 = none)
    pub gamma: u32,
    pub gamma_pinned: bool,
    pub method: Option<Method>,
    /// effective seed (`params.seed_or(id)`)
    pub seed: u64,
    /// digest over the full `SamplingParams` (change detector for
    /// fields the replay does not consume directly)
    pub params_digest: u64,
    pub rng_state: u64,
    pub rng_inc: u64,
    /// true when this admission landed while other slots were still
    /// decoding (continuous-batching mid-flight refill)
    pub refill: bool,
}

/// One active slot's view of one speculative step: the slot's own γ,
/// RNG position before the draft draws, the drafted tokens, digests of
/// the logit tensors the verifier consumed (post
/// temperature/top-k/top-p), and the commit outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotStep {
    pub slot: u32,
    pub id: u64,
    pub len_before: u32,
    /// this slot's speculation depth for the step (ragged batches:
    /// slots differ)
    pub gamma: u32,
    pub method: Method,
    pub rng_state: u64,
    pub rng_inc: u64,
    /// γ drafted token ids
    pub draft: Vec<i32>,
    /// digest of the draft logit rows `z_q` fed to verification
    pub zq_digest: u64,
    /// digest of the target logit rows `z_p` fed to verification
    pub zp_digest: u64,
    pub accept_len: u32,
    /// full γ+1 verifier output row (accepted prefix + resample/bonus)
    pub out_row: Vec<i32>,
    /// tokens actually streamed this step (post stop-sequence trim —
    /// can be shorter than `accept_len + 1`, or retract to empty)
    pub committed: Vec<i32>,
    pub finish: Option<FinishReason>,
}

/// One engine speculative step over the active slot set (each slot
/// records its own γ — see [`SlotStep::gamma`]).
#[derive(Debug, Clone, PartialEq)]
pub struct StepEvent {
    pub slots: Vec<SlotStep>,
}

/// Pipelined-scheduler events. The checker replays the chain model
/// against them ([`super::checker`]): `depth` is the 1-based window
/// position, and the per-slot boolean vectors are validated against
/// the oracle's own accept/commit replay — a flipped salvage flag in
/// either direction is a divergence, so the scheduler cannot silently
/// adopt a row the serial engine would have recomputed differently.
#[derive(Debug, Clone, PartialEq)]
pub enum PipelineEv {
    /// chain launched onto the dispatcher lane (`gamma` = the largest
    /// per-slot γ of block 1, `depth` = the configured window k)
    Launch { gamma: u32, depth: u32 },
    /// barrier proved the prediction gating block `depth` right for
    /// every active slot
    BarrierHit { depth: u32 },
    /// prediction gating block `depth` missed for at least one slot;
    /// `slot_hits` = per-slot chain validity surviving the barrier
    /// (cumulative — a slot false here stays false for the rest of the
    /// chain). Empty in traces loaded from v2 (all-or-nothing barrier).
    BarrierMiss { depth: u32, slot_hits: Vec<bool> },
    /// a prefetched block of depth `depth` was consumed at a step
    /// start; `salvaged` = which slots adopted its rows (the rest were
    /// redone serially)
    Adopt { depth: u32, salvaged: Vec<bool> },
    /// prefetched block invalidated by slot-set change before adoption
    /// (v2 traces only — v3 folds this into per-slot validity)
    Discard,
    /// in-flight chain cancelled (every slot invalid / engine drop)
    CancelInflight,
}

/// The trace event stream.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    Admit(AdmitEvent),
    Step(StepEvent),
    Cancel { id: u64, slot: Option<u32> },
    Pipeline(PipelineEv),
    /// verifier dispatch marker (`rows` = total draft rows verified,
    /// Σ γᵢ over active slots; `groups` = distinct methods batched)
    Verify { rows: u32, groups: u32 },
}

/// A fully-loaded trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub header: TraceHeader,
    pub events: Vec<TraceEvent>,
}

// ---------------------------------------------------------------------------
// binary encoding

const TAG_HEADER: u8 = 0;
const TAG_ADMIT: u8 = 1;
const TAG_STEP: u8 = 2;
const TAG_CANCEL: u8 = 3;
const TAG_PIPELINE: u8 = 4;
const TAG_VERIFY: u8 = 5;

#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }
    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn i64(&mut self, x: i64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn f32(&mut self, x: f32) {
        self.u32(x.to_bits());
    }
    fn bool(&mut self, x: bool) {
        self.u8(x as u8);
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn vec_i32(&mut self, xs: &[i32]) {
        self.u32(xs.len() as u32);
        for x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn vec_bool(&mut self, xs: &[bool]) {
        self.u32(xs.len() as u32);
        for x in xs {
            self.u8(*x as u8);
        }
    }
    fn method(&mut self, m: &Method) {
        let (kind, a, b) = method_parts(m);
        self.u8(kind);
        self.i64(a);
        self.i64(b);
    }
    fn opt_method(&mut self, m: &Option<Method>) {
        match m {
            None => self.u8(255),
            Some(m) => self.method(m),
        }
    }
    fn opt_f32(&mut self, x: Option<f32>) {
        match x {
            None => self.u8(0),
            Some(x) => {
                self.u8(1);
                self.f32(x);
            }
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

type DecResult<T> = Result<T, String>;

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> DecResult<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "trace truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn done(&self) -> bool {
        self.pos >= self.buf.len()
    }
    fn u8(&mut self) -> DecResult<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> DecResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> DecResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> DecResult<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> DecResult<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn bool(&mut self) -> DecResult<bool> {
        Ok(self.u8()? != 0)
    }
    fn str(&mut self) -> DecResult<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|e| format!("bad utf8 in trace: {e}"))
    }
    fn vec_i32(&mut self) -> DecResult<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn vec_bool(&mut self) -> DecResult<Vec<bool>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.iter().map(|b| *b != 0).collect())
    }
    fn method(&mut self) -> DecResult<Method> {
        let kind = self.u8()?;
        let a = self.i64()?;
        let b = self.i64()?;
        method_from_parts(kind, a, b)
    }
    fn opt_method(&mut self) -> DecResult<Option<Method>> {
        let kind = self.u8()?;
        if kind == 255 {
            return Ok(None);
        }
        let a = self.i64()?;
        let b = self.i64()?;
        Ok(Some(method_from_parts(kind, a, b)?))
    }
    fn opt_f32(&mut self) -> DecResult<Option<f32>> {
        if self.u8()? == 0 {
            Ok(None)
        } else {
            Ok(Some(self.f32()?))
        }
    }
}

fn method_parts(m: &Method) -> (u8, i64, i64) {
    match *m {
        Method::Baseline => (0, 0, 0),
        Method::Exact => (1, 0, 0),
        Method::Sigmoid {
            alpha_milli,
            beta_milli,
        } => (2, alpha_milli, beta_milli),
        Method::Sigmoid16 {
            alpha_milli,
            beta_milli,
        } => (3, alpha_milli, beta_milli),
    }
}

fn method_from_parts(kind: u8, a: i64, b: i64) -> DecResult<Method> {
    Ok(match kind {
        0 => Method::Baseline,
        1 => Method::Exact,
        2 => Method::Sigmoid {
            alpha_milli: a,
            beta_milli: b,
        },
        3 => Method::Sigmoid16 {
            alpha_milli: a,
            beta_milli: b,
        },
        k => return Err(format!("unknown method kind {k} in trace")),
    })
}

fn finish_code(f: FinishReason) -> u8 {
    match f {
        FinishReason::Length => 0,
        FinishReason::Stop => 1,
        FinishReason::StopSeq => 2,
        FinishReason::Context => 3,
        FinishReason::Cancelled => 4,
    }
}

fn finish_from_code(c: u8) -> DecResult<FinishReason> {
    Ok(match c {
        0 => FinishReason::Length,
        1 => FinishReason::Stop,
        2 => FinishReason::StopSeq,
        3 => FinishReason::Context,
        4 => FinishReason::Cancelled,
        c => return Err(format!("unknown finish code {c} in trace")),
    })
}

/// Finish reason wire names, shared by the JSON encoding and reports.
pub fn finish_name(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::StopSeq => "stop_seq",
        FinishReason::Context => "context",
        FinishReason::Cancelled => "cancel",
    }
}

fn finish_from_name(s: &str) -> DecResult<FinishReason> {
    Ok(match s {
        "length" => FinishReason::Length,
        "stop" => FinishReason::Stop,
        "stop_seq" => FinishReason::StopSeq,
        "context" => FinishReason::Context,
        "cancel" => FinishReason::Cancelled,
        s => return Err(format!("unknown finish reason {s:?} in trace")),
    })
}

fn frame(out: &mut Vec<u8>, tag: u8, payload: &[u8]) {
    out.push(tag);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Binary prelude: magic + version + header frame. The streaming
/// recorder writes this once at open, then appends event frames.
pub fn encode_prelude(h: &TraceHeader) -> Vec<u8> {
    let mut e = Enc::default();
    e.str(&h.pair);
    e.u32(h.batch);
    e.u32(h.seq_len);
    e.u32(h.vocab);
    e.u32(h.gmax);
    e.u64(h.engine_seed);
    e.method(&h.method);
    e.str(&h.backend);
    e.str(&h.mode);
    e.str(&h.pipeline);
    e.u32(h.pipeline_depth);
    e.u32(h.gamma_init);
    e.bool(h.gamma_pinned);
    e.bool(h.self_draft);
    match &h.sim {
        None => e.u8(0),
        Some(s) => {
            e.u8(1);
            e.u64(s.seed);
            e.f32(s.agreement);
        }
    }
    let mut out = Vec::with_capacity(e.buf.len() + 16);
    out.extend_from_slice(&TRACE_MAGIC);
    out.extend_from_slice(&h.version.to_le_bytes());
    frame(&mut out, TAG_HEADER, &e.buf);
    out
}

/// One event as a self-contained binary frame.
pub fn encode_event(ev: &TraceEvent) -> Vec<u8> {
    let mut e = Enc::default();
    let tag = match ev {
        TraceEvent::Admit(a) => {
            e.u32(a.slot);
            e.u64(a.id);
            e.vec_i32(&a.prompt);
            e.u32(a.stop_ids.len() as u32);
            for s in &a.stop_ids {
                e.vec_i32(s);
            }
            e.u32(a.max_new_tokens);
            e.f32(a.temperature);
            e.opt_f32(a.draft_temperature);
            e.u32(a.top_k);
            e.f32(a.top_p);
            e.u32(a.gamma);
            e.bool(a.gamma_pinned);
            e.opt_method(&a.method);
            e.u64(a.seed);
            e.u64(a.params_digest);
            e.u64(a.rng_state);
            e.u64(a.rng_inc);
            e.bool(a.refill);
            TAG_ADMIT
        }
        TraceEvent::Step(s) => {
            e.u32(s.slots.len() as u32);
            for t in &s.slots {
                e.u32(t.slot);
                e.u64(t.id);
                e.u32(t.len_before);
                e.u32(t.gamma);
                e.method(&t.method);
                e.u64(t.rng_state);
                e.u64(t.rng_inc);
                e.vec_i32(&t.draft);
                e.u64(t.zq_digest);
                e.u64(t.zp_digest);
                e.u32(t.accept_len);
                e.vec_i32(&t.out_row);
                e.vec_i32(&t.committed);
                match t.finish {
                    None => e.u8(255),
                    Some(f) => e.u8(finish_code(f)),
                }
            }
            TAG_STEP
        }
        TraceEvent::Cancel { id, slot } => {
            e.u64(*id);
            match slot {
                None => e.u8(0),
                Some(s) => {
                    e.u8(1);
                    e.u32(*s);
                }
            }
            TAG_CANCEL
        }
        TraceEvent::Pipeline(p) => {
            match p {
                PipelineEv::Launch { gamma, depth } => {
                    e.u8(0);
                    e.u32(*gamma);
                    e.u32(*depth);
                }
                PipelineEv::BarrierHit { depth } => {
                    e.u8(1);
                    e.u32(*depth);
                }
                PipelineEv::BarrierMiss { depth, slot_hits } => {
                    e.u8(2);
                    e.u32(*depth);
                    e.vec_bool(slot_hits);
                }
                PipelineEv::Discard => e.u8(3),
                PipelineEv::CancelInflight => e.u8(4),
                PipelineEv::Adopt { depth, salvaged } => {
                    e.u8(5);
                    e.u32(*depth);
                    e.vec_bool(salvaged);
                }
            }
            TAG_PIPELINE
        }
        TraceEvent::Verify { rows, groups } => {
            e.u32(*rows);
            e.u32(*groups);
            TAG_VERIFY
        }
    };
    let mut out = Vec::with_capacity(e.buf.len() + 5);
    frame(&mut out, tag, &e.buf);
    out
}

/// Serialize a whole trace to the binary format.
pub fn to_binary(t: &Trace) -> Vec<u8> {
    let mut out = encode_prelude(&t.header);
    for ev in &t.events {
        out.extend_from_slice(&encode_event(ev));
    }
    out
}

fn decode_header(d: &mut Dec, wire_version: u32) -> DecResult<TraceHeader> {
    Ok(TraceHeader {
        // normalized: a v2 trace loads as the current version (depth 1)
        // so a re-save round-trips as a valid current-format trace
        version: TRACE_VERSION,
        pair: d.str()?,
        batch: d.u32()?,
        seq_len: d.u32()?,
        vocab: d.u32()?,
        gmax: d.u32()?,
        engine_seed: d.u64()?,
        method: d.method()?,
        backend: d.str()?,
        mode: d.str()?,
        pipeline: d.str()?,
        pipeline_depth: if wire_version >= 3 { d.u32()? } else { 1 },
        gamma_init: d.u32()?,
        gamma_pinned: d.bool()?,
        self_draft: d.bool()?,
        sim: if d.u8()? == 0 {
            None
        } else {
            Some(SimHeader {
                seed: d.u64()?,
                agreement: d.f32()?,
            })
        },
    })
}

fn decode_event(tag: u8, payload: &[u8], wire_version: u32) -> DecResult<TraceEvent> {
    let mut d = Dec::new(payload);
    let ev = match tag {
        TAG_ADMIT => TraceEvent::Admit(AdmitEvent {
            slot: d.u32()?,
            id: d.u64()?,
            prompt: d.vec_i32()?,
            stop_ids: {
                let n = d.u32()? as usize;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(d.vec_i32()?);
                }
                v
            },
            max_new_tokens: d.u32()?,
            temperature: d.f32()?,
            draft_temperature: d.opt_f32()?,
            top_k: d.u32()?,
            top_p: d.f32()?,
            gamma: d.u32()?,
            gamma_pinned: d.bool()?,
            method: d.opt_method()?,
            seed: d.u64()?,
            params_digest: d.u64()?,
            rng_state: d.u64()?,
            rng_inc: d.u64()?,
            refill: d.bool()?,
        }),
        TAG_STEP => {
            let n = d.u32()? as usize;
            let mut slots = Vec::with_capacity(n);
            for _ in 0..n {
                slots.push(SlotStep {
                    slot: d.u32()?,
                    id: d.u64()?,
                    len_before: d.u32()?,
                    gamma: d.u32()?,
                    method: d.method()?,
                    rng_state: d.u64()?,
                    rng_inc: d.u64()?,
                    draft: d.vec_i32()?,
                    zq_digest: d.u64()?,
                    zp_digest: d.u64()?,
                    accept_len: d.u32()?,
                    out_row: d.vec_i32()?,
                    committed: d.vec_i32()?,
                    finish: match d.u8()? {
                        255 => None,
                        c => Some(finish_from_code(c)?),
                    },
                });
            }
            TraceEvent::Step(StepEvent { slots })
        }
        TAG_CANCEL => TraceEvent::Cancel {
            id: d.u64()?,
            slot: if d.u8()? == 0 { None } else { Some(d.u32()?) },
        },
        TAG_PIPELINE => TraceEvent::Pipeline(match (d.u8()?, wire_version) {
            // v2 wire shapes: single-block window, all-or-nothing barrier
            (0, 2) => PipelineEv::Launch {
                gamma: d.u32()?,
                depth: 1,
            },
            (1, 2) => PipelineEv::BarrierHit { depth: 1 },
            (2, 2) => PipelineEv::BarrierMiss {
                depth: 1,
                slot_hits: Vec::new(),
            },
            (0, _) => PipelineEv::Launch {
                gamma: d.u32()?,
                depth: d.u32()?,
            },
            (1, _) => PipelineEv::BarrierHit { depth: d.u32()? },
            (2, _) => PipelineEv::BarrierMiss {
                depth: d.u32()?,
                slot_hits: d.vec_bool()?,
            },
            (3, _) => PipelineEv::Discard,
            (4, _) => PipelineEv::CancelInflight,
            (5, v) if v >= 3 => PipelineEv::Adopt {
                depth: d.u32()?,
                salvaged: d.vec_bool()?,
            },
            (k, _) => return Err(format!("unknown pipeline event kind {k}")),
        }),
        TAG_VERIFY => TraceEvent::Verify {
            rows: d.u32()?,
            groups: d.u32()?,
        },
        t => return Err(format!("unknown frame tag {t}")),
    };
    if !d.done() {
        return Err(format!("{} trailing bytes in frame tag {tag}", payload.len() - d.pos));
    }
    Ok(ev)
}

/// Parse a binary trace.
pub fn from_binary(bytes: &[u8]) -> DecResult<Trace> {
    let mut d = Dec::new(bytes);
    let magic = d.take(4)?;
    if magic != TRACE_MAGIC {
        return Err("not a specd binary trace (bad magic)".into());
    }
    let version = d.u32()?;
    if !(TRACE_VERSION_MIN..=TRACE_VERSION).contains(&version) {
        return Err(format!(
            "trace version {version} not supported (checker knows versions \
             {TRACE_VERSION_MIN}..={TRACE_VERSION})"
        ));
    }
    let tag = d.u8()?;
    if tag != TAG_HEADER {
        return Err(format!("expected header frame, got tag {tag}"));
    }
    let len = d.u32()? as usize;
    let payload = d.take(len)?;
    let header = decode_header(&mut Dec::new(payload), version)?;
    let mut events = Vec::new();
    while !d.done() {
        let tag = d.u8()?;
        let len = d.u32()? as usize;
        let payload = d.take(len)?;
        events.push(decode_event(tag, payload, version)?);
    }
    Ok(Trace { header, events })
}

// ---------------------------------------------------------------------------
// JSON-lines encoding

fn hex(x: u64) -> Value {
    Value::Str(format!("{x:#x}"))
}

fn from_hex(v: &Value, key: &str) -> DecResult<u64> {
    let s = v
        .as_str()
        .ok_or_else(|| format!("trace json: {key} not a string"))?;
    let s = s.strip_prefix("0x").unwrap_or(s);
    u64::from_str_radix(s, 16).map_err(|e| format!("trace json: bad {key}: {e}"))
}

fn num(x: impl Into<f64>) -> Value {
    Value::Num(x.into())
}

fn method_json(m: &Method) -> Value {
    let (_, a, b) = method_parts(m);
    obj(vec![
        ("name", Value::Str(m.name().into())),
        ("alpha_milli", num(a as f64)),
        ("beta_milli", num(b as f64)),
    ])
}

fn method_from_json(v: &Value) -> DecResult<Method> {
    let name = v
        .get("name")
        .and_then(|n| n.as_str())
        .ok_or("trace json: method missing name")?;
    let a = v.get("alpha_milli").and_then(|x| x.as_i64()).unwrap_or(0);
    let b = v.get("beta_milli").and_then(|x| x.as_i64()).unwrap_or(0);
    let kind = match name {
        "baseline" => 0,
        "exact" => 1,
        "sigmoid" => 2,
        "sigmoid16" => 3,
        n => return Err(format!("trace json: unknown method {n:?}")),
    };
    method_from_parts(kind, a, b)
}

fn tokens_json(xs: &[i32]) -> Value {
    Value::Arr(xs.iter().map(|t| num(*t as f64)).collect())
}

fn tokens_from_json(v: &Value, key: &str) -> DecResult<Vec<i32>> {
    v.as_arr()
        .ok_or_else(|| format!("trace json: {key} not an array"))?
        .iter()
        .map(|t| {
            t.as_i64()
                .map(|x| x as i32)
                .ok_or_else(|| format!("trace json: {key} holds a non-number"))
        })
        .collect()
}

fn get<'a>(v: &'a Value, key: &str) -> DecResult<&'a Value> {
    v.get(key)
        .ok_or_else(|| format!("trace json: missing key {key:?}"))
}

fn get_u32(v: &Value, key: &str) -> DecResult<u32> {
    get(v, key)?
        .as_i64()
        .map(|x| x as u32)
        .ok_or_else(|| format!("trace json: {key} not a number"))
}

fn get_f32(v: &Value, key: &str) -> DecResult<f32> {
    get(v, key)?
        .as_f64()
        .map(|x| x as f32)
        .ok_or_else(|| format!("trace json: {key} not a number"))
}

fn get_bool(v: &Value, key: &str) -> DecResult<bool> {
    get(v, key)?
        .as_bool()
        .ok_or_else(|| format!("trace json: {key} not a bool"))
}

fn get_str<'a>(v: &'a Value, key: &str) -> DecResult<&'a str> {
    get(v, key)?
        .as_str()
        .ok_or_else(|| format!("trace json: {key} not a string"))
}

fn header_json(h: &TraceHeader) -> Value {
    obj(vec![
        ("trace", Value::Str("specd".into())),
        ("version", num(h.version as f64)),
        ("pair", Value::Str(h.pair.clone())),
        ("batch", num(h.batch as f64)),
        ("seq_len", num(h.seq_len as f64)),
        ("vocab", num(h.vocab as f64)),
        ("gmax", num(h.gmax as f64)),
        ("engine_seed", hex(h.engine_seed)),
        ("method", method_json(&h.method)),
        ("backend", Value::Str(h.backend.clone())),
        ("mode", Value::Str(h.mode.clone())),
        ("pipeline", Value::Str(h.pipeline.clone())),
        ("pipeline_depth", num(h.pipeline_depth as f64)),
        ("gamma_init", num(h.gamma_init as f64)),
        ("gamma_pinned", Value::Bool(h.gamma_pinned)),
        ("self_draft", Value::Bool(h.self_draft)),
        (
            "sim",
            match &h.sim {
                None => Value::Null,
                Some(s) => obj(vec![
                    ("seed", hex(s.seed)),
                    ("agreement", num(s.agreement as f64)),
                ]),
            },
        ),
    ])
}

fn header_from_json(v: &Value) -> DecResult<TraceHeader> {
    if get_str(v, "trace")? != "specd" {
        return Err("trace json: not a specd trace".into());
    }
    let version = get_u32(v, "version")?;
    if !(TRACE_VERSION_MIN..=TRACE_VERSION).contains(&version) {
        return Err(format!(
            "trace version {version} not supported (checker knows versions \
             {TRACE_VERSION_MIN}..={TRACE_VERSION})"
        ));
    }
    Ok(TraceHeader {
        version: TRACE_VERSION,
        pair: get_str(v, "pair")?.to_string(),
        batch: get_u32(v, "batch")?,
        seq_len: get_u32(v, "seq_len")?,
        vocab: get_u32(v, "vocab")?,
        gmax: get_u32(v, "gmax")?,
        engine_seed: from_hex(get(v, "engine_seed")?, "engine_seed")?,
        method: method_from_json(get(v, "method")?)?,
        backend: get_str(v, "backend")?.to_string(),
        mode: get_str(v, "mode")?.to_string(),
        pipeline: get_str(v, "pipeline")?.to_string(),
        pipeline_depth: if version >= 3 {
            get_u32(v, "pipeline_depth")?
        } else {
            1
        },
        gamma_init: get_u32(v, "gamma_init")?,
        gamma_pinned: get_bool(v, "gamma_pinned")?,
        self_draft: get_bool(v, "self_draft")?,
        sim: match get(v, "sim")? {
            Value::Null => None,
            s => Some(SimHeader {
                seed: from_hex(get(s, "seed")?, "sim.seed")?,
                agreement: get_f32(s, "agreement")?,
            }),
        },
    })
}

fn event_json(ev: &TraceEvent) -> Value {
    match ev {
        TraceEvent::Admit(a) => obj(vec![
            ("ev", Value::Str("admit".into())),
            ("slot", num(a.slot as f64)),
            ("id", hex(a.id)),
            ("prompt", tokens_json(&a.prompt)),
            (
                "stop_ids",
                Value::Arr(a.stop_ids.iter().map(|s| tokens_json(s)).collect()),
            ),
            ("max_new_tokens", num(a.max_new_tokens as f64)),
            ("temperature", num(a.temperature as f64)),
            (
                "draft_temperature",
                a.draft_temperature
                    .map(|t| num(t as f64))
                    .unwrap_or(Value::Null),
            ),
            ("top_k", num(a.top_k as f64)),
            ("top_p", num(a.top_p as f64)),
            ("gamma", num(a.gamma as f64)),
            ("gamma_pinned", Value::Bool(a.gamma_pinned)),
            (
                "method",
                a.method.as_ref().map(method_json).unwrap_or(Value::Null),
            ),
            ("seed", hex(a.seed)),
            ("params_digest", hex(a.params_digest)),
            ("rng_state", hex(a.rng_state)),
            ("rng_inc", hex(a.rng_inc)),
            ("refill", Value::Bool(a.refill)),
        ]),
        TraceEvent::Step(s) => obj(vec![
            ("ev", Value::Str("step".into())),
            (
                "slots",
                Value::Arr(
                    s.slots
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("slot", num(t.slot as f64)),
                                ("id", hex(t.id)),
                                ("len_before", num(t.len_before as f64)),
                                ("gamma", num(t.gamma as f64)),
                                ("method", method_json(&t.method)),
                                ("rng_state", hex(t.rng_state)),
                                ("rng_inc", hex(t.rng_inc)),
                                ("draft", tokens_json(&t.draft)),
                                ("zq_digest", hex(t.zq_digest)),
                                ("zp_digest", hex(t.zp_digest)),
                                ("accept_len", num(t.accept_len as f64)),
                                ("out_row", tokens_json(&t.out_row)),
                                ("committed", tokens_json(&t.committed)),
                                (
                                    "finish",
                                    t.finish
                                        .map(|f| Value::Str(finish_name(f).into()))
                                        .unwrap_or(Value::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        TraceEvent::Cancel { id, slot } => obj(vec![
            ("ev", Value::Str("cancel".into())),
            ("id", hex(*id)),
            (
                "slot",
                slot.map(|s| num(s as f64)).unwrap_or(Value::Null),
            ),
        ]),
        TraceEvent::Pipeline(p) => {
            let mut fields = vec![("ev", Value::Str("pipeline".into()))];
            let bools = |xs: &[bool]| Value::Arr(xs.iter().map(|b| Value::Bool(*b)).collect());
            let kind = match p {
                PipelineEv::Launch { gamma, depth } => {
                    fields.push(("gamma", num(*gamma as f64)));
                    fields.push(("depth", num(*depth as f64)));
                    "launch"
                }
                PipelineEv::BarrierHit { depth } => {
                    fields.push(("depth", num(*depth as f64)));
                    "hit"
                }
                PipelineEv::BarrierMiss { depth, slot_hits } => {
                    fields.push(("depth", num(*depth as f64)));
                    fields.push(("slot_hits", bools(slot_hits)));
                    "miss"
                }
                PipelineEv::Adopt { depth, salvaged } => {
                    fields.push(("depth", num(*depth as f64)));
                    fields.push(("salvaged", bools(salvaged)));
                    "adopt"
                }
                PipelineEv::Discard => "discard",
                PipelineEv::CancelInflight => "cancel_inflight",
            };
            fields.push(("kind", Value::Str(kind.into())));
            obj(fields)
        }
        TraceEvent::Verify { rows, groups } => obj(vec![
            ("ev", Value::Str("verify".into())),
            ("rows", num(*rows as f64)),
            ("groups", num(*groups as f64)),
        ]),
    }
}

fn event_from_json(v: &Value) -> DecResult<TraceEvent> {
    Ok(match get_str(v, "ev")? {
        "admit" => TraceEvent::Admit(AdmitEvent {
            slot: get_u32(v, "slot")?,
            id: from_hex(get(v, "id")?, "id")?,
            prompt: tokens_from_json(get(v, "prompt")?, "prompt")?,
            stop_ids: get(v, "stop_ids")?
                .as_arr()
                .ok_or("trace json: stop_ids not an array")?
                .iter()
                .map(|s| tokens_from_json(s, "stop_ids"))
                .collect::<DecResult<_>>()?,
            max_new_tokens: get_u32(v, "max_new_tokens")?,
            temperature: get_f32(v, "temperature")?,
            draft_temperature: match get(v, "draft_temperature")? {
                Value::Null => None,
                t => Some(
                    t.as_f64()
                        .ok_or("trace json: draft_temperature not a number")?
                        as f32,
                ),
            },
            top_k: get_u32(v, "top_k")?,
            top_p: get_f32(v, "top_p")?,
            gamma: get_u32(v, "gamma")?,
            gamma_pinned: get_bool(v, "gamma_pinned")?,
            method: match get(v, "method")? {
                Value::Null => None,
                m => Some(method_from_json(m)?),
            },
            seed: from_hex(get(v, "seed")?, "seed")?,
            params_digest: from_hex(get(v, "params_digest")?, "params_digest")?,
            rng_state: from_hex(get(v, "rng_state")?, "rng_state")?,
            rng_inc: from_hex(get(v, "rng_inc")?, "rng_inc")?,
            refill: get_bool(v, "refill")?,
        }),
        "step" => TraceEvent::Step(StepEvent {
            slots: get(v, "slots")?
                .as_arr()
                .ok_or("trace json: slots not an array")?
                .iter()
                .map(|t| {
                    Ok(SlotStep {
                        slot: get_u32(t, "slot")?,
                        id: from_hex(get(t, "id")?, "id")?,
                        len_before: get_u32(t, "len_before")?,
                        gamma: get_u32(t, "gamma")?,
                        method: method_from_json(get(t, "method")?)?,
                        rng_state: from_hex(get(t, "rng_state")?, "rng_state")?,
                        rng_inc: from_hex(get(t, "rng_inc")?, "rng_inc")?,
                        draft: tokens_from_json(get(t, "draft")?, "draft")?,
                        zq_digest: from_hex(get(t, "zq_digest")?, "zq_digest")?,
                        zp_digest: from_hex(get(t, "zp_digest")?, "zp_digest")?,
                        accept_len: get_u32(t, "accept_len")?,
                        out_row: tokens_from_json(get(t, "out_row")?, "out_row")?,
                        committed: tokens_from_json(get(t, "committed")?, "committed")?,
                        finish: match get(t, "finish")? {
                            Value::Null => None,
                            f => Some(finish_from_name(
                                f.as_str().ok_or("trace json: finish not a string")?,
                            )?),
                        },
                    })
                })
                .collect::<DecResult<_>>()?,
        }),
        "cancel" => TraceEvent::Cancel {
            id: from_hex(get(v, "id")?, "id")?,
            slot: match get(v, "slot")? {
                Value::Null => None,
                s => Some(s.as_i64().ok_or("trace json: slot not a number")? as u32),
            },
        },
        "pipeline" => {
            // v2 JSON events carry no depth / per-slot fields: default
            // to the single-block window they were recorded under
            let depth = match v.get("depth") {
                None => 1,
                Some(d) => d.as_i64().ok_or("trace json: depth not a number")? as u32,
            };
            let bools = |key: &str| -> DecResult<Vec<bool>> {
                match v.get(key) {
                    None => Ok(Vec::new()),
                    Some(arr) => arr
                        .as_arr()
                        .ok_or_else(|| format!("trace json: {key} not an array"))?
                        .iter()
                        .map(|b| {
                            b.as_bool()
                                .ok_or_else(|| format!("trace json: {key} holds a non-bool"))
                        })
                        .collect(),
                }
            };
            TraceEvent::Pipeline(match get_str(v, "kind")? {
                "launch" => PipelineEv::Launch {
                    gamma: get_u32(v, "gamma")?,
                    depth,
                },
                "hit" => PipelineEv::BarrierHit { depth },
                "miss" => PipelineEv::BarrierMiss {
                    depth,
                    slot_hits: bools("slot_hits")?,
                },
                "adopt" => PipelineEv::Adopt {
                    depth,
                    salvaged: bools("salvaged")?,
                },
                "discard" => PipelineEv::Discard,
                "cancel_inflight" => PipelineEv::CancelInflight,
                k => return Err(format!("trace json: unknown pipeline kind {k:?}")),
            })
        }
        "verify" => TraceEvent::Verify {
            rows: get_u32(v, "rows")?,
            groups: get_u32(v, "groups")?,
        },
        e => return Err(format!("trace json: unknown event {e:?}")),
    })
}

/// Serialize a trace as JSON-lines (header line, then one event per line).
pub fn to_jsonl(t: &Trace) -> String {
    let mut out = header_json(&t.header).dump();
    out.push('\n');
    for ev in &t.events {
        out.push_str(&event_json(ev).dump());
        out.push('\n');
    }
    out
}

/// Parse a JSON-lines trace.
pub fn from_jsonl(text: &str) -> DecResult<Trace> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let first = lines.next().ok_or("trace json: empty input")?;
    let header =
        header_from_json(&json::parse(first).map_err(|e| format!("trace json header: {e}"))?)?;
    let mut events = Vec::new();
    for (i, line) in lines.enumerate() {
        let v = json::parse(line).map_err(|e| format!("trace json line {}: {e}", i + 2))?;
        events.push(event_from_json(&v)?);
    }
    Ok(Trace { header, events })
}

// ---------------------------------------------------------------------------
// file I/O

/// Load a trace from disk, sniffing the format: `SPTR` magic → binary,
/// anything else → JSON-lines.
pub fn load(path: &Path) -> DecResult<Trace> {
    let bytes =
        std::fs::read(path).map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    if bytes.starts_with(&TRACE_MAGIC) {
        from_binary(&bytes)
    } else {
        let text = String::from_utf8(bytes)
            .map_err(|_| "trace is neither binary (no magic) nor utf-8 json-lines".to_string())?;
        from_jsonl(&text)
    }
}

/// Write a trace to disk in the binary format.
pub fn save_binary(t: &Trace, path: &Path) -> DecResult<()> {
    std::fs::write(path, to_binary(t)).map_err(|e| format!("cannot write {}: {e}", path.display()))
}

/// Write a trace to disk as JSON-lines.
pub fn save_jsonl(t: &Trace, path: &Path) -> DecResult<()> {
    std::fs::write(path, to_jsonl(t)).map_err(|e| format!("cannot write {}: {e}", path.display()))
}

// ---------------------------------------------------------------------------
// trace diffing

/// Name of a slot-step field that differs between `a` and `b`, with
/// both values — checked in recording order so the reported field is
/// the first to disagree.
fn slot_field_diff(a: &SlotStep, b: &SlotStep) -> Option<(&'static str, String)> {
    macro_rules! diff {
        ($field:ident) => {
            if a.$field != b.$field {
                return Some((
                    stringify!($field),
                    format!("{:?} vs {:?}", a.$field, b.$field),
                ));
            }
        };
    }
    diff!(slot);
    diff!(id);
    diff!(len_before);
    diff!(gamma);
    diff!(method);
    diff!(rng_state);
    diff!(rng_inc);
    diff!(draft);
    diff!(zq_digest);
    diff!(zp_digest);
    diff!(accept_len);
    diff!(out_row);
    diff!(committed);
    diff!(finish);
    None
}

/// Locate the first difference between two traces, described down to
/// the step/slot/field — how `specd trace corpus` reports a committed
/// recording that a fresh re-record no longer matches.
///
/// Returns `None` when the traces are identical.
pub fn first_difference(a: &Trace, b: &Trace) -> Option<String> {
    if a.header != b.header {
        return Some(format!(
            "headers differ: {:?} vs {:?}",
            a.header, b.header
        ));
    }
    let mut step_no = 0usize;
    for (i, (ea, eb)) in a.events.iter().zip(b.events.iter()).enumerate() {
        if matches!(ea, TraceEvent::Step(_)) || matches!(eb, TraceEvent::Step(_)) {
            step_no += 1;
        }
        if ea == eb {
            continue;
        }
        return Some(match (ea, eb) {
            (TraceEvent::Step(sa), TraceEvent::Step(sb)) => {
                for (ta, tb) in sa.slots.iter().zip(sb.slots.iter()) {
                    if let Some((field, detail)) = slot_field_diff(ta, tb) {
                        return Some(format!(
                            "step {step_no} slot {} (request {}): {field} differs — {detail}",
                            ta.slot, ta.id
                        ));
                    }
                }
                format!(
                    "step {step_no}: slot sets differ ({} vs {} slots)",
                    sa.slots.len(),
                    sb.slots.len()
                )
            }
            (TraceEvent::Admit(aa), TraceEvent::Admit(ab)) => {
                let field = if aa.refill != ab.refill {
                    "refill"
                } else if aa.rng_state != ab.rng_state || aa.rng_inc != ab.rng_inc {
                    "rng"
                } else if aa.params_digest != ab.params_digest {
                    "params_digest"
                } else {
                    "fields"
                };
                format!(
                    "event {i} (before step {}): admit of request {} differs in {field}: \
                     {aa:?} vs {ab:?}",
                    step_no + 1,
                    aa.id
                )
            }
            _ => format!("event {i} (before step {}): {ea:?} vs {eb:?}", step_no + 1),
        });
    }
    if a.events.len() != b.events.len() {
        return Some(format!(
            "event counts differ: {} vs {} (first {} identical)",
            a.events.len(),
            b.events.len(),
            a.events.len().min(b.events.len())
        ));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        Trace {
            header: TraceHeader {
                version: TRACE_VERSION,
                pair: "sim".into(),
                batch: 2,
                seq_len: 96,
                vocab: 48,
                gmax: 6,
                engine_seed: 11,
                method: Method::sigmoid(-1e3, 1e3),
                backend: "native".into(),
                mode: "speculative".into(),
                pipeline: "on".into(),
                pipeline_depth: 2,
                gamma_init: 4,
                gamma_pinned: false,
                self_draft: false,
                sim: Some(SimHeader {
                    seed: 0xBEEF,
                    agreement: 0.9,
                }),
            },
            events: vec![
                TraceEvent::Admit(AdmitEvent {
                    slot: 0,
                    id: 7,
                    prompt: vec![1, 5, 9],
                    stop_ids: vec![vec![4], vec![9, 2]],
                    max_new_tokens: 16,
                    temperature: 0.8,
                    draft_temperature: Some(0.5),
                    top_k: 12,
                    top_p: 0.9,
                    gamma: 3,
                    gamma_pinned: true,
                    method: Some(Method::Exact),
                    seed: 0xFFFF_FFFF_FFFF_FFFE,
                    params_digest: 0xDEAD_BEEF_DEAD_BEEF,
                    rng_state: u64::MAX - 3,
                    rng_inc: 15,
                    refill: true,
                }),
                TraceEvent::Pipeline(PipelineEv::Launch { gamma: 4, depth: 2 }),
                TraceEvent::Step(StepEvent {
                    slots: vec![
                        SlotStep {
                            slot: 0,
                            id: 7,
                            len_before: 3,
                            gamma: 4,
                            method: Method::Exact,
                            rng_state: 0x0123_4567_89AB_CDEF,
                            rng_inc: 15,
                            draft: vec![3, 4, 5, 6],
                            zq_digest: 0xAAAA_BBBB_CCCC_DDDD,
                            zp_digest: 0x1111_2222_3333_4444,
                            accept_len: 2,
                            out_row: vec![3, 4, 8, 0, 0],
                            committed: vec![3, 4, 8],
                            finish: Some(FinishReason::StopSeq),
                        },
                        // ragged sibling: same step, different γ
                        SlotStep {
                            slot: 1,
                            id: 8,
                            len_before: 5,
                            gamma: 2,
                            method: Method::Baseline,
                            rng_state: 0x5555_6666_7777_8888,
                            rng_inc: 17,
                            draft: vec![10, 11],
                            zq_digest: 0x9999_0000_9999_0000,
                            zp_digest: 0x4242_4242_4242_4242,
                            accept_len: 2,
                            out_row: vec![10, 11, 12],
                            committed: vec![10, 11, 12],
                            finish: None,
                        },
                    ],
                }),
                TraceEvent::Pipeline(PipelineEv::Adopt {
                    depth: 1,
                    salvaged: vec![true, false],
                }),
                TraceEvent::Pipeline(PipelineEv::BarrierMiss {
                    depth: 2,
                    slot_hits: vec![true, false],
                }),
                TraceEvent::Verify { rows: 6, groups: 2 },
                TraceEvent::Cancel { id: 9, slot: None },
                TraceEvent::Cancel {
                    id: 7,
                    slot: Some(0),
                },
            ],
        }
    }

    #[test]
    fn binary_round_trip() {
        let t = sample_trace();
        let bytes = to_binary(&t);
        let back = from_binary(&bytes).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn jsonl_round_trip() {
        let t = sample_trace();
        let text = to_jsonl(&t);
        let back = from_jsonl(&text).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn binary_jsonl_binary_round_trip() {
        let t = sample_trace();
        let back = from_jsonl(&to_jsonl(&from_binary(&to_binary(&t)).unwrap())).unwrap();
        assert_eq!(to_binary(&back), to_binary(&t));
    }

    #[test]
    fn truncated_binary_is_an_error_not_a_panic() {
        let bytes = to_binary(&sample_trace());
        for cut in [0, 3, 7, 12, bytes.len() - 1] {
            assert!(from_binary(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(from_binary(b"NOPE0000").is_err());
    }

    #[test]
    fn unknown_version_rejected() {
        let mut bytes = to_binary(&sample_trace());
        bytes[4] = 99;
        let err = from_binary(&bytes).unwrap_err();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn v2_binary_trace_still_loads() {
        // hand-encode a v2 prelude + pipeline events in the v2 wire
        // shapes and prove the loader maps them onto the v3 event
        // model at depth 1 with a normalized header
        let t = sample_trace();
        let mut e = Enc::default();
        e.str(&t.header.pair);
        e.u32(t.header.batch);
        e.u32(t.header.seq_len);
        e.u32(t.header.vocab);
        e.u32(t.header.gmax);
        e.u64(t.header.engine_seed);
        e.method(&t.header.method);
        e.str(&t.header.backend);
        e.str(&t.header.mode);
        e.str(&t.header.pipeline);
        // v2: no pipeline_depth field
        e.u32(t.header.gamma_init);
        e.bool(t.header.gamma_pinned);
        e.bool(t.header.self_draft);
        let sim = t.header.sim.as_ref().unwrap();
        e.u8(1);
        e.u64(sim.seed);
        e.f32(sim.agreement);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&TRACE_MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        frame(&mut bytes, TAG_HEADER, &e.buf);
        // v2 pipeline frames: launch (γ only), hit, miss — no payloads
        let mut p = Enc::default();
        p.u8(0);
        p.u32(4);
        frame(&mut bytes, TAG_PIPELINE, &p.buf);
        frame(&mut bytes, TAG_PIPELINE, &[1]);
        frame(&mut bytes, TAG_PIPELINE, &[2]);
        frame(&mut bytes, TAG_PIPELINE, &[3]);

        let back = from_binary(&bytes).unwrap();
        assert_eq!(back.header.version, TRACE_VERSION, "header normalized");
        assert_eq!(back.header.pipeline_depth, 1);
        assert_eq!(
            back.events,
            vec![
                TraceEvent::Pipeline(PipelineEv::Launch { gamma: 4, depth: 1 }),
                TraceEvent::Pipeline(PipelineEv::BarrierHit { depth: 1 }),
                TraceEvent::Pipeline(PipelineEv::BarrierMiss {
                    depth: 1,
                    slot_hits: vec![],
                }),
                TraceEvent::Pipeline(PipelineEv::Discard),
            ]
        );
        // a normalized v2 trace re-saves as a valid current trace
        let resaved = to_binary(&back);
        assert_eq!(from_binary(&resaved).unwrap(), back);
    }

    #[test]
    fn digest_is_order_sensitive() {
        assert_ne!(digest_f32(&[1.0, 2.0, 3.0]), digest_f32(&[3.0, 2.0, 1.0]));
        assert_ne!(digest_i32(&[1, 2]), digest_i32(&[2, 1]));
        // single-bit flips move the digest
        assert_ne!(
            digest_f32(&[1.0, f32::from_bits(7)]),
            digest_f32(&[1.0, f32::from_bits(6)])
        );
    }
}
