//! Randomized record-then-check schedules (`specd trace fuzz`).
//!
//! Each [`FuzzCase`] drives a *pipelined* decode over the simulated
//! model pair — methods × γ policies × batch sizes × stop sequences ×
//! mid-decode cancels and queue churn — records it through the
//! engine's [`crate::trace::TraceSink`] hook, then replays the trace
//! through the offline oracle checker ([`crate::trace::check`]). Any
//! divergence means either the engine, the pipelined scheduler, the
//! native kernels, or the trace layer itself broke bit-identity — the
//! report pins the first divergent step.
//!
//! Everything here is deterministic from the fuzz seed, so a failing
//! case number reproduces exactly.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::engine::{
    Backend, Engine, EngineConfig, GenRequest, Mode, PipelineMode, SamplingParams,
};
use crate::runtime::{Runtime, SimSpec};
use crate::sampling::Method;
use crate::util::rng::Pcg32;

use super::checker::{check, CheckReport};
use super::format::Trace;
use super::recorder::TraceRecorder;

/// The verification methods the fuzzer mixes into batches — the HLO
/// trio plus the fp16-overflow sigmoid whose NaN τ rejects every draft
/// (the pipelined scheduler's worst case).
pub fn method_pool() -> [Method; 5] {
    [
        Method::Exact,
        Method::Baseline,
        Method::sigmoid(-1e3, 1e3),
        Method::sigmoid16(-1e3, 1e3),
        Method::sigmoid16(-1e5, 1e5),
    ]
}

/// One deterministic record-then-check schedule.
#[derive(Debug, Clone)]
pub struct FuzzCase {
    pub batch: usize,
    pub vocab: usize,
    /// draft/target agreement of the sim pair
    pub agreement: f32,
    /// sim model-pair seed
    pub model_seed: u64,
    /// engine RNG base seed
    pub engine_seed: u64,
    /// engine default verification method
    pub method: Method,
    /// sprinkle per-request method overrides over the batch
    pub mixed_methods: bool,
    pub n_reqs: usize,
    pub max_new: usize,
    pub gamma_init: usize,
    /// sim model-pair γ capacity (per-slot γ plans are clamped under it)
    pub gmax: usize,
    /// when non-empty, pin request `i`'s γ to `pin_gammas[i % len]` —
    /// forces genuinely ragged mixed-γ batches regardless of the
    /// random per-request params
    pub pin_gammas: Vec<usize>,
    pub pipeline: PipelineMode,
    /// speculation-window depth k (1 = single-block prefetch)
    pub pipeline_depth: usize,
    /// per-slot partial-hit adoption at the commit barrier (false =
    /// all-or-nothing windows)
    pub pipeline_salvage: bool,
    /// `(after step k, request id)` mid-decode cancellations
    pub cancels: Vec<(usize, u64)>,
    /// derivation seed for per-request params/stops
    pub seed: u64,
}

impl Default for FuzzCase {
    fn default() -> Self {
        FuzzCase {
            batch: 2,
            vocab: 64,
            agreement: 0.9,
            model_seed: 0xBEEF,
            engine_seed: 11,
            method: Method::Exact,
            mixed_methods: false,
            n_reqs: 4,
            max_new: 16,
            gamma_init: 4,
            gmax: 6,
            pin_gammas: Vec::new(),
            pipeline: PipelineMode::On,
            pipeline_depth: 2,
            pipeline_salvage: true,
            cancels: Vec::new(),
            seed: 1,
        }
    }
}

impl FuzzCase {
    fn sim_spec(&self) -> SimSpec {
        SimSpec {
            vocab: self.vocab,
            seq_len: 96,
            gmax: self.gmax,
            batches: vec![self.batch],
            seed: self.model_seed,
            agreement: self.agreement,
            model_delay: Duration::ZERO,
        }
    }

    /// Build the engine this case decodes on (sim runtime, native
    /// verification, pipelining per the case).
    pub fn engine(&self) -> Result<Engine> {
        let rt = Arc::new(Runtime::simulated(self.sim_spec()));
        Engine::new(
            rt,
            EngineConfig {
                pair: "sim".into(),
                batch: self.batch,
                method: self.method,
                backend: Backend::Native,
                mode: Mode::Speculative,
                gamma_init: self.gamma_init,
                gamma_pinned: false,
                self_draft: false,
                pipeline: self.pipeline,
                pipeline_depth: self.pipeline_depth,
                pipeline_salvage: self.pipeline_salvage,
                seed: self.engine_seed,
            },
        )
    }

    /// The case's request load, derived deterministically from
    /// `self.seed`: varied prompts, temperatures, top-k/p, γ caps and
    /// pins, draft temperatures, token-level stop sequences, and —
    /// when `mixed_methods` — per-request verification methods.
    pub fn requests(&self) -> Vec<GenRequest> {
        let mut rng = Pcg32::derive(self.seed, 0x7261_6365); // "race"
        let pool = method_pool();
        (0..self.n_reqs as u64)
            .map(|i| {
                let mut prompt = vec![1, 3 + i as i32, 9, 14];
                for _ in 0..rng.below(4) {
                    prompt.push(1 + rng.below(self.vocab as u32 - 2) as i32);
                }
                let max_new =
                    1 + self.max_new / 2 + rng.below(self.max_new as u32 / 2 + 1) as usize;
                let mut p = SamplingParams::default()
                    .with_max_new_tokens(max_new)
                    .with_temperature([0.0, 0.5, 0.8, 1.0, 1.2][rng.below(5) as usize])
                    .with_seed(self.seed.wrapping_mul(131).wrapping_add(i));
                match rng.below(6) {
                    0 => p = p.with_top_k(12),
                    1 => p = p.with_top_p(0.9),
                    2 => p = p.with_gamma(3),
                    3 => p = p.pin_gamma(2),
                    4 => p = p.with_draft_temperature(0.1),
                    _ => {}
                }
                if self.mixed_methods && rng.below(2) == 0 {
                    p = p.with_method(pool[rng.below(pool.len() as u32) as usize]);
                }
                if !self.pin_gammas.is_empty() {
                    let g = self.pin_gammas[i as usize % self.pin_gammas.len()];
                    p = p.pin_gamma(g);
                }
                let mut r = GenRequest::new(i, prompt, p);
                // token-level stops straight from the sim vocab (no
                // tokenizer in the loop)
                match rng.below(5) {
                    0 => r.stop_ids = vec![vec![17]],
                    1 => r.stop_ids = vec![vec![9, 4]],
                    2 => r.stop_ids = vec![vec![5], vec![30, 2, 7]],
                    _ => {}
                }
                r
            })
            .collect()
    }
}

/// Run a case to completion with a buffered recorder attached,
/// executing the cancel schedule mid-decode. Returns the trace.
pub fn record_case(case: &FuzzCase) -> Result<(Trace, Arc<TraceRecorder>)> {
    let mut e = case.engine()?;
    let rec = Arc::new(TraceRecorder::buffered(e.trace_header()));
    e.set_trace(rec.clone());
    for r in case.requests() {
        e.submit(r);
    }
    let mut step = 0usize;
    while e.active() > 0 || e.pending() > 0 {
        e.step()?;
        e.take_deltas();
        for &(at, id) in &case.cancels {
            if at == step {
                // unknown / already-finished ids are fine: the cancel
                // is a no-op and nothing is recorded
                let _ = e.cancel(id);
            }
        }
        step += 1;
        if step >= 10_000 {
            bail!("fuzz case did not terminate in 10k steps: {case:?}");
        }
    }
    Ok((rec.snapshot(), rec))
}

/// Record one case, then replay its trace against the oracle checker.
pub fn run_case(case: &FuzzCase) -> Result<CheckReport> {
    let (trace, _rec) = record_case(case)?;
    check(&trace).map_err(|e| anyhow::anyhow!("trace unreplayable: {e}"))
}

/// Derive case `idx` of a fuzz run from the run seed.
pub fn derive_case(run_seed: u64, idx: u64) -> FuzzCase {
    let mut rng = Pcg32::derive(run_seed, idx.wrapping_add(1));
    let pool = method_pool();
    let batch = 1 + rng.below(4) as usize;
    FuzzCase {
        batch,
        vocab: 48 + 16 * rng.below(2) as usize,
        agreement: [0.5, 0.9, 0.97, 0.99][rng.below(4) as usize],
        model_seed: 0xBEEF ^ (rng.next_u32() as u64),
        engine_seed: rng.next_u32() as u64,
        method: pool[rng.below(pool.len() as u32) as usize],
        mixed_methods: rng.below(2) == 0,
        n_reqs: batch + rng.below(2 + batch as u32) as usize,
        max_new: 8 + rng.below(20) as usize,
        gamma_init: 3 + rng.below(3) as usize,
        gmax: [6, 8][rng.below(2) as usize],
        // a third of the cases force a genuinely ragged batch (pins
        // above gmax clamp at admission, which is itself worth fuzzing)
        pin_gammas: match rng.below(3) {
            0 => vec![2, 5, 7],
            _ => Vec::new(),
        },
        pipeline: PipelineMode::On,
        pipeline_depth: 1 + rng.below(3) as usize,
        // mostly partial adoption (the new default); keep a tail of
        // all-or-nothing windows so the legacy barrier stays fuzzed
        pipeline_salvage: rng.below(10) != 0,
        cancels: match rng.below(3) {
            0 => Vec::new(),
            1 => vec![(2, 0)],
            _ => vec![(1, 0), (3, batch as u64)],
        },
        seed: run_seed ^ (idx.wrapping_mul(0x9E37_79B9)),
    }
}

/// Fuzz-run summary.
#[derive(Debug, Clone, Default)]
pub struct FuzzReport {
    pub cases: usize,
    pub steps: usize,
    pub tokens: usize,
    pub pipeline_events: usize,
    /// prefetched blocks adopted across all cases
    pub pipeline_adopts: usize,
    /// slot-rows salvaged across all cases (partial-hit wins)
    pub pipeline_salvaged: usize,
    /// description of the first failing case, if any
    pub failure: Option<String>,
}

impl FuzzReport {
    pub fn ok(&self) -> bool {
        self.failure.is_none()
    }
}

/// One-line description of derived case `idx` — printed per case and
/// embedded in failure reports so the parameters a seed reproduces are
/// visible.
pub fn case_label(run_seed: u64, idx: u64) -> String {
    let case = derive_case(run_seed, idx);
    format!(
        "case {idx}: b={} v={} agree={} method={} mixed={} reqs={} cancels={} k={} salvage={}",
        case.batch,
        case.vocab,
        case.agreement,
        case.method.name(),
        case.mixed_methods,
        case.n_reqs,
        case.cancels.len(),
        case.pipeline_depth,
        case.pipeline_salvage
    )
}

/// Re-derive and re-run exactly one case of a fuzz run — the
/// `specd trace fuzz --seed N --case K` reproduction path.
pub fn run_derived_case(run_seed: u64, idx: u64) -> Result<CheckReport> {
    run_case(&derive_case(run_seed, idx))
}

/// Record-then-check `n_cases` derived schedules; stops at the first
/// failure, whose report carries the `--seed N --case K` line that
/// reproduces it. `log` receives one progress line per case.
pub fn fuzz(n_cases: usize, run_seed: u64, mut log: impl FnMut(String)) -> Result<FuzzReport> {
    let mut report = FuzzReport::default();
    for idx in 0..n_cases as u64 {
        let case = derive_case(run_seed, idx);
        let label = case_label(run_seed, idx);
        let failed = |what: String| {
            format!(
                "{label} — {what}\n  reproduce: specd trace fuzz --seed {run_seed} --case {idx}"
            )
        };
        match run_case(&case) {
            Ok(cr) if cr.ok() => {
                log(format!(
                    "{label} — ok ({} steps, {} tokens)",
                    cr.steps, cr.tokens
                ));
                report.cases += 1;
                report.steps += cr.steps;
                report.tokens += cr.tokens;
                report.pipeline_events += cr.pipeline_events;
                report.pipeline_adopts += cr.pipeline_adopts;
                report.pipeline_salvaged += cr.pipeline_salvaged;
            }
            Ok(cr) => {
                let d = cr.divergence.expect("not ok");
                report.failure = Some(failed(format!("DIVERGED: {d}")));
                log(report.failure.clone().unwrap());
                return Ok(report);
            }
            Err(e) => {
                report.failure = Some(failed(format!("ERROR: {e}")));
                log(report.failure.clone().unwrap());
                return Ok(report);
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_case_records_and_replays_clean() {
        let case = FuzzCase {
            mixed_methods: true,
            cancels: vec![(2, 0)],
            ..FuzzCase::default()
        };
        let report = run_case(&case).expect("replayable");
        assert!(report.ok(), "divergence: {:?}", report.divergence);
        assert!(report.steps > 0);
        assert!(report.tokens > 0);
        assert_eq!(report.requests, case.n_reqs);
    }

    #[test]
    fn ragged_pinned_case_replays_clean() {
        let case = FuzzCase {
            batch: 3,
            n_reqs: 6,
            gmax: 8,
            pin_gammas: vec![2, 5, 7],
            mixed_methods: true,
            ..FuzzCase::default()
        };
        let report = run_case(&case).expect("replayable");
        assert!(report.ok(), "divergence: {:?}", report.divergence);
        assert!(report.refills > 0, "queue churn should mid-flight refill");
    }

    #[test]
    fn derived_cases_are_deterministic() {
        let a = derive_case(42, 3);
        let b = derive_case(42, 3);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn reported_seed_and_case_reproduce_the_same_parameters() {
        // a failure line names (run_seed, idx); the repro path
        // (`trace fuzz --seed N --case K`) must re-derive the identical
        // case AND the identical request schedule from those two values
        let (run_seed, idx) = (0xFEED_u64, 5u64);
        let reported = derive_case(run_seed, idx);
        let reproduced = derive_case(run_seed, idx);
        assert_eq!(format!("{reported:?}"), format!("{reproduced:?}"));
        let reqs_a = reported.requests();
        let reqs_b = reproduced.requests();
        assert_eq!(format!("{reqs_a:?}"), format!("{reqs_b:?}"));
        // and the printed label matches what the derived case actually is
        assert!(
            case_label(run_seed, idx).contains(&format!("b={}", reported.batch)),
            "label does not describe the derived case"
        );
    }
}
