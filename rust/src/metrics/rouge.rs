//! ROUGE-1 (unigram overlap F1) — the summarization metric in Table 1.
//!
//! Standard clipped-count formulation (Lin 2004): overlap = Σ_w min(
//! count_hyp(w), count_ref(w)); precision = overlap/|hyp|, recall =
//! overlap/|ref|, F1 = harmonic mean.

use std::collections::HashMap;

fn counts(words: &[&str]) -> HashMap<String, usize> {
    let mut map = HashMap::new();
    for w in words {
        *map.entry(w.to_lowercase()).or_insert(0) += 1;
    }
    map
}

/// ROUGE-1 precision/recall/F1.
pub fn rouge1(reference: &str, hypothesis: &str) -> (f64, f64, f64) {
    let r: Vec<&str> = reference.split_whitespace().collect();
    let h: Vec<&str> = hypothesis.split_whitespace().collect();
    if r.is_empty() || h.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let rc = counts(&r);
    let hc = counts(&h);
    let overlap: usize = hc
        .iter()
        .map(|(w, c)| c.min(rc.get(w).unwrap_or(&0)))
        .sum();
    let p = overlap as f64 / h.len() as f64;
    let rec = overlap as f64 / r.len() as f64;
    let f1 = if p + rec == 0.0 {
        0.0
    } else {
        2.0 * p * rec / (p + rec)
    };
    (p, rec, f1)
}

/// Convenience: just the F1 (what Table 1 reports as "ROUGE-1").
pub fn rouge1_f1(reference: &str, hypothesis: &str) -> f64 {
    rouge1(reference, hypothesis).2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_text_scores_one() {
        let (p, r, f) = rouge1("the cat sat", "the cat sat");
        assert_eq!((p, r, f), (1.0, 1.0, 1.0));
    }

    #[test]
    fn disjoint_text_scores_zero() {
        assert_eq!(rouge1_f1("aa bb", "cc dd"), 0.0);
    }

    #[test]
    fn known_partial_overlap() {
        // ref: "the cat sat on the mat" (6), hyp: "the cat" (2)
        // clipped overlap = 2 -> p = 1.0, r = 1/3, f1 = 0.5
        let (p, r, f) = rouge1("the cat sat on the mat", "the cat");
        assert!((p - 1.0).abs() < 1e-12);
        assert!((r - 1.0 / 3.0).abs() < 1e-12);
        assert!((f - 0.5).abs() < 1e-12);
    }

    #[test]
    fn counts_are_clipped() {
        // hyp repeats "the" 4x but ref has it twice -> overlap clipped to 2
        let (p, _, _) = rouge1("the a the b", "the the the the");
        assert!((p - 0.5).abs() < 1e-12);
    }

    #[test]
    fn case_insensitive() {
        assert_eq!(rouge1_f1("The Cat", "the cat"), 1.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(rouge1_f1("", "x"), 0.0);
        assert_eq!(rouge1_f1("x", ""), 0.0);
    }

    #[test]
    fn symmetry_of_f1() {
        let a = "the scheduler batches requests";
        let b = "the batcher schedules the queue";
        let f1 = rouge1_f1(a, b);
        let f2 = rouge1_f1(b, a);
        assert!((f1 - f2).abs() < 1e-12);
        assert!(f1 > 0.0 && f1 < 1.0);
    }
}
