//! Word error rate: Levenshtein distance over whitespace-split words,
//! normalised by reference length — the ASR metric in Table 1/4.

/// Word-level edit distance (substitution/insertion/deletion all cost 1),
/// two-row dynamic program: O(|ref|·|hyp|) time, O(|hyp|) space.
pub fn word_edit_distance(reference: &[&str], hypothesis: &[&str]) -> usize {
    if reference.is_empty() {
        return hypothesis.len();
    }
    if hypothesis.is_empty() {
        return reference.len();
    }
    let mut prev: Vec<usize> = (0..=hypothesis.len()).collect();
    let mut curr = vec![0usize; hypothesis.len() + 1];
    for (i, rw) in reference.iter().enumerate() {
        curr[0] = i + 1;
        for (j, hw) in hypothesis.iter().enumerate() {
            let sub = prev[j] + usize::from(rw != hw);
            let del = prev[j + 1] + 1;
            let ins = curr[j] + 1;
            curr[j + 1] = sub.min(del).min(ins);
        }
        std::mem::swap(&mut prev, &mut curr);
    }
    prev[hypothesis.len()]
}

fn words(s: &str) -> Vec<&str> {
    s.split_whitespace().collect()
}

/// WER = edit_distance(ref_words, hyp_words) / |ref_words|.
///
/// Case-sensitive (both sides come from the same tokenizer). An empty
/// reference with a non-empty hypothesis is scored as 1.0 per hyp word
/// cap at 1.0? — no: standard WER is unbounded above; we follow that
/// (the paper's ±10^5 row reports WER 29.34).
pub fn wer(reference: &str, hypothesis: &str) -> f64 {
    let r = words(reference);
    let h = words(hypothesis);
    if r.is_empty() {
        return if h.is_empty() { 0.0 } else { h.len() as f64 };
    }
    word_edit_distance(&r, &h) as f64 / r.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, Config};

    #[test]
    fn identical_is_zero() {
        assert_eq!(wer("the scheduler accepts", "the scheduler accepts"), 0.0);
    }

    #[test]
    fn known_distances() {
        // 1 substitution over 3 words
        assert!((wer("a b c", "a x c") - 1.0 / 3.0).abs() < 1e-12);
        // 1 deletion
        assert!((wer("a b c", "a c") - 1.0 / 3.0).abs() < 1e-12);
        // 1 insertion
        assert!((wer("a b c", "a b x c") - 1.0 / 3.0).abs() < 1e-12);
        // everything wrong
        assert!((wer("a b", "x y") - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_cases() {
        assert_eq!(wer("", ""), 0.0);
        assert_eq!(wer("a b", ""), 1.0);
        assert_eq!(wer("", "a b c"), 3.0);
    }

    #[test]
    fn wer_can_exceed_one() {
        // hypothesis much longer than reference (paper Table 2: WER 29.34)
        let h = vec!["x"; 50].join(" ");
        assert!(wer("a", &h) > 10.0);
    }

    #[test]
    fn whitespace_normalisation() {
        assert_eq!(wer("a  b\t c", "a b c"), 0.0);
    }

    #[test]
    fn prop_triangle_like_bounds() {
        forall("wer bounds", Config { cases: 60, ..Config::default() }, |rng, size| {
            let vocab = ["alpha", "beta", "gamma", "delta"];
            let mk = |rng: &mut crate::util::rng::Pcg32, n: usize| {
                (0..n).map(|_| *rng.choice(&vocab)).collect::<Vec<_>>().join(" ")
            };
            let n = size.max(1);
            let a = mk(rng, n);
            let m = rng.below(2 * n as u32) as usize;
            let b = mk(rng, m);
            let w = wer(&a, &b);
            let na = a.split_whitespace().count() as f64;
            let nb = b.split_whitespace().count() as f64;
            // distance bounded by max(len) => wer <= max(na, nb)/na
            if w < 0.0 || w > (na.max(nb) / na) + 1e-12 {
                return Err(format!("wer {w} out of bounds ({na}, {nb})"));
            }
            // symmetry of the underlying distance
            let w2 = wer(&b, &a);
            let d1 = w * na;
            let d2 = if nb == 0.0 { w2 } else { w2 * nb };
            if (d1 - d2).abs() > 1e-9 {
                return Err(format!("distance asymmetry {d1} vs {d2}"));
            }
            Ok(())
        });
    }
}
