//! Task metrics used by the paper's evaluation: WER for the ASR-role
//! workload, ROUGE-1 for the summarization-role workload.

pub mod rouge;
pub mod wer;

pub use rouge::rouge1_f1;
pub use wer::wer;
