//! Analytic per-step cost model for the three verification methods.
//!
//! Decomposes one speculative-sampling step (the call stack the paper
//! profiles, §4.1) into:
//!
//! * a framework **floor** no sampling-side change removes (dispatch,
//!   bookkeeping, sync) — visible in the paper as sigmoid's per-step times
//!   clustering at ~3ms regardless of model (Table 6/8);
//! * the unfused **element-wise chain** over (B, γ, V) matrices
//!   (sub/clamp/sum/div/cumsum of Eq. 2-3) — removed by both optimized
//!   kernels (fused into tiles);
//! * the **softmax + categorical stack** over (B, 2γ+1, V) — removed only
//!   by the sigmoid approximation (Eq. 5);
//! * per-kernel **launch** costs (kernel counts: ~22 unfused / 5 exact /
//!   2 sigmoid);
//! * the fused kernel's own **HBM traffic** at a fraction of peak.
//!
//! `bytes_hbm` and `busy_time` are tracked separately so Table 3's
//! realized-bandwidth metric (bytes / GPU-busy-time) can be reproduced.

use super::profiles::DeviceProfile;
use crate::sampling::Method;

/// Workload of one verification step.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub batch: usize,
    pub gamma: usize,
    pub vocab: usize,
    /// bytes per logit element (2 = fp16 — Whisper; 4 = fp32 — Llama/Qwen)
    pub dtype_bytes: usize,
}

/// Cost of one kernel in the sequence.
#[derive(Debug, Clone)]
pub struct KernelCost {
    pub name: &'static str,
    pub bytes: f64,
    pub busy: f64,
}

/// Aggregated per-step cost for a method.
#[derive(Debug, Clone)]
pub struct MethodCost {
    pub method: &'static str,
    pub kernels: Vec<KernelCost>,
    /// total step time as the paper's profiler sees it (floor + busy)
    pub step_time: f64,
    /// GPU-busy portion only (denominator of realized bandwidth)
    pub busy_time: f64,
    /// HBM bytes moved by the sampling call stack
    pub bytes_hbm: f64,
    /// kernel launches issued
    pub launches: usize,
}

impl MethodCost {
    /// Table 3 metric: bytes transferred / GPU-busy time.
    pub fn realized_bandwidth(&self) -> f64 {
        if self.busy_time <= 0.0 {
            return 0.0;
        }
        self.bytes_hbm / self.busy_time
    }
}

fn kernel(
    dev: &DeviceProfile,
    name: &'static str,
    bytes: f64,
    eff_bw: f64,
) -> KernelCost {
    KernelCost {
        name,
        bytes,
        busy: dev.min_kernel_busy.max(bytes / eff_bw),
    }
}

/// Simulate one verification step for `method` on `dev`.
pub fn simulate_step(dev: &DeviceProfile, cfg: SimConfig, method: Method) -> MethodCost {
    let b = cfg.batch as f64;
    let g = cfg.gamma as f64;
    let v = cfg.vocab as f64;
    let dt = cfg.dtype_bytes as f64;
    let gv = b * g * v * dt; // one pass over the draft-positions matrix
    let smv = b * (2.0 * g + 1.0) * v * dt; // softmax touches p rows (γ+1) + q rows (γ)

    let mut kernels: Vec<KernelCost> = Vec::new();
    match method {
        Method::Baseline => {
            // HF-transformers-style unfused stack.
            // softmax on z_p and z_q: stable softmax = max pass + exp/sum
            // pass + normalize pass over each matrix (3 passes, r+w each).
            kernels.push(kernel(dev, "softmax_p", 3.0 * 2.0 * (g + 1.0) / (2.0 * g + 1.0) * smv, dev.eff_bw_softmax));
            kernels.push(kernel(dev, "softmax_q", 3.0 * 2.0 * g / (2.0 * g + 1.0) * smv, dev.eff_bw_softmax));
            // gather/ratio/min/compare on the γ selected entries (small)
            for name in ["gather_p", "gather_q", "ratio", "min1", "accept_cmp", "cumprod"] {
                kernels.push(kernel(dev, name, b * g * dt * 4.0, dev.eff_bw_chain));
            }
            // residual chain over full (γ, V) matrices: sub, clamp, sum,
            // div-normalize, cumsum (2 passes), searchsorted
            kernels.push(kernel(dev, "residual_sub", 3.0 * gv, dev.eff_bw_chain));
            kernels.push(kernel(dev, "residual_clamp", 2.0 * gv, dev.eff_bw_chain));
            kernels.push(kernel(dev, "residual_sum", gv, dev.eff_bw_chain));
            kernels.push(kernel(dev, "residual_div", 2.0 * gv, dev.eff_bw_chain));
            kernels.push(kernel(dev, "residual_cumsum", 2.0 * gv, dev.eff_bw_chain));
            kernels.push(kernel(dev, "residual_draw", gv / g, dev.eff_bw_chain));
            // bonus row sampling: softmax + cumsum + draw over (1, V)
            kernels.push(kernel(dev, "bonus_softmax", 6.0 * b * v * dt, dev.eff_bw_softmax));
            kernels.push(kernel(dev, "bonus_cumsum", 2.0 * b * v * dt, dev.eff_bw_chain));
            kernels.push(kernel(dev, "bonus_draw", b * v * dt, dev.eff_bw_chain));
            // bookkeeping: where/concat/slice/copy of emitted tokens
            for name in ["sel_where", "concat_out", "slice_out", "copy_state", "sync_flags"] {
                kernels.push(kernel(dev, name, b * (g + 1.0) * dt * 4.0, dev.eff_bw_chain));
            }
        }
        Method::Exact => {
            // softmaxes persist (the kernel consumes probabilities)…
            kernels.push(kernel(dev, "softmax_p", 3.0 * 2.0 * (g + 1.0) / (2.0 * g + 1.0) * smv, dev.eff_bw_softmax));
            kernels.push(kernel(dev, "softmax_q", 3.0 * 2.0 * g / (2.0 * g + 1.0) * smv, dev.eff_bw_softmax));
            // …but the whole element-wise chain becomes ONE tiled kernel:
            // read p,q once; write tau, a, b_k once (Fig. 1).
            let fused_bytes = 2.0 * gv /* read p,q */ + 2.0 * gv /* write tau,a */
                + b * g * dev.vocab_tiles(cfg.vocab) as f64 * dt; // b_k partials
            kernels.push(KernelCost {
                name: "fused_verify",
                bytes: fused_bytes,
                busy: dev
                    .min_kernel_busy
                    .max(fused_bytes / (dev.fused_bw_frac * dev.peak_bw)),
            });
            // cross-tile aggregation + resample/bonus finish (one small kernel)
            kernels.push(kernel(dev, "finish", 4.0 * b * v * dt, dev.eff_bw_chain));
        }
        Method::Sigmoid { .. } | Method::Sigmoid16 { .. } => {
            // no softmax at all: one fused kernel reads raw logits and
            // applies Eq. 5 element-wise in-tile (Fig. 2).
            let fused_bytes = 2.0 * gv + 2.0 * gv
                + b * g * dev.vocab_tiles(cfg.vocab) as f64 * dt;
            kernels.push(KernelCost {
                name: "fused_verify_sigmoid",
                bytes: fused_bytes,
                busy: dev
                    .min_kernel_busy
                    .max(fused_bytes / (dev.fused_bw_frac * dev.peak_bw)),
            });
            kernels.push(kernel(dev, "finish", 4.0 * b * v * dt, dev.eff_bw_chain));
        }
    }

    let busy: f64 = kernels.iter().map(|k| k.busy).sum();
    let bytes: f64 = kernels.iter().map(|k| k.bytes).sum();
    let launches = kernels.len();
    MethodCost {
        method: method.name(),
        step_time: dev.step_floor + busy + launches as f64 * dev.launch_latency,
        busy_time: busy,
        bytes_hbm: bytes,
        launches,
        kernels,
    }
}

/// Peak HBM usage model for Fig. 4/5: weights + optimizer-free runtime
/// state + sampling buffers. `target_params`/`draft_params` let the table
/// harness plug in the *paper's* model sizes (7B/13B/…) so the absolute
/// scale matches Fig. 4.
pub fn peak_memory_bytes(
    cfg: SimConfig,
    target_params: f64,
    draft_params: f64,
    weight_dtype_bytes: f64,
) -> f64 {
    let weights = (target_params + draft_params) * weight_dtype_bytes;
    let dt = cfg.dtype_bytes as f64;
    let b = cfg.batch as f64;
    let v = cfg.vocab as f64;
    let g = cfg.gamma as f64;
    // logit matrices p/q (+ tau/a for the verify step), γ-dependent but tiny
    // relative to weights — the paper observes ±200MB flat curves.
    let sampling = b * (2.0 * g + 1.0) * v * dt * 2.0 + b * 2.0 * g * v * dt;
    // CUDA context + allocator slack (constant)
    let context = 1.2e9;
    weights + sampling + context
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::profiles::{A100_80G, RTX_2080_TI};

    fn whisper_small() -> SimConfig {
        // Whisper small.en: V = 51865, fp16 logits
        SimConfig { batch: 1, gamma: 5, vocab: 51865, dtype_bytes: 2 }
    }

    fn qwen() -> SimConfig {
        // Qwen 7B: V = 151936, fp32 logits (§4.3: "full precision")
        SimConfig { batch: 1, gamma: 5, vocab: 151_936, dtype_bytes: 4 }
    }

    #[test]
    fn per_step_times_in_paper_band() {
        // Table 6 (ASR, A100): baseline ≈ 4.1-4.4ms, exact ≈ 3.7-3.9ms,
        // sigmoid ≈ 3.1-3.6ms. Allow generous bands — shape over absolutes.
        let base = simulate_step(&A100_80G, whisper_small(), Method::Baseline);
        let exact = simulate_step(&A100_80G, whisper_small(), Method::Exact);
        let sig = simulate_step(&A100_80G, whisper_small(), Method::sigmoid(-1e3, 1e3));
        assert!((3.0e-3..6.0e-3).contains(&base.step_time), "{}", base.step_time);
        assert!(exact.step_time < base.step_time);
        assert!(sig.step_time < exact.step_time);
        // exact improvement in the paper's 5-15% band
        let d_exact = (base.step_time - exact.step_time) / base.step_time * 100.0;
        assert!((4.0..20.0).contains(&d_exact), "exact Δ% = {d_exact}");
        // sigmoid per-step improvement 15-45% (Table 6 band)
        let d_sig = (base.step_time - sig.step_time) / base.step_time * 100.0;
        assert!((15.0..50.0).contains(&d_sig), "sigmoid Δ% = {d_sig}");
    }

    #[test]
    fn sigmoid_wins_grow_with_vocab() {
        // Table 6: Qwen (152k vocab) shows the largest sigmoid gains (72%).
        let d = |cfg: SimConfig| {
            let b = simulate_step(&A100_80G, cfg, Method::Baseline).step_time;
            let s = simulate_step(&A100_80G, cfg, Method::sigmoid(-1e4, 1e4)).step_time;
            (b - s) / b * 100.0
        };
        let small = d(whisper_small());
        let big = d(qwen());
        assert!(big > small + 10.0, "whisper {small}% vs qwen {big}%");
        assert!((40.0..85.0).contains(&big), "{big}");
    }

    #[test]
    fn exact_is_bit_exact_so_only_time_changes() {
        let base = simulate_step(&A100_80G, qwen(), Method::Baseline);
        let exact = simulate_step(&A100_80G, qwen(), Method::Exact);
        assert!(exact.launches < base.launches);
        assert!(exact.bytes_hbm < base.bytes_hbm);
    }

    #[test]
    fn realized_bandwidth_ordering_matches_table3() {
        // sigmoid achieves the highest realized bandwidth on every combo
        for cfg in [whisper_small(), qwen()] {
            let b = simulate_step(&A100_80G, cfg, Method::Baseline);
            let s = simulate_step(&A100_80G, cfg, Method::sigmoid(-1e4, 1e4));
            assert!(s.realized_bandwidth() > b.realized_bandwidth());
            // and everything sits far below peak (paper: ≤ 63 GB/s vs 2 TB/s)
            for m in [&b, &s] {
                assert!(m.realized_bandwidth() < 0.2 * A100_80G.peak_bw);
            }
        }
    }

    #[test]
    fn bandwidths_in_paper_order_of_magnitude() {
        // Table 3 reports 9-63 GB/s
        let b = simulate_step(&A100_80G, whisper_small(), Method::Baseline);
        let bw = b.realized_bandwidth() / 1e9;
        assert!((1.0..120.0).contains(&bw), "{bw} GB/s");
    }

    #[test]
    fn rtx2080ti_slower_but_same_shape() {
        let cfg = whisper_small();
        let a = simulate_step(&A100_80G, cfg, Method::Baseline);
        let t = simulate_step(&RTX_2080_TI, cfg, Method::Baseline);
        assert!(t.step_time > a.step_time);
        let te = simulate_step(&RTX_2080_TI, cfg, Method::Exact);
        let d = (t.step_time - te.step_time) / t.step_time * 100.0;
        assert!((3.0..20.0).contains(&d), "{d}");
    }

    #[test]
    fn step_time_stable_over_gamma() {
        // Fig. 3: execution times flat-ish in γ (floor dominates)
        let t = |g| {
            simulate_step(
                &A100_80G,
                SimConfig { gamma: g, ..whisper_small() },
                Method::Exact,
            )
            .step_time
        };
        let ratio = t(20) / t(1);
        assert!(ratio < 2.0, "γ=20 vs γ=1 ratio {ratio}");
    }

    #[test]
    fn prop_method_ordering_holds_across_workloads() {
        // exact ≤ baseline and sigmoid ≤ exact in step time, for any
        // reasonable (γ, V, dtype) on both devices
        use crate::util::proptest::{forall, Config};
        forall("sim ordering", Config { cases: 80, ..Config::default() }, |rng, _| {
            let cfg = SimConfig {
                batch: 1 + rng.below(4) as usize,
                gamma: 1 + rng.below(20) as usize,
                vocab: 1000 + rng.below(255_000) as usize,
                dtype_bytes: if rng.below(2) == 0 { 2 } else { 4 },
            };
            for dev in [&A100_80G, &RTX_2080_TI] {
                let b = simulate_step(dev, cfg, Method::Baseline);
                let e = simulate_step(dev, cfg, Method::Exact);
                let s = simulate_step(dev, cfg, Method::sigmoid(-1e3, 1e3));
                if !(e.step_time < b.step_time) {
                    return Err(format!("exact !< baseline at {cfg:?} on {}", dev.name));
                }
                if !(s.step_time < e.step_time) {
                    return Err(format!("sigmoid !< exact at {cfg:?} on {}", dev.name));
                }
                if !(s.bytes_hbm < b.bytes_hbm) {
                    return Err(format!("sigmoid bytes !< baseline at {cfg:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_step_time_monotone_in_vocab_and_gamma() {
        use crate::util::proptest::{forall, Config};
        forall("sim monotone", Config { cases: 60, ..Config::default() }, |rng, _| {
            let base = SimConfig {
                batch: 1,
                gamma: 1 + rng.below(15) as usize,
                vocab: 2000 + rng.below(100_000) as usize,
                dtype_bytes: 4,
            };
            for m in [Method::Baseline, Method::Exact, Method::sigmoid(-1e3, 1e3)] {
                let t0 = simulate_step(&A100_80G, base, m).step_time;
                let tv = simulate_step(
                    &A100_80G,
                    SimConfig { vocab: base.vocab * 2, ..base },
                    m,
                )
                .step_time;
                let tg = simulate_step(
                    &A100_80G,
                    SimConfig { gamma: base.gamma + 2, ..base },
                    m,
                )
                .step_time;
                if tv < t0 || tg < t0 {
                    return Err(format!("{} not monotone at {base:?}", m.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn peak_memory_flat_in_gamma_matches_fig4() {
        // Llama2 7B + Sheared 1.3B in fp16: ~16.6GB weights; γ sweep moves
        // usage by well under 200MB (paper Fig. 4).
        let mem = |g| {
            peak_memory_bytes(
                SimConfig { batch: 1, gamma: g, vocab: 32000, dtype_bytes: 4 },
                7.0e9,
                1.3e9,
                2.0,
            )
        };
        let lo = mem(1);
        let hi = mem(20);
        assert!(hi > lo);
        assert!(hi - lo < 200.0e6, "Δ = {}MB", (hi - lo) / 1e6);
        assert!((15.0e9..20.0e9).contains(&lo), "{lo}");
    }
}
