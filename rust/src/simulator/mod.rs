//! GPU device cost model.
//!
//! The paper's numbers come from A100-80GB and RTX 2080 Ti GPUs that this
//! environment does not have (and the Pallas kernel runs in interpret
//! mode, so its wall-clock is a CPU number). This module substitutes an
//! explicit analytic model of the quantities the paper's §4.3 actually
//! analyses — HBM↔SRAM sector traffic, kernel-launch counts, reduction
//! structure — so the GPU-shaped results (Tables 1/3/4, Δ% bands) can be
//! regenerated and sanity-checked against the measured CPU ratios.
//!
//! Model: each verification method is a sequence of kernels; a kernel
//! reads/writes `bytes` through HBM at `mem_eff × peak_bandwidth` and pays
//! a fixed launch overhead. Verification is strongly memory-bound (the
//! paper observes realized bandwidths 100× below peak — launch overhead
//! and short tensors dominate), which the defaults reflect.

pub mod model;
pub mod profiles;

pub use model::{peak_memory_bytes, simulate_step, KernelCost, MethodCost, SimConfig};
pub use profiles::{DeviceProfile, A100_80G, RTX_2080_TI};
