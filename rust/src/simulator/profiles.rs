//! Device profiles for the two GPUs in the paper's evaluation.
//!
//! Peak numbers come from vendor datasheets (NVIDIA A100 whitepaper 2020;
//! TU102 specs). Effective-bandwidth / overhead constants are *calibrated*
//! once against the paper's Table 6 per-step timings (see DESIGN.md §3 —
//! the substitution table) and then held fixed for every experiment; the
//! reproduction targets the relative Δ% shape, not datasheet absolutes.

/// Static description of a GPU for the cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub sms: u32,
    /// on-chip SRAM (shared memory + L1) per SM, bytes
    pub sram_per_sm: usize,
    /// HBM capacity, bytes
    pub hbm_capacity: usize,
    /// peak HBM bandwidth, bytes/s
    pub peak_bw: f64,
    /// max threads per block (the paper's n = 1024)
    pub max_threads_per_block: u32,
    /// kernel launch latency, seconds
    pub launch_latency: f64,
    /// minimum effective busy time of a small eager kernel, seconds
    /// (occupancy ramp + tail effects; calibrated)
    pub min_kernel_busy: f64,
    /// framework floor per decoding step that no sampling-side
    /// optimization removes (python/torch dispatch, bookkeeping,
    /// device sync), seconds (calibrated to Table 6/8 sigmoid times)
    pub step_floor: f64,
    /// effective bandwidth of the unfused element-wise op chain
    /// (short eager kernels never reach peak), bytes/s (calibrated)
    pub eff_bw_chain: f64,
    /// effective bandwidth of the softmax + categorical-draw stack,
    /// bytes/s (calibrated)
    pub eff_bw_softmax: f64,
    /// fraction of peak achievable by the fused tiled kernel
    pub fused_bw_frac: f64,
}

/// NVIDIA A100-SXM 80GB (the paper's main testbed).
pub const A100_80G: DeviceProfile = DeviceProfile {
    name: "a100-80g",
    sms: 108,
    sram_per_sm: 192 * 1024,
    hbm_capacity: 80 * 1024 * 1024 * 1024,
    peak_bw: 2.039e12,
    max_threads_per_block: 1024,
    launch_latency: 4.0e-6,
    min_kernel_busy: 40.0e-6,
    step_floor: 2.8e-3,
    eff_bw_chain: 35.0e9,
    eff_bw_softmax: 21.0e9,
    fused_bw_frac: 0.65,
};

/// NVIDIA RTX 2080 Ti 11GB (the paper's Table 4 testbed).
pub const RTX_2080_TI: DeviceProfile = DeviceProfile {
    name: "rtx-2080-ti",
    sms: 68,
    sram_per_sm: 96 * 1024,
    hbm_capacity: 11 * 1024 * 1024 * 1024,
    peak_bw: 6.16e11,
    max_threads_per_block: 1024,
    launch_latency: 5.0e-6,
    min_kernel_busy: 30.0e-6,
    step_floor: 3.8e-3,
    eff_bw_chain: 14.0e9,
    eff_bw_softmax: 8.0e9,
    fused_bw_frac: 0.55,
};

impl DeviceProfile {
    pub fn by_name(name: &str) -> Option<&'static DeviceProfile> {
        match name {
            "a100" | "a100-80g" => Some(&A100_80G),
            "2080ti" | "rtx-2080-ti" => Some(&RTX_2080_TI),
            _ => None,
        }
    }

    /// Number of vocab tiles for the paper's kernel grid (K = ceil(V/n)).
    pub fn vocab_tiles(&self, vocab: usize) -> usize {
        vocab.div_ceil(self.max_threads_per_block as usize)
    }

    /// VMEM/SRAM bytes one verification tile needs (2 in + 2 out + partial),
    /// mirroring `python/compile/kernels/spec_verify.py::vmem_bytes`.
    pub fn tile_sram_bytes(&self, dtype_bytes: usize) -> usize {
        (2 + 2) * self.max_threads_per_block as usize * dtype_bytes + dtype_bytes
    }

    /// Does one tile fit in a single SM's scratchpad? (paper's occupancy
    /// argument — must hold for both devices)
    pub fn tile_fits(&self, dtype_bytes: usize) -> bool {
        self.tile_sram_bytes(dtype_bytes) <= self.sram_per_sm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(DeviceProfile::by_name("a100").unwrap().name, "a100-80g");
        assert_eq!(DeviceProfile::by_name("2080ti").unwrap().sms, 68);
        assert!(DeviceProfile::by_name("h100").is_none());
    }

    #[test]
    fn vocab_tiling_matches_paper_n() {
        // 52k vocab (Whisper) on n=1024 -> 51 tiles
        assert_eq!(A100_80G.vocab_tiles(51865), 51);
        assert_eq!(A100_80G.vocab_tiles(1024), 1);
        assert_eq!(A100_80G.vocab_tiles(1025), 2);
    }

    #[test]
    fn tiles_fit_in_sram_on_both_devices() {
        for d in [&A100_80G, &RTX_2080_TI] {
            assert!(d.tile_fits(4), "{} f32", d.name);
            assert!(d.tile_fits(2), "{} f16", d.name);
        }
    }

    #[test]
    fn a100_is_faster_than_2080ti() {
        assert!(A100_80G.peak_bw > RTX_2080_TI.peak_bw);
        assert!(A100_80G.step_floor < RTX_2080_TI.step_floor);
    }
}
