//! # specd — optimized speculative sampling serving engine
//!
//! Reproduction of *"Optimized Speculative Sampling for GPU Hardware
//! Accelerators"* (Wagner et al., EMNLP 2024) as a three-layer stack:
//!
//! * **L3 (this crate)** — the serving coordinator: continuous batcher,
//!   adaptive-γ controller, verification backends, TCP server, metrics,
//!   and the device cost model used for GPU-shaped performance claims.
//! * **L2 (python/compile, build time)** — JAX graphs for the draft/target
//!   models and the fused verification step, lowered once to HLO text.
//! * **L1 (python/compile/kernels, build time)** — the paper's tiled
//!   verification kernels written in Pallas.
//!
//! Python never runs on the request path: everything the engine executes is
//! an AOT-compiled artifact loaded from `artifacts/` via PJRT
//! ([`runtime`]), plus a pure-rust oracle ([`sampling::verify`]) used for
//! cross-validation and a segment-parallel native backend.
//!
//! ## Verification kernel layer
//!
//! The native verify path is a layered kernel architecture
//! ([`sampling::kernels`]) mirroring the paper's §3 matrix partitioning
//! on CPU threads: softmax/sigmoid probability construction, residual
//! building and blocked-prefix-sum inverse-CDF sampling run
//! segment-parallel over matrix rows and fixed vocab chunks on a
//! **persistent worker pool** (spawned at most once, lazily, on the
//! first parallel region; parked between steps; joined on drop), with
//! fixed-order chunk
//! reductions keeping outputs **bit-identical** to the scalar oracle
//! for every thread count. A preallocated
//! [`sampling::kernels::VerifyWorkspace`] (owned by the engine's
//! verifier), borrowed [`runtime::TensorView`] model inputs, and
//! in-place output staging
//! ([`runtime::LoadedExecutable::run_views_into`]) eliminate the
//! per-step `O(γ·V)` clones and collects from the decode loop.
//! Verification dispatches a per-slot [`sampling::Method`], which is
//! what lets per-request method overrides run on any batch size.
//!
//! ## Pipelined decode scheduler
//!
//! The decode loop itself is pipelined ([`engine::pipeline`],
//! `--pipeline on|off|auto`): step N's CPU verification runs
//! concurrently with step N+1's draft/score model dispatch on a
//! dedicated dispatcher lane, via all-accept commit speculation that is
//! adopted only when the barrier proves it equal to the serial outcome
//! — so outputs (tokens, deltas, stats, RNG streams) stay
//! **bit-identical** to the serial loop for any seed. A deterministic
//! in-process model simulator ([`runtime::Runtime::simulated`],
//! `SPECD_SIM=1`) runs the whole engine without PJRT, which is what the
//! pipelined-vs-serial parity suite and decode benches are built on.
//!
//! ## Deterministic trace record/replay
//!
//! Both determinism claims above are checkable on any individual run,
//! not just in the test suite: the engine streams a compact versioned
//! execution trace ([`trace`]) — RNG stream *positions* rather than
//! drawn floats, logit digests, per-slot methods, accept lengths,
//! commit decisions, pipeline barrier events — through a near-zero-cost
//! [`trace::TraceSink`]. The offline checker ([`trace::check`],
//! `specd trace check`) replays a trace against the scalar oracle
//! ([`sampling::verify`]) over the simulated model pair and reports the
//! first divergent step and field; `specd trace fuzz` drives randomized
//! pipelined schedules (mixed per-slot methods, mid-decode cancels)
//! through record-then-check end to end.
//!
//! `docs/ARCHITECTURE.md` walks the whole decode path end-to-end and
//! maps the paper's §3 onto these modules; `docs/PERF.md` documents the
//! benchmark methodology and the tracked perf trajectory.
//!
//! ## Request API
//!
//! Per-request policy is a first-class [`engine::SamplingParams`] — the
//! single source of request defaults and validation, threaded end-to-end:
//!
//! * target/draft **temperatures** and **top-k / top-p** truncation of
//!   the target distribution (logit masking shared between the oracle and
//!   the AOT verify path — see [`sampling::filter`]);
//! * **stop sequences** detected at commit and trimmed from the output;
//! * per-request **seed**, **γ cap/pin** for the adaptive draft-length
//!   controller, and a **verification-method override** dispatched
//!   per-slot on any batch size.
//!
//! ## Wire protocol v2
//!
//! The TCP front-end ([`server`]) speaks a versioned JSON-lines protocol:
//! a `{"v":2,"op":"generate",…,"params":{…}}` envelope carrying
//! `SamplingParams`, incremental `{"event":"delta"}` token chunks for
//! streaming requests, a final `{"event":"done"}` summary, structured
//! `{"event":"error","code":…}` rejections validated at admission, and a
//! `{"op":"cancel","id":…}` control line that frees the slot mid-decode.
//! Legacy v1 one-shot lines keep working via a compatibility shim mapped
//! onto `SamplingParams::default()`.
//!
//! Entry points: [`engine::Engine`] for in-process serving,
//! [`server`] for the TCP front-end, [`tables`] for regenerating every
//! table/figure of the paper's evaluation section.

pub mod engine;
pub mod metrics;
pub mod runtime;
pub mod sampling;
pub mod server;
pub mod simulator;
pub mod tables;
pub mod tokenizer;
pub mod trace;
pub mod util;
pub mod workload;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifacts directory, overridable via `SPECD_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("SPECD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
