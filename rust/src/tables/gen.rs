//! Table/figure generators. Each `tN`/`fN` function returns a printable
//! report; `generate` dispatches from the CLI id.

use anyhow::Result;

use crate::sampling::Method;
use crate::simulator::{peak_memory_bytes, simulate_step, DeviceProfile};
use crate::util::bench::Table;
use crate::util::stats::rel_improvement_pct;
use crate::workload::TaskKind;

use super::eval::{run_all_methods, run_method, EvalContext};
use super::paper::{PaperCombo, ASR_SPLITS, COMBOS, SUM_SPLITS};
use crate::engine::Backend;
use crate::workload::make_tasks;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableId {
    T1,
    T2,
    T3,
    T4,
    T5,
    T6,
    T8,
    F3,
    F4,
    F5,
}

impl TableId {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "t1" => Some(TableId::T1),
            "t2" | "t7" => Some(TableId::T2), // T7 = appendix extension of T2
            "t3" => Some(TableId::T3),
            "t4" => Some(TableId::T4),
            "t5" => Some(TableId::T5),
            "t6" => Some(TableId::T6),
            "t8" => Some(TableId::T8),
            "f3" => Some(TableId::F3),
            "f4" => Some(TableId::F4),
            "f5" => Some(TableId::F5),
            _ => None,
        }
    }

    pub const ALL: &'static [TableId] = &[
        TableId::T1,
        TableId::T2,
        TableId::T3,
        TableId::T4,
        TableId::T5,
        TableId::T6,
        TableId::T8,
        TableId::F3,
        TableId::F4,
        TableId::F5,
    ];
}

/// Dispatch a table/figure id.
pub fn generate(id: TableId, ctx: &EvalContext, device: &DeviceProfile) -> Result<String> {
    match id {
        TableId::T1 => t1(ctx, device),
        TableId::T2 => t2(ctx),
        TableId::T3 => t3(ctx, device),
        TableId::T4 => t1_for_device(ctx, DeviceProfile::by_name("2080ti").unwrap(), "Table 4 — RTX 2080 Ti"),
        TableId::T5 => t5(ctx),
        TableId::T6 => t6(ctx, device),
        TableId::T8 => t8(ctx),
        TableId::F3 => f3(ctx, device),
        TableId::F4 => f45(ctx, device, TaskKind::Summarize, "Figure 4 — peak memory vs γ (Xsum role)"),
        TableId::F5 => f45(ctx, device, TaskKind::Asr, "Figure 5 — peak memory vs γ (CV16 role)"),
    }
}

fn fmt_metric(kind: TaskKind, x: f64) -> String {
    match kind {
        TaskKind::Asr => format!("{x:.2}"),
        TaskKind::Summarize => format!("{x:.2}"),
    }
}

fn pct(x: f64) -> String {
    format!("{x:.1}%")
}

/// Simulated per-step Δ% for a paper combo, composed with the *measured*
/// step-count ratio (sigmoid's higher acceptance → fewer steps), which is
/// how Table 1's profiling-time deltas exceed the per-step deltas.
fn sim_profiling_delta(
    dev: &DeviceProfile,
    combo: &PaperCombo,
    gamma: usize,
    method: Method,
    steps_ratio: f64,
) -> f64 {
    let base = simulate_step(dev, combo.sim_config(gamma), Method::Baseline).step_time;
    let new = simulate_step(dev, combo.sim_config(gamma), method).step_time;
    rel_improvement_pct(base, new * steps_ratio)
}

// ---------------------------------------------------------------------------
// Table 1 (and Table 4 via a different device profile)

fn t1(ctx: &EvalContext, device: &DeviceProfile) -> Result<String> {
    t1_for_device(ctx, device, "Table 1 — accuracy + Δ% profiling time")
}

fn t1_for_device(ctx: &EvalContext, device: &DeviceProfile, title: &str) -> Result<String> {
    let mut out = format!("{title}\n(device model: {}, measured = PJRT-CPU)\n\n", device.name);
    let mut table = Table::new(&[
        "dataset",
        "task",
        "metric(base)",
        "metric(exact)",
        "metric(sigmoid)",
        "Δ%prof exact (meas)",
        "Δ%prof sigmoid (meas)",
        "Δ%prof exact (sim)",
        "Δ%prof sigmoid (sim)",
    ]);
    let splits: Vec<(TaskKind, &str, u64)> = ASR_SPLITS
        .iter()
        .map(|&(n, s)| (TaskKind::Asr, n, s))
        .chain(SUM_SPLITS.iter().map(|&(n, s)| (TaskKind::Summarize, n, s)))
        .collect();
    for (kind, split, seed) in splits {
        let combo = COMBOS.iter().find(|c| {
            c.task == if kind == TaskKind::Asr { "asr" } else { "sum" }
        })
        .unwrap();
        let (base, exact, sig) = run_all_methods(ctx, kind, seed, combo.alpha_beta())?;
        let gmean = base.gamma_mean.round().max(1.0) as usize;
        let steps_ratio_e = exact.steps as f64 / base.steps.max(1) as f64;
        let steps_ratio_s = sig.steps as f64 / base.steps.max(1) as f64;
        table.row(vec![
            split.into(),
            kind.metric_name().into(),
            fmt_metric(kind, base.metric),
            fmt_metric(kind, exact.metric),
            fmt_metric(kind, sig.metric),
            pct(rel_improvement_pct(base.profiling_total, exact.profiling_total)),
            pct(rel_improvement_pct(base.profiling_total, sig.profiling_total)),
            pct(sim_profiling_delta(device, combo, gmean, Method::Exact, steps_ratio_e)),
            pct(sim_profiling_delta(
                device,
                combo,
                gmean,
                Method::sigmoid(combo.alpha_beta().0, combo.alpha_beta().1),
                steps_ratio_s,
            )),
        ]);
    }
    out.push_str(&table.render());
    out.push_str(
        "\nexpected shape: metric(exact) == metric(base) to the last digit; \
         sigmoid slightly worse; sim deltas in the paper's bands \
         (exact 6-13%, sigmoid 37-94%).\n",
    );
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 2 / Table 7 — effect of (α, β)

fn t2(ctx: &EvalContext) -> Result<String> {
    let mut out = String::from("Table 2/7 — effect of logit scaling (α, β) on sigmoid\n\n");
    for (kind, split_seed) in [(TaskKind::Asr, 104u64), (TaskKind::Summarize, 202u64)] {
        let tasks = make_tasks(&ctx.corpus, kind, ctx.n_examples, split_seed);
        let base = run_method(ctx, &tasks, Method::Baseline, Backend::Hlo, 5, false)?;
        let mut table = Table::new(&[
            "scale (α, β)",
            kind.metric_name(),
            "Δ% prof time (meas)",
            "acceptance",
        ]);
        table.row(vec![
            "baseline".into(),
            fmt_metric(kind, base.metric),
            "-".into(),
            pct(base.acceptance_rate * 100.0),
        ]);
        for exp in [1i32, 3, 4, 5] {
            let scale = 10f32.powi(exp);
            let run = run_method(
                ctx,
                &tasks,
                Method::sigmoid(-scale, scale),
                Backend::Hlo,
                5,
                false,
            )?;
            table.row(vec![
                format!("-1e{exp} 1e{exp}"),
                fmt_metric(kind, run.metric),
                pct(rel_improvement_pct(base.profiling_total, run.profiling_total)),
                pct(run.acceptance_rate * 100.0),
            ]);
        }
        // the paper's actual fp16 regime: at ±1e5 the half-precision
        // rescale overflows to NaN → reject-everything → catastrophic
        // accuracy AND slower-than-baseline time (Table 2's −10826% row)
        for exp in [3i32, 5] {
            let scale = 10f32.powi(exp);
            let run = run_method(
                ctx,
                &tasks,
                Method::sigmoid16(-scale, scale),
                Backend::Hlo,
                5,
                false,
            )?;
            table.row(vec![
                format!("-1e{exp} 1e{exp} (fp16)"),
                fmt_metric(kind, run.metric),
                pct(rel_improvement_pct(base.profiling_total, run.profiling_total)),
                pct(run.acceptance_rate * 100.0),
            ]);
        }
        out.push_str(&format!("task = {kind:?}\n"));
        out.push_str(&table.render());
        out.push('\n');
    }
    out.push_str(
        "expected shape: moderate scales (±1e3, ±1e4) close to baseline \
         accuracy; ±1e5 collapses (accept-everything — Table 2's WER 29.34 \
         failure mode); ±1e1 distorts the distribution.\n",
    );
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 3 — realized bandwidth

fn t3(ctx: &EvalContext, device: &DeviceProfile) -> Result<String> {
    let mut out = format!(
        "Table 3 — realized HBM bandwidth per method (simulated {}, γ=5)\n\n",
        device.name
    );
    let mut table = Table::new(&[
        "target/draft",
        "baseline",
        "exact",
        "sigmoid",
        "bytes base (MB)",
        "bytes sigmoid (MB)",
    ]);
    for combo in COMBOS {
        let (a, b) = combo.alpha_beta();
        let cfg = combo.sim_config(5);
        let base = simulate_step(device, cfg, Method::Baseline);
        let exact = simulate_step(device, cfg, Method::Exact);
        let sig = simulate_step(device, cfg, Method::sigmoid(a, b));
        table.row(vec![
            format!("{} / {}", combo.target, combo.draft),
            format!("{:.2} GB/s", base.realized_bandwidth() / 1e9),
            format!("{:.2} GB/s", exact.realized_bandwidth() / 1e9),
            format!("{:.2} GB/s", sig.realized_bandwidth() / 1e9),
            format!("{:.2}", base.bytes_hbm / 1e6),
            format!("{:.2}", sig.bytes_hbm / 1e6),
        ]);
    }
    out.push_str(&table.render());
    out.push_str(&format!(
        "\npeak HBM bandwidth: {:.0} GB/s — all realized values far below \
         peak, matching the paper's conclusion that memory transfer is not \
         the limiting factor.\n",
        device.peak_bw / 1e9
    ));
    // measured column: real verify-artifact timings on this machine
    let _ = ctx;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 5 — wall-clock improvement of the whole generation loop

fn t5(ctx: &EvalContext) -> Result<String> {
    let mut out = String::from("Table 5 — relative wall-clock improvement (measured, full decode loop)\n\n");
    let mut table = Table::new(&[
        "task",
        "split",
        "wallclock base (s)",
        "Δ% exact",
        "Δ% sigmoid",
        "tokens/step base",
        "tokens/step sigmoid",
    ]);
    let splits: Vec<(TaskKind, &str, u64)> = ASR_SPLITS
        .iter()
        .take(2)
        .map(|&(n, s)| (TaskKind::Asr, n, s))
        .chain(SUM_SPLITS.iter().map(|&(n, s)| (TaskKind::Summarize, n, s)))
        .collect();
    for (kind, split, seed) in splits {
        let combo = COMBOS
            .iter()
            .find(|c| c.task == if kind == TaskKind::Asr { "asr" } else { "sum" })
            .unwrap();
        let (base, exact, sig) = run_all_methods(ctx, kind, seed, combo.alpha_beta())?;
        table.row(vec![
            format!("{kind:?}"),
            split.into(),
            format!("{:.3}", base.wallclock),
            pct(rel_improvement_pct(base.wallclock, exact.wallclock)),
            pct(rel_improvement_pct(base.wallclock, sig.wallclock)),
            format!("{:.2}", base.emitted_tokens as f64 / base.steps.max(1) as f64),
            format!("{:.2}", sig.emitted_tokens as f64 / sig.steps.max(1) as f64),
        ]);
    }
    out.push_str(&table.render());
    out.push_str(
        "\nnote: on CPU the model forward passes dominate wall-clock, so \
         measured deltas are smaller than profiling-time deltas — same \
         caveat as the paper's §A.4.\n",
    );
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 6 — average time per decoding step

fn t6(ctx: &EvalContext, device: &DeviceProfile) -> Result<String> {
    let mut out = String::from(
        "Table 6 — avg±std time in the sampling call stack per decode step\n\n",
    );
    let mut table = Table::new(&[
        "task",
        "meas base (ms)",
        "meas exact (ms)",
        "meas sigmoid (ms)",
        "sim base (ms)",
        "sim exact (ms)",
        "sim sigmoid (ms)",
    ]);
    for (kind, seed) in [(TaskKind::Asr, 103u64), (TaskKind::Summarize, 201u64)] {
        let combo = COMBOS
            .iter()
            .find(|c| c.task == if kind == TaskKind::Asr { "asr" } else { "sum" })
            .unwrap();
        let (a, b) = combo.alpha_beta();
        let (base, exact, sig) = run_all_methods(ctx, kind, seed, (a, b))?;
        let cfg = combo.sim_config(5);
        table.row(vec![
            format!("{kind:?}"),
            base.per_step_verify.mean_std_ms(),
            exact.per_step_verify.mean_std_ms(),
            sig.per_step_verify.mean_std_ms(),
            format!("{:.2}", simulate_step(device, cfg, Method::Baseline).step_time * 1e3),
            format!("{:.2}", simulate_step(device, cfg, Method::Exact).step_time * 1e3),
            format!(
                "{:.2}",
                simulate_step(device, cfg, Method::sigmoid(a, b)).step_time * 1e3
            ),
        ]);
    }
    out.push_str(&table.render());
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 8 — acceptance rates vs γ

fn t8(ctx: &EvalContext) -> Result<String> {
    let mut out = String::from("Table 8 — acceptance rate + avg verify time by pinned γ (measured)\n\n");
    let mut table = Table::new(&[
        "method",
        "γ=3 acc",
        "γ=5 acc",
        "γ=10 acc",
        "γ=15 acc",
        "γ=3 ms",
        "γ=5 ms",
        "γ=10 ms",
        "γ=15 ms",
    ]);
    let tasks = make_tasks(&ctx.corpus, TaskKind::Summarize, ctx.n_examples, 202);
    for (label, method) in [
        ("sigmoid", Method::sigmoid(-1e4, 1e4)),
        ("exact", Method::Exact),
        ("baseline", Method::Baseline),
    ] {
        let mut accs = Vec::new();
        let mut times = Vec::new();
        for g in [3usize, 5, 10, 15] {
            let run = run_method(ctx, &tasks, method, Backend::Hlo, g, true)?;
            accs.push(pct(run.acceptance_rate * 100.0));
            times.push(format!("{:.2}", run.per_step_verify.mean * 1e3));
        }
        table.row(vec![
            label.into(),
            accs[0].clone(),
            accs[1].clone(),
            accs[2].clone(),
            accs[3].clone(),
            times[0].clone(),
            times[1].clone(),
            times[2].clone(),
            times[3].clone(),
        ]);
    }
    out.push_str(&table.render());
    out.push_str(
        "\nexpected shape: baseline == exact acceptance exactly; sigmoid \
         acceptance ≥ exact (τ̂ ratios compress toward 1).\n",
    );
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figure 3 — per-step time vs γ

fn f3(ctx: &EvalContext, device: &DeviceProfile) -> Result<String> {
    let mut out = String::from(
        "Figure 3 — avg verification time per decode step vs pinned γ\n\
         columns: γ, measured ms (baseline/exact/sigmoid), simulated ms (same)\n\n",
    );
    let tasks = make_tasks(&ctx.corpus, TaskKind::Summarize, ctx.n_examples.min(6), 202);
    let combo = COMBOS.iter().find(|c| c.task == "sum").unwrap();
    let (a, b) = combo.alpha_beta();
    let mut table = Table::new(&[
        "γ",
        "meas base",
        "meas exact",
        "meas sigmoid",
        "sim base",
        "sim exact",
        "sim sigmoid",
    ]);
    for g in [1usize, 2, 3, 5, 8, 10, 15, 20] {
        let avail = ctx
            .runtime
            .manifest
            .verify_gammas("baseline", ctx.batch, ctx.runtime.manifest.vocab_size);
        if !avail.contains(&g) {
            continue;
        }
        let base = run_method(ctx, &tasks, Method::Baseline, Backend::Hlo, g, true)?;
        let exact = run_method(ctx, &tasks, Method::Exact, Backend::Hlo, g, true)?;
        let sig = run_method(ctx, &tasks, Method::sigmoid(a, b), Backend::Hlo, g, true)?;
        let cfg = combo.sim_config(g);
        table.row(vec![
            format!("{g}"),
            format!("{:.3}", base.per_step_verify.mean * 1e3),
            format!("{:.3}", exact.per_step_verify.mean * 1e3),
            format!("{:.3}", sig.per_step_verify.mean * 1e3),
            format!("{:.2}", simulate_step(device, cfg, Method::Baseline).step_time * 1e3),
            format!("{:.2}", simulate_step(device, cfg, Method::Exact).step_time * 1e3),
            format!("{:.2}", simulate_step(device, cfg, Method::sigmoid(a, b)).step_time * 1e3),
        ]);
    }
    out.push_str(&table.render());
    out.push_str("\nexpected shape: optimized curves below baseline at every γ; flat-ish in γ.\n");
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figures 4/5 — peak memory vs γ

fn f45(
    ctx: &EvalContext,
    _device: &DeviceProfile,
    kind: TaskKind,
    title: &str,
) -> Result<String> {
    let mut out = format!("{title}\ncolumns: γ, measured peak host-buffer MB per method, simulated paper-scale GB\n\n");
    let tasks = make_tasks(&ctx.corpus, kind, ctx.n_examples.min(4), 77);
    let combo = COMBOS
        .iter()
        .find(|c| c.task == if kind == TaskKind::Asr { "asr" } else { "sum" })
        .unwrap();
    let (a, b) = combo.alpha_beta();
    let mut table = Table::new(&[
        "γ",
        "meas base MB",
        "meas exact MB",
        "meas sigmoid MB",
        "sim paper-scale GB",
    ]);
    for g in [1usize, 3, 5, 8, 10, 15, 20] {
        let avail = ctx
            .runtime
            .manifest
            .verify_gammas("baseline", ctx.batch, ctx.runtime.manifest.vocab_size);
        if !avail.contains(&g) {
            continue;
        }
        let base = run_method(ctx, &tasks, Method::Baseline, Backend::Hlo, g, true)?;
        let exact = run_method(ctx, &tasks, Method::Exact, Backend::Hlo, g, true)?;
        let sig = run_method(ctx, &tasks, Method::sigmoid(a, b), Backend::Hlo, g, true)?;
        let sim = peak_memory_bytes(
            combo.sim_config(g),
            combo.target_params,
            combo.draft_params,
            2.0,
        );
        table.row(vec![
            format!("{g}"),
            format!("{:.2}", base.peak_mem_bytes as f64 / 1e6),
            format!("{:.2}", exact.peak_mem_bytes as f64 / 1e6),
            format!("{:.2}", sig.peak_mem_bytes as f64 / 1e6),
            format!("{:.2}", sim / 1e9),
        ]);
    }
    out.push_str(&table.render());
    out.push_str("\nexpected shape: flat in γ (weights dominate); methods within noise of each other.\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_id_parsing() {
        assert_eq!(TableId::parse("t1"), Some(TableId::T1));
        assert_eq!(TableId::parse("T7"), Some(TableId::T2));
        assert_eq!(TableId::parse("f4"), Some(TableId::F4));
        assert_eq!(TableId::parse("t9"), None);
        assert_eq!(TableId::ALL.len(), 10);
    }

    #[test]
    fn sim_delta_composes_step_ratio() {
        let dev = DeviceProfile::by_name("a100").unwrap();
        let combo = &COMBOS[0];
        // same per-step time but half the steps => 50% improvement
        let d = sim_profiling_delta(dev, combo, 5, Method::Baseline, 0.5);
        assert!((d - 50.0).abs() < 1e-9);
        // exact with same step count: paper band
        let d = sim_profiling_delta(dev, combo, 5, Method::Exact, 1.0);
        assert!((4.0..20.0).contains(&d), "{d}");
    }
}
