//! Regeneration harness for every table and figure in the paper's
//! evaluation section (the experiment index lives in DESIGN.md §5).
//!
//! Two kinds of numbers appear side by side, clearly labelled:
//!
//! * **measured** — real executions of the AOT artifacts through PJRT-CPU
//!   on this machine (accuracy metrics, acceptance rates, profiling-time
//!   ratios between methods);
//! * **simulated** — the calibrated GPU cost model
//!   ([`crate::simulator`]) evaluated at the paper's model scales
//!   (52k-256k vocabularies, fp16/fp32 logits), which is where the
//!   A100/2080Ti-shaped Δ% and bandwidth numbers come from.
//!
//! `specd table --id t1|t2|t3|t4|t5|t6|t8` and `specd figure --id
//! f3|f4|f5` print these; the bench targets under `rust/benches/` wrap
//! the same entry points.

pub mod eval;
pub mod gen;
pub mod paper;

pub use eval::{run_method, EvalContext, MethodRun};
pub use gen::{generate, TableId};
