//! Shared measurement harness: run a workload through the engine with a
//! given verification method and collect the quantities the paper
//! reports.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{
    Backend, Engine, EngineConfig, GenRequest, Mode, PipelineMode, SamplingParams,
};
use crate::runtime::Runtime;
use crate::sampling::Method;
use crate::tokenizer::Tokenizer;
use crate::util::stats::Summary;
use crate::workload::{make_tasks, Corpus, Task, TaskKind};

/// Everything an evaluation run needs.
pub struct EvalContext {
    pub runtime: Arc<Runtime>,
    pub tokenizer: Tokenizer,
    pub corpus: Corpus,
    pub pair: String,
    pub batch: usize,
    pub n_examples: usize,
    pub seed: u64,
    /// per-request policy applied to every task (max_new_tokens and seed
    /// are overridden per task)
    pub params: SamplingParams,
}

impl EvalContext {
    /// Open runtime + tokenizer + corpus from the default locations.
    pub fn open_default(n_examples: usize) -> Result<Self> {
        let runtime = Arc::new(Runtime::open_default()?);
        let tokenizer = Tokenizer::load(&crate::artifacts_dir().join("tokenizer.json"))?;
        let corpus = Corpus::load_default()?;
        Ok(EvalContext {
            runtime,
            tokenizer,
            corpus,
            pair: "base".into(),
            batch: 1,
            n_examples,
            seed: 1234,
            params: SamplingParams::default().with_temperature(0.5),
        })
    }
}

/// Result of running one (method, workload) combination.
#[derive(Debug, Clone)]
pub struct MethodRun {
    pub method: Method,
    /// WER (asr) or ROUGE-1 (sum), averaged over examples
    pub metric: f64,
    /// Σ verification-call-stack time over all steps+examples (seconds) —
    /// the paper's "profiling time"
    pub profiling_total: f64,
    /// wall time of the whole decode loop (seconds) — Table 5's quantity
    pub wallclock: f64,
    pub steps: usize,
    pub emitted_tokens: usize,
    /// per-step verification time distribution (Table 6 / Fig. 3)
    pub per_step_verify: Summary,
    pub acceptance_rate: f64,
    pub gamma_mean: f64,
    /// peak host-buffer bytes during the run (Fig. 4/5 measured column)
    pub peak_mem_bytes: usize,
}

/// Run `tasks` through a fresh engine configured for `method`.
///
/// Seeds are derived from the task index only, so two methods see
/// identical requests and uniforms — `exact` therefore reproduces
/// `baseline` token-for-token, as in the paper.
#[allow(clippy::too_many_arguments)]
pub fn run_method(
    ctx: &EvalContext,
    tasks: &[Task],
    method: Method,
    backend: Backend,
    gamma_init: usize,
    gamma_pinned: bool,
) -> Result<MethodRun> {
    let config = EngineConfig {
        pair: ctx.pair.clone(),
        batch: ctx.batch,
        method,
        backend,
        mode: Mode::Speculative,
        gamma_init,
        gamma_pinned,
        self_draft: false,
        pipeline: PipelineMode::Auto,
        pipeline_depth: 2,
        pipeline_salvage: true,
        seed: ctx.seed,
    };
    let mut engine = Engine::new(ctx.runtime.clone(), config)?;
    ctx.runtime.gauge.reset_peak();

    let reqs: Vec<GenRequest> = tasks
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let params = ctx
                .params
                .clone()
                .with_max_new_tokens(t.max_new_tokens)
                .with_seed(ctx.seed.wrapping_add(i as u64));
            GenRequest::new(i as u64, ctx.tokenizer.encode(&t.prompt), params)
                .tokenize_stops(&ctx.tokenizer)
        })
        .collect();

    let started = Instant::now();
    let results = engine.generate(reqs)?;
    let wallclock = started.elapsed().as_secs_f64();

    let mut metric_sum = 0.0;
    for (task, result) in tasks.iter().zip(&results) {
        let hyp = ctx.tokenizer.decode_until_stop(&result.token_ids);
        metric_sum += task.score(&hyp);
    }
    let stats = &engine.stats;
    Ok(MethodRun {
        method,
        metric: metric_sum / tasks.len().max(1) as f64,
        profiling_total: stats.profiling_time_total(),
        wallclock,
        steps: stats.steps,
        emitted_tokens: stats.emitted,
        per_step_verify: stats.verify_time.summary(),
        acceptance_rate: stats.acceptance_rate(),
        gamma_mean: stats.gamma_series.mean(),
        peak_mem_bytes: ctx.runtime.gauge.peak_bytes(),
    })
}

/// Run all three methods on the same task set (the Table 1 row group).
pub fn run_all_methods(
    ctx: &EvalContext,
    kind: TaskKind,
    split_seed: u64,
    alpha_beta: (f32, f32),
) -> Result<(MethodRun, MethodRun, MethodRun)> {
    let tasks = make_tasks(&ctx.corpus, kind, ctx.n_examples, split_seed);
    let base = run_method(ctx, &tasks, Method::Baseline, Backend::Hlo, 5, false)?;
    let exact = run_method(ctx, &tasks, Method::Exact, Backend::Hlo, 5, false)?;
    let sig = run_method(
        ctx,
        &tasks,
        Method::sigmoid(alpha_beta.0, alpha_beta.1),
        Backend::Hlo,
        5,
        false,
    )?;
    Ok((base, exact, sig))
}

#[cfg(test)]
mod tests {
    // Everything here needs built artifacts; see rust/tests/it_tables.rs.
}
