//! The paper's model/dataset combinations, used to drive the simulator at
//! the scales the authors evaluated (Tables 1/3/4/6 and Figs. 3-5).

use crate::simulator::SimConfig;

/// One target/draft combination from Table 1.
#[derive(Debug, Clone, Copy)]
pub struct PaperCombo {
    pub target: &'static str,
    pub draft: &'static str,
    /// evaluation task family
    pub task: &'static str,
    pub vocab: usize,
    /// logit bytes: Whisper runs fp16, Llama/Qwen/Gemma logits fp32 (§4.3)
    pub dtype_bytes: usize,
    pub target_params: f64,
    pub draft_params: f64,
}

pub const COMBOS: &[PaperCombo] = &[
    PaperCombo {
        target: "Whisper Small.EN",
        draft: "Distil-Whisper Small.EN",
        task: "asr",
        vocab: 51_865,
        dtype_bytes: 2,
        target_params: 244e6,
        draft_params: 166e6,
    },
    PaperCombo {
        target: "Whisper Large V2",
        draft: "Distil-Whisper Large V2",
        task: "asr",
        vocab: 51_865,
        dtype_bytes: 2,
        target_params: 1.55e9,
        draft_params: 756e6,
    },
    PaperCombo {
        target: "Llama2 7B",
        draft: "Sheared Llama 1.3B",
        task: "sum",
        vocab: 32_000,
        dtype_bytes: 4,
        target_params: 7e9,
        draft_params: 1.3e9,
    },
    PaperCombo {
        target: "Llama2 13B",
        draft: "Sheared Llama 1.3B",
        task: "sum",
        vocab: 32_000,
        dtype_bytes: 4,
        target_params: 13e9,
        draft_params: 1.3e9,
    },
    PaperCombo {
        target: "Qwen 7B",
        draft: "Qwen 0.5B",
        task: "sum",
        vocab: 151_936,
        dtype_bytes: 4,
        target_params: 7e9,
        draft_params: 0.5e9,
    },
    PaperCombo {
        target: "Gemma 7B",
        draft: "Gemma 2B",
        task: "sum",
        vocab: 256_000,
        dtype_bytes: 4,
        target_params: 7e9,
        draft_params: 2e9,
    },
];

impl PaperCombo {
    pub fn sim_config(&self, gamma: usize) -> SimConfig {
        SimConfig {
            batch: 1,
            gamma,
            vocab: self.vocab,
            dtype_bytes: self.dtype_bytes,
        }
    }

    /// The (α, β) the paper uses for this task family (§4.1).
    pub fn alpha_beta(&self) -> (f32, f32) {
        if self.task == "asr" {
            (-1e3, 1e3)
        } else {
            (-1e4, 1e4)
        }
    }
}

/// ASR "dataset" labels for Table 1 rows (synthetic splits of the corpus
/// playing the roles of LibriSpeech clean/other, TED-LIUM, CV16).
pub const ASR_SPLITS: &[(&str, u64)] = &[
    ("synth-libri-clean", 101),
    ("synth-libri-other", 102),
    ("synth-tedlium", 103),
    ("synth-cv16", 104),
];

/// Summarization "dataset" labels (Xsum / CNN-DM roles).
pub const SUM_SPLITS: &[(&str, u64)] = &[("synth-cnndm", 201), ("synth-xsum", 202)];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combos_cover_both_tasks() {
        assert!(COMBOS.iter().any(|c| c.task == "asr"));
        assert!(COMBOS.iter().filter(|c| c.task == "sum").count() == 4);
    }

    #[test]
    fn alpha_beta_follows_section_41() {
        let asr = COMBOS.iter().find(|c| c.task == "asr").unwrap();
        assert_eq!(asr.alpha_beta(), (-1e3, 1e3));
        let s = COMBOS.iter().find(|c| c.task == "sum").unwrap();
        assert_eq!(s.alpha_beta(), (-1e4, 1e4));
    }
}
