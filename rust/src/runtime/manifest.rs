//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

/// One artifact's manifest record.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub method: Option<String>,
    pub pair: Option<String>,
    pub b: usize,
    pub g: usize,
    pub v: usize,
    pub s: usize,
    /// (dtype, shape) per positional input
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<(String, Vec<usize>)>,
}

impl ArtifactEntry {
    fn from_json(v: &Value, dir: &Path) -> Result<Self> {
        let name = v
            .req("name")
            .map_err(|e| anyhow!("{e}"))?
            .as_str()
            .context("name")?
            .to_string();
        let file = dir.join(
            v.req("file")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .context("file")?,
        );
        let get_usize = |key: &str| v.get(key).and_then(Value::as_usize).unwrap_or(0);
        let iospec = |key: &str| -> Result<Vec<(String, Vec<usize>)>> {
            v.req(key)
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .context("iospec not array")?
                .iter()
                .map(|entry| {
                    let pair = entry.as_arr().context("iospec entry")?;
                    let dtype = pair[0].as_str().context("dtype")?.to_string();
                    let shape = pair[1]
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<Vec<_>>>()?;
                    Ok((dtype, shape))
                })
                .collect()
        };
        Ok(ArtifactEntry {
            name,
            file,
            kind: v
                .req("kind")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .context("kind")?
                .to_string(),
            method: v.get("method").and_then(Value::as_str).map(String::from),
            pair: v.get("pair").and_then(Value::as_str).map(String::from),
            b: get_usize("b"),
            g: get_usize("g"),
            v: get_usize("v"),
            s: get_usize("s"),
            inputs: iospec("inputs")?,
            outputs: iospec("outputs")?,
        })
    }
}

/// Parsed manifest with lookup indexes.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab_size: usize,
    pub seq_len: usize,
    pub gmax: usize,
    /// pair name -> (target params, draft params)
    pub pairs: HashMap<String, (usize, usize)>,
    pub entries: Vec<ArtifactEntry>,
    by_name: HashMap<String, usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::from_json(&text, dir)
    }

    pub fn from_json(text: &str, dir: &Path) -> Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let version = v.get("version").and_then(Value::as_i64).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let entries: Vec<ArtifactEntry> = v
            .req("artifacts")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .context("artifacts")?
            .iter()
            .map(|e| ArtifactEntry::from_json(e, dir))
            .collect::<Result<_>>()?;
        let by_name = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.clone(), i))
            .collect();
        let mut pairs = HashMap::new();
        if let Some(Value::Obj(fields)) = v.get("pairs") {
            for (name, p) in fields {
                let t = p.get("target_params").and_then(Value::as_usize).unwrap_or(0);
                let d = p.get("draft_params").and_then(Value::as_usize).unwrap_or(0);
                pairs.insert(name.clone(), (t, d));
            }
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            vocab_size: v.get("vocab_size").and_then(Value::as_usize).unwrap_or(0),
            seq_len: v.get("seq_len").and_then(Value::as_usize).unwrap_or(0),
            gmax: v.get("gmax").and_then(Value::as_usize).unwrap_or(0),
            pairs,
            entries,
            by_name,
        })
    }

    /// Build an in-memory manifest describing a simulated model pair
    /// (no files behind the entries — the runtime serves them via
    /// [`crate::runtime::sim::SimExec`]). One `draft_step` /
    /// `target_step` / `target_score` triple per batch size, with the
    /// same iospecs the AOT artifacts carry, so the engine-side shape
    /// validation is identical on both execution paths.
    pub fn synthetic(
        pair: &str,
        vocab: usize,
        seq_len: usize,
        gmax: usize,
        batches: &[usize],
    ) -> Self {
        let mut entries: Vec<ArtifactEntry> = Vec::new();
        let f32s = |shape: Vec<usize>| ("float32".to_string(), shape);
        let i32s = |shape: Vec<usize>| ("int32".to_string(), shape);
        for &b in batches {
            for kind in ["draft_step", "target_step"] {
                entries.push(ArtifactEntry {
                    name: format!("{kind}_{pair}_b{b}"),
                    file: PathBuf::new(),
                    kind: kind.to_string(),
                    method: None,
                    pair: Some(pair.to_string()),
                    b,
                    g: 0,
                    v: vocab,
                    s: seq_len,
                    inputs: vec![
                        i32s(vec![b, seq_len]),
                        i32s(vec![b]),
                        f32s(vec![b]),
                        f32s(vec![b]),
                    ],
                    outputs: vec![i32s(vec![b]), f32s(vec![b, vocab])],
                });
            }
            entries.push(ArtifactEntry {
                name: format!("target_score_{pair}_b{b}"),
                file: PathBuf::new(),
                kind: "target_score".to_string(),
                method: None,
                pair: Some(pair.to_string()),
                b,
                g: gmax,
                v: vocab,
                s: seq_len,
                inputs: vec![i32s(vec![b, seq_len]), i32s(vec![b])],
                outputs: vec![f32s(vec![b, gmax + 1, vocab])],
            });
        }
        let by_name = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.clone(), i))
            .collect();
        let mut pairs = HashMap::new();
        pairs.insert(pair.to_string(), (0usize, 0usize));
        Manifest {
            dir: PathBuf::from("<sim>"),
            vocab_size: vocab,
            seq_len,
            gmax,
            pairs,
            entries,
            by_name,
        }
    }

    pub fn by_name(&self, name: &str) -> Result<&ArtifactEntry> {
        self.by_name
            .get(name)
            .map(|&i| &self.entries[i])
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    /// Find the verify artifact for (method, b, g, v).
    pub fn verify(&self, method: &str, b: usize, g: usize, v: usize) -> Result<&ArtifactEntry> {
        self.by_name(&format!("verify_{method}_b{b}_g{g}_v{v}"))
    }

    pub fn model(&self, kind: &str, pair: &str, b: usize) -> Result<&ArtifactEntry> {
        self.by_name(&format!("{kind}_{pair}_b{b}"))
    }

    /// γ values available for a (method, b, v) verify family, sorted.
    pub fn verify_gammas(&self, method: &str, b: usize, v: usize) -> Vec<usize> {
        let mut gs: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| {
                e.kind == "verify"
                    && e.method.as_deref() == Some(method)
                    && e.b == b
                    && e.v == v
            })
            .map(|e| e.g)
            .collect();
        gs.sort_unstable();
        gs
    }

    /// batch sizes available for a pair's model artifacts.
    pub fn model_batches(&self, pair: &str) -> Vec<usize> {
        let mut bs: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == "draft_step" && e.pair.as_deref() == Some(pair))
            .map(|e| e.b)
            .collect();
        bs.sort_unstable();
        bs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "version": 1, "vocab_size": 128, "seq_len": 256, "gmax": 20,
        "pairs": {"base": {"target": "target-base", "draft": "draft-base",
                            "target_params": 900000, "draft_params": 120000}},
        "artifacts": [
            {"name": "draft_step_base_b1", "file": "draft_step_base_b1.hlo.txt",
             "kind": "draft_step", "pair": "base", "b": 1, "s": 256, "v": 128,
             "inputs": [["int32",[1,256]],["int32",[1]],["float32",[1]],["float32",[1]]],
             "outputs": [["int32",[1]],["float32",[1,128]]]},
            {"name": "verify_exact_b1_g5_v128", "file": "verify_exact_b1_g5_v128.hlo.txt",
             "kind": "verify", "method": "exact", "b": 1, "g": 5, "v": 128,
             "inputs": [["float32",[1,6,128]]], "outputs": [["int32",[1]]]},
            {"name": "verify_exact_b1_g2_v128", "file": "verify_exact_b1_g2_v128.hlo.txt",
             "kind": "verify", "method": "exact", "b": 1, "g": 2, "v": 128,
             "inputs": [["float32",[1,3,128]]], "outputs": [["int32",[1]]]}
        ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::from_json(DOC, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.vocab_size, 128);
        assert_eq!(m.pairs["base"], (900000, 120000));
        let e = m.verify("exact", 1, 5, 128).unwrap();
        assert_eq!(e.g, 5);
        assert_eq!(e.inputs[0].1, vec![1, 6, 128]);
        assert!(m.verify("exact", 1, 9, 128).is_err());
        assert_eq!(m.model("draft_step", "base", 1).unwrap().kind, "draft_step");
    }

    #[test]
    fn gamma_listing_sorted() {
        let m = Manifest::from_json(DOC, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.verify_gammas("exact", 1, 128), vec![2, 5]);
        assert!(m.verify_gammas("sigmoid", 1, 128).is_empty());
    }

    #[test]
    fn synthetic_manifest_mirrors_artifact_contracts() {
        let m = Manifest::synthetic("sim", 64, 32, 5, &[1, 4]);
        assert_eq!(m.vocab_size, 64);
        assert_eq!(m.model_batches("sim"), vec![1, 4]);
        let d = m.model("draft_step", "sim", 4).unwrap();
        assert_eq!(d.inputs.len(), 4);
        assert_eq!(d.inputs[0], ("int32".to_string(), vec![4, 32]));
        assert_eq!(d.outputs[1], ("float32".to_string(), vec![4, 64]));
        let sc = m.model("target_score", "sim", 1).unwrap();
        assert_eq!(sc.outputs[0], ("float32".to_string(), vec![1, 6, 64]));
        // no verify artifacts: the sim path pairs with Backend::Native
        assert!(m.verify_gammas("exact", 1, 64).is_empty());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = DOC.replace("\"version\": 1", "\"version\": 99");
        assert!(Manifest::from_json(&bad, Path::new("/tmp")).is_err());
    }
}
