//! PJRT CPU client wrapper: HLO-text loading, executable caching,
//! profiled execution, and a peak-memory gauge for the Fig. 4/5
//! reproduction.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::manifest::{ArtifactEntry, Manifest};
use super::sim::{SimExec, SimKind, SimSpec};
use super::tensor::{HostTensor, TensorView};
use crate::util::timer::Profiler;

/// Peak/current host-buffer accounting. PJRT-CPU buffers alias host
/// memory, so literal traffic is the faithful "device memory" proxy;
/// [`crate::simulator`] scales this model to real HBM capacities.
#[derive(Debug, Default)]
pub struct MemoryGauge {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl MemoryGauge {
    pub fn alloc(&self, bytes: usize) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn free(&self, bytes: usize) {
        self.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn current_bytes(&self) -> usize {
        self.current.load(Ordering::Relaxed)
    }

    pub fn reset_peak(&self) {
        self.peak
            .store(self.current.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// How an executable actually runs: a compiled PJRT artifact, or the
/// deterministic in-process simulator ([`crate::runtime::sim`]) when the
/// runtime was opened with [`Runtime::simulated`]. The engine never sees
/// the difference — both sit behind [`LoadedExecutable::run_views_into`]
/// with identical shape validation and scope accounting.
enum ExecBackend {
    Pjrt(xla::PjRtLoadedExecutable),
    Sim(SimExec),
}

/// A compiled artifact plus its manifest record.
pub struct LoadedExecutable {
    pub entry: ArtifactEntry,
    exec: ExecBackend,
    profiler: Arc<Profiler>,
    gauge: Arc<MemoryGauge>,
}

impl LoadedExecutable {
    /// Execute with shape-checked owned inputs; returns the tuple
    /// elements. Thin adapter over [`LoadedExecutable::run_views`] —
    /// hot paths that reuse step buffers should call
    /// [`LoadedExecutable::run_views_into`] directly to avoid cloning
    /// inputs into owned tensors or allocating outputs.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let views: Vec<TensorView<'_>> = inputs.iter().map(HostTensor::view).collect();
        self.run_views(&views)
    }

    /// Execute with shape-checked borrowed inputs; returns the tuple
    /// elements.
    ///
    /// Allocates a fresh output vector per call — hot paths that run the
    /// same artifact every decode step should hold a reusable buffer and
    /// call [`LoadedExecutable::run_views_into`] instead.
    pub fn run_views(&self, inputs: &[TensorView<'_>]) -> Result<Vec<HostTensor>> {
        let mut outputs = Vec::new();
        self.run_views_into(inputs, &mut outputs)?;
        Ok(outputs)
    }

    /// Execute with shape-checked borrowed inputs, writing the tuple
    /// elements into `outputs` in place — the staging-workspace form of
    /// [`LoadedExecutable::run_views`]. Each slot's buffer capacity is
    /// reused ([`HostTensor::copy_from_literal`]), so once shapes reach
    /// their high-water mark a decode step performs **no output
    /// allocation**; together with the borrowed input views this removes
    /// every per-step `to_vec`/`clone` from the engine's draft and score
    /// staging (the one unavoidable copy is literal creation — PJRT owns
    /// its input buffers).
    ///
    /// Scope accounting: `exec/<name>` for the PJRT call itself plus
    /// `exec_kind/<kind>[/<method>]` aggregates used by the Δ%-profiling
    /// tables.
    pub fn run_views_into(
        &self,
        inputs: &[TensorView<'_>],
        outputs: &mut Vec<HostTensor>,
    ) -> Result<()> {
        anyhow::ensure!(
            inputs.len() == self.entry.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.entry.name,
            self.entry.inputs.len(),
            inputs.len()
        );
        for (i, (t, (dtype, shape))) in inputs.iter().zip(&self.entry.inputs).enumerate() {
            t.check_spec(dtype, shape, i)
                .with_context(|| format!("artifact {}", self.entry.name))?;
        }

        let in_bytes: usize = inputs.iter().map(TensorView::size_bytes).sum();
        self.gauge.alloc(in_bytes);

        let started = Instant::now();
        match &self.exec {
            ExecBackend::Pjrt(exe) => {
                let literals: Vec<xla::Literal> = inputs
                    .iter()
                    .map(TensorView::to_literal)
                    .collect::<Result<_>>()?;
                let result = exe
                    .execute::<xla::Literal>(&literals)
                    .with_context(|| format!("executing {}", self.entry.name))?;
                let tuple = result[0][0]
                    .to_literal_sync()
                    .context("fetching result literal")?
                    .to_tuple()
                    .context("untupling result")?;
                outputs.truncate(tuple.len());
                for (i, lit) in tuple.iter().enumerate() {
                    match outputs.get_mut(i) {
                        Some(slot) => slot.copy_from_literal(lit)?,
                        None => outputs.push(HostTensor::from_literal(lit)?),
                    }
                }
            }
            ExecBackend::Sim(sim) => {
                sim.run(inputs, outputs)
                    .with_context(|| format!("simulating {}", self.entry.name))?;
            }
        }
        let elapsed = started.elapsed();

        let out_bytes: usize = outputs.iter().map(HostTensor::size_bytes).sum();
        self.gauge.alloc(out_bytes);
        self.gauge.free(in_bytes + out_bytes);

        self.profiler.record(&format!("exec/{}", self.entry.name), elapsed);
        let kind_scope = match &self.entry.method {
            Some(m) => format!("exec_kind/{}/{}", self.entry.kind, m),
            None => format!("exec_kind/{}", self.entry.kind),
        };
        self.profiler.record(&kind_scope, elapsed);
        Ok(())
    }
}

/// Artifact runtime with an executable cache keyed by artifact name:
/// either a PJRT CPU client over the AOT HLO artifacts, or the
/// in-process deterministic simulator ([`Runtime::simulated`]) serving
/// the same executable contracts with no artifacts at all.
pub struct Runtime {
    pub manifest: Manifest,
    pub profiler: Arc<Profiler>,
    pub gauge: Arc<MemoryGauge>,
    /// `None` when this runtime simulates its models
    client: Option<xla::PjRtClient>,
    /// `Some` when this runtime simulates its models
    sim: Option<SimSpec>,
    cache: Mutex<HashMap<String, Arc<LoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifacts directory (compiles nothing yet — executables
    /// are compiled lazily on first use and cached).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::info!(
            "runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.entries.len()
        );
        Ok(Runtime {
            manifest,
            profiler: Arc::new(Profiler::new()),
            gauge: Arc::new(MemoryGauge::default()),
            client: Some(client),
            sim: None,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Build a runtime over the deterministic model simulator: a
    /// synthetic manifest (model pair `"sim"`) and [`SimExec`]
    /// executables behind the usual [`LoadedExecutable`] surface. No
    /// artifacts, no PJRT — the decode loop, the native verification
    /// kernels, and the pipelined scheduler all run end-to-end on it
    /// (the verify HLO path does not: pair it with `Backend::Native`).
    pub fn simulated(spec: SimSpec) -> Self {
        let manifest =
            Manifest::synthetic("sim", spec.vocab, spec.seq_len, spec.gmax, &spec.batches);
        crate::info!(
            "runtime: simulated models v={} s={} gmax={}",
            spec.vocab,
            spec.seq_len,
            spec.gmax
        );
        Runtime {
            manifest,
            profiler: Arc::new(Profiler::new()),
            gauge: Arc::new(MemoryGauge::default()),
            client: None,
            sim: Some(spec),
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// Default location (`artifacts/` or `$SPECD_ARTIFACTS`), or the
    /// simulated runtime when `SPECD_SIM=1` (model pair `"sim"`,
    /// native-backend verification).
    pub fn open_default() -> Result<Self> {
        if std::env::var("SPECD_SIM").is_ok_and(|v| v == "1" || v == "true") {
            return Ok(Self::simulated(SimSpec::from_env()));
        }
        Self::open(&crate::artifacts_dir())
    }

    /// Whether this runtime serves simulated models.
    pub fn is_simulated(&self) -> bool {
        self.sim.is_some()
    }

    /// The simulator spec this runtime was opened with (`None` for a
    /// PJRT artifact runtime). The trace recorder embeds it in the
    /// trace header so `specd trace check` can rebuild the identical
    /// model pair offline.
    pub fn sim_spec(&self) -> Option<&SimSpec> {
        self.sim.as_ref()
    }

    /// Load (compile) an artifact by name, with caching.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.by_name(name)?.clone();
        let exec = match (&self.client, &self.sim) {
            (_, Some(spec)) => {
                let kind = SimKind::parse(&entry.kind).ok_or_else(|| {
                    anyhow::anyhow!(
                        "simulated runtime has no {:?} executables \
                         (verification uses Backend::Native)",
                        entry.kind
                    )
                })?;
                ExecBackend::Sim(SimExec::new(kind, entry.b, spec.clone()))
            }
            (Some(client), None) => {
                let _scope = self.profiler.scope(&format!("compile/{name}"));
                let proto = xla::HloModuleProto::from_text_file(&entry.file)
                    .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                ExecBackend::Pjrt(
                    client
                        .compile(&comp)
                        .with_context(|| format!("compiling {name}"))?,
                )
            }
            (None, None) => unreachable!("runtime without client or simulator"),
        };
        let loaded = Arc::new(LoadedExecutable {
            entry,
            exec,
            profiler: self.profiler.clone(),
            gauge: self.gauge.clone(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Load the verify artifact for (method, b, g, v).
    pub fn load_verify(
        &self,
        method: &str,
        b: usize,
        g: usize,
        v: usize,
    ) -> Result<Arc<LoadedExecutable>> {
        let name = self.manifest.verify(method, b, g, v)?.name.clone();
        self.load(&name)
    }

    /// Load a model artifact (`draft_step` / `target_step` /
    /// `target_score`) for a pair + batch size.
    pub fn load_model(&self, kind: &str, pair: &str, b: usize) -> Result<Arc<LoadedExecutable>> {
        let name = self.manifest.model(kind, pair, b)?.name.clone();
        self.load(&name)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

// PJRT handles live behind Arc'd C++ objects; the client is used from the
// engine thread and the server threads.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}
unsafe impl Send for LoadedExecutable {}
unsafe impl Sync for LoadedExecutable {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_runtime_serves_model_executables() {
        let rt = Runtime::simulated(SimSpec {
            vocab: 32,
            seq_len: 16,
            gmax: 4,
            batches: vec![1, 2],
            ..SimSpec::default()
        });
        assert!(rt.is_simulated());
        let exe = rt.load_model("draft_step", "sim", 2).unwrap();
        assert_eq!(exe.entry.kind, "draft_step");
        // cached on repeat loads
        let again = rt.load_model("draft_step", "sim", 2).unwrap();
        assert!(Arc::ptr_eq(&exe, &again));
        assert_eq!(rt.cached_count(), 1);
        // shape validation runs against the synthetic manifest
        let tokens = vec![0i32; 2 * 16];
        let lens = vec![1i32; 2];
        let u = vec![0.5f32; 2];
        let temp = vec![1.0f32; 2];
        let mut out = Vec::new();
        exe.run_views_into(
            &[
                TensorView::i32(&[2, 16], &tokens),
                TensorView::i32(&[2], &lens),
                TensorView::f32(&[2], &u),
                TensorView::f32(&[2], &temp),
            ],
            &mut out,
        )
        .unwrap();
        assert_eq!(out[0].as_i32().unwrap().len(), 2);
        assert_eq!(out[1].as_f32().unwrap().len(), 2 * 32);
        // a wrong shape is rejected before execution
        assert!(exe
            .run_views_into(&[TensorView::i32(&[2, 16], &tokens)], &mut out)
            .is_err());
        // verify artifacts do not exist on the sim path
        assert!(rt.load_verify("exact", 2, 5, 32).is_err());
    }

    #[test]
    fn memory_gauge_tracks_peak() {
        let g = MemoryGauge::default();
        g.alloc(100);
        g.alloc(50);
        g.free(120);
        g.alloc(10);
        assert_eq!(g.peak_bytes(), 150);
        assert_eq!(g.current_bytes(), 40);
        g.reset_peak();
        assert_eq!(g.peak_bytes(), 40);
    }

    // Runtime/executable tests live in rust/tests/it_runtime.rs — they
    // need built artifacts and the PJRT plugin.
}
