//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! * [`tensor`] — host-side tensor type + literal conversion
//! * [`manifest`] — typed view of `artifacts/manifest.json`
//! * [`client`] — PJRT CPU client wrapper, executable cache, memory gauge
//! * [`sim`] — deterministic in-process model simulator
//!   ([`Runtime::simulated`]): the artifact-free execution path behind
//!   the same [`LoadedExecutable`] surface, used by the pipelined-decode
//!   parity tests and benches

pub mod client;
pub mod manifest;
pub mod sim;
pub mod tensor;

pub use client::{LoadedExecutable, Runtime};
pub use manifest::{ArtifactEntry, Manifest};
pub use sim::{SimExec, SimKind, SimSpec};
pub use tensor::{HostTensor, TensorView};
