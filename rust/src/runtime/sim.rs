//! Deterministic in-process model simulator — the artifact-free twin of
//! the PJRT execution path.
//!
//! The vendored `xla` crate is a typed stub: it compiles the full PJRT
//! surface but reports "runtime unavailable" at client creation, so a
//! container without the native XLA library can never execute the AOT
//! artifacts — and, before this module existed, could never drive the
//! decode loop at all. [`SimExec`] fills that hole: a pure-Rust toy
//! language model implementing the *exact* artifact contracts
//! (`draft_step` / `target_step` / `target_score` input/output shapes,
//! internal temperature-scaled sampling from a supplied uniform), so the
//! whole engine — continuous batching, the adaptive-γ controller, the
//! native verification kernels, and the pipelined decode scheduler — runs
//! end-to-end with no artifacts. The pipelined-vs-serial parity tests and
//! the decode sections of `bench_e2e` are built on it.
//!
//! ## Model
//!
//! Logits are a pure hash of the context window (the last
//! [`CTX_WINDOW`] committed tokens) and the candidate token id, mixed
//! with the spec seed via splitmix64. Draft and target share a common
//! logit component and add model-specific perturbations scaled by
//! `1 - agreement`, so speculative acceptance rates are tunable:
//! `agreement = 1.0` gives identical models (acceptance 1), `0.0` gives
//! independent models. Everything is computed per batch row from that
//! row's tokens alone, so outputs are **bit-identical across batch
//! sizes and call schedules** — the property the pipelined scheduler's
//! parity tests lean on (a prefetched model call must produce the same
//! bits as the same call issued serially).
//!
//! ## Latency emulation
//!
//! `model_delay` busy-spins each call for a fixed duration before
//! computing, emulating the device-dispatch latency the pipelined
//! scheduler exists to hide. The delay never affects outputs — only
//! where the wall-clock goes.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::tensor::{HostTensor, TensorView};
use crate::sampling::verify;

/// Context tokens hashed into each logit row.
pub const CTX_WINDOW: usize = 6;

/// Configuration of a simulated model pair + runtime dimensions.
#[derive(Debug, Clone)]
pub struct SimSpec {
    pub vocab: usize,
    pub seq_len: usize,
    pub gmax: usize,
    /// batch sizes the synthetic manifest advertises
    pub batches: Vec<usize>,
    /// model-pair seed: distinct seeds are distinct model pairs
    pub seed: u64,
    /// draft/target agreement in `[0, 1]` (1.0 = identical logits)
    pub agreement: f32,
    /// per-call busy-wait emulating device dispatch latency
    pub model_delay: Duration,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            vocab: 128,
            seq_len: 256,
            gmax: 10,
            batches: vec![1, 2, 3, 4, 8],
            seed: 0xC0FF_EE11,
            agreement: 0.9,
            model_delay: Duration::ZERO,
        }
    }
}

impl SimSpec {
    /// Default spec with `SPECD_SIM_DELAY_US` / `SPECD_SIM_AGREEMENT`
    /// environment overrides applied (the knobs the decode benches use).
    pub fn from_env() -> Self {
        let mut spec = SimSpec::default();
        if let Ok(v) = std::env::var("SPECD_SIM_DELAY_US") {
            if let Ok(us) = v.parse::<u64>() {
                spec.model_delay = Duration::from_micros(us);
            }
        }
        if let Ok(v) = std::env::var("SPECD_SIM_AGREEMENT") {
            if let Ok(a) = v.parse::<f32>() {
                spec.agreement = a.clamp(0.0, 1.0);
            }
        }
        spec
    }
}

/// Which artifact contract a [`SimExec`] implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimKind {
    /// `(tokens[B,S], lens[B], u[B], temp[B]) -> (toks[B], logits[B,V])`,
    /// sampling from the draft model's distribution
    DraftStep,
    /// same contract as [`SimKind::DraftStep`], target model
    TargetStep,
    /// `(tokens[B,S], lens[B]) -> logits[B, GMAX+1, V]`: target logits
    /// for the trailing `GMAX+1` context lengths (row `GMAX` = full
    /// context `lens[i]`, row `GMAX - k` = context `lens[i] - k`)
    TargetScore,
}

impl SimKind {
    pub fn parse(kind: &str) -> Option<SimKind> {
        match kind {
            "draft_step" | "draft_self_step" => Some(SimKind::DraftStep),
            "target_step" => Some(SimKind::TargetStep),
            "target_score" => Some(SimKind::TargetScore),
            _ => None,
        }
    }
}

/// One simulated executable (kind + batch size + model spec).
#[derive(Debug, Clone)]
pub struct SimExec {
    pub kind: SimKind,
    pub batch: usize,
    spec: SimSpec,
}

/// splitmix64 — the one mixing primitive everything derives from.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Map a hash to a logit in roughly `[-scale, scale)`.
fn hash_logit(h: u64, scale: f32) -> f32 {
    let unit = (h >> 40) as f32 * (1.0 / (1u64 << 24) as f32); // [0, 1)
    (unit * 2.0 - 1.0) * scale
}

const DRAFT_SALT: u64 = 0x5EED_D12A_F700_0001;
const TARGET_SALT: u64 = 0x5EED_7A26_E700_0002;

impl SimExec {
    pub fn new(kind: SimKind, batch: usize, spec: SimSpec) -> Self {
        SimExec { kind, batch, spec }
    }

    /// Hash of the last [`CTX_WINDOW`] tokens of `tokens[..len]`.
    fn ctx_hash(&self, tokens: &[i32], len: usize) -> u64 {
        let len = len.min(tokens.len()).max(1);
        let start = len.saturating_sub(CTX_WINDOW);
        let mut h = mix(self.spec.seed ^ (len as u64).wrapping_mul(0x9E37));
        for &t in &tokens[start..len] {
            h = mix(h ^ (t as u64).wrapping_add(0x1234_5678));
        }
        h
    }

    /// Fill one logit row for the given model role (`true` = draft).
    fn logits_into(&self, ctx: u64, draft: bool, out: &mut [f32]) {
        let noise = 1.0 - self.spec.agreement.clamp(0.0, 1.0);
        let salt = if draft { DRAFT_SALT } else { TARGET_SALT };
        for (j, e) in out.iter_mut().enumerate() {
            let shared = hash_logit(mix(ctx ^ j as u64), 3.0);
            let own = hash_logit(mix(ctx ^ j as u64 ^ salt), 3.0);
            *e = shared + noise * own;
        }
    }

    /// Sample a token from temperature-scaled `logits` via inverse CDF
    /// (the same arithmetic the AOT step graphs bake in: scale, stable
    /// softmax, threshold at `u`). `temp <= 0` is greedy argmax.
    fn sample(logits: &[f32], temp: f32, u: f32, scratch: &mut Vec<f32>) -> i32 {
        scratch.clear();
        if temp <= 0.0 {
            let mut best = 0usize;
            for (i, &x) in logits.iter().enumerate().skip(1) {
                if x > logits[best] {
                    best = i;
                }
            }
            return best as i32;
        }
        let inv = 1.0 / temp;
        scratch.extend(logits.iter().map(|&x| x * inv));
        let row = &mut scratch[..];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for e in row.iter_mut() {
            *e = (*e - max).exp();
            sum += *e;
        }
        let inv_sum = 1.0 / sum;
        for e in row.iter_mut() {
            *e *= inv_sum;
        }
        verify::inverse_cdf_sample(row, u) as i32
    }

    fn spin(&self) {
        if self.spec.model_delay.is_zero() {
            return;
        }
        let t0 = Instant::now();
        // Hybrid wait: sleep off the bulk of long delays (with a ~100µs
        // guard for scheduler wakeup slop), then spin the remainder so
        // the simulated step time stays accurate to a few µs without
        // burning a core for the whole delay. Matters once a depth-k
        // pipeline keeps several simulated forward passes in flight.
        const SLEEP_GUARD: Duration = Duration::from_micros(100);
        if self.spec.model_delay >= Duration::from_micros(150) {
            std::thread::sleep(self.spec.model_delay - SLEEP_GUARD);
        }
        while t0.elapsed() < self.spec.model_delay {
            std::hint::spin_loop();
        }
    }

    /// Execute against borrowed inputs, staging outputs in place (the
    /// sim twin of the PJRT execute path in
    /// [`crate::runtime::LoadedExecutable::run_views_into`]; shape
    /// validation happens there, against the synthetic manifest).
    pub fn run(&self, inputs: &[TensorView<'_>], outputs: &mut Vec<HostTensor>) -> Result<()> {
        self.spin();
        let (b, s, v, w) = (
            self.batch,
            self.spec.seq_len,
            self.spec.vocab,
            self.spec.gmax + 1,
        );
        let tokens = match inputs.first() {
            Some(TensorView::I32 { data, .. }) => *data,
            _ => bail!("sim: input 0 must be i32 tokens"),
        };
        let lens = match inputs.get(1) {
            Some(TensorView::I32 { data, .. }) => *data,
            _ => bail!("sim: input 1 must be i32 lens"),
        };
        match self.kind {
            SimKind::DraftStep | SimKind::TargetStep => {
                let u = match inputs.get(2) {
                    Some(TensorView::F32 { data, .. }) => *data,
                    _ => bail!("sim: input 2 must be f32 uniforms"),
                };
                let temp = match inputs.get(3) {
                    Some(TensorView::F32 { data, .. }) => *data,
                    _ => bail!("sim: input 3 must be f32 temperatures"),
                };
                let draft = self.kind == SimKind::DraftStep;
                // write straight into the caller's reusable staging
                // tensors — the sim side of the run_views_into
                // workspace pattern, no per-call output allocation
                ensure_slots(outputs, 2);
                outputs.truncate(2);
                let (toks_slot, logits_slot) = outputs.split_at_mut(1);
                let toks = prep_i32(&mut toks_slot[0], &[b]);
                let logits = prep_f32(&mut logits_slot[0], &[b, v]);
                let mut scratch: Vec<f32> = Vec::with_capacity(v);
                for i in 0..b {
                    let row = &mut logits[i * v..(i + 1) * v];
                    let ctx = self.ctx_hash(&tokens[i * s..(i + 1) * s], lens[i] as usize);
                    self.logits_into(ctx, draft, row);
                    toks[i] = Self::sample(row, temp[i], u[i], &mut scratch);
                }
            }
            SimKind::TargetScore => {
                ensure_slots(outputs, 1);
                outputs.truncate(1);
                let logits = prep_f32(&mut outputs[0], &[b, w, v]);
                for i in 0..b {
                    let len = lens[i] as usize;
                    let row_tokens = &tokens[i * s..(i + 1) * s];
                    for k in 0..w {
                        // row w-1 is the full context; earlier rows walk
                        // back one token each (clamped at context 1)
                        let cl = len.saturating_sub(w - 1 - k).max(1);
                        let ctx = self.ctx_hash(row_tokens, cl);
                        let row = &mut logits[(i * w + k) * v..(i * w + k + 1) * v];
                        self.logits_into(ctx, false, row);
                    }
                }
            }
        }
        Ok(())
    }
}

/// Grow `outputs` to at least `n` slots (placeholders are retyped by
/// the prep helpers on first use).
fn ensure_slots(outputs: &mut Vec<HostTensor>, n: usize) {
    while outputs.len() < n {
        outputs.push(HostTensor::i32(&[0], Vec::new()));
    }
}

/// Shape an i32 output slot in place (reusing its capacity; a
/// wrong-dtype placeholder is replaced) and return its data buffer.
fn prep_i32<'a>(slot: &'a mut HostTensor, shape: &[usize]) -> &'a mut Vec<i32> {
    let n: usize = shape.iter().product();
    if !matches!(slot, HostTensor::I32 { .. }) {
        *slot = HostTensor::i32(&[0], Vec::new());
    }
    match slot {
        HostTensor::I32 { shape: sh, data } => {
            sh.clear();
            sh.extend_from_slice(shape);
            data.clear();
            data.resize(n, 0);
            data
        }
        _ => unreachable!("slot retyped above"),
    }
}

/// Shape an f32 output slot in place (reusing its capacity; a
/// wrong-dtype placeholder is replaced) and return its data buffer.
fn prep_f32<'a>(slot: &'a mut HostTensor, shape: &[usize]) -> &'a mut Vec<f32> {
    let n: usize = shape.iter().product();
    if !matches!(slot, HostTensor::F32 { .. }) {
        *slot = HostTensor::f32(&[0], Vec::new());
    }
    match slot {
        HostTensor::F32 { shape: sh, data } => {
            sh.clear();
            sh.extend_from_slice(shape);
            data.clear();
            data.resize(n, 0.0);
            data
        }
        _ => unreachable!("slot retyped above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SimSpec {
        SimSpec {
            vocab: 32,
            seq_len: 16,
            gmax: 4,
            ..SimSpec::default()
        }
    }

    fn run_draft(exec: &SimExec, tokens: Vec<i32>, lens: Vec<i32>) -> (Vec<i32>, Vec<f32>) {
        let b = exec.batch;
        let s = exec.spec.seq_len;
        let u = vec![0.37f32; b];
        let temp = vec![0.8f32; b];
        let mut out = Vec::new();
        exec.run(
            &[
                TensorView::i32(&[b, s], &tokens),
                TensorView::i32(&[b], &lens),
                TensorView::f32(&[b], &u),
                TensorView::f32(&[b], &temp),
            ],
            &mut out,
        )
        .unwrap();
        (
            out[0].as_i32().unwrap().to_vec(),
            out[1].as_f32().unwrap().to_vec(),
        )
    }

    #[test]
    fn deterministic_and_shape_correct() {
        let exec = SimExec::new(SimKind::DraftStep, 2, spec());
        let tokens: Vec<i32> = (0..2 * 16).map(|i| (i % 30) as i32).collect();
        let lens = vec![5, 9];
        let (t1, l1) = run_draft(&exec, tokens.clone(), lens.clone());
        let (t2, l2) = run_draft(&exec, tokens, lens);
        assert_eq!(t1, t2);
        assert_eq!(l1, l2);
        assert_eq!(t1.len(), 2);
        assert_eq!(l1.len(), 2 * 32);
        assert!(t1.iter().all(|&t| (0..32).contains(&t)));
    }

    #[test]
    fn rows_are_batch_independent() {
        // row i of a batched call equals the same row run at batch 1 —
        // the property the pipelined scheduler's prefetch relies on
        let sp = spec();
        let b2 = SimExec::new(SimKind::DraftStep, 2, sp.clone());
        let b1 = SimExec::new(SimKind::DraftStep, 1, sp.clone());
        let tokens: Vec<i32> = (0..2 * 16).map(|i| ((i * 7) % 30) as i32).collect();
        let lens = vec![4, 11];
        let (t, l) = run_draft(&b2, tokens.clone(), lens.clone());
        for i in 0..2 {
            let (ti, li) = run_draft(&b1, tokens[i * 16..(i + 1) * 16].to_vec(), vec![lens[i]]);
            assert_eq!(ti[0], t[i], "row {i}");
            assert_eq!(li, l[i * 32..(i + 1) * 32].to_vec(), "row {i}");
        }
    }

    #[test]
    fn score_last_row_matches_step_logits() {
        // target_score row GMAX (full context) must be the same logits
        // target_step computes at that context
        let sp = spec();
        let score = SimExec::new(SimKind::TargetScore, 1, sp.clone());
        let step = SimExec::new(SimKind::TargetStep, 1, sp.clone());
        let tokens: Vec<i32> = (0..16).map(|i| ((i * 3) % 30) as i32).collect();
        let lens = vec![7];
        let mut out = Vec::new();
        score
            .run(
                &[
                    TensorView::i32(&[1, 16], &tokens),
                    TensorView::i32(&[1], &lens),
                ],
                &mut out,
            )
            .unwrap();
        let win = out[0].as_f32().unwrap().to_vec();
        let w = sp.gmax + 1;
        assert_eq!(win.len(), w * 32);
        let (_, step_logits) = {
            let u = vec![0.5f32];
            let temp = vec![1.0f32];
            let mut o = Vec::new();
            step.run(
                &[
                    TensorView::i32(&[1, 16], &tokens),
                    TensorView::i32(&[1], &lens),
                    TensorView::f32(&[1], &u),
                    TensorView::f32(&[1], &temp),
                ],
                &mut o,
            )
            .unwrap();
            (o[0].as_i32().unwrap().to_vec(), o[1].as_f32().unwrap().to_vec())
        };
        assert_eq!(&win[(w - 1) * 32..w * 32], &step_logits[..]);
    }

    #[test]
    fn agreement_moves_draft_toward_target() {
        let mut hi = spec();
        hi.agreement = 1.0;
        let mut lo = spec();
        lo.agreement = 0.0;
        let tokens: Vec<i32> = (0..16).map(|i| (i % 30) as i32).collect();
        let ctx_len = 6usize;
        let row = |sp: &SimSpec, draft: bool| {
            let e = SimExec::new(SimKind::DraftStep, 1, sp.clone());
            let mut out = vec![0.0f32; sp.vocab];
            let ctx = e.ctx_hash(&tokens, ctx_len);
            e.logits_into(ctx, draft, &mut out);
            out
        };
        // full agreement: draft == target exactly
        assert_eq!(row(&hi, true), row(&hi, false));
        // zero agreement: they differ
        assert_ne!(row(&lo, true), row(&lo, false));
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let logits = vec![0.1f32, 2.0, -1.0, 0.5];
        let mut scratch = Vec::new();
        assert_eq!(SimExec::sample(&logits, 0.0, 0.99, &mut scratch), 1);
        // and at finite temperature u=0 picks the first token with mass
        let t = SimExec::sample(&logits, 1.0, 0.0, &mut scratch);
        assert_eq!(t, 0);
    }
}
