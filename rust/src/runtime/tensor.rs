//! Host-side tensors and conversion to/from `xla::Literal`.
//!
//! The dtypes the artifacts and the native verify path use (f32, i32,
//! and fp16 logit storage) are supported; shapes are explicit so input
//! validation against the manifest happens before PJRT sees anything.
//!
//! fp16 tensors carry raw IEEE binary16 bit patterns (`u16`) — the
//! native sigmoid16 ingestion path widens them inside the kernel
//! layer's fused prob-construction pass
//! ([`crate::sampling::kernels::construct_prob_row_logits`]), so the
//! half-width storage is what crosses the staging boundary and no f32
//! widening copy is ever materialised.

use anyhow::{bail, Context, Result};

/// View a plain-old-data element slice as raw bytes (safe: f32/i32/u16
/// are POD with alignment ≥ 1).
fn bytemuck_cast<T>(data: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            data.as_ptr() as *const u8,
            std::mem::size_of_val(data),
        )
    }
}

/// Dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    /// Half-precision storage: raw IEEE binary16 bit patterns.
    F16 { shape: Vec<usize>, data: Vec<u16> },
}

/// Borrowed tensor view — the zero-copy input form of [`HostTensor`].
/// The engine hot path builds these over its preallocated step buffers
/// instead of cloning each buffer into an owned tensor every decode
/// step (at bench scale that was megabytes of memcpy per step).
#[derive(Debug, Clone, Copy)]
pub enum TensorView<'a> {
    F32 { shape: &'a [usize], data: &'a [f32] },
    I32 { shape: &'a [usize], data: &'a [i32] },
    F16 { shape: &'a [usize], data: &'a [u16] },
}

impl<'a> TensorView<'a> {
    pub fn f32(shape: &'a [usize], data: &'a [f32]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorView::F32 { shape, data }
    }

    pub fn i32(shape: &'a [usize], data: &'a [i32]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorView::I32 { shape, data }
    }

    pub fn f16(shape: &'a [usize], data: &'a [u16]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorView::F16 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            TensorView::F32 { shape, .. }
            | TensorView::I32 { shape, .. }
            | TensorView::F16 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            TensorView::F32 { .. } => "float32",
            TensorView::I32 { .. } => "int32",
            TensorView::F16 { .. } => "float16",
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorView::F32 { data, .. } => data.len(),
            TensorView::I32 { data, .. } => data.len(),
            TensorView::F16 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            TensorView::F16 { data, .. } => data.len() * 2,
            _ => self.len() * 4,
        }
    }

    /// Convert to an XLA literal (the one unavoidable copy — PJRT owns
    /// its input buffers).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, bytes): (xla::ElementType, &[u8]) = match self {
            TensorView::F32 { data, .. } => (xla::ElementType::F32, bytemuck_cast(data)),
            TensorView::I32 { data, .. } => (xla::ElementType::S32, bytemuck_cast(data)),
            TensorView::F16 { .. } => bail!(
                "float16 tensors are native-only logit staging; widen through the kernel \
                 layer's fused ingestion before handing anything to PJRT"
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, self.shape(), bytes)
            .with_context(|| format!("creating literal {:?} {:?}", ty, self.shape()))
    }

    /// Validate against a manifest iospec entry `(dtype, shape)`.
    pub fn check_spec(&self, dtype: &str, shape: &[usize], arg_idx: usize) -> Result<()> {
        if self.dtype() != dtype {
            bail!(
                "arg {arg_idx}: dtype mismatch: got {}, artifact wants {dtype}",
                self.dtype()
            );
        }
        if self.shape() != shape {
            bail!(
                "arg {arg_idx}: shape mismatch: got {:?}, artifact wants {shape:?}",
                self.shape()
            );
        }
        Ok(())
    }
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::f32(&[], vec![x])
    }

    /// fp16 tensor from raw binary16 bit patterns.
    pub fn f16(shape: &[usize], data: Vec<u16>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F16 {
            shape: shape.to_vec(),
            data,
        }
    }

    /// fp16 tensor narrowed from f32 values (IEEE round-to-nearest-even,
    /// via [`crate::sampling::verify::f32_to_f16_bits`]) — how the
    /// simulated model block emits half-precision logits.
    pub fn f16_from_f32(shape: &[usize], data: &[f32]) -> Self {
        HostTensor::f16(
            shape,
            data.iter()
                .map(|&x| crate::sampling::verify::f32_to_f16_bits(x))
                .collect(),
        )
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. }
            | HostTensor::I32 { shape, .. }
            | HostTensor::F16 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "float32",
            HostTensor::I32 { .. } => "int32",
            HostTensor::F16 { .. } => "float16",
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
            HostTensor::F16 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            HostTensor::F16 { data, .. } => data.len() * 2,
            _ => self.len() * 4,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor, got {}", self.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor, got {}", self.dtype()),
        }
    }

    /// Raw binary16 bit patterns of an fp16 tensor.
    pub fn as_f16_bits(&self) -> Result<&[u16]> {
        match self {
            HostTensor::F16 { data, .. } => Ok(data),
            _ => bail!("expected f16 tensor, got {}", self.dtype()),
        }
    }

    /// Borrow as a [`TensorView`] (the form [`LoadedExecutable::run_views`]
    /// consumes; `run` goes through this adapter).
    ///
    /// [`LoadedExecutable::run_views`]: crate::runtime::LoadedExecutable::run_views
    pub fn view(&self) -> TensorView<'_> {
        match self {
            HostTensor::F32 { shape, data } => TensorView::F32 { shape, data },
            HostTensor::I32 { shape, data } => TensorView::I32 { shape, data },
            HostTensor::F16 { shape, data } => TensorView::F16 { shape, data },
        }
    }

    /// Convert to an XLA literal.
    ///
    /// Perf iteration 2 (EXPERIMENTS.md §Perf): build the literal in ONE
    /// copy via `create_from_shape_and_untyped_data` instead of
    /// `vec1(...).reshape(...)`, which copied the buffer twice (once into
    /// the rank-1 literal, once into the reshaped one). At the bench-scale
    /// verify inputs (γ=5, V=32k ⇒ ~2.6MB of logits per step) this removes
    /// ~5MB of memcpy per verification call.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        self.view().to_literal()
    }

    /// Convert from an XLA literal (copies).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::f32(&dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(HostTensor::i32(&dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported artifact dtype {other:?}"),
        }
    }

    /// Refill `self` from an XLA literal **in place**, reusing the data
    /// `Vec`'s capacity — the output-side twin of
    /// [`crate::runtime::TensorView`]: where views remove the per-step
    /// clone of model *inputs*, this removes the per-step `to_vec` of
    /// model *outputs*. The decode loop's staging buffers keep their
    /// high-water allocation, so a steady-state
    /// [`crate::runtime::LoadedExecutable::run_views_into`] call
    /// allocates nothing (a dtype change falls back to a fresh
    /// conversion; artifact output dtypes never change between steps).
    pub fn copy_from_literal(&mut self, lit: &xla::Literal) -> Result<()> {
        let ashape = lit.array_shape().context("literal has no array shape")?;
        let bytes = lit.untyped_data();
        match (ashape.ty(), &mut *self) {
            (xla::ElementType::F32, HostTensor::F32 { shape, data }) => {
                shape.clear();
                shape.extend(ashape.dims().iter().map(|&d| d as usize));
                data.clear();
                data.extend(
                    bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]])),
                );
                Ok(())
            }
            (xla::ElementType::S32, HostTensor::I32 { shape, data }) => {
                shape.clear();
                shape.extend(ashape.dims().iter().map(|&d| d as usize));
                data.clear();
                data.extend(
                    bytes
                        .chunks_exact(4)
                        .map(|c| i32::from_ne_bytes([c[0], c[1], c[2], c[3]])),
                );
                Ok(())
            }
            // dtype switch: cold path, replace wholesale
            (_, slot) => {
                *slot = HostTensor::from_literal(lit)?;
                Ok(())
            }
        }
    }

    /// Validate against a manifest iospec entry `(dtype, shape)`.
    pub fn check_spec(&self, dtype: &str, shape: &[usize], arg_idx: usize) -> Result<()> {
        self.view().check_spec(dtype, shape, arg_idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), "float32");
        assert_eq!(t.size_bytes(), 24);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn views_borrow_without_copying() {
        let t = HostTensor::i32(&[2, 2], vec![1, 2, 3, 4]);
        let v = t.view();
        assert_eq!(v.shape(), t.shape());
        assert_eq!(v.dtype(), "int32");
        assert_eq!(v.len(), 4);
        assert_eq!(v.size_bytes(), 16);
        assert!(v.check_spec("int32", &[2, 2], 0).is_ok());
        assert!(v.check_spec("float32", &[2, 2], 0).is_err());
        assert!(v.check_spec("int32", &[4], 0).is_err());

        let shape = [3usize];
        let data = [0.5f32, 1.5, 2.5];
        let v = TensorView::f32(&shape, &data);
        assert_eq!(v.shape(), &[3]);
        assert!(!v.is_empty());
    }

    #[test]
    fn f16_storage_mode() {
        use crate::sampling::verify::{f16_bits_to_f32, f32_to_f16_bits};

        let vals = [0.0f32, 1.0, -2.5, 65504.0, 1e-5];
        let t = HostTensor::f16_from_f32(&[5], &vals);
        assert_eq!(t.dtype(), "float16");
        assert_eq!(t.shape(), &[5]);
        assert_eq!(t.len(), 5);
        // the point of the storage mode: half the staging bytes
        assert_eq!(t.size_bytes(), 10);
        let bits = t.as_f16_bits().unwrap();
        assert_eq!(bits.len(), 5);
        for (&b, &x) in bits.iter().zip(&vals) {
            assert_eq!(b, f32_to_f16_bits(x));
            // every one of these survives the round trip within f16 ulp
            let back = f16_bits_to_f32(b);
            assert!((back - x).abs() <= (x.abs() * 1e-3).max(1e-7), "{x} -> {back}");
        }
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_err());

        let v = t.view();
        assert_eq!(v.dtype(), "float16");
        assert_eq!(v.size_bytes(), 10);
        assert!(v.check_spec("float16", &[5], 0).is_ok());
        assert!(v.check_spec("float32", &[5], 0).is_err());
        // fp16 never crosses into PJRT — staging is native-only
        assert!(v.to_literal().is_err());

        let raw = HostTensor::f16(&[2], vec![0x7c00, 0xfc00]);
        let b = raw.as_f16_bits().unwrap();
        assert!(f16_bits_to_f32(b[0]).is_infinite());
        assert!(f16_bits_to_f32(b[1]) < 0.0);
    }

    #[test]
    fn spec_check() {
        let t = HostTensor::i32(&[4], vec![1, 2, 3, 4]);
        assert!(t.check_spec("int32", &[4], 0).is_ok());
        assert!(t.check_spec("float32", &[4], 0).is_err());
        assert!(t.check_spec("int32", &[2, 2], 0).is_err());
    }

    #[test]
    fn copy_from_literal_reuses_the_allocation() {
        let data: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_ne_bytes()).collect();
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &[2, 2],
            &bytes,
        )
        .unwrap();
        // start with a bigger buffer: the refill must shrink in place
        let mut t = HostTensor::f32(&[8], vec![0.0; 8]);
        let ptr = t.as_f32().unwrap().as_ptr();
        t.copy_from_literal(&lit).unwrap();
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.as_f32().unwrap(), &data[..]);
        assert_eq!(t.as_f32().unwrap().as_ptr(), ptr, "no reallocation");
        // a dtype switch falls back to a fresh conversion
        let ib: Vec<u8> = [7i32, 8].iter().flat_map(|x| x.to_ne_bytes()).collect();
        let il = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[2],
            &ib,
        )
        .unwrap();
        t.copy_from_literal(&il).unwrap();
        assert_eq!(t.shape(), &[2]);
        assert_eq!(t.as_i32().unwrap(), &[7, 8]);
    }

    // executable round-trips live in rust/tests/ (they need the PJRT runtime)
}
