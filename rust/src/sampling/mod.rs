//! Pure-rust speculative sampling oracle.
//!
//! Bit-comparable reimplementation (in f32, matching the AOT graphs'
//! arithmetic) of the verification semantics in §3.1 Eq. 1-3. Three roles:
//!
//! 1. cross-validation: integration tests execute the HLO artifacts and
//!    assert their outputs against this module;
//! 2. a `native` verifier backend for [`crate::engine`] — useful when the
//!    model vocab is small and PJRT dispatch overhead dominates;
//! 3. the workload for the L3 micro-benchmarks.

pub mod filter;
pub mod verify;

pub use filter::{mask_logits_top_k_top_p, MASKED_LOGIT};
pub use verify::{
    inverse_cdf_sample, sigmoid_approx, softmax_rows, spec_step, Method, StepOutput,
};
