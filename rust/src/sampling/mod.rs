//! Pure-rust speculative sampling: scalar oracle + parallel kernels.
//!
//! [`verify`] is the bit-comparable scalar reimplementation (in f32,
//! matching the AOT graphs' arithmetic) of the verification semantics in
//! §3.1 Eq. 1-3. Three roles:
//!
//! 1. cross-validation: integration tests execute the HLO artifacts and
//!    assert their outputs against this module;
//! 2. the reference the segment-parallel kernel layer is proven
//!    bit-identical to;
//! 3. the workload for the L3 micro-benchmarks.
//!
//! [`kernels`] is the serving-path implementation of the same semantics:
//! segment-parallel over matrix rows / vocab chunks (the §3 partitioning
//! on CPU threads), zero-alloc and zero-spawn at steady state via a
//! preallocated [`kernels::VerifyWorkspace`] that owns a persistent
//! worker pool, with per-slot [`Method`] dispatch for heterogeneous
//! batches. The `native` verifier backend of [`crate::engine`] runs on
//! it.

pub mod filter;
pub mod kernels;
pub mod verify;

pub use filter::{mask_logits_top_k_top_p, MASKED_LOGIT};
pub use kernels::simd::SimdMode;
pub use kernels::{KernelConfig, Logits, VerifyWorkspace};
pub use verify::{
    exp_approx, f16_bits_to_f32, f32_to_f16_bits, inverse_cdf_sample, sigmoid_approx,
    softmax_rows, spec_step, Method, StepOutput,
};
