//! Top-k / nucleus (top-p) truncation shared by the engine and the
//! sampling oracle.
//!
//! Truncation is expressed as *logit masking*: excluded entries are set
//! to [`MASKED_LOGIT`], so one filtered row flows unchanged through every
//! downstream consumer — the native oracle's softmax, the AOT verify
//! artifacts (whose on-device softmax renormalises over the survivors),
//! and the sigmoid approximation (σ of a hugely negative input is 0).
//!
//! Speculative-sampling note: the engine masks only the *target*
//! distribution p. The draft distribution q must stay the true proposal
//! the drafts were sampled from; rejection sampling then yields exactly
//! the truncated target regardless of q's support (a draft token outside
//! the nucleus has p = 0, so τ = 0 and it is rejected).

use std::cmp::Ordering;

/// Mask value for excluded logits. Large enough that `exp(x - max)` is
/// exactly 0 in f32 and the sigmoid rescale stays finite, but far from
/// f32 overflow even after temperature scaling.
pub const MASKED_LOGIT: f32 = -1.0e30;

/// In-place top-k / top-p truncation of one logit row.
///
/// `top_k == 0` and `top_p >= 1.0` disable the respective criterion.
/// Top-k applies first; top-p then keeps the smallest prefix of the
/// (renormalised) survivors whose cumulative probability reaches `top_p`
/// — the HF-transformers composition. The most probable token always
/// survives.
pub fn mask_logits_top_k_top_p(row: &mut [f32], top_k: usize, top_p: f32) {
    let v = row.len();
    if v == 0 {
        return;
    }
    let k_active = top_k > 0 && top_k < v;
    let p_active = top_p < 1.0;
    if !k_active && !p_active {
        return;
    }

    let mut idx: Vec<u32> = (0..v as u32).collect();
    idx.sort_by(|&a, &b| {
        row[b as usize]
            .partial_cmp(&row[a as usize])
            .unwrap_or(Ordering::Equal)
    });

    let mut keep = if k_active { top_k } else { v };
    if p_active {
        let max = row[idx[0] as usize];
        let exps: Vec<f32> = idx[..keep]
            .iter()
            .map(|&i| (row[i as usize] - max).exp())
            .collect();
        let total: f32 = exps.iter().sum();
        let target = top_p * total;
        let mut cum = 0.0f32;
        let mut n = 0usize;
        for e in &exps {
            cum += e;
            n += 1;
            if cum >= target {
                break;
            }
        }
        keep = n.max(1);
    }
    for &i in &idx[keep..] {
        row[i as usize] = MASKED_LOGIT;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::softmax_rows;

    fn survivors(row: &[f32]) -> Vec<usize> {
        row.iter()
            .enumerate()
            .filter(|(_, &x)| x > MASKED_LOGIT)
            .map(|(i, _)| i)
            .collect()
    }

    #[test]
    fn disabled_filters_leave_row_untouched() {
        let orig = vec![1.0f32, -2.0, 0.5, 3.0];
        let mut row = orig.clone();
        mask_logits_top_k_top_p(&mut row, 0, 1.0);
        assert_eq!(row, orig);
        // top_k >= v is also a no-op
        mask_logits_top_k_top_p(&mut row, 4, 1.0);
        assert_eq!(row, orig);
    }

    #[test]
    fn top_k_keeps_k_largest() {
        let mut row = vec![0.1f32, 2.0, -1.0, 1.5, 0.9];
        mask_logits_top_k_top_p(&mut row, 2, 1.0);
        assert_eq!(survivors(&row), vec![1, 3]);
    }

    #[test]
    fn top_k_one_keeps_argmax_only() {
        let mut row = vec![-3.0f32, 7.0, 0.0, 6.9];
        mask_logits_top_k_top_p(&mut row, 1, 1.0);
        assert_eq!(survivors(&row), vec![1]);
    }

    #[test]
    fn top_p_keeps_nucleus() {
        // probs 0.7, 0.2, 0.1 (ln is monotone so use ln-probs as logits)
        let mut row = vec![0.7f32.ln(), 0.2f32.ln(), 0.1f32.ln()];
        mask_logits_top_k_top_p(&mut row, 0, 0.75);
        assert_eq!(survivors(&row), vec![0, 1]);
        let mut row = vec![0.7f32.ln(), 0.2f32.ln(), 0.1f32.ln()];
        mask_logits_top_k_top_p(&mut row, 0, 0.65);
        assert_eq!(survivors(&row), vec![0]);
    }

    #[test]
    fn argmax_always_survives_even_for_tiny_top_p() {
        let mut row = vec![0.0f32, 5.0, 1.0];
        mask_logits_top_k_top_p(&mut row, 0, 1e-6);
        assert_eq!(survivors(&row), vec![1]);
    }

    #[test]
    fn masked_row_softmax_renormalises_over_survivors() {
        let mut row = vec![1.0f32, 0.5, 0.0, -0.5];
        mask_logits_top_k_top_p(&mut row, 2, 1.0);
        softmax_rows(&mut row, 4);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert_eq!(row[2], 0.0);
        assert_eq!(row[3], 0.0);
        assert!(row[0] > row[1] && row[1] > 0.0);
    }

    #[test]
    fn prop_filter_invariants() {
        use crate::util::proptest::{forall, Config};
        forall(
            "filter invariants",
            Config {
                cases: 100,
                ..Config::default()
            },
            |rng, size| {
                let v = 4 + size;
                let mut row: Vec<f32> =
                    (0..v).map(|_| rng.gaussian() as f32 * 3.0).collect();
                let orig = row.clone();
                let top_k = rng.below(v as u32 + 2) as usize;
                let top_p = 0.05 + 0.95 * rng.uniform_f32();
                mask_logits_top_k_top_p(&mut row, top_k, top_p);
                let kept = survivors(&row);
                if kept.is_empty() {
                    return Err("no survivors".into());
                }
                if top_k > 0 && kept.len() > top_k {
                    return Err(format!("{} survivors > top_k {top_k}", kept.len()));
                }
                // survivors keep their original logits and dominate the
                // masked entries
                let min_kept = kept
                    .iter()
                    .map(|&i| orig[i])
                    .fold(f32::INFINITY, f32::min);
                for i in 0..v {
                    if kept.contains(&i) {
                        if row[i] != orig[i] {
                            return Err("survivor logit changed".into());
                        }
                    } else if orig[i] > min_kept {
                        return Err("masked a logit above a survivor".into());
                    }
                }
                Ok(())
            },
        );
    }
}
