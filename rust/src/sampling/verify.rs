//! Speculative verification semantics in pure rust (f32).
//!
//! Mirrors `python/compile/verify_graph.py` operation-for-operation so the
//! outputs are comparable with the AOT executables: stable softmax, the
//! guarded tau division, residual resampling via unnormalised inverse CDF,
//! and the bonus draw on all-accept.
//!
//! This module is the **scalar reference**: self-contained, sequential,
//! allocation-happy, optimised for auditability. The serving hot path
//! runs the segment-parallel, zero-alloc implementation in
//! [`crate::sampling::kernels`], which reuses the per-row primitives
//! below and is bit-identical to this oracle for every thread count and
//! chunk size (row reductions here — softmax sums *and* the inverse-CDF
//! totals/prefixes — are already expressed as fixed-order folds over
//! [`VOCAB_CHUNK`] blocks, the same reduction graph the parallel
//! kernels execute).
//!
//! ## Worked example
//!
//! One verification step, by hand: the draft proposes token 1 twice,
//! the target agrees, so both drafts are accepted and a bonus token is
//! drawn from the target's extra row.
//!
//! ```
//! use specd::sampling::verify::{spec_step, Method};
//!
//! let v = 4;
//! // draft logits (γ=2 rows): token 1 is strongly preferred
//! let z_q = vec![
//!     -4.0, 4.0, -4.0, -4.0,
//!     -4.0, 4.0, -4.0, -4.0,
//! ];
//! // target logits (γ+1 rows): agrees with the draft; the bonus row
//! // (row γ) puts everything on token 2
//! let z_p = vec![
//!     -4.0, 4.0, -4.0, -4.0,
//!     -4.0, 4.0, -4.0, -4.0,
//!     -9.0, -9.0, 9.0, -9.0,
//! ];
//! let out = spec_step(
//!     &z_p, &z_q, v,
//!     &[1, 1],      // the two drafted tokens
//!     &[0.9, 0.9],  // acceptance uniforms (τ ≈ 1, so both accept)
//!     0.5, 0.5,     // resample/bonus uniforms
//!     Method::Exact, None,
//! );
//! assert_eq!(out.accept_len, 2);
//! assert_eq!(out.tokens, vec![1, 1, 2]); // drafts + the bonus draw
//! ```

use crate::util::timer::Profiler;

/// Fixed vocab-chunk size (elements) for row reductions — softmax row
/// sums *and* the inverse-CDF totals/prefixes. Both the scalar reference
/// and the parallel kernels fold per-chunk partials in chunk order, so
/// partitioning work across threads cannot reassociate the sums. For
/// `v <= VOCAB_CHUNK` (every model vocab in the artifact set) this
/// degenerates to the plain sequential sum.
pub const VOCAB_CHUNK: usize = 4096;

/// Verification method (§3.2). `Baseline` and `Exact` are semantically
/// identical here (the distinction is kernel structure, which only exists
/// on the accelerator); both are provided so profiling scopes match the
/// HLO backends one-to-one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Baseline,
    Exact,
    /// Element-wise sigmoid approximation with scaling constants (α, β).
    Sigmoid { alpha_milli: i64, beta_milli: i64 },
    /// Sigmoid approximation with the (z−α)/(β−α) rescale performed in
    /// fp16 — the paper's actual numeric regime for Whisper, which
    /// overflows (→ NaN → reject-everything) at |α| = 1e5 (Table 2).
    Sigmoid16 { alpha_milli: i64, beta_milli: i64 },
}

/// Round α/β to integer milli-units, to nearest (f32 carries ~7
/// significant digits, so `1.234 * 1000.0` lands at `1233.9999…`;
/// truncation would collapse it to 1233 and `alpha_beta()` would not
/// round-trip).
fn to_milli(x: f32) -> i64 {
    (x * 1000.0).round() as i64
}

impl Method {
    pub fn sigmoid(alpha: f32, beta: f32) -> Self {
        Method::Sigmoid {
            alpha_milli: to_milli(alpha),
            beta_milli: to_milli(beta),
        }
    }

    pub fn sigmoid16(alpha: f32, beta: f32) -> Self {
        Method::Sigmoid16 {
            alpha_milli: to_milli(alpha),
            beta_milli: to_milli(beta),
        }
    }

    pub fn alpha_beta(&self) -> Option<(f32, f32)> {
        match self {
            Method::Sigmoid {
                alpha_milli,
                beta_milli,
            }
            | Method::Sigmoid16 {
                alpha_milli,
                beta_milli,
            } => Some((*alpha_milli as f32 / 1000.0, *beta_milli as f32 / 1000.0)),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::Exact => "exact",
            Method::Sigmoid { .. } => "sigmoid",
            Method::Sigmoid16 { .. } => "sigmoid16",
        }
    }
}

// ---------------------------------------------------------------------------
// fp16 emulation (no half type in the vendored crate set)

/// Round an f32 to the nearest IEEE binary16 and back (round-to-nearest-
/// even, overflow to ±inf) — enough to emulate the paper's fp16 rescale.
pub fn f16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let sign = bits >> 31;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7f_ffff;
    if exp == 0xff {
        // inf / nan pass through
        return x;
    }
    let e16 = exp - 127 + 15;
    if e16 >= 0x1f {
        // overflow -> ±inf
        return f32::from_bits((sign << 31) | 0x7f80_0000);
    }
    if e16 <= 0 {
        // subnormal-or-zero in f16; flush tiny values through a scaled
        // round (adequate here: logits scaled by 1e-3..1e-5 stay normal)
        if e16 < -10 {
            return if sign == 1 { -0.0 } else { 0.0 };
        }
        let shift = (14 - e16) as u32; // bits to drop from the 24-bit sig
        let sig = frac | 0x80_0000;
        let rounded = round_even(sig, shift);
        let val = rounded as f32 * (0.5f32).powi(24 - shift as i32 - 1 + 15 + 10);
        return if sign == 1 { -val } else { val };
    }
    // normal: keep 10 fraction bits of the 23
    let rounded = round_even(frac, 13);
    let (frac16, e16) = if rounded >= 1 << 10 {
        (0u32, e16 + 1)
    } else {
        (rounded, e16)
    };
    if e16 >= 0x1f {
        return f32::from_bits((sign << 31) | 0x7f80_0000);
    }
    let exp32 = (e16 - 15 + 127) as u32;
    f32::from_bits((sign << 31) | (exp32 << 23) | (frac16 << 13))
}

fn round_even(sig: u32, shift: u32) -> u32 {
    let kept = sig >> shift;
    let rem = sig & ((1 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && kept & 1 == 1) {
        kept + 1
    } else {
        kept
    }
}

/// Result of verifying one batch row.
#[derive(Debug, Clone, PartialEq)]
pub struct StepOutput {
    /// number of draft tokens accepted (leading run)
    pub accept_len: usize,
    /// emitted tokens: accepted drafts + one resampled/bonus token; always
    /// `accept_len + 1` entries.
    pub tokens: Vec<i32>,
}

/// Numerically-stable softmax over each row of a (rows, v) matrix, in
/// place. Row sums fold per-[`VOCAB_CHUNK`] partials in fixed chunk
/// order (see the module docs), which is what lets the segment-parallel
/// kernels stay bit-identical to this reference.
pub fn softmax_rows(x: &mut [f32], v: usize) {
    debug_assert_eq!(x.len() % v, 0);
    for row in x.chunks_mut(v) {
        softmax_row(row);
    }
}

/// One softmax row with the fixed-order chunked reduction (shared by the
/// scalar reference and every parallel schedule).
pub(crate) fn softmax_row(row: &mut [f32]) {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for blk in row.chunks_mut(VOCAB_CHUNK) {
        let mut part = 0.0f32;
        for e in blk.iter_mut() {
            *e = (*e - max).exp();
            part += *e;
        }
        sum += part;
    }
    let inv = 1.0 / sum;
    for e in row.iter_mut() {
        *e *= inv;
    }
}

/// `dst = softmax(src)` for one row — the out-of-place twin of
/// [`softmax_row`] used by the kernel layer (identical arithmetic graph,
/// so the result is bit-identical).
pub(crate) fn softmax_row_from(src: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    let max = src.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for (sb, db) in src.chunks(VOCAB_CHUNK).zip(dst.chunks_mut(VOCAB_CHUNK)) {
        let mut part = 0.0f32;
        for (d, &s) in db.iter_mut().zip(sb) {
            *d = (s - max).exp();
            part += *d;
        }
        sum += part;
    }
    let inv = 1.0 / sum;
    for e in dst.iter_mut() {
        *e *= inv;
    }
}

/// Element-wise sigmoid approximation of softmax (Eq. 5), in place.
pub fn sigmoid_approx(x: &mut [f32], alpha: f32, beta: f32) {
    let inv = 1.0 / (beta - alpha);
    for e in x.iter_mut() {
        let z = (*e - alpha) * inv;
        *e = 1.0 / (1.0 + (-z).exp());
    }
}

/// `dst = sigmoid_approx(src)` — out-of-place element-wise twin for the
/// kernel layer.
pub(crate) fn sigmoid_row_from(src: &[f32], dst: &mut [f32], alpha: f32, beta: f32) {
    debug_assert_eq!(src.len(), dst.len());
    let inv = 1.0 / (beta - alpha);
    for (d, &s) in dst.iter_mut().zip(src) {
        let z = (s - alpha) * inv;
        *d = 1.0 / (1.0 + (-z).exp());
    }
}

/// Eq. 5 with the rescale computed in (emulated) fp16: (z−α)/(β−α) with
/// every intermediate rounded to binary16, then σ in f32. Overflows to
/// inf/inf = NaN at |α| ≳ 65504, matching the paper's fp16 pipeline.
pub fn sigmoid_approx_fp16(x: &mut [f32], alpha: f32, beta: f32) {
    let a16 = f16_round(alpha);
    let denom = f16_round(f16_round(beta) - a16);
    for e in x.iter_mut() {
        let z = f16_round(f16_round(f16_round(*e) - a16) / denom);
        *e = 1.0 / (1.0 + (-z).exp());
    }
}

/// `dst = sigmoid_approx_fp16(src)` — out-of-place element-wise twin for
/// the kernel layer.
pub(crate) fn sigmoid16_row_from(src: &[f32], dst: &mut [f32], alpha: f32, beta: f32) {
    debug_assert_eq!(src.len(), dst.len());
    let a16 = f16_round(alpha);
    let denom = f16_round(f16_round(beta) - a16);
    for (d, &s) in dst.iter_mut().zip(src) {
        let z = f16_round(f16_round(f16_round(s) - a16) / denom);
        *d = 1.0 / (1.0 + (-z).exp());
    }
}

/// Draw from an unnormalised non-negative weight vector by inverse CDF
/// (threshold `u * total`; zero-mass rows fall back to first-occurrence
/// argmax, matching `jnp.argmax` in the AOT graphs).
///
/// Like the softmax row sums, the reduction graph is **blocked**: the
/// total is a fixed-order fold of per-[`VOCAB_CHUNK`] partial sums, the
/// winning block is located by walking that same prefix fold, and only
/// the winning block is scanned element-wise (its running CDF seeded
/// with the block's prefix). For `v <= VOCAB_CHUNK` — every model vocab
/// in the artifact set — this degenerates bit-for-bit to the plain
/// sequential scan. The blocked graph is what lets the kernel layer
/// compute the partials chunk-parallel
/// ([`crate::sampling::kernels`]'s `inverse_cdf_sample_blocked`) while
/// staying bit-identical to this scalar reference.
///
/// Rounding guard: the block lookup tests `prefix + partial > thresh`
/// while the in-block scan accumulates element-wise from `prefix`, and
/// the two can disagree in the last ulp. When the scan of the selected
/// block falls through, the block's final element is returned — that
/// rule is part of the reference semantics, so every parallel schedule
/// reproduces it exactly.
// `!(total > 0)` below also catches NaN totals (fp16-overflow
// residuals), matching the jnp graph's `where(total > 0, tok, argmax)` —
// a rewrite to `total <= 0.0` would drop the NaN arm.
#[allow(clippy::neg_cmp_op_on_partial_ord)]
pub fn inverse_cdf_sample(weights: &[f32], u: f32) -> usize {
    if weights.len() <= VOCAB_CHUNK {
        // single block: the blocked graph degenerates to the plain
        // one-pass scan bit-for-bit (a sequential sum IS the lone block
        // partial, and the in-block scan starts from prefix 0.0), so
        // take the cheap path — this is the hot slot-parallel case,
        // every artifact vocab fits in one block
        let total: f32 = weights.iter().sum();
        if !(total > 0.0) {
            return argmax_first(weights);
        }
        let thresh = u * total;
        let mut cdf = 0.0f32;
        for (i, w) in weights.iter().enumerate() {
            cdf += w;
            if cdf > thresh {
                return i;
            }
        }
        return weights.len() - 1;
    }
    // multi-block: per-block partials (each a sequential sum of its own
    // block, the arithmetic every parallel schedule reproduces), then
    // the shared fold/lookup/scan stages
    let parts: Vec<f32> = weights
        .chunks(VOCAB_CHUNK)
        .map(|blk| {
            let mut part = 0.0f32;
            for &w in blk {
                part += w;
            }
            part
        })
        .collect();
    inverse_cdf_from_partials(weights, &parts, u)
}

/// Stages 2–3 of the blocked inverse-CDF reduction graph, shared
/// verbatim by the scalar multi-block arm of [`inverse_cdf_sample`] and
/// the chunk-parallel kernel twin (which computes `parts` on the worker
/// pool): a fixed-order fold of the per-[`VOCAB_CHUNK`] partials into
/// the total, a walk of the same prefix fold to locate the winning
/// block, and an element-wise scan of that one block seeded with its
/// prefix — including the fall-through-to-block-end rounding guard.
/// Keeping this in one place is what keeps the two paths bit-identical
/// by construction.
// `!(total > 0)` also catches NaN totals (fp16-overflow residuals).
#[allow(clippy::neg_cmp_op_on_partial_ord)]
pub(crate) fn inverse_cdf_from_partials(weights: &[f32], parts: &[f32], u: f32) -> usize {
    let v = weights.len();
    let mut total = 0.0f32;
    for &part in parts {
        total += part;
    }
    if !(total > 0.0) {
        return argmax_first(weights);
    }
    let thresh = u * total;
    let mut prefix = 0.0f32;
    for (bi, &part) in parts.iter().enumerate() {
        if prefix + part > thresh {
            let off = bi * VOCAB_CHUNK;
            let blk = &weights[off..(off + VOCAB_CHUNK).min(v)];
            let mut cdf = prefix;
            for (i, &w) in blk.iter().enumerate() {
                cdf += w;
                if cdf > thresh {
                    return off + i;
                }
            }
            return off + blk.len() - 1;
        }
        prefix += part;
    }
    v - 1
}

/// First-occurrence argmax (the zero/NaN-mass fallback arm of
/// [`inverse_cdf_sample`], matching `jnp.argmax` in the AOT graphs).
pub(crate) fn argmax_first(weights: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, w) in weights.iter().enumerate().skip(1) {
        if *w > weights[best] {
            best = i;
        }
    }
    best
}

/// Acceptance ratio τ(x) = min(1, p/q) with the q==0 guard (Eq. 1).
#[inline]
pub fn tau(p: f32, q: f32) -> f32 {
    if q > 0.0 {
        (p / q).min(1.0)
    } else {
        1.0
    }
}

/// One acceptance decision: accept draft position `c` iff `u <= τ`.
/// `Sigmoid16` uses the unguarded NaN-propagating ratio (rust's
/// `f32::min` would swallow the NaN): accept iff `u <= r || r >= 1` — a
/// NaN ratio (fp16 overflow) fails both comparisons and REJECTS, the
/// semantics the paper's torch pipeline exhibits at ±1e5 scaling.
#[inline]
pub(crate) fn accept_decision(p: f32, q: f32, u: f32, method: Method) -> bool {
    if matches!(method, Method::Sigmoid16 { .. }) {
        let r = p / q;
        u <= r || r >= 1.0
    } else {
        u <= tau(p, q)
    }
}

/// One full speculative verification step for a single sequence.
///
/// * `z_p`: target logits, `(gamma + 1) * v` row-major (row γ = bonus row)
/// * `z_q`: draft logits, `gamma * v`
/// * `draft`: the γ drafted token ids
/// * `u_acc`: γ acceptance uniforms; `u_res`, `u_bonus`: resample/bonus
///
/// An optional profiler receives the same scope names as the HLO backends
/// (`verify/softmax`, `verify/kernel`, `verify/finish`) so Δ%-profiling
/// comparisons are apples-to-apples.
#[allow(clippy::too_many_arguments)]
pub fn spec_step(
    z_p: &[f32],
    z_q: &[f32],
    v: usize,
    draft: &[i32],
    u_acc: &[f32],
    u_res: f32,
    u_bonus: f32,
    method: Method,
    profiler: Option<&Profiler>,
) -> StepOutput {
    let gamma = draft.len();
    debug_assert_eq!(z_p.len(), (gamma + 1) * v);
    debug_assert_eq!(z_q.len(), gamma * v);
    debug_assert_eq!(u_acc.len(), gamma);

    // --- probability construction ("softmax" scope; sigmoid replaces it)
    let mut p = z_p.to_vec();
    let mut q = z_q.to_vec();
    {
        let _g = profiler.map(|pr| pr.scope("verify/softmax"));
        match method {
            Method::Baseline | Method::Exact => {
                softmax_rows(&mut p, v);
                softmax_rows(&mut q, v);
            }
            Method::Sigmoid { .. } => {
                let (alpha, beta) = method.alpha_beta().unwrap();
                sigmoid_approx(&mut p, alpha, beta);
                sigmoid_approx(&mut q, alpha, beta);
            }
            Method::Sigmoid16 { .. } => {
                let (alpha, beta) = method.alpha_beta().unwrap();
                sigmoid_approx_fp16(&mut p, alpha, beta);
                sigmoid_approx_fp16(&mut q, alpha, beta);
            }
        }
    }

    // --- acceptance loop (the "kernel" work: tau at drafted tokens).
    // Accept iff u <= tau, exactly as the AOT graphs compute it; see
    // [`accept_decision`] for the Sigmoid16 NaN-rejection semantics.
    let mut accept_len = gamma;
    {
        let _g = profiler.map(|pr| pr.scope("verify/kernel"));
        for c in 0..gamma {
            let x = draft[c] as usize;
            if !accept_decision(p[c * v + x], q[c * v + x], u_acc[c], method) {
                accept_len = c;
                break;
            }
        }
    }

    // --- resample / bonus ("finish" scope)
    let _g = profiler.map(|pr| pr.scope("verify/finish"));
    let mut tokens: Vec<i32> = draft[..accept_len].to_vec();
    if accept_len == gamma {
        let bonus_row = &p[gamma * v..(gamma + 1) * v];
        tokens.push(inverse_cdf_sample(bonus_row, u_bonus) as i32);
    } else {
        let c = accept_len;
        let residual: Vec<f32> = (0..v)
            .map(|x| (p[c * v + x] - q[c * v + x]).max(0.0))
            .collect();
        tokens.push(inverse_cdf_sample(&residual, u_res) as i32);
    }
    StepOutput { accept_len, tokens }
}

/// Batched wrapper with the same layout as the HLO verify artifacts:
/// returns `(accept_len, out_tokens)` where `out_tokens` is
/// `(gamma + 1)` per row, `-1`-padded. `methods` carries one
/// verification method per row (per-slot overrides in a heterogeneous
/// batch); pass `&[m; b]` for the homogeneous case.
///
/// This is the sequential scalar oracle; the serving engine runs the
/// slot-parallel, zero-alloc equivalent
/// [`crate::sampling::kernels::spec_step_batch_ws`], which is asserted
/// bit-identical to this function by the kernel parity property tests.
#[allow(clippy::too_many_arguments)]
pub fn spec_step_batch(
    z_p: &[f32],
    z_q: &[f32],
    b: usize,
    gamma: usize,
    v: usize,
    draft: &[i32],
    u_acc: &[f32],
    u_res: &[f32],
    u_bonus: &[f32],
    methods: &[Method],
    profiler: Option<&Profiler>,
) -> (Vec<i32>, Vec<i32>) {
    debug_assert_eq!(methods.len(), b);
    let mut accept = vec![0i32; b];
    let mut out = vec![-1i32; b * (gamma + 1)];
    for row in 0..b {
        let o = spec_step(
            &z_p[row * (gamma + 1) * v..(row + 1) * (gamma + 1) * v],
            &z_q[row * gamma * v..(row + 1) * gamma * v],
            v,
            &draft[row * gamma..(row + 1) * gamma],
            &u_acc[row * gamma..(row + 1) * gamma],
            u_res[row],
            u_bonus[row],
            methods[row],
            profiler,
        );
        accept[row] = o.accept_len as i32;
        out[row * (gamma + 1)..row * (gamma + 1) + o.tokens.len()]
            .copy_from_slice(&o.tokens);
    }
    (accept, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, Config};
    use crate::util::rng::Pcg32;

    fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
    }

    #[test]
    fn softmax_rows_normalises() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.iter().all(|&p| p > 0.0));
        }
        // monotone in logits
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_is_shift_invariant_and_stable() {
        let mut a = vec![1000.0, 1001.0, 999.0];
        let mut b = vec![0.0, 1.0, -1.0];
        softmax_rows(&mut a, 3);
        softmax_rows(&mut b, 3);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
            assert!(x.is_finite());
        }
    }

    #[test]
    fn tau_guards_zero_q() {
        assert_eq!(tau(0.5, 0.0), 1.0);
        assert_eq!(tau(0.0, 0.0), 1.0);
        assert_eq!(tau(0.2, 0.4), 0.5);
        assert_eq!(tau(0.4, 0.2), 1.0);
    }

    #[test]
    fn inverse_cdf_known_thresholds() {
        let w = [0.1, 0.2, 0.7];
        assert_eq!(inverse_cdf_sample(&w, 0.05), 0);
        assert_eq!(inverse_cdf_sample(&w, 0.15), 1);
        assert_eq!(inverse_cdf_sample(&w, 0.95), 2);
        assert_eq!(inverse_cdf_sample(&[0.0, 0.0, 1.0], 0.0), 2);
        assert_eq!(inverse_cdf_sample(&[0.0; 4], 0.5), 0); // zero mass -> argmax
    }

    #[test]
    fn inverse_cdf_blocked_degenerates_to_sequential_for_small_v() {
        // for v <= VOCAB_CHUNK the blocked graph must reproduce the plain
        // sequential scan bit-for-bit (one block, prefix 0.0)
        let mut rng = Pcg32::seeded(31);
        for _ in 0..50 {
            let v = 1 + rng.below(VOCAB_CHUNK as u32) as usize;
            let w: Vec<f32> = (0..v).map(|_| rng.uniform_f32()).collect();
            let u = rng.uniform_f32();
            let total: f32 = w.iter().sum();
            let thresh = u * total;
            let mut cdf = 0.0f32;
            let mut expect = v - 1;
            for (i, &x) in w.iter().enumerate() {
                cdf += x;
                if cdf > thresh {
                    expect = i;
                    break;
                }
            }
            assert_eq!(inverse_cdf_sample(&w, u), expect, "v={v} u={u}");
        }
    }

    #[test]
    fn inverse_cdf_multi_block_thresholds() {
        // 2 full blocks + a ragged tail of uniform mass: sums of small
        // integers are exact in f32, so indices are analytic
        let v = 2 * VOCAB_CHUNK + 5;
        let w = vec![1.0f32; v];
        assert_eq!(inverse_cdf_sample(&w, 0.0), 0);
        // thresh = 0.5 * v = 4098.5 -> first index with cdf 4099
        assert_eq!(inverse_cdf_sample(&w, 0.5), v / 2);
        // mass concentrated in the last block
        let mut w = vec![0.0f32; v];
        w[2 * VOCAB_CHUNK + 3] = 2.0;
        assert_eq!(inverse_cdf_sample(&w, 0.9), 2 * VOCAB_CHUNK + 3);
        // zero mass across multiple blocks -> first-occurrence argmax
        let mut w = vec![0.0f32; v];
        w[VOCAB_CHUNK + 17] = f32::NAN; // NaN total also takes the argmax arm
        assert_eq!(inverse_cdf_sample(&w, 0.5), 0);
    }

    #[test]
    fn identical_p_q_accepts_all_and_emits_bonus() {
        let v = 16;
        let mut rng = Pcg32::seeded(0);
        let z_q = randn(&mut rng, 3 * v, 2.0);
        let mut z_p = z_q.clone();
        z_p.extend(randn(&mut rng, v, 2.0)); // bonus row
        let out = spec_step(
            &z_p, &z_q, v, &[1, 2, 3], &[0.99, 0.99, 0.99], 0.5, 0.5,
            Method::Exact, None,
        );
        assert_eq!(out.accept_len, 3);
        assert_eq!(out.tokens.len(), 4);
        assert_eq!(&out.tokens[..3], &[1, 2, 3]);
    }

    #[test]
    fn certain_rejection_resamples_from_residual() {
        // q loves token 0, p loves token 1 -> reject, residual argmax = 1
        let v = 8;
        let mut z_q = vec![-10.0f32; v];
        z_q[0] = 10.0;
        let mut z_p = vec![-10.0f32; 2 * v];
        z_p[1] = 10.0;
        z_p[v + 1] = 10.0;
        let out = spec_step(
            &z_p, &z_q, v, &[0], &[0.9], 0.5, 0.5, Method::Baseline, None,
        );
        assert_eq!(out.accept_len, 0);
        assert_eq!(out.tokens, vec![1]);
    }

    #[test]
    fn sigmoid_extreme_scaling_accepts_everything() {
        let v = 32;
        let mut rng = Pcg32::seeded(1);
        let z_p = randn(&mut rng, 3 * v, 5.0);
        let z_q = randn(&mut rng, 2 * v, 5.0);
        let out = spec_step(
            &z_p, &z_q, v, &[3, 4], &[0.999, 0.999], 0.1, 0.1,
            Method::sigmoid(-1e5, 1e5), None,
        );
        assert_eq!(out.accept_len, 2); // the Table 2 ±1e5 collapse
    }

    #[test]
    fn baseline_and_exact_agree_everywhere() {
        forall("baseline==exact", Config { cases: 40, ..Config::default() }, |rng, size| {
            let v = 4 + size;
            let gamma = 1 + (size % 5);
            let z_p = randn(rng, (gamma + 1) * v, 3.0);
            let z_q = randn(rng, gamma * v, 3.0);
            let draft: Vec<i32> = (0..gamma).map(|_| rng.below(v as u32) as i32).collect();
            let u_acc: Vec<f32> = (0..gamma).map(|_| rng.uniform_f32()).collect();
            let (ur, ub) = (rng.uniform_f32(), rng.uniform_f32());
            let a = spec_step(&z_p, &z_q, v, &draft, &u_acc, ur, ub, Method::Baseline, None);
            let e = spec_step(&z_p, &z_q, v, &draft, &u_acc, ur, ub, Method::Exact, None);
            if a != e {
                return Err(format!("{a:?} != {e:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn emitted_token_count_is_accept_len_plus_one() {
        forall("emit count", Config { cases: 60, ..Config::default() }, |rng, size| {
            let v = 4 + size;
            let gamma = 1 + (size % 7);
            let z_p = randn(rng, (gamma + 1) * v, 4.0);
            let z_q = randn(rng, gamma * v, 4.0);
            let draft: Vec<i32> = (0..gamma).map(|_| rng.below(v as u32) as i32).collect();
            let u_acc: Vec<f32> = (0..gamma).map(|_| rng.uniform_f32()).collect();
            let o = spec_step(&z_p, &z_q, v, &draft, &u_acc,
                              rng.uniform_f32(), rng.uniform_f32(),
                              Method::Baseline, None);
            if o.tokens.len() != o.accept_len + 1 {
                return Err(format!("{} tokens for accept_len {}", o.tokens.len(), o.accept_len));
            }
            if o.accept_len > gamma {
                return Err("accept_len beyond gamma".into());
            }
            if o.tokens.iter().any(|&t| t < 0 || t as usize >= v) {
                return Err(format!("token out of range: {:?}", o.tokens));
            }
            Ok(())
        });
    }

    #[test]
    fn batch_wrapper_matches_single_rows() {
        // heterogeneous per-row methods: each row must follow its own
        let (b, gamma, v) = (3, 4, 24);
        let methods = [Method::Exact, Method::sigmoid(-1e3, 1e3), Method::Baseline];
        let mut rng = Pcg32::seeded(9);
        let z_p = randn(&mut rng, b * (gamma + 1) * v, 3.0);
        let z_q = randn(&mut rng, b * gamma * v, 3.0);
        let draft: Vec<i32> = (0..b * gamma).map(|_| rng.below(v as u32) as i32).collect();
        let u_acc: Vec<f32> = (0..b * gamma).map(|_| rng.uniform_f32()).collect();
        let u_res: Vec<f32> = (0..b).map(|_| rng.uniform_f32()).collect();
        let u_bonus: Vec<f32> = (0..b).map(|_| rng.uniform_f32()).collect();
        let (alen, out) = spec_step_batch(
            &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus,
            &methods, None,
        );
        for row in 0..b {
            let o = spec_step(
                &z_p[row * (gamma + 1) * v..(row + 1) * (gamma + 1) * v],
                &z_q[row * gamma * v..(row + 1) * gamma * v],
                v,
                &draft[row * gamma..(row + 1) * gamma],
                &u_acc[row * gamma..(row + 1) * gamma],
                u_res[row],
                u_bonus[row],
                methods[row],
                None,
            );
            assert_eq!(alen[row] as usize, o.accept_len);
            let got = &out[row * (gamma + 1)..row * (gamma + 1) + o.tokens.len()];
            assert_eq!(got, o.tokens.as_slice());
            // padding beyond emitted tokens
            assert!(out[row * (gamma + 1) + o.tokens.len()..(row + 1) * (gamma + 1)]
                .iter()
                .all(|&t| t == -1));
        }
    }

    #[test]
    fn sigmoid_constructor_rounds_to_nearest_milli() {
        // f32 representation error must not truncate 1.234 to 1.233
        for milli in [-100_000i64, -1999, -3, 0, 3, 500, 1234, 99_999] {
            let a = milli as f32 / 1000.0;
            let m = Method::sigmoid(a, a + 10.0);
            let (ra, _) = m.alpha_beta().unwrap();
            assert_eq!(ra, a, "alpha {a} did not round-trip");
            let m16 = Method::sigmoid16(a, a + 10.0);
            assert_eq!(m16.alpha_beta().unwrap().0, a);
        }
        // .9995 sits on the milli boundary: round to nearest, not toward 0
        let m = Method::sigmoid(-0.9999, 0.9999);
        assert_eq!(m.alpha_beta(), Some((-1.0, 1.0)));
    }

    #[test]
    fn softmax_chunked_reduction_matches_plain_sum_for_small_v() {
        // for v <= VOCAB_CHUNK the chunked fold degenerates to the plain
        // sequential sum bit-for-bit
        let mut rng = Pcg32::seeded(21);
        let v = 97;
        let mut chunked = randn(&mut rng, 3 * v, 4.0);
        let mut plain = chunked.clone();
        softmax_rows(&mut chunked, v);
        for row in plain.chunks_mut(v) {
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for e in row.iter_mut() {
                *e = (*e - max).exp();
                sum += *e;
            }
            let inv = 1.0 / sum;
            for e in row.iter_mut() {
                *e *= inv;
            }
        }
        assert_eq!(chunked, plain);
    }

    #[test]
    fn out_of_place_rows_match_in_place() {
        let mut rng = Pcg32::seeded(22);
        let v = 64;
        let src = randn(&mut rng, v, 3.0);
        for (a, b) in [(-1e3f32, 1e3f32), (-1e5, 1e5)] {
            let mut inplace = src.clone();
            let mut out = vec![0.0f32; v];
            softmax_row(&mut inplace);
            softmax_row_from(&src, &mut out);
            assert_eq!(inplace, out);

            let mut inplace = src.clone();
            sigmoid_approx(&mut inplace, a, b);
            sigmoid_row_from(&src, &mut out, a, b);
            assert_eq!(inplace, out);

            let mut inplace = src.clone();
            sigmoid_approx_fp16(&mut inplace, a, b);
            sigmoid16_row_from(&src, &mut out, a, b);
            assert_eq!(inplace, out);
        }
    }

    #[test]
    fn f16_round_reference_values() {
        // exactly representable values pass through
        for x in [0.0f32, 1.0, -2.5, 0.5, 65504.0] {
            assert_eq!(f16_round(x), x, "{x}");
        }
        // rounding to 10 fraction bits: 1 + 2^-11 is a 0.5-ulp tie and
        // rounds to even (1.0); 1 + 3·2^-11 is a 1.5-ulp tie and rounds
        // to the even neighbour 1 + 2·2^-10
        assert_eq!(f16_round(1.0 + 2f32.powi(-11)), 1.0);
        assert_eq!(f16_round(1.0 + 3.0 * 2f32.powi(-11)), 1.0 + 2f32.powi(-9));
        // just above the half-ulp tie rounds up
        assert_eq!(
            f16_round(1.0 + 2f32.powi(-11) + 2f32.powi(-13)),
            1.0 + 2f32.powi(-10)
        );
        // overflow -> inf (f16 max finite = 65504)
        assert_eq!(f16_round(65520.0), f32::INFINITY);
        assert_eq!(f16_round(1e5), f32::INFINITY);
        assert_eq!(f16_round(-1e5), f32::NEG_INFINITY);
        // inf/nan pass through
        assert!(f16_round(f32::NAN).is_nan());
        assert_eq!(f16_round(f32::INFINITY), f32::INFINITY);
    }

    #[test]
    fn f16_round_error_is_within_half_ulp() {
        let mut rng = Pcg32::seeded(5);
        for _ in 0..2000 {
            let x = (rng.gaussian() as f32) * 100.0;
            let r = f16_round(x);
            let ulp = 2f32.powi(x.abs().log2().floor() as i32 - 10);
            assert!((r - x).abs() <= ulp * 0.5 + 1e-12, "{x} -> {r}");
        }
    }

    #[test]
    fn sigmoid16_moderate_scale_close_to_f32() {
        let mut a = vec![3.0f32, -4.0, 0.25];
        let mut b = a.clone();
        sigmoid_approx(&mut a, -1e3, 1e3);
        sigmoid_approx_fp16(&mut b, -1e3, 1e3);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn sigmoid16_overflow_rejects_everything() {
        let v = 16;
        let mut rng = Pcg32::seeded(6);
        let z_p = randn(&mut rng, 3 * v, 5.0);
        let z_q = randn(&mut rng, 2 * v, 5.0);
        let out = spec_step(
            &z_p, &z_q, v, &[1, 2], &[0.1, 0.1], 0.5, 0.5,
            Method::sigmoid16(-1e5, 1e5), None,
        );
        // NaN tau fails every acceptance test: reject at position 0
        assert_eq!(out.accept_len, 0);
        assert_eq!(out.tokens.len(), 1);
        // while f32 sigmoid at the same scale accepts both drafts
        let out32 = spec_step(
            &z_p, &z_q, v, &[1, 2], &[0.1, 0.1], 0.5, 0.5,
            Method::sigmoid(-1e5, 1e5), None,
        );
        assert_eq!(out32.accept_len, 2);
    }

    #[test]
    fn acceptance_rate_increases_with_agreement() {
        // draft == target logits -> accept rate 1; independent logits -> lower
        let v = 64;
        let gamma = 5;
        let trials = 200;
        let mut rng = Pcg32::seeded(3);
        let mut acc_same = 0usize;
        let mut acc_indep = 0usize;
        for _ in 0..trials {
            let z_q = randn(&mut rng, gamma * v, 3.0);
            let mut z_p_same = z_q.clone();
            z_p_same.extend(randn(&mut rng, v, 3.0));
            let z_p_ind = randn(&mut rng, (gamma + 1) * v, 3.0);
            // draft sampled from q
            let mut draft = Vec::new();
            for c in 0..gamma {
                let mut row = z_q[c * v..(c + 1) * v].to_vec();
                softmax_rows(&mut row, v);
                draft.push(inverse_cdf_sample(&row, rng.uniform_f32()) as i32);
            }
            let u_acc: Vec<f32> = (0..gamma).map(|_| rng.uniform_f32()).collect();
            let o1 = spec_step(&z_p_same, &z_q, v, &draft, &u_acc, 0.5, 0.5,
                               Method::Exact, None);
            let o2 = spec_step(&z_p_ind, &z_q, v, &draft, &u_acc, 0.5, 0.5,
                               Method::Exact, None);
            acc_same += o1.accept_len;
            acc_indep += o2.accept_len;
        }
        assert_eq!(acc_same, trials * gamma);
        assert!(acc_indep < acc_same / 2, "{acc_indep} vs {acc_same}");
    }
}
