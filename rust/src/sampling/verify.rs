//! Speculative verification semantics in pure rust (f32).
//!
//! Mirrors `python/compile/verify_graph.py` operation-for-operation so the
//! outputs are comparable with the AOT executables: stable softmax, the
//! guarded tau division, residual resampling via unnormalised inverse CDF,
//! and the bonus draw on all-accept.
//!
//! This module is the **scalar reference**: self-contained, sequential,
//! allocation-happy, optimised for auditability. The serving hot path
//! runs the segment-parallel, zero-alloc implementation in
//! [`crate::sampling::kernels`], which reuses the per-row primitives
//! below and is bit-identical to this oracle for every thread count,
//! chunk size, and SIMD mode. Two levels of reduction structure make
//! that possible: row reductions — softmax sums *and* the inverse-CDF
//! totals/prefixes — are fixed-order folds over [`VOCAB_CHUNK`] blocks
//! (the graph the thread-parallel kernels execute), and *within* each
//! block the fold runs over [`LANE`] independent accumulators folded in
//! lane order (the graph an 8-wide vector unit executes). Exponentials
//! go through the shared polynomial [`exp_approx`] rather than libm, so
//! a vectorized twin can reproduce them operation-for-operation. See
//! `docs/ARCHITECTURE.md` ("the lane-width reduction contract").
//!
//! ## Worked example
//!
//! One verification step, by hand: the draft proposes token 1 twice,
//! the target agrees, so both drafts are accepted and a bonus token is
//! drawn from the target's extra row.
//!
//! ```
//! use specd::sampling::verify::{spec_step, Method};
//!
//! let v = 4;
//! // draft logits (γ=2 rows): token 1 is strongly preferred
//! let z_q = vec![
//!     -4.0, 4.0, -4.0, -4.0,
//!     -4.0, 4.0, -4.0, -4.0,
//! ];
//! // target logits (γ+1 rows): agrees with the draft; the bonus row
//! // (row γ) puts everything on token 2
//! let z_p = vec![
//!     -4.0, 4.0, -4.0, -4.0,
//!     -4.0, 4.0, -4.0, -4.0,
//!     -9.0, -9.0, 9.0, -9.0,
//! ];
//! let out = spec_step(
//!     &z_p, &z_q, v,
//!     &[1, 1],      // the two drafted tokens
//!     &[0.9, 0.9],  // acceptance uniforms (τ ≈ 1, so both accept)
//!     0.5, 0.5,     // resample/bonus uniforms
//!     Method::Exact, None,
//! );
//! assert_eq!(out.accept_len, 2);
//! assert_eq!(out.tokens, vec![1, 1, 2]); // drafts + the bonus draw
//! ```

use crate::util::timer::Profiler;

/// Fixed vocab-chunk size (elements) for row reductions — softmax row
/// sums *and* the inverse-CDF totals/prefixes. Both the scalar reference
/// and the parallel kernels fold per-chunk partials in chunk order, so
/// partitioning work across threads cannot reassociate the sums. For
/// `v <= VOCAB_CHUNK` (every model vocab in the artifact set) this
/// degenerates to the plain sequential sum.
pub const VOCAB_CHUNK: usize = 4096;

/// Lane width (f32 elements) of the in-block reduction graph. Inside
/// each [`VOCAB_CHUNK`] block, sums and maxima run over `LANE`
/// independent accumulators — element `k` of a block lands on lane
/// `k % LANE`, tail elements continue on lanes `0..tail` — and the
/// accumulators are folded in lane order at the end. This is the PR 3
/// move one level down: the scalar reference executes the exact
/// arithmetic graph an 8-wide vector unit (AVX2 ymm, or the compiler's
/// autovectorizer) produces, so the SIMD kernel paths stay bit-identical
/// to this oracle. 8 lanes of f32 = one 256-bit register.
pub const LANE: usize = 8;

// ---------------------------------------------------------------------------
// shared exp polynomial + lane-graph reduction primitives
//
// `f32::exp` routes through libm, whose last-ulp behaviour is
// implementation-defined and has no 8-wide twin — a vectorized kernel
// could never reproduce it bit-for-bit. Every exponential on the verify
// path instead uses this fixed polynomial, built only from exactly
// rounded IEEE single ops (mul/add/sub, min/max, integer bit shifts) so
// the scalar reference and the `std::arch` AVX2 path in
// `sampling::kernels::simd` compute literally the same operation
// sequence per element. No `mul_add`: FMA rounds once where mul+add
// rounds twice, and the two differ in the last ulp.

/// Clamp bounds: 2^n stays a normal f32 scale factor (n ∈ [-126, 127]),
/// so the bit-shift reconstruction below never has to handle the
/// subnormal/overflow exponent range. exp saturates at ~1.65e38 /
/// ~1.6e-38 instead of ±inf/0 — indistinguishable through the softmax
/// normalisation and sigmoid denominators this feeds.
pub(crate) const EXP_HI: f32 = 88.0;
pub(crate) const EXP_LO: f32 = -87.0;
pub(crate) const EXP_LOG2E: f32 = std::f32::consts::LOG2_E;
/// Cody–Waite split of ln 2: `LN2_HI` holds the top bits exactly, so
/// `x - n·LN2_HI` is exact for |n| ≤ 128 and the reduced argument keeps
/// full precision.
pub(crate) const EXP_LN2_HI: f32 = 0.693_359_375;
pub(crate) const EXP_LN2_LO: f32 = -2.121_944_4e-4;
/// 1.5·2^23: adding and subtracting it rounds to the nearest integer
/// under round-nearest-even — the same rounding `_mm256_cvtps_epi32`
/// and `_mm256_round_ps` apply (`f32::round` would round half away
/// from zero and disagree with the vector unit on exact halves).
pub(crate) const EXP_RND: f32 = 12_582_912.0;
pub(crate) const EXP_P0: f32 = 1.987_569_15e-4;
pub(crate) const EXP_P1: f32 = 1.398_199_95e-3;
pub(crate) const EXP_P2: f32 = 8.333_451_9e-3;
pub(crate) const EXP_P3: f32 = 4.166_579_6e-2;
pub(crate) const EXP_P4: f32 = 1.666_666_5e-1;
pub(crate) const EXP_P5: f32 = 5.000_000_1e-1;

/// e^x by range reduction + degree-6 polynomial (Cephes coefficients),
/// accurate to ~1 ulp over the clamped range. Every operation is an
/// exactly rounded IEEE f32 op with an AVX2 twin, which is what makes
/// the vectorized kernels bit-identical to this scalar form (see the
/// section comment above). NaN passes through (the Sigmoid16 fp16
/// overflow semantics depend on it); ±inf saturate via the clamp.
#[inline]
pub fn exp_approx(x: f32) -> f32 {
    if x.is_nan() {
        return x; // the AVX2 twin blends NaN lanes back in at the end
    }
    let xc = x.min(EXP_HI).max(EXP_LO);
    // n = round_even(x / ln 2) via the magic-number trick
    let n = (xc * EXP_LOG2E + EXP_RND) - EXP_RND;
    // r = x - n·ln2, Cody-Waite two-term split
    let r = (xc - n * EXP_LN2_HI) - n * EXP_LN2_LO;
    let z = r * r;
    let mut y = EXP_P0;
    y = y * r + EXP_P1;
    y = y * r + EXP_P2;
    y = y * r + EXP_P3;
    y = y * r + EXP_P4;
    y = y * r + EXP_P5;
    y = (y * z + r) + 1.0;
    // 2^n assembled directly in the exponent field (n is integral and
    // clamped into the normal range)
    let pow2 = f32::from_bits((((n as i32) + 127) as u32) << 23);
    y * pow2
}

/// Fold the lane accumulators in lane order — the last stage of every
/// lane-graph reduction, shared (as code) by the scalar reference and
/// the AVX2 path, which stores its ymm accumulator to an array and
/// calls this.
#[inline]
pub(crate) fn lane_fold_sum(acc: &[f32; LANE]) -> f32 {
    let mut s = acc[0];
    for &a in &acc[1..] {
        s += a;
    }
    s
}

/// Lane-order fold for maxima. The comparison form `if a > m` (not
/// `f32::max`) is the semantics of the `maxps` instruction with the
/// accumulator in the second operand: NaN never wins, an existing
/// accumulator survives ties.
#[inline]
pub(crate) fn lane_fold_max(acc: &[f32; LANE]) -> f32 {
    let mut m = acc[0];
    for &a in &acc[1..] {
        if a > m {
            m = a;
        }
    }
    m
}

/// Max over a slice on the [`LANE`]-wide reduction graph. NaN elements
/// are ignored (comparison semantics, matching both the old
/// `f32::max` fold and `maxps(x, acc)`), so a poisoned logit row still
/// produces the max of its ordered elements.
pub(crate) fn lane_max(xs: &[f32]) -> f32 {
    let mut acc = [f32::NEG_INFINITY; LANE];
    let mut groups = xs.chunks_exact(LANE);
    for g in groups.by_ref() {
        for j in 0..LANE {
            if g[j] > acc[j] {
                acc[j] = g[j];
            }
        }
    }
    for (j, &x) in groups.remainder().iter().enumerate() {
        if x > acc[j] {
            acc[j] = x;
        }
    }
    lane_fold_max(&acc)
}

/// Sum over one block on the [`LANE`]-wide reduction graph: element `k`
/// accumulates on lane `k % LANE`, lanes fold in order. Callers fold
/// per-[`VOCAB_CHUNK`] block results in chunk order, exactly as before —
/// only the *inside* of a block changed shape.
pub(crate) fn lane_sum(xs: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANE];
    let mut groups = xs.chunks_exact(LANE);
    for g in groups.by_ref() {
        for j in 0..LANE {
            acc[j] += g[j];
        }
    }
    for (j, &x) in groups.remainder().iter().enumerate() {
        acc[j] += x;
    }
    lane_fold_sum(&acc)
}

/// `dst = exp(src - max)` over one block, returning the block's
/// lane-graph sum — the fused phase-2 softmax primitive. The AVX2 twin
/// (`kernels::simd`) keeps the accumulators in one ymm register and
/// reproduces this graph exactly.
pub(crate) fn exp_sub_sum_block(src: &[f32], dst: &mut [f32], max: f32) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let n = src.len();
    let full = n - n % LANE;
    let mut acc = [0.0f32; LANE];
    let mut k = 0;
    while k < full {
        for j in 0..LANE {
            let e = exp_approx(src[k + j] - max);
            dst[k + j] = e;
            acc[j] += e;
        }
        k += LANE;
    }
    for j in 0..(n - full) {
        let e = exp_approx(src[full + j] - max);
        dst[full + j] = e;
        acc[j] += e;
    }
    lane_fold_sum(&acc)
}

/// In-place twin of [`exp_sub_sum_block`] (same graph: the borrow
/// checker just cannot express `src == dst` through two slices).
pub(crate) fn exp_sub_sum_block_inplace(blk: &mut [f32], max: f32) -> f32 {
    let n = blk.len();
    let full = n - n % LANE;
    let mut acc = [0.0f32; LANE];
    let mut k = 0;
    while k < full {
        for j in 0..LANE {
            let e = exp_approx(blk[k + j] - max);
            blk[k + j] = e;
            acc[j] += e;
        }
        k += LANE;
    }
    for j in 0..(n - full) {
        let e = exp_approx(blk[full + j] - max);
        blk[full + j] = e;
        acc[j] += e;
    }
    lane_fold_sum(&acc)
}

/// Verification method (§3.2). `Baseline` and `Exact` are semantically
/// identical here (the distinction is kernel structure, which only exists
/// on the accelerator); both are provided so profiling scopes match the
/// HLO backends one-to-one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Baseline,
    Exact,
    /// Element-wise sigmoid approximation with scaling constants (α, β).
    Sigmoid { alpha_milli: i64, beta_milli: i64 },
    /// Sigmoid approximation with the (z−α)/(β−α) rescale performed in
    /// fp16 — the paper's actual numeric regime for Whisper, which
    /// overflows (→ NaN → reject-everything) at |α| = 1e5 (Table 2).
    Sigmoid16 { alpha_milli: i64, beta_milli: i64 },
}

/// Round α/β to integer milli-units, to nearest (f32 carries ~7
/// significant digits, so `1.234 * 1000.0` lands at `1233.9999…`;
/// truncation would collapse it to 1233 and `alpha_beta()` would not
/// round-trip).
fn to_milli(x: f32) -> i64 {
    (x * 1000.0).round() as i64
}

impl Method {
    pub fn sigmoid(alpha: f32, beta: f32) -> Self {
        Method::Sigmoid {
            alpha_milli: to_milli(alpha),
            beta_milli: to_milli(beta),
        }
    }

    pub fn sigmoid16(alpha: f32, beta: f32) -> Self {
        Method::Sigmoid16 {
            alpha_milli: to_milli(alpha),
            beta_milli: to_milli(beta),
        }
    }

    pub fn alpha_beta(&self) -> Option<(f32, f32)> {
        match self {
            Method::Sigmoid {
                alpha_milli,
                beta_milli,
            }
            | Method::Sigmoid16 {
                alpha_milli,
                beta_milli,
            } => Some((*alpha_milli as f32 / 1000.0, *beta_milli as f32 / 1000.0)),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::Exact => "exact",
            Method::Sigmoid { .. } => "sigmoid",
            Method::Sigmoid16 { .. } => "sigmoid16",
        }
    }
}

// ---------------------------------------------------------------------------
// fp16 emulation (no half type in the vendored crate set)
//
// Exact IEEE binary16 conversions at the bit level. These back both the
// paper's Sigmoid16 rescale (`f16_round`) and the half-precision logit
// ingestion path (`HostTensor::F16` staging widened inside the kernels'
// probability-construction pass — see `sampling::kernels::Logits`).

/// Convert an f32 to IEEE binary16 bits: round-to-nearest-even, proper
/// subnormals, overflow to ±inf, NaN quietened with its top payload
/// bits kept (the behaviour of hardware `vcvtps2ph`).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7f_ffff;
    if exp == 0xff {
        return if frac != 0 {
            sign | 0x7e00 | ((frac >> 13) as u16 & 0x3ff) // NaN, quiet bit set
        } else {
            sign | 0x7c00 // ±inf
        };
    }
    if exp == 0 {
        // f32 subnormals (< 2^-126) are far below the smallest f16
        // subnormal (2^-24): round to signed zero
        return sign;
    }
    let e16 = exp - 127 + 15;
    if e16 >= 0x1f {
        return sign | 0x7c00; // overflow -> ±inf
    }
    if e16 <= 0 {
        // f16 subnormal target: |x| < 2^-25 rounds to zero (ties-to-even
        // lands on zero at exactly 2^-25, which has e16 = -10)
        if e16 < -10 {
            return sign;
        }
        // drop bits from the full 24-bit significand onto the 2^-24
        // grid; a carry to 1024 is the minimum normal and its bit
        // pattern (exp field 1, mantissa 0) falls out of the addition
        let sig = frac | 0x80_0000;
        return sign | round_even(sig, (14 - e16) as u32) as u16;
    }
    // normal: keep 10 of the 23 fraction bits; a mantissa carry
    // propagates into the exponent field arithmetically, and a carry
    // out of e16 == 30 lands exactly on the inf pattern 0x7c00
    let k = ((e16 as u32) << 10) + round_even(frac, 13);
    if k >= 0x7c00 {
        return sign | 0x7c00;
    }
    sign | k as u16
}

/// Widen IEEE binary16 bits to the exactly representable f32 (every
/// binary16 value is). Signalling NaNs come back quietened (payload
/// kept, quiet bit set) — the behaviour of hardware `vcvtph2ps`.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    if exp == 0x1f {
        let quiet = if frac != 0 { 0x40_0000 } else { 0 };
        return f32::from_bits(sign | 0x7f80_0000 | quiet | (frac << 13));
    }
    if exp == 0 {
        if frac == 0 {
            return f32::from_bits(sign); // ±0
        }
        // subnormal: normalise frac·2^-24 into f32's normal range
        let p = 31 - frac.leading_zeros(); // msb position, 0..=9
        let exp32 = p + 103; // p - 24 + 127
        let mant = (frac << (23 - p)) & 0x7f_ffff;
        return f32::from_bits(sign | (exp32 << 23) | mant);
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (frac << 13))
}

/// Round an f32 to the nearest IEEE binary16 and back (round-to-nearest-
/// even, overflow to ±inf) — the paper's fp16 rescale, emulated exactly.
#[inline]
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

fn round_even(sig: u32, shift: u32) -> u32 {
    let kept = sig >> shift;
    let rem = sig & ((1 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && kept & 1 == 1) {
        kept + 1
    } else {
        kept
    }
}

/// Result of verifying one batch row.
#[derive(Debug, Clone, PartialEq)]
pub struct StepOutput {
    /// number of draft tokens accepted (leading run)
    pub accept_len: usize,
    /// emitted tokens: accepted drafts + one resampled/bonus token; always
    /// `accept_len + 1` entries.
    pub tokens: Vec<i32>,
}

/// Numerically-stable softmax over each row of a (rows, v) matrix, in
/// place. Row sums fold per-[`VOCAB_CHUNK`] partials in fixed chunk
/// order (see the module docs), which is what lets the segment-parallel
/// kernels stay bit-identical to this reference.
pub fn softmax_rows(x: &mut [f32], v: usize) {
    debug_assert_eq!(x.len() % v, 0);
    for row in x.chunks_mut(v) {
        softmax_row(row);
    }
}

/// One softmax row with the fixed-order chunked reduction (shared by the
/// scalar reference and every parallel schedule): row max and per-block
/// exp-sums both on the [`LANE`] graph, block partials folded in chunk
/// order.
pub(crate) fn softmax_row(row: &mut [f32]) {
    let max = lane_max(row);
    let mut sum = 0.0f32;
    for blk in row.chunks_mut(VOCAB_CHUNK) {
        sum += exp_sub_sum_block_inplace(blk, max);
    }
    let inv = 1.0 / sum;
    for e in row.iter_mut() {
        *e *= inv;
    }
}

/// `dst = softmax(src)` for one row — the out-of-place twin of
/// [`softmax_row`] used by the kernel layer (identical arithmetic graph,
/// so the result is bit-identical).
pub(crate) fn softmax_row_from(src: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    let max = lane_max(src);
    let mut sum = 0.0f32;
    for (sb, db) in src.chunks(VOCAB_CHUNK).zip(dst.chunks_mut(VOCAB_CHUNK)) {
        sum += exp_sub_sum_block(sb, db, max);
    }
    let inv = 1.0 / sum;
    for e in dst.iter_mut() {
        *e *= inv;
    }
}

/// Element-wise sigmoid approximation of softmax (Eq. 5), in place.
/// Element-wise ops need no lane structure — IEEE mul/add/div are
/// exactly rounded, so any vectorization is bit-identical for free; the
/// exponential routes through the shared [`exp_approx`] so the AVX2
/// twin matches it too.
pub fn sigmoid_approx(x: &mut [f32], alpha: f32, beta: f32) {
    let inv = 1.0 / (beta - alpha);
    for e in x.iter_mut() {
        let z = (*e - alpha) * inv;
        *e = 1.0 / (1.0 + exp_approx(-z));
    }
}

/// `dst = sigmoid_approx(src)` — out-of-place element-wise twin for the
/// kernel layer.
pub(crate) fn sigmoid_row_from(src: &[f32], dst: &mut [f32], alpha: f32, beta: f32) {
    debug_assert_eq!(src.len(), dst.len());
    let inv = 1.0 / (beta - alpha);
    for (d, &s) in dst.iter_mut().zip(src) {
        let z = (s - alpha) * inv;
        *d = 1.0 / (1.0 + exp_approx(-z));
    }
}

/// Eq. 5 with the rescale computed in (emulated) fp16: (z−α)/(β−α) with
/// every intermediate rounded to binary16, then σ in f32. Overflows to
/// inf/inf = NaN at |α| ≳ 65504, matching the paper's fp16 pipeline.
pub fn sigmoid_approx_fp16(x: &mut [f32], alpha: f32, beta: f32) {
    let a16 = f16_round(alpha);
    let denom = f16_round(f16_round(beta) - a16);
    for e in x.iter_mut() {
        let z = f16_round(f16_round(f16_round(*e) - a16) / denom);
        *e = 1.0 / (1.0 + exp_approx(-z));
    }
}

/// `dst = sigmoid_approx_fp16(src)` — out-of-place element-wise twin for
/// the kernel layer.
pub(crate) fn sigmoid16_row_from(src: &[f32], dst: &mut [f32], alpha: f32, beta: f32) {
    debug_assert_eq!(src.len(), dst.len());
    let a16 = f16_round(alpha);
    let denom = f16_round(f16_round(beta) - a16);
    for (d, &s) in dst.iter_mut().zip(src) {
        let z = f16_round(f16_round(f16_round(s) - a16) / denom);
        *d = 1.0 / (1.0 + exp_approx(-z));
    }
}

/// Draw from an unnormalised non-negative weight vector by inverse CDF
/// (threshold `u * total`; zero-mass rows fall back to first-occurrence
/// argmax, matching `jnp.argmax` in the AOT graphs).
///
/// Like the softmax row sums, the reduction graph is **blocked**: the
/// total is a fixed-order fold of per-[`VOCAB_CHUNK`] partial sums, the
/// winning block is located by walking that same prefix fold, and only
/// the winning block is scanned element-wise (its running CDF seeded
/// with the block's prefix). For `v <= VOCAB_CHUNK` — every model vocab
/// in the artifact set — this degenerates bit-for-bit to the plain
/// sequential scan. The blocked graph is what lets the kernel layer
/// compute the partials chunk-parallel
/// ([`crate::sampling::kernels`]'s `inverse_cdf_sample_blocked`) while
/// staying bit-identical to this scalar reference.
///
/// Rounding guard: the block lookup tests `prefix + partial > thresh`
/// while the in-block scan accumulates element-wise from `prefix`, and
/// the two can disagree in the last ulp. When the scan of the selected
/// block falls through, the block's final element is returned — that
/// rule is part of the reference semantics, so every parallel schedule
/// reproduces it exactly.
// `!(total > 0)` below also catches NaN totals (fp16-overflow
// residuals), matching the jnp graph's `where(total > 0, tok, argmax)` —
// a rewrite to `total <= 0.0` would drop the NaN arm.
#[allow(clippy::neg_cmp_op_on_partial_ord)]
pub fn inverse_cdf_sample(weights: &[f32], u: f32) -> usize {
    if weights.len() <= VOCAB_CHUNK {
        // single block: the blocked graph degenerates to the one-block
        // case bit-for-bit (the lane-graph sum of the whole slice IS
        // the lone block partial, and the in-block scan starts from
        // prefix 0.0), so take the cheap path — this is the hot
        // slot-parallel case, every artifact vocab fits in one block
        let total = lane_sum(weights);
        if !(total > 0.0) {
            return argmax_first(weights);
        }
        let thresh = u * total;
        let mut cdf = 0.0f32;
        for (i, w) in weights.iter().enumerate() {
            cdf += w;
            if cdf > thresh {
                return i;
            }
        }
        return weights.len() - 1;
    }
    // multi-block: per-block partials (each the lane-graph sum of its
    // own block, the arithmetic every parallel/SIMD schedule
    // reproduces), then the shared fold/lookup/scan stages
    let parts: Vec<f32> = weights.chunks(VOCAB_CHUNK).map(lane_sum).collect();
    inverse_cdf_from_partials(weights, &parts, u)
}

/// Stages 2–3 of the blocked inverse-CDF reduction graph, shared
/// verbatim by the scalar multi-block arm of [`inverse_cdf_sample`] and
/// the chunk-parallel kernel twin (which computes `parts` on the worker
/// pool): a fixed-order fold of the per-[`VOCAB_CHUNK`] partials into
/// the total, a walk of the same prefix fold to locate the winning
/// block, and an element-wise scan of that one block seeded with its
/// prefix — including the fall-through-to-block-end rounding guard.
/// Keeping this in one place is what keeps the two paths bit-identical
/// by construction.
// `!(total > 0)` also catches NaN totals (fp16-overflow residuals).
#[allow(clippy::neg_cmp_op_on_partial_ord)]
pub(crate) fn inverse_cdf_from_partials(weights: &[f32], parts: &[f32], u: f32) -> usize {
    let v = weights.len();
    let mut total = 0.0f32;
    for &part in parts {
        total += part;
    }
    if !(total > 0.0) {
        return argmax_first(weights);
    }
    let thresh = u * total;
    let mut prefix = 0.0f32;
    for (bi, &part) in parts.iter().enumerate() {
        if prefix + part > thresh {
            let off = bi * VOCAB_CHUNK;
            let blk = &weights[off..(off + VOCAB_CHUNK).min(v)];
            let mut cdf = prefix;
            for (i, &w) in blk.iter().enumerate() {
                cdf += w;
                if cdf > thresh {
                    return off + i;
                }
            }
            return off + blk.len() - 1;
        }
        prefix += part;
    }
    v - 1
}

/// First-occurrence argmax (the zero/NaN-mass fallback arm of
/// [`inverse_cdf_sample`], matching `jnp.argmax` in the AOT graphs).
pub(crate) fn argmax_first(weights: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, w) in weights.iter().enumerate().skip(1) {
        if *w > weights[best] {
            best = i;
        }
    }
    best
}

/// Acceptance ratio τ(x) = min(1, p/q) with the q==0 guard (Eq. 1).
#[inline]
pub fn tau(p: f32, q: f32) -> f32 {
    if q > 0.0 {
        (p / q).min(1.0)
    } else {
        1.0
    }
}

/// One acceptance decision: accept draft position `c` iff `u <= τ`.
/// `Sigmoid16` uses the unguarded NaN-propagating ratio (rust's
/// `f32::min` would swallow the NaN): accept iff `u <= r || r >= 1` — a
/// NaN ratio (fp16 overflow) fails both comparisons and REJECTS, the
/// semantics the paper's torch pipeline exhibits at ±1e5 scaling.
#[inline]
pub(crate) fn accept_decision(p: f32, q: f32, u: f32, method: Method) -> bool {
    if matches!(method, Method::Sigmoid16 { .. }) {
        let r = p / q;
        u <= r || r >= 1.0
    } else {
        u <= tau(p, q)
    }
}

/// One full speculative verification step for a single sequence.
///
/// * `z_p`: target logits, `(gamma + 1) * v` row-major (row γ = bonus row)
/// * `z_q`: draft logits, `gamma * v`
/// * `draft`: the γ drafted token ids
/// * `u_acc`: γ acceptance uniforms; `u_res`, `u_bonus`: resample/bonus
///
/// An optional profiler receives the same scope names as the HLO backends
/// (`verify/softmax`, `verify/kernel`, `verify/finish`) so Δ%-profiling
/// comparisons are apples-to-apples.
#[allow(clippy::too_many_arguments)]
pub fn spec_step(
    z_p: &[f32],
    z_q: &[f32],
    v: usize,
    draft: &[i32],
    u_acc: &[f32],
    u_res: f32,
    u_bonus: f32,
    method: Method,
    profiler: Option<&Profiler>,
) -> StepOutput {
    let gamma = draft.len();
    debug_assert_eq!(z_p.len(), (gamma + 1) * v);
    debug_assert_eq!(z_q.len(), gamma * v);
    debug_assert_eq!(u_acc.len(), gamma);

    // --- probability construction ("softmax" scope; sigmoid replaces it)
    let mut p = z_p.to_vec();
    let mut q = z_q.to_vec();
    {
        let _g = profiler.map(|pr| pr.scope("verify/softmax"));
        match method {
            Method::Baseline | Method::Exact => {
                softmax_rows(&mut p, v);
                softmax_rows(&mut q, v);
            }
            Method::Sigmoid { .. } => {
                let (alpha, beta) = method.alpha_beta().unwrap();
                sigmoid_approx(&mut p, alpha, beta);
                sigmoid_approx(&mut q, alpha, beta);
            }
            Method::Sigmoid16 { .. } => {
                let (alpha, beta) = method.alpha_beta().unwrap();
                sigmoid_approx_fp16(&mut p, alpha, beta);
                sigmoid_approx_fp16(&mut q, alpha, beta);
            }
        }
    }

    // --- acceptance loop (the "kernel" work: tau at drafted tokens).
    // Accept iff u <= tau, exactly as the AOT graphs compute it; see
    // [`accept_decision`] for the Sigmoid16 NaN-rejection semantics.
    let mut accept_len = gamma;
    {
        let _g = profiler.map(|pr| pr.scope("verify/kernel"));
        for c in 0..gamma {
            let x = draft[c] as usize;
            if !accept_decision(p[c * v + x], q[c * v + x], u_acc[c], method) {
                accept_len = c;
                break;
            }
        }
    }

    // --- resample / bonus ("finish" scope)
    let _g = profiler.map(|pr| pr.scope("verify/finish"));
    let mut tokens: Vec<i32> = draft[..accept_len].to_vec();
    if accept_len == gamma {
        let bonus_row = &p[gamma * v..(gamma + 1) * v];
        tokens.push(inverse_cdf_sample(bonus_row, u_bonus) as i32);
    } else {
        let c = accept_len;
        let residual: Vec<f32> = (0..v)
            .map(|x| (p[c * v + x] - q[c * v + x]).max(0.0))
            .collect();
        tokens.push(inverse_cdf_sample(&residual, u_res) as i32);
    }
    StepOutput { accept_len, tokens }
}

/// Batched wrapper with the same layout as the HLO verify artifacts:
/// returns `(accept_len, out_tokens)` where `out_tokens` is
/// `(gamma + 1)` per row, `-1`-padded. `methods` carries one
/// verification method per row (per-slot overrides in a heterogeneous
/// batch); pass `&[m; b]` for the homogeneous case.
///
/// This is the sequential scalar oracle; the serving engine runs the
/// slot-parallel, zero-alloc equivalent
/// [`crate::sampling::kernels::spec_step_batch_ws`], which is asserted
/// bit-identical to this function by the kernel parity property tests.
#[allow(clippy::too_many_arguments)]
pub fn spec_step_batch(
    z_p: &[f32],
    z_q: &[f32],
    b: usize,
    gamma: usize,
    v: usize,
    draft: &[i32],
    u_acc: &[f32],
    u_res: &[f32],
    u_bonus: &[f32],
    methods: &[Method],
    profiler: Option<&Profiler>,
) -> (Vec<i32>, Vec<i32>) {
    debug_assert_eq!(methods.len(), b);
    let mut accept = vec![0i32; b];
    let mut out = vec![-1i32; b * (gamma + 1)];
    for row in 0..b {
        let o = spec_step(
            &z_p[row * (gamma + 1) * v..(row + 1) * (gamma + 1) * v],
            &z_q[row * gamma * v..(row + 1) * gamma * v],
            v,
            &draft[row * gamma..(row + 1) * gamma],
            &u_acc[row * gamma..(row + 1) * gamma],
            u_res[row],
            u_bonus[row],
            methods[row],
            profiler,
        );
        accept[row] = o.accept_len as i32;
        out[row * (gamma + 1)..row * (gamma + 1) + o.tokens.len()]
            .copy_from_slice(&o.tokens);
    }
    (accept, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, Config};
    use crate::util::rng::Pcg32;

    fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
    }

    #[test]
    fn softmax_rows_normalises() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.iter().all(|&p| p > 0.0));
        }
        // monotone in logits
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_is_shift_invariant_and_stable() {
        let mut a = vec![1000.0, 1001.0, 999.0];
        let mut b = vec![0.0, 1.0, -1.0];
        softmax_rows(&mut a, 3);
        softmax_rows(&mut b, 3);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
            assert!(x.is_finite());
        }
    }

    #[test]
    fn tau_guards_zero_q() {
        assert_eq!(tau(0.5, 0.0), 1.0);
        assert_eq!(tau(0.0, 0.0), 1.0);
        assert_eq!(tau(0.2, 0.4), 0.5);
        assert_eq!(tau(0.4, 0.2), 1.0);
    }

    #[test]
    fn inverse_cdf_known_thresholds() {
        let w = [0.1, 0.2, 0.7];
        assert_eq!(inverse_cdf_sample(&w, 0.05), 0);
        assert_eq!(inverse_cdf_sample(&w, 0.15), 1);
        assert_eq!(inverse_cdf_sample(&w, 0.95), 2);
        assert_eq!(inverse_cdf_sample(&[0.0, 0.0, 1.0], 0.0), 2);
        assert_eq!(inverse_cdf_sample(&[0.0; 4], 0.5), 0); // zero mass -> argmax
    }

    #[test]
    fn inverse_cdf_blocked_degenerates_to_sequential_for_small_v() {
        // for v <= VOCAB_CHUNK the blocked graph must reproduce the
        // one-block form bit-for-bit: lane-graph total, then the plain
        // sequential scan from prefix 0.0
        let mut rng = Pcg32::seeded(31);
        for _ in 0..50 {
            let v = 1 + rng.below(VOCAB_CHUNK as u32) as usize;
            let w: Vec<f32> = (0..v).map(|_| rng.uniform_f32()).collect();
            let u = rng.uniform_f32();
            let total = lane_sum(&w);
            let thresh = u * total;
            let mut cdf = 0.0f32;
            let mut expect = v - 1;
            for (i, &x) in w.iter().enumerate() {
                cdf += x;
                if cdf > thresh {
                    expect = i;
                    break;
                }
            }
            assert_eq!(inverse_cdf_sample(&w, u), expect, "v={v} u={u}");
        }
    }

    #[test]
    fn inverse_cdf_multi_block_thresholds() {
        // 2 full blocks + a ragged tail of uniform mass: sums of small
        // integers are exact in f32, so indices are analytic
        let v = 2 * VOCAB_CHUNK + 5;
        let w = vec![1.0f32; v];
        assert_eq!(inverse_cdf_sample(&w, 0.0), 0);
        // thresh = 0.5 * v = 4098.5 -> first index with cdf 4099
        assert_eq!(inverse_cdf_sample(&w, 0.5), v / 2);
        // mass concentrated in the last block
        let mut w = vec![0.0f32; v];
        w[2 * VOCAB_CHUNK + 3] = 2.0;
        assert_eq!(inverse_cdf_sample(&w, 0.9), 2 * VOCAB_CHUNK + 3);
        // zero mass across multiple blocks -> first-occurrence argmax
        let mut w = vec![0.0f32; v];
        w[VOCAB_CHUNK + 17] = f32::NAN; // NaN total also takes the argmax arm
        assert_eq!(inverse_cdf_sample(&w, 0.5), 0);
    }

    #[test]
    fn identical_p_q_accepts_all_and_emits_bonus() {
        let v = 16;
        let mut rng = Pcg32::seeded(0);
        let z_q = randn(&mut rng, 3 * v, 2.0);
        let mut z_p = z_q.clone();
        z_p.extend(randn(&mut rng, v, 2.0)); // bonus row
        let out = spec_step(
            &z_p, &z_q, v, &[1, 2, 3], &[0.99, 0.99, 0.99], 0.5, 0.5,
            Method::Exact, None,
        );
        assert_eq!(out.accept_len, 3);
        assert_eq!(out.tokens.len(), 4);
        assert_eq!(&out.tokens[..3], &[1, 2, 3]);
    }

    #[test]
    fn certain_rejection_resamples_from_residual() {
        // q loves token 0, p loves token 1 -> reject, residual argmax = 1
        let v = 8;
        let mut z_q = vec![-10.0f32; v];
        z_q[0] = 10.0;
        let mut z_p = vec![-10.0f32; 2 * v];
        z_p[1] = 10.0;
        z_p[v + 1] = 10.0;
        let out = spec_step(
            &z_p, &z_q, v, &[0], &[0.9], 0.5, 0.5, Method::Baseline, None,
        );
        assert_eq!(out.accept_len, 0);
        assert_eq!(out.tokens, vec![1]);
    }

    #[test]
    fn sigmoid_extreme_scaling_accepts_everything() {
        let v = 32;
        let mut rng = Pcg32::seeded(1);
        let z_p = randn(&mut rng, 3 * v, 5.0);
        let z_q = randn(&mut rng, 2 * v, 5.0);
        let out = spec_step(
            &z_p, &z_q, v, &[3, 4], &[0.999, 0.999], 0.1, 0.1,
            Method::sigmoid(-1e5, 1e5), None,
        );
        assert_eq!(out.accept_len, 2); // the Table 2 ±1e5 collapse
    }

    #[test]
    fn baseline_and_exact_agree_everywhere() {
        forall("baseline==exact", Config { cases: 40, ..Config::default() }, |rng, size| {
            let v = 4 + size;
            let gamma = 1 + (size % 5);
            let z_p = randn(rng, (gamma + 1) * v, 3.0);
            let z_q = randn(rng, gamma * v, 3.0);
            let draft: Vec<i32> = (0..gamma).map(|_| rng.below(v as u32) as i32).collect();
            let u_acc: Vec<f32> = (0..gamma).map(|_| rng.uniform_f32()).collect();
            let (ur, ub) = (rng.uniform_f32(), rng.uniform_f32());
            let a = spec_step(&z_p, &z_q, v, &draft, &u_acc, ur, ub, Method::Baseline, None);
            let e = spec_step(&z_p, &z_q, v, &draft, &u_acc, ur, ub, Method::Exact, None);
            if a != e {
                return Err(format!("{a:?} != {e:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn emitted_token_count_is_accept_len_plus_one() {
        forall("emit count", Config { cases: 60, ..Config::default() }, |rng, size| {
            let v = 4 + size;
            let gamma = 1 + (size % 7);
            let z_p = randn(rng, (gamma + 1) * v, 4.0);
            let z_q = randn(rng, gamma * v, 4.0);
            let draft: Vec<i32> = (0..gamma).map(|_| rng.below(v as u32) as i32).collect();
            let u_acc: Vec<f32> = (0..gamma).map(|_| rng.uniform_f32()).collect();
            let o = spec_step(&z_p, &z_q, v, &draft, &u_acc,
                              rng.uniform_f32(), rng.uniform_f32(),
                              Method::Baseline, None);
            if o.tokens.len() != o.accept_len + 1 {
                return Err(format!("{} tokens for accept_len {}", o.tokens.len(), o.accept_len));
            }
            if o.accept_len > gamma {
                return Err("accept_len beyond gamma".into());
            }
            if o.tokens.iter().any(|&t| t < 0 || t as usize >= v) {
                return Err(format!("token out of range: {:?}", o.tokens));
            }
            Ok(())
        });
    }

    #[test]
    fn batch_wrapper_matches_single_rows() {
        // heterogeneous per-row methods: each row must follow its own
        let (b, gamma, v) = (3, 4, 24);
        let methods = [Method::Exact, Method::sigmoid(-1e3, 1e3), Method::Baseline];
        let mut rng = Pcg32::seeded(9);
        let z_p = randn(&mut rng, b * (gamma + 1) * v, 3.0);
        let z_q = randn(&mut rng, b * gamma * v, 3.0);
        let draft: Vec<i32> = (0..b * gamma).map(|_| rng.below(v as u32) as i32).collect();
        let u_acc: Vec<f32> = (0..b * gamma).map(|_| rng.uniform_f32()).collect();
        let u_res: Vec<f32> = (0..b).map(|_| rng.uniform_f32()).collect();
        let u_bonus: Vec<f32> = (0..b).map(|_| rng.uniform_f32()).collect();
        let (alen, out) = spec_step_batch(
            &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus,
            &methods, None,
        );
        for row in 0..b {
            let o = spec_step(
                &z_p[row * (gamma + 1) * v..(row + 1) * (gamma + 1) * v],
                &z_q[row * gamma * v..(row + 1) * gamma * v],
                v,
                &draft[row * gamma..(row + 1) * gamma],
                &u_acc[row * gamma..(row + 1) * gamma],
                u_res[row],
                u_bonus[row],
                methods[row],
                None,
            );
            assert_eq!(alen[row] as usize, o.accept_len);
            let got = &out[row * (gamma + 1)..row * (gamma + 1) + o.tokens.len()];
            assert_eq!(got, o.tokens.as_slice());
            // padding beyond emitted tokens
            assert!(out[row * (gamma + 1) + o.tokens.len()..(row + 1) * (gamma + 1)]
                .iter()
                .all(|&t| t == -1));
        }
    }

    #[test]
    fn sigmoid_constructor_rounds_to_nearest_milli() {
        // f32 representation error must not truncate 1.234 to 1.233
        for milli in [-100_000i64, -1999, -3, 0, 3, 500, 1234, 99_999] {
            let a = milli as f32 / 1000.0;
            let m = Method::sigmoid(a, a + 10.0);
            let (ra, _) = m.alpha_beta().unwrap();
            assert_eq!(ra, a, "alpha {a} did not round-trip");
            let m16 = Method::sigmoid16(a, a + 10.0);
            assert_eq!(m16.alpha_beta().unwrap().0, a);
        }
        // .9995 sits on the milli boundary: round to nearest, not toward 0
        let m = Method::sigmoid(-0.9999, 0.9999);
        assert_eq!(m.alpha_beta(), Some((-1.0, 1.0)));
    }

    #[test]
    fn softmax_chunked_reduction_matches_lane_graph_for_small_v() {
        // for v <= VOCAB_CHUNK the chunked fold degenerates to a single
        // block, and inside the block the reduction is the pinned 8-lane
        // accumulator graph: element k sums on lane k % LANE (the tail
        // continues lanes 0..tail since a full group is LANE-aligned),
        // lanes folded in lane order
        let mut rng = Pcg32::seeded(21);
        let v = 97; // deliberately not a multiple of LANE
        let mut chunked = randn(&mut rng, 3 * v, 4.0);
        let plain = chunked.clone();
        softmax_rows(&mut chunked, v);
        for (got, src) in chunked.chunks(v).zip(plain.chunks(v)) {
            let mut macc = [f32::NEG_INFINITY; LANE];
            for (k, &s) in src.iter().enumerate() {
                if s > macc[k % LANE] {
                    macc[k % LANE] = s;
                }
            }
            let max = lane_fold_max(&macc);
            let mut e = vec![0.0f32; v];
            let mut acc = [0.0f32; LANE];
            for (k, &s) in src.iter().enumerate() {
                e[k] = exp_approx(s - max);
                acc[k % LANE] += e[k];
            }
            let inv = 1.0 / lane_fold_sum(&acc);
            let expect: Vec<f32> = e.iter().map(|x| x * inv).collect();
            assert_eq!(got, &expect[..]);
        }
    }

    #[test]
    fn exp_approx_tracks_libm_and_handles_specials() {
        let mut rng = Pcg32::seeded(11);
        for _ in 0..4000 {
            let x = (rng.uniform_f32() - 0.5) * 40.0;
            let got = exp_approx(x);
            let want = x.exp();
            let rel = ((got - want) / want).abs();
            assert!(rel < 1e-6, "exp({x}) = {got}, libm {want}");
        }
        assert_eq!(exp_approx(0.0), 1.0);
        assert!(exp_approx(f32::NAN).is_nan());
        // saturation instead of overflow/underflow: stays finite,
        // positive, and ordered — indistinguishable through the softmax
        // normalisation and sigmoid denominators
        assert!(exp_approx(1000.0).is_finite());
        assert!(exp_approx(f32::INFINITY) > 1e38);
        let tiny = exp_approx(-1000.0);
        assert!(tiny > 0.0 && tiny < 1e-37);
        assert_eq!(exp_approx(f32::NEG_INFINITY), tiny);
    }

    #[test]
    fn lane_reductions_degenerate_to_flat_for_tiny_inputs() {
        // fewer elements than LANE: every element lands on its own lane,
        // the fold visits them in order — equal to the flat sum/max
        let xs = [0.125f32, -2.0, 3.5];
        assert_eq!(lane_sum(&xs), 0.125 - 2.0 + 3.5);
        assert_eq!(lane_max(&xs), 3.5);
        assert_eq!(lane_sum(&[]), 0.0);
        assert_eq!(lane_max(&[f32::NAN, 1.0]), 1.0); // NaN never wins
        assert_eq!(lane_max(&[f32::NAN]), f32::NEG_INFINITY);
    }

    #[test]
    fn f16_bits_round_trip_exhaustively() {
        // every binary16 value widens exactly, so narrowing the widened
        // value must reproduce the original bits; signalling NaNs come
        // back with the quiet bit set (vcvtph2ps semantics)
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            let back = f32_to_f16_bits(x);
            let exp = (h >> 10) & 0x1f;
            let frac = h & 0x3ff;
            if exp == 0x1f && frac != 0 {
                assert!(x.is_nan());
                assert_eq!(back, h | 0x200, "nan {h:#06x}");
            } else {
                assert_eq!(back, h, "{h:#06x} -> {x} -> {back:#06x}");
            }
        }
    }

    #[test]
    fn f32_to_f16_rounds_to_nearest_even_at_the_edges() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff);
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // tie at the inf boundary
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e5), 0xfc00);
        // subnormal grid: 2^-24 is the smallest f16 subnormal; half of
        // it ties to even (zero), three quarters rounds up
        assert_eq!(f32_to_f16_bits(2f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16_bits(2f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16_bits(1.5 * 2f32.powi(-25)), 0x0001);
        // f32 subnormals are below half the f16 subnormal ulp
        assert_eq!(f32_to_f16_bits(f32::from_bits(1)), 0x0000);
        assert_eq!(f16_bits_to_f32(0x0001), 2f32.powi(-24));
        assert_eq!(f16_bits_to_f32(0x03ff), 1023.0 * 2f32.powi(-24));
        assert_eq!(f16_bits_to_f32(0x3555), {
            // 0.333... in f16: 0x3555 = 2^-2 · (1 + 341/1024)
            (1.0 + 341.0 / 1024.0) * 0.25
        });
    }

    #[test]
    fn out_of_place_rows_match_in_place() {
        let mut rng = Pcg32::seeded(22);
        let v = 64;
        let src = randn(&mut rng, v, 3.0);
        for (a, b) in [(-1e3f32, 1e3f32), (-1e5, 1e5)] {
            let mut inplace = src.clone();
            let mut out = vec![0.0f32; v];
            softmax_row(&mut inplace);
            softmax_row_from(&src, &mut out);
            assert_eq!(inplace, out);

            let mut inplace = src.clone();
            sigmoid_approx(&mut inplace, a, b);
            sigmoid_row_from(&src, &mut out, a, b);
            assert_eq!(inplace, out);

            let mut inplace = src.clone();
            sigmoid_approx_fp16(&mut inplace, a, b);
            sigmoid16_row_from(&src, &mut out, a, b);
            assert_eq!(inplace, out);
        }
    }

    #[test]
    fn f16_round_reference_values() {
        // exactly representable values pass through
        for x in [0.0f32, 1.0, -2.5, 0.5, 65504.0] {
            assert_eq!(f16_round(x), x, "{x}");
        }
        // rounding to 10 fraction bits: 1 + 2^-11 is a 0.5-ulp tie and
        // rounds to even (1.0); 1 + 3·2^-11 is a 1.5-ulp tie and rounds
        // to the even neighbour 1 + 2·2^-10
        assert_eq!(f16_round(1.0 + 2f32.powi(-11)), 1.0);
        assert_eq!(f16_round(1.0 + 3.0 * 2f32.powi(-11)), 1.0 + 2f32.powi(-9));
        // just above the half-ulp tie rounds up
        assert_eq!(
            f16_round(1.0 + 2f32.powi(-11) + 2f32.powi(-13)),
            1.0 + 2f32.powi(-10)
        );
        // overflow -> inf (f16 max finite = 65504)
        assert_eq!(f16_round(65520.0), f32::INFINITY);
        assert_eq!(f16_round(1e5), f32::INFINITY);
        assert_eq!(f16_round(-1e5), f32::NEG_INFINITY);
        // inf/nan pass through
        assert!(f16_round(f32::NAN).is_nan());
        assert_eq!(f16_round(f32::INFINITY), f32::INFINITY);
    }

    #[test]
    fn f16_round_error_is_within_half_ulp() {
        let mut rng = Pcg32::seeded(5);
        for _ in 0..2000 {
            let x = (rng.gaussian() as f32) * 100.0;
            let r = f16_round(x);
            let ulp = 2f32.powi(x.abs().log2().floor() as i32 - 10);
            assert!((r - x).abs() <= ulp * 0.5 + 1e-12, "{x} -> {r}");
        }
    }

    #[test]
    fn sigmoid16_moderate_scale_close_to_f32() {
        let mut a = vec![3.0f32, -4.0, 0.25];
        let mut b = a.clone();
        sigmoid_approx(&mut a, -1e3, 1e3);
        sigmoid_approx_fp16(&mut b, -1e3, 1e3);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn sigmoid16_overflow_rejects_everything() {
        let v = 16;
        let mut rng = Pcg32::seeded(6);
        let z_p = randn(&mut rng, 3 * v, 5.0);
        let z_q = randn(&mut rng, 2 * v, 5.0);
        let out = spec_step(
            &z_p, &z_q, v, &[1, 2], &[0.1, 0.1], 0.5, 0.5,
            Method::sigmoid16(-1e5, 1e5), None,
        );
        // NaN tau fails every acceptance test: reject at position 0
        assert_eq!(out.accept_len, 0);
        assert_eq!(out.tokens.len(), 1);
        // while f32 sigmoid at the same scale accepts both drafts
        let out32 = spec_step(
            &z_p, &z_q, v, &[1, 2], &[0.1, 0.1], 0.5, 0.5,
            Method::sigmoid(-1e5, 1e5), None,
        );
        assert_eq!(out32.accept_len, 2);
    }

    #[test]
    fn acceptance_rate_increases_with_agreement() {
        // draft == target logits -> accept rate 1; independent logits -> lower
        let v = 64;
        let gamma = 5;
        let trials = 200;
        let mut rng = Pcg32::seeded(3);
        let mut acc_same = 0usize;
        let mut acc_indep = 0usize;
        for _ in 0..trials {
            let z_q = randn(&mut rng, gamma * v, 3.0);
            let mut z_p_same = z_q.clone();
            z_p_same.extend(randn(&mut rng, v, 3.0));
            let z_p_ind = randn(&mut rng, (gamma + 1) * v, 3.0);
            // draft sampled from q
            let mut draft = Vec::new();
            for c in 0..gamma {
                let mut row = z_q[c * v..(c + 1) * v].to_vec();
                softmax_rows(&mut row, v);
                draft.push(inverse_cdf_sample(&row, rng.uniform_f32()) as i32);
            }
            let u_acc: Vec<f32> = (0..gamma).map(|_| rng.uniform_f32()).collect();
            let o1 = spec_step(&z_p_same, &z_q, v, &draft, &u_acc, 0.5, 0.5,
                               Method::Exact, None);
            let o2 = spec_step(&z_p_ind, &z_q, v, &draft, &u_acc, 0.5, 0.5,
                               Method::Exact, None);
            acc_same += o1.accept_len;
            acc_indep += o2.accept_len;
        }
        assert_eq!(acc_same, trials * gamma);
        assert!(acc_indep < acc_same / 2, "{acc_indep} vs {acc_same}");
    }
}
