//! Segment-parallel verification kernels with zero-alloc workspaces and
//! a persistent worker pool.
//!
//! The paper's §3 observation is that the intermediate matrices of
//! speculative sampling — the softmax/sigmoid probability rows, the τ
//! ratios, the residual weights — are embarrassingly parallel across
//! matrix segments, so they can be computed concurrently by thread
//! blocks over fixed vocab chunks. This module is that partitioning
//! mapped onto CPU threads for the native verification backend:
//!
//! * **probability construction** runs one parallel region per logits
//!   matrix: whole rows per worker when the batch provides enough rows
//!   (`B·(γ+1)` target rows + `B·γ` draft rows), or per-row
//!   [`verify::VOCAB_CHUNK`] segments when a small batch meets a huge
//!   vocabulary (the `B=1, V=32k` bench regime);
//! * **acceptance** is the `O(B·γ)` τ-comparison scan — scalar, it is
//!   never the bottleneck;
//! * **resample/bonus** constructs residual rows and draws the
//!   inverse-CDF sample slot-parallel — and, at `B = 1`, chunk-parallel
//!   within the single row via blocked prefix sums
//!   (per-[`verify::VOCAB_CHUNK`] partials computed concurrently, folded
//!   in fixed order, then one block scanned element-wise).
//!
//! Parallel regions execute on the workspace-owned persistent
//! [`pool::WorkerPool`]: workers are spawned at most once (lazily, on
//! the first parallel region), parked between steps, and shut down when
//! the workspace drops. PR 3 forked and joined scoped threads for every
//! region — the CPU analogue of the per-step kernel-launch overhead §3
//! is about — so at steady state a region now costs two condvar
//! transitions instead of N spawns.
//!
//! ## Determinism
//!
//! Outputs are **bit-identical** to the scalar oracle
//! ([`verify::spec_step`] per row) for every thread count and chunk
//! size: work partitioning never reassociates a floating-point
//! reduction. Row maxima are exact under any association; row sums and
//! the inverse-CDF totals/prefixes are folded from fixed-order
//! [`verify::VOCAB_CHUNK`] block partials in both the scalar reference
//! and every parallel schedule (the same arithmetic graph, only its
//! execution order varies). The parity property tests below assert this
//! across all four [`Method`]s, chunk sizes, and thread counts —
//! including the `Sigmoid16` fp16-overflow → NaN → reject-everything
//! path and the multi-block (`V > VOCAB_CHUNK`) blocked-prefix-sum
//! sampling path.
//!
//! PR 8 extends the contract one level down, to lane width: inside
//! every [`verify::VOCAB_CHUNK`] block, sums and maxima accumulate on
//! [`verify::LANE`] independent lanes folded in fixed lane order, and
//! every exponential routes through the shared polynomial
//! [`verify::exp_approx`]. The runtime-dispatched AVX2 twins in
//! [`simd`] (`SPECD_SIMD`, default auto-detect) execute that identical
//! arithmetic graph with one ymm register as the lane accumulator, so
//! SIMD on/off is bit-identical by construction — see
//! docs/ARCHITECTURE.md, "The lane-width reduction contract". Pool
//! spans are rounded up to lane multiples ([`verify::LANE`]) so vector
//! bodies see whole lane groups; that is scheduling only and cannot
//! move a reduction boundary.
//!
//! ## Workspaces
//!
//! [`VerifyWorkspace`] owns every intermediate buffer (probability
//! matrices, residual rows, chunk partials) **and the worker pool**,
//! grown/spawned once and reused, so a steady-state
//! [`spec_step_batch_ws`] call allocates no buffers and spawns no
//! threads. [`KernelConfig::min_parallel_elems`] still gates small
//! problems onto the inline scalar schedule — a condvar round-trip is
//! cheap, but not free.
//!
//! Profiler scopes mirror the HLO backends one-to-one
//! (`verify/softmax`, `verify/kernel`, `verify/finish`) plus
//! `verify/partition` for the segment-plan + workspace bookkeeping, so
//! Δ%-profiling comparisons stay apples-to-apples.
//!
//! ## Worked example
//!
//! One batched verification step against the scalar oracle:
//!
//! ```
//! use specd::sampling::kernels::{spec_step_batch_ws, KernelConfig, VerifyWorkspace};
//! use specd::sampling::{verify, Method};
//!
//! let (b, gamma, v) = (2, 2, 8);
//! let z_p: Vec<f32> = (0..b * (gamma + 1) * v).map(|i| (i % 7) as f32).collect();
//! let z_q: Vec<f32> = (0..b * gamma * v).map(|i| (i % 5) as f32).collect();
//! let draft = vec![1i32, 2, 3, 4];
//! let u_acc = vec![0.5f32; b * gamma];
//! let (u_res, u_bonus) = (vec![0.3f32; b], vec![0.7f32; b]);
//! let methods = vec![Method::Exact, Method::sigmoid(-1e3, 1e3)];
//!
//! // the workspace owns the persistent pool; reuse it for every step
//! let mut ws = VerifyWorkspace::new(KernelConfig::default());
//! let (mut accept, mut tokens) = (Vec::new(), Vec::new());
//! spec_step_batch_ws(
//!     &mut ws, &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus,
//!     &methods, &mut accept, &mut tokens, None,
//! );
//!
//! // bit-identical to the sequential reference, for every KernelConfig
//! let (accept_ref, tokens_ref) = verify::spec_step_batch(
//!     &z_p, &z_q, b, gamma, v, &draft, &u_acc, &u_res, &u_bonus, &methods, None,
//! );
//! assert_eq!((accept, tokens), (accept_ref, tokens_ref));
//! ```

pub mod pool;
pub mod simd;

use crate::sampling::verify::{self, inverse_cdf_sample, Method, VOCAB_CHUNK};
use crate::util::timer::Profiler;

/// Scheduling knobs for the kernel layer. None of these affect results
/// (see the module docs on determinism) — only where the work runs.
#[derive(Debug, Clone, Copy)]
pub struct KernelConfig {
    /// maximum worker threads per parallel region (including the caller)
    pub threads: usize,
    /// scheduling granularity (elements) for sub-row segment work;
    /// reductions always use the fixed [`VOCAB_CHUNK`] blocks
    pub chunk: usize,
    /// matrices smaller than this many elements stay on the scalar path
    /// (a pool region costs a couple of condvar transitions — far below
    /// the old scoped-spawn cost, but at the model vocab of the toy
    /// artifact set the whole verify step is cheaper still)
    pub min_parallel_elems: usize,
    /// pin pool workers to distinct cores at spawn (opt-in via
    /// `SPECD_VERIFY_PIN=1`; best-effort, no-op where unsupported, and
    /// never affects results — placement only)
    pub pin_cores: bool,
    /// which bit-identical implementation of the lane reduction graph
    /// runs the inner loops (`SPECD_SIMD`; see [`simd::SimdMode`])
    pub simd: simd::SimdMode,
}

impl Default for KernelConfig {
    fn default() -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8);
        KernelConfig {
            threads,
            chunk: VOCAB_CHUNK,
            min_parallel_elems: 64 * 1024,
            pin_cores: false,
            simd: simd::SimdMode::Auto,
        }
    }
}

impl KernelConfig {
    /// Force the sequential path (bit-identical, useful as a reference).
    pub fn scalar() -> Self {
        KernelConfig {
            threads: 1,
            ..KernelConfig::default()
        }
    }

    pub fn with_threads(threads: usize) -> Self {
        KernelConfig {
            threads: threads.max(1),
            ..KernelConfig::default()
        }
    }

    /// Default config with `SPECD_VERIFY_THREADS` / `SPECD_VERIFY_CHUNK`
    /// / `SPECD_VERIFY_PIN` / `SPECD_SIMD` environment overrides
    /// applied. Malformed values warn and keep the default instead of
    /// being silently dropped.
    pub fn from_env() -> Self {
        let mut cfg = KernelConfig::default();
        if let Some(t) = env_usize("SPECD_VERIFY_THREADS") {
            cfg.threads = t.max(1);
        }
        if let Some(c) = env_usize("SPECD_VERIFY_CHUNK") {
            cfg.chunk = c.max(1);
        }
        if let Ok(v) = std::env::var("SPECD_VERIFY_PIN") {
            match v.trim() {
                "" | "0" | "false" => {}
                "1" | "true" => cfg.pin_cores = true,
                other => crate::warn!(
                    "ignoring malformed SPECD_VERIFY_PIN={other:?} (want 0 or 1); using default"
                ),
            }
        }
        if let Ok(v) = std::env::var("SPECD_SIMD") {
            cfg.simd = simd::SimdMode::parse(&v);
        }
        cfg
    }

    fn effective_threads(&self, elems: usize) -> usize {
        if self.threads <= 1 || elems < self.min_parallel_elems {
            1
        } else {
            self.threads
        }
    }
}

fn env_usize(key: &str) -> Option<usize> {
    match std::env::var(key) {
        Ok(raw) => parse_env_usize(key, &raw),
        Err(_) => None,
    }
}

/// Parse one `SPECD_VERIFY_*` override: empty means unset, anything
/// else must be an unsigned integer — malformed values warn once per
/// read and fall back to the default rather than vanishing silently.
fn parse_env_usize(key: &str, raw: &str) -> Option<usize> {
    let raw = raw.trim();
    if raw.is_empty() {
        return None;
    }
    match raw.parse::<usize>() {
        Ok(v) => Some(v),
        Err(_) => {
            crate::warn!(
                "ignoring malformed {key}={raw:?} (want an unsigned integer); using default"
            );
            None
        }
    }
}

/// Round a scheduling chunk up to a [`verify::LANE`] multiple so pool
/// spans hand vector bodies whole lane groups. Scheduling only: every
/// span body is element-wise or reduces over [`VOCAB_CHUNK`] blocks, so
/// span boundaries cannot move a reduction boundary.
fn align_lane(chunk: usize) -> usize {
    chunk.max(1).div_ceil(verify::LANE) * verify::LANE
}

/// Preallocated buffers + persistent worker pool for the batched
/// verification hot path. Owned by the engine's verifier and reused
/// across decode steps; `ensure` grows buffers once per high-water mark
/// and the pool spawns its workers at most once (lazily, on the first
/// parallel region), so steady-state steps allocate nothing and spawn
/// nothing. Dropping the workspace shuts down and joins the workers.
#[derive(Debug)]
pub struct VerifyWorkspace {
    pub cfg: KernelConfig,
    /// long-lived workers serving every parallel region of every step
    pool: pool::WorkerPool,
    /// target probability matrix, `B · (γ+1) · V`
    p: Vec<f32>,
    /// draft probability matrix, `B · γ · V`
    q: Vec<f32>,
    /// residual weight rows, `B · V`
    residual: Vec<f32>,
    /// per-[`VOCAB_CHUNK`] partials for the sub-row (few rows × huge V)
    /// softmax schedule and the blocked inverse-CDF prefix sums
    partials: Vec<f32>,
}

impl VerifyWorkspace {
    pub fn new(cfg: KernelConfig) -> Self {
        VerifyWorkspace {
            pool: pool::WorkerPool::with_affinity(cfg.threads, cfg.pin_cores),
            cfg,
            p: Vec::new(),
            q: Vec::new(),
            residual: Vec::new(),
            partials: Vec::new(),
        }
    }

    /// The workspace-owned persistent pool (observability/test hook —
    /// e.g. asserting that consecutive verify steps reuse the same
    /// worker threads).
    pub fn pool(&self) -> &pool::WorkerPool {
        &self.pool
    }

    /// Pre-size for a `(b, gamma, v)` step shape (optional; `ensure`
    /// also grows on demand).
    pub fn with_capacity(cfg: KernelConfig, b: usize, gamma: usize, v: usize) -> Self {
        let mut ws = Self::new(cfg);
        ws.ensure(b, gamma, v);
        ws
    }

    fn ensure(&mut self, b: usize, gamma: usize, v: usize) {
        grow(&mut self.p, b * (gamma + 1) * v);
        grow(&mut self.q, b * gamma * v);
        grow(&mut self.residual, b * v);
        grow(&mut self.partials, v.div_ceil(VOCAB_CHUNK));
    }
}

fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

/// One batched, segment-parallel speculative verification step with
/// per-slot method dispatch.
///
/// Layout matches [`verify::spec_step_batch`] / the HLO artifacts:
/// `z_p` is `(B, γ+1, V)` target logits, `z_q` is `(B, γ, V)` draft
/// logits, and `methods` carries one verification method per batch row.
/// Results are written into the caller's reusable buffers: `accept`
/// receives `B` accepted lengths, `out_tokens` receives `B · (γ+1)`
/// emitted tokens, `-1`-padded.
///
/// Bit-identical to running the scalar oracle row by row, for every
/// `KernelConfig` (see the module docs).
#[allow(clippy::too_many_arguments)]
pub fn spec_step_batch_ws(
    ws: &mut VerifyWorkspace,
    z_p: &[f32],
    z_q: &[f32],
    b: usize,
    gamma: usize,
    v: usize,
    draft: &[i32],
    u_acc: &[f32],
    u_res: &[f32],
    u_bonus: &[f32],
    methods: &[Method],
    accept: &mut Vec<i32>,
    out_tokens: &mut Vec<i32>,
    profiler: Option<&Profiler>,
) {
    debug_assert_eq!(z_p.len(), b * (gamma + 1) * v);
    debug_assert_eq!(z_q.len(), b * gamma * v);
    debug_assert_eq!(draft.len(), b * gamma);
    debug_assert_eq!(u_acc.len(), b * gamma);
    debug_assert_eq!(u_res.len(), b);
    debug_assert_eq!(u_bonus.len(), b);
    assert_eq!(methods.len(), b, "one method per batch row");

    accept.clear();
    accept.resize(b, 0);
    out_tokens.clear();
    out_tokens.resize(b * (gamma + 1), -1);

    // --- segment plan + workspace bookkeeping
    let (threads, chunk, simd) = {
        let _g = profiler.map(|pr| pr.scope("verify/partition"));
        ws.ensure(b, gamma, v);
        let elems = b * (2 * gamma + 1) * v;
        (
            ws.cfg.effective_threads(elems),
            align_lane(ws.cfg.chunk),
            ws.cfg.simd.active(),
        )
    };
    let VerifyWorkspace {
        p, q, residual, partials, pool, ..
    } = ws;
    let pool = &*pool;
    let p = &mut p[..b * (gamma + 1) * v];
    let q = &mut q[..b * gamma * v];
    let residual = &mut residual[..b * v];

    // --- probability construction (the scalar path's "softmax" scope;
    // sigmoid methods replace the op, not the scope)
    {
        let _g = profiler.map(|pr| pr.scope("verify/softmax"));
        construct_matrix(
            pool,
            threads,
            chunk,
            z_p,
            &mut *p,
            v,
            &|r| methods[r / (gamma + 1)],
            &mut partials[..],
            simd,
        );
        construct_matrix(
            pool,
            threads,
            chunk,
            z_q,
            &mut *q,
            v,
            &|r| methods[r / gamma],
            &mut partials[..],
            simd,
        );
    }

    // --- acceptance scan (τ at the drafted tokens)
    {
        let _g = profiler.map(|pr| pr.scope("verify/kernel"));
        for i in 0..b {
            let mut alen = gamma;
            for c in 0..gamma {
                let x = draft[i * gamma + c] as usize;
                let pp = p[(i * (gamma + 1) + c) * v + x];
                let qq = q[(i * gamma + c) * v + x];
                if !verify::accept_decision(pp, qq, u_acc[i * gamma + c], methods[i]) {
                    alen = c;
                    break;
                }
            }
            accept[i] = alen as i32;
        }
    }

    // --- resample / bonus
    {
        let _g = profiler.map(|pr| pr.scope("verify/finish"));
        let p = &*p;
        let q = &*q;
        let accept = &accept[..];
        if b == 1 && threads > 1 {
            // single slot: segment-parallel residual construction, then
            // the chunk-parallel blocked-prefix-sum inverse-CDF lookup
            let alen = accept[0] as usize;
            out_tokens[..alen].copy_from_slice(&draft[..alen]);
            if alen == gamma {
                let bonus = &p[gamma * v..][..v];
                out_tokens[gamma] = inverse_cdf_sample_blocked(
                    pool, threads, bonus, u_bonus[0], partials, simd,
                ) as i32;
            } else {
                let prow = &p[alen * v..][..v];
                let qrow = &q[alen * v..][..v];
                pool::for_each_span(pool, threads, &mut *residual, chunk, |first, span| {
                    let off = first * chunk;
                    residual_into(
                        &prow[off..off + span.len()],
                        &qrow[off..off + span.len()],
                        span,
                        simd,
                    );
                });
                out_tokens[alen] = inverse_cdf_sample_blocked(
                    pool, threads, residual, u_res[0], partials, simd,
                ) as i32;
            }
        } else {
            // slot-parallel: each worker finishes a run of slots
            pool::for_each_span2(
                pool,
                threads.min(b),
                residual,
                v,
                &mut out_tokens[..],
                gamma + 1,
                |first_slot, res_span, tok_span| {
                    let slots = res_span.len() / v;
                    for k in 0..slots {
                        let i = first_slot + k;
                        let alen = accept[i] as usize;
                        let trow = &mut tok_span[k * (gamma + 1)..][..gamma + 1];
                        trow[..alen].copy_from_slice(&draft[i * gamma..i * gamma + alen]);
                        if alen == gamma {
                            let bonus = &p[(i * (gamma + 1) + gamma) * v..][..v];
                            trow[gamma] = inverse_cdf_sample(bonus, u_bonus[i]) as i32;
                        } else {
                            let res = &mut res_span[k * v..][..v];
                            let prow = &p[(i * (gamma + 1) + alen) * v..][..v];
                            let qrow = &q[(i * gamma + alen) * v..][..v];
                            residual_into(prow, qrow, res, simd);
                            trow[alen] = inverse_cdf_sample(res, u_res[i]) as i32;
                        }
                    }
                },
            );
        }
    }
}

/// Build probability rows from logits: `dst[r] = construct(src row r)`
/// under `method_of(r)` — a row→method mapping so the rectangular
/// schedules (`r / rows_per_slot`) and the ragged prefix-table lookup
/// share one implementation.
#[allow(clippy::too_many_arguments)]
fn construct_matrix(
    pool: &pool::WorkerPool,
    threads: usize,
    chunk: usize,
    src: &[f32],
    dst: &mut [f32],
    v: usize,
    method_of: &(dyn Fn(usize) -> Method + Sync),
    partials: &mut [f32],
    simd: bool,
) {
    let rows = dst.len() / v;
    if rows == 0 || v == 0 {
        return;
    }
    if threads > 1 && rows < threads && v > VOCAB_CHUNK {
        // sub-row schedule: few rows meeting a huge vocabulary — split
        // each row over vocab segments
        for r in 0..rows {
            construct_row_subrow(
                pool,
                threads,
                chunk,
                &src[r * v..][..v],
                &mut dst[r * v..][..v],
                method_of(r),
                &mut *partials,
                simd,
            );
        }
    } else {
        // row schedule: whole rows per worker (one pool region);
        // threads == 1 degenerates to the inline scalar loop
        pool::for_each_span(pool, threads, dst, v, |first_row, span| {
            for (k, drow) in span.chunks_mut(v).enumerate() {
                let r = first_row + k;
                construct_row_from(&src[r * v..][..v], drow, method_of(r), simd);
            }
        });
    }
}

/// Slot owning ragged row `r` under prefix table `off` (`off[i] ≤ r <
/// off[i+1]`; zero-row slots are skipped by construction).
fn slot_of_row(off: &[usize], r: usize) -> usize {
    off.partition_point(|&o| o <= r) - 1
}

/// One batched speculative verification step over **ragged per-slot γ**
/// row spans.
///
/// Slot `i` runs `gammas[i]` drafts: its draft rows (`z_q`, `draft`,
/// `u_acc`) live at `q_off[i]..q_off[i+1]` and its target rows (`z_p`,
/// `out_tokens`) at `p_off[i]..p_off[i+1]`, with `q_off`/`p_off` the
/// γ-prefix tables (`q_off[i] = Σ_{j<i} γⱼ`, `p_off[i] = Σ_{j<i}
/// (γⱼ+1)` counting only slots with `γⱼ > 0`). A slot with `gammas[i] ==
/// 0` (an empty engine slot) contributes no rows and gets `accept[i] =
/// 0`.
///
/// When every slot carries the **same** non-zero γ the ragged layout
/// coincides with the rectangular one and this delegates verbatim to
/// [`spec_step_batch_ws`] — uniform batches keep the slot-parallel /
/// chunk-parallel finish schedules (and their benchmarked performance)
/// unchanged. Genuinely ragged batches run the same probability
/// construction schedules (row→method resolved through the prefix
/// table) and a sequential per-slot finish: [`pool::for_each_span2`]
/// needs uniform span units, which ragged token spans don't have, and
/// mixed-γ batches are bounded by the *largest* slot's model calls
/// anyway. Either way the result is bit-identical to running the scalar
/// oracle ([`verify::spec_step`]) per slot on its slices.
#[allow(clippy::too_many_arguments)]
pub fn spec_step_ragged_ws(
    ws: &mut VerifyWorkspace,
    z_p: &[f32],
    z_q: &[f32],
    b: usize,
    gammas: &[usize],
    q_off: &[usize],
    p_off: &[usize],
    v: usize,
    draft: &[i32],
    u_acc: &[f32],
    u_res: &[f32],
    u_bonus: &[f32],
    methods: &[Method],
    accept: &mut Vec<i32>,
    out_tokens: &mut Vec<i32>,
    profiler: Option<&Profiler>,
) {
    assert_eq!(gammas.len(), b, "one γ per batch slot");
    assert_eq!(methods.len(), b, "one method per batch slot");
    debug_assert_eq!(q_off.len(), b + 1);
    debug_assert_eq!(p_off.len(), b + 1);
    let total_q = q_off[b];
    let total_p = p_off[b];
    debug_assert_eq!(z_p.len(), total_p * v);
    debug_assert_eq!(z_q.len(), total_q * v);
    debug_assert_eq!(draft.len(), total_q);
    debug_assert_eq!(u_acc.len(), total_q);
    debug_assert_eq!(u_res.len(), b);
    debug_assert_eq!(u_bonus.len(), b);

    // uniform fast path: identical layout ⇒ identical schedules
    if b > 0 && gammas[0] > 0 && gammas.iter().all(|&g| g == gammas[0]) {
        return spec_step_batch_ws(
            ws, z_p, z_q, b, gammas[0], v, draft, u_acc, u_res, u_bonus, methods, accept,
            out_tokens, profiler,
        );
    }

    accept.clear();
    accept.resize(b, 0);
    out_tokens.clear();
    out_tokens.resize(total_p, -1);
    if total_p == 0 {
        return;
    }

    // --- segment plan + workspace bookkeeping
    let gmax = gammas.iter().copied().max().unwrap_or(0);
    let (threads, chunk, simd) = {
        let _g = profiler.map(|pr| pr.scope("verify/partition"));
        ws.ensure(b, gmax, v);
        let elems = (total_p + total_q) * v;
        (
            ws.cfg.effective_threads(elems),
            align_lane(ws.cfg.chunk),
            ws.cfg.simd.active(),
        )
    };
    let VerifyWorkspace {
        p, q, residual, partials, pool, ..
    } = ws;
    let pool = &*pool;
    let p = &mut p[..total_p * v];
    let q = &mut q[..total_q * v];
    let residual = &mut residual[..b * v];

    // --- probability construction over the ragged rows
    {
        let _g = profiler.map(|pr| pr.scope("verify/softmax"));
        construct_matrix(
            pool,
            threads,
            chunk,
            z_p,
            &mut *p,
            v,
            &|r| methods[slot_of_row(p_off, r)],
            &mut partials[..],
            simd,
        );
        construct_matrix(
            pool,
            threads,
            chunk,
            z_q,
            &mut *q,
            v,
            &|r| methods[slot_of_row(q_off, r)],
            &mut partials[..],
            simd,
        );
    }

    // --- acceptance scan (τ at the drafted tokens)
    {
        let _g = profiler.map(|pr| pr.scope("verify/kernel"));
        for i in 0..b {
            let g = gammas[i];
            let mut alen = g;
            for c in 0..g {
                let r = q_off[i] + c;
                let x = draft[r] as usize;
                let pp = p[(p_off[i] + c) * v + x];
                let qq = q[r * v + x];
                if !verify::accept_decision(pp, qq, u_acc[r], methods[i]) {
                    alen = c;
                    break;
                }
            }
            accept[i] = alen as i32;
        }
    }

    // --- resample / bonus: sequential per slot (ragged token spans
    // have no uniform unit for the span2 schedule; see the docs above)
    {
        let _g = profiler.map(|pr| pr.scope("verify/finish"));
        let p = &*p;
        let q = &*q;
        for i in 0..b {
            let g = gammas[i];
            if g == 0 {
                continue;
            }
            let alen = accept[i] as usize;
            let trow = &mut out_tokens[p_off[i]..p_off[i] + g + 1];
            trow[..alen].copy_from_slice(&draft[q_off[i]..q_off[i] + alen]);
            if alen == g {
                let bonus = &p[(p_off[i] + g) * v..][..v];
                trow[g] = inverse_cdf_sample(bonus, u_bonus[i]) as i32;
            } else {
                let res = &mut residual[i * v..][..v];
                let prow = &p[(p_off[i] + alen) * v..][..v];
                let qrow = &q[(q_off[i] + alen) * v..][..v];
                residual_into(prow, qrow, res, simd);
                trow[alen] = inverse_cdf_sample(res, u_res[i]) as i32;
            }
        }
    }
}

/// `dst = P(src)` for one logit row under `method` — softmax for
/// `Baseline`/`Exact`, the element-wise sigmoid approximations
/// otherwise. This is the single probability-construction primitive
/// every kernel schedule routes through, exported so other layers that
/// must reproduce a verification row **bit-for-bit** (the pipelined
/// scheduler's bonus-token prediction in
/// [`crate::engine`]) share the exact arithmetic graph
/// instead of reimplementing it.
pub fn construct_prob_row(src: &[f32], dst: &mut [f32], method: Method) {
    construct_row_from(src, dst, method, env_simd_active())
}

/// A borrowed logit row in either storage precision. The half-precision
/// variant carries raw IEEE binary16 bit patterns (the accelerator's
/// native logit dtype for the sigmoid16 pipeline); ingestion widens
/// exactly — every f16 value is representable in f32 — so constructing
/// from `F16(h)` is bit-identical to widening first and constructing
/// from the f32 row, without the staging copy.
#[derive(Debug, Clone, Copy)]
pub enum Logits<'a> {
    F32(&'a [f32]),
    F16(&'a [u16]),
}

impl Logits<'_> {
    pub fn len(&self) -> usize {
        match self {
            Logits::F32(s) => s.len(),
            Logits::F16(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<'a> From<&'a [f32]> for Logits<'a> {
    fn from(s: &'a [f32]) -> Self {
        Logits::F32(s)
    }
}

impl<'a> From<&'a [u16]> for Logits<'a> {
    fn from(s: &'a [u16]) -> Self {
        Logits::F16(s)
    }
}

/// [`construct_prob_row`] over either logit precision. fp16 rows fuse
/// the widening into the probability-construction pass: bits are
/// widened directly into `dst` and the in-place constructors run on
/// top, so the f16→f32 conversion never materialises a second staging
/// row (the ingestion bandwidth is the halved f16 read plus the write
/// the construction pass performs anyway).
pub fn construct_prob_row_logits(src: Logits<'_>, dst: &mut [f32], method: Method) {
    match src {
        Logits::F32(s) => construct_row_from(s, dst, method, env_simd_active()),
        Logits::F16(s) => {
            debug_assert_eq!(s.len(), dst.len());
            for (d, &h) in dst.iter_mut().zip(s) {
                *d = verify::f16_bits_to_f32(h);
            }
            match method {
                Method::Baseline | Method::Exact => verify::softmax_row(dst),
                Method::Sigmoid { .. } => {
                    let (alpha, beta) = method.alpha_beta().unwrap();
                    verify::sigmoid_approx(dst, alpha, beta);
                }
                Method::Sigmoid16 { .. } => {
                    let (alpha, beta) = method.alpha_beta().unwrap();
                    verify::sigmoid_approx_fp16(dst, alpha, beta);
                }
            }
        }
    }
}

/// `SPECD_SIMD` resolved once for the standalone row entry points
/// (the engine's bonus prediction); the step kernels resolve their own
/// [`KernelConfig::simd`] per workspace. Either resolution is
/// bit-identical, so caching cannot cause divergence.
fn env_simd_active() -> bool {
    static ACTIVE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ACTIVE.get_or_init(|| {
        std::env::var("SPECD_SIMD")
            .map(|v| simd::SimdMode::parse(&v))
            .unwrap_or(simd::SimdMode::Auto)
            .active()
    })
}

fn construct_row_from(src: &[f32], dst: &mut [f32], method: Method, simd: bool) {
    match method {
        Method::Baseline | Method::Exact => {
            if simd {
                simd::softmax_row_from(src, dst);
            } else {
                verify::softmax_row_from(src, dst);
            }
        }
        Method::Sigmoid { .. } => {
            let (alpha, beta) = method.alpha_beta().unwrap();
            if simd {
                simd::sigmoid_row_from(src, dst, alpha, beta);
            } else {
                verify::sigmoid_row_from(src, dst, alpha, beta);
            }
        }
        Method::Sigmoid16 { .. } => {
            // the fp16 τ chain narrows through f16_round per element;
            // it stays scalar on every path (never the bottleneck, and
            // one implementation is easier to keep bit-exact)
            let (alpha, beta) = method.alpha_beta().unwrap();
            verify::sigmoid16_row_from(src, dst, alpha, beta);
        }
    }
}

/// `dst = max(p - q, 0)` — one residual block on the dispatched lane
/// path. Element-wise, so span partitioning cannot affect results.
fn residual_into(p: &[f32], q: &[f32], dst: &mut [f32], simd: bool) {
    if simd {
        simd::residual_block(p, q, dst);
    } else {
        for ((r, &pp), &qq) in dst.iter_mut().zip(p).zip(q) {
            *r = (pp - qq).max(0.0);
        }
    }
}

/// Block max on the dispatched lane path ([`verify::lane_max`] twin).
fn block_max(xs: &[f32], simd: bool) -> f32 {
    if simd {
        simd::lane_max_block(xs)
    } else {
        verify::lane_max(xs)
    }
}

/// Block sum on the dispatched lane path ([`verify::lane_sum`] twin).
fn block_sum(xs: &[f32], simd: bool) -> f32 {
    if simd {
        simd::lane_sum_block(xs)
    } else {
        verify::lane_sum(xs)
    }
}

/// `dst = exp(src - max)` + block sum on the dispatched lane path.
fn exp_sub_sum(src: &[f32], dst: &mut [f32], max: f32, simd: bool) -> f32 {
    if simd {
        simd::exp_sub_sum_block(src, dst, max)
    } else {
        verify::exp_sub_sum_block(src, dst, max)
    }
}

/// `dst *= inv` on the dispatched lane path (element-wise).
fn scale_span(dst: &mut [f32], inv: f32, simd: bool) {
    if simd {
        simd::scale_block(dst, inv);
    } else {
        for e in dst.iter_mut() {
            *e *= inv;
        }
    }
}

/// One row partitioned over vocab segments. Sigmoid methods are
/// element-wise (one region); softmax runs the three-phase schedule —
/// parallel block maxima, parallel exp + block sums, parallel scale —
/// with the [`VOCAB_CHUNK`] partials folded in fixed order between
/// phases, reproducing the scalar reduction graph exactly.
#[allow(clippy::too_many_arguments)]
fn construct_row_subrow(
    pool: &pool::WorkerPool,
    threads: usize,
    chunk: usize,
    src: &[f32],
    dst: &mut [f32],
    method: Method,
    partials: &mut [f32],
    simd: bool,
) {
    match method {
        Method::Sigmoid { .. } | Method::Sigmoid16 { .. } => {
            let (alpha, beta) = method.alpha_beta().unwrap();
            let fp16 = matches!(method, Method::Sigmoid16 { .. });
            pool::for_each_span(pool, threads, dst, chunk, |first, span| {
                let off = first * chunk;
                let sblk = &src[off..off + span.len()];
                if fp16 {
                    verify::sigmoid16_row_from(sblk, span, alpha, beta);
                } else if simd {
                    simd::sigmoid_row_from(sblk, span, alpha, beta);
                } else {
                    verify::sigmoid_row_from(sblk, span, alpha, beta);
                }
            });
        }
        Method::Baseline | Method::Exact => {
            let v = src.len();
            let nblk = v.div_ceil(VOCAB_CHUNK);
            let parts = &mut partials[..nblk];
            // phase 1: per-block lane-graph maxima (max over the lane
            // graph is exact under any block association — NaN never
            // wins a comparison, so block maxima compose)
            pool::for_each_span(pool, threads, &mut *parts, 1, |first, span| {
                for (k, m) in span.iter_mut().enumerate() {
                    let off = (first + k) * VOCAB_CHUNK;
                    let blk = &src[off..(off + VOCAB_CHUNK).min(v)];
                    *m = block_max(blk, simd);
                }
            });
            let mut max = f32::NEG_INFINITY;
            for &part in parts.iter() {
                if part > max {
                    max = part;
                }
            }
            // phase 2: exp + per-block lane-graph partial sums
            pool::for_each_span2(
                pool,
                threads,
                &mut *dst,
                VOCAB_CHUNK,
                &mut *parts,
                1,
                |first, dspan, pspan| {
                    for (k, part) in pspan.iter_mut().enumerate() {
                        let off = (first + k) * VOCAB_CHUNK;
                        let len = VOCAB_CHUNK.min(v - off);
                        let d = &mut dspan[k * VOCAB_CHUNK..][..len];
                        let s = &src[off..off + len];
                        *part = exp_sub_sum(s, d, max, simd);
                    }
                },
            );
            // fixed-order fold of the block partials — identical to the
            // scalar reference's chunk loop
            let mut sum = 0.0f32;
            for &part in parts.iter() {
                sum += part;
            }
            let inv = 1.0 / sum;
            // phase 3: scale
            pool::for_each_span(pool, threads, &mut *dst, VOCAB_CHUNK, |_, span| {
                scale_span(span, inv, simd);
            });
        }
    }
}

/// Chunk-parallel inverse-CDF draw via blocked prefix sums — the
/// parallel twin of [`verify::inverse_cdf_sample`], bit-identical to it
/// for every thread count.
///
/// Only stage 1 differs from the scalar reference: the
/// per-[`VOCAB_CHUNK`] partial sums are computed **in parallel** (each
/// block's partial is a pure sequential sum of that block, so which
/// lane computes it cannot change the value). Stages 2–3 — the
/// fixed-order fold, winning-block lookup, and in-block scan — are the
/// literal shared code path `verify::inverse_cdf_from_partials`, so the
/// two implementations cannot drift apart.
pub(crate) fn inverse_cdf_sample_blocked(
    pool: &pool::WorkerPool,
    threads: usize,
    weights: &[f32],
    u: f32,
    partials: &mut [f32],
    simd: bool,
) -> usize {
    let v = weights.len();
    if v <= VOCAB_CHUNK || threads <= 1 {
        // single block (or no parallelism): the scalar reference IS the
        // blocked graph
        return inverse_cdf_sample(weights, u);
    }
    let nblk = v.div_ceil(VOCAB_CHUNK);
    let parts = &mut partials[..nblk];
    // stage 1: parallel per-block lane-graph partial sums — the same
    // [`verify::lane_sum`] graph the scalar reference folds per block
    pool::for_each_span(pool, threads, &mut *parts, 1, |first, span| {
        for (k, s) in span.iter_mut().enumerate() {
            let off = (first + k) * VOCAB_CHUNK;
            let blk = &weights[off..(off + VOCAB_CHUNK).min(v)];
            *s = block_sum(blk, simd);
        }
    });
    // stages 2-3: shared with the scalar reference
    verify::inverse_cdf_from_partials(weights, parts, u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::verify::spec_step_batch;
    use crate::util::proptest::{forall, Config};
    use crate::util::rng::Pcg32;

    fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
    }

    struct Case {
        b: usize,
        gamma: usize,
        v: usize,
        z_p: Vec<f32>,
        z_q: Vec<f32>,
        draft: Vec<i32>,
        u_acc: Vec<f32>,
        u_res: Vec<f32>,
        u_bonus: Vec<f32>,
        methods: Vec<Method>,
    }

    fn make_case(rng: &mut Pcg32, b: usize, gamma: usize, v: usize) -> Case {
        let pool = [
            Method::Baseline,
            Method::Exact,
            Method::sigmoid(-1e3, 1e3),
            Method::sigmoid16(-1e3, 1e3),
            // the Table 2 fp16-overflow row: NaN τ rejects everything
            Method::sigmoid16(-1e5, 1e5),
        ];
        Case {
            b,
            gamma,
            v,
            z_p: randn(rng, b * (gamma + 1) * v, 3.0),
            z_q: randn(rng, b * gamma * v, 3.0),
            draft: (0..b * gamma).map(|_| rng.below(v as u32) as i32).collect(),
            u_acc: (0..b * gamma).map(|_| rng.uniform_f32()).collect(),
            u_res: (0..b).map(|_| rng.uniform_f32()).collect(),
            u_bonus: (0..b).map(|_| rng.uniform_f32()).collect(),
            methods: (0..b)
                .map(|_| pool[rng.below(pool.len() as u32) as usize])
                .collect(),
        }
    }

    fn run_ws(case: &Case, cfg: KernelConfig) -> (Vec<i32>, Vec<i32>) {
        let mut ws = VerifyWorkspace::new(cfg);
        let mut accept = Vec::new();
        let mut tokens = Vec::new();
        spec_step_batch_ws(
            &mut ws,
            &case.z_p,
            &case.z_q,
            case.b,
            case.gamma,
            case.v,
            &case.draft,
            &case.u_acc,
            &case.u_res,
            &case.u_bonus,
            &case.methods,
            &mut accept,
            &mut tokens,
            None,
        );
        (accept, tokens)
    }

    fn run_oracle(case: &Case) -> (Vec<i32>, Vec<i32>) {
        spec_step_batch(
            &case.z_p,
            &case.z_q,
            case.b,
            case.gamma,
            case.v,
            &case.draft,
            &case.u_acc,
            &case.u_res,
            &case.u_bonus,
            &case.methods,
            None,
        )
    }

    fn force_parallel(mut cfg: KernelConfig) -> KernelConfig {
        cfg.min_parallel_elems = 0;
        cfg
    }

    #[test]
    fn parallel_kernels_bit_identical_to_scalar_oracle() {
        // the acceptance criterion: accept lengths and emitted tokens
        // match the scalar oracle exactly, for every thread count, with
        // heterogeneous per-row methods drawn from all four Methods
        forall(
            "kernel parity",
            Config { cases: 60, ..Config::default() },
            |rng, size| {
                let v = 4 + size * 3;
                let gamma = 1 + (size % 6);
                let b = 1 + (size % 5);
                let case = make_case(rng, b, gamma, v);
                let expect = run_oracle(&case);
                for threads in [1usize, 2, 3, 8] {
                    let cfg = force_parallel(KernelConfig::with_threads(threads));
                    let got = run_ws(&case, cfg);
                    if got != expect {
                        return Err(format!(
                            "threads={threads} b={b} γ={gamma} v={v}: {got:?} != {expect:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn chunk_size_never_changes_results() {
        // the scheduling chunk is not the reduction chunk: any value
        // must reproduce the oracle bit-for-bit
        forall(
            "chunk invariance",
            Config { cases: 30, ..Config::default() },
            |rng, size| {
                let v = 8 + size * 4;
                // b = 1 exercises the segment-parallel residual path,
                // where the scheduling chunk actually bites
                let b = 1 + (size % 2);
                let case = make_case(rng, b, 3, v);
                let expect = run_oracle(&case);
                for chunk in [1usize, 7, 64, VOCAB_CHUNK] {
                    for threads in [2usize, 5] {
                        let mut cfg = force_parallel(KernelConfig::with_threads(threads));
                        cfg.chunk = chunk;
                        let got = run_ws(&case, cfg);
                        if got != expect {
                            return Err(format!(
                                "chunk={chunk} threads={threads} v={v}: mismatch"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn subrow_schedule_matches_oracle_at_large_vocab() {
        // rows < threads && v > VOCAB_CHUNK exercises the three-phase
        // per-row segment schedule
        let mut rng = Pcg32::seeded(77);
        for method in [
            Method::Exact,
            Method::Baseline,
            Method::sigmoid(-1e3, 1e3),
            Method::sigmoid16(-1e3, 1e3),
        ] {
            let mut case = make_case(&mut rng, 1, 1, VOCAB_CHUNK + 513);
            case.methods = vec![method];
            let expect = run_oracle(&case);
            let got = run_ws(&case, force_parallel(KernelConfig::with_threads(8)));
            assert_eq!(got, expect, "method {}", method.name());
        }
    }

    #[test]
    fn sigmoid16_overflow_rejects_everything_through_parallel_path() {
        let mut rng = Pcg32::seeded(78);
        let mut case = make_case(&mut rng, 3, 4, 32);
        // row 1 overflows fp16 (NaN τ → reject all); the neighbours keep
        // their methods — per-slot dispatch must isolate the damage
        case.methods = vec![
            Method::Exact,
            Method::sigmoid16(-1e5, 1e5),
            Method::sigmoid(-1e3, 1e3),
        ];
        // u = 0 accepts unconditionally everywhere EXCEPT against a NaN τ
        for u in case.u_acc.iter_mut() {
            *u = 0.0;
        }
        let expect = run_oracle(&case);
        for threads in [1usize, 4] {
            let got = run_ws(&case, force_parallel(KernelConfig::with_threads(threads)));
            assert_eq!(got, expect);
            assert_eq!(got.0[1], 0, "NaN τ must reject every draft in row 1");
        }
    }

    #[test]
    fn workspace_reuse_does_not_leak_state_across_steps() {
        let mut rng = Pcg32::seeded(79);
        let cfg = force_parallel(KernelConfig::with_threads(4));
        let mut ws = VerifyWorkspace::new(cfg);
        let mut accept = Vec::new();
        let mut tokens = Vec::new();
        // shrink then grow: (b, γ, v) changes between steps
        for (b, gamma, v) in [(4usize, 5usize, 64usize), (1, 2, 16), (3, 6, 80)] {
            let case = make_case(&mut rng, b, gamma, v);
            spec_step_batch_ws(
                &mut ws,
                &case.z_p,
                &case.z_q,
                b,
                gamma,
                v,
                &case.draft,
                &case.u_acc,
                &case.u_res,
                &case.u_bonus,
                &case.methods,
                &mut accept,
                &mut tokens,
                None,
            );
            assert_eq!((accept.clone(), tokens.clone()), run_oracle(&case));
        }
    }

    #[test]
    fn consecutive_verify_steps_reuse_the_same_worker_threads() {
        // the tentpole regression: the workspace-owned pool hands the
        // SAME OS threads to every decode step — no per-step spawns —
        // and shuts them down cleanly when the workspace drops
        use std::collections::HashSet;
        use std::sync::Mutex;

        let mut rng = Pcg32::seeded(81);
        let cfg = force_parallel(KernelConfig::with_threads(4));
        let mut ws = VerifyWorkspace::new(cfg);
        let width = ws.pool().width();
        assert!(width > 1, "threads=4 must spawn workers");

        let lane_ids = |ws: &VerifyWorkspace| -> HashSet<std::thread::ThreadId> {
            let ids = Mutex::new(HashSet::new());
            ws.pool().run(width * 4, &|_| {
                ids.lock().unwrap().insert(std::thread::current().id());
            });
            ids.into_inner().unwrap()
        };
        let lanes = lane_ids(&ws);
        assert_eq!(lanes.len(), width, "every lane participates");

        let (mut accept, mut tokens) = (Vec::new(), Vec::new());
        for step in 0..3 {
            let case = make_case(&mut rng, 2, 3, 48);
            spec_step_batch_ws(
                &mut ws,
                &case.z_p,
                &case.z_q,
                case.b,
                case.gamma,
                case.v,
                &case.draft,
                &case.u_acc,
                &case.u_res,
                &case.u_bonus,
                &case.methods,
                &mut accept,
                &mut tokens,
                None,
            );
            assert_eq!((accept.clone(), tokens.clone()), run_oracle(&case));
            assert_eq!(lane_ids(&ws), lanes, "step {step}: same threads");
        }
        // drop joins the workers — must return, not hang or leak
        drop(ws);
    }

    #[test]
    fn blocked_inverse_cdf_matches_scalar_for_every_schedule() {
        // direct parity of the chunk-parallel prefix-sum draw against
        // the scalar reference, across thread counts and multi-block
        // vocab sizes (incl. ragged final blocks and zero/NaN mass)
        let mut rng = Pcg32::seeded(82);
        let pool = pool::WorkerPool::new(4);
        for v in [
            VOCAB_CHUNK + 1,
            2 * VOCAB_CHUNK,
            2 * VOCAB_CHUNK + 513,
            3 * VOCAB_CHUNK + 7,
        ] {
            let mut partials = vec![0.0f32; v.div_ceil(VOCAB_CHUNK)];
            for case in 0..6 {
                let mut w: Vec<f32> =
                    (0..v).map(|_| rng.uniform_f32().max(0.0)).collect();
                match case {
                    // concentrate mass at a boundary-straddling index
                    0 => {
                        for x in w.iter_mut() {
                            *x = 0.0;
                        }
                        w[VOCAB_CHUNK - 1] = 0.5;
                        w[VOCAB_CHUNK] = 0.5;
                    }
                    // zero mass -> argmax arm
                    1 => {
                        for x in w.iter_mut() {
                            *x = 0.0;
                        }
                    }
                    // NaN total -> argmax arm
                    2 => {
                        w[v / 2] = f32::NAN;
                    }
                    _ => {}
                }
                for u in [0.0f32, 0.25, 0.5, 0.999, rng.uniform_f32()] {
                    let expect = inverse_cdf_sample(&w, u);
                    for threads in [2usize, 3, 8] {
                        // both lane paths: scalar always, AVX2 when the
                        // host has it (simd::have_avx2() is false
                        // elsewhere, collapsing to the scalar case)
                        for simd in [false, simd::have_avx2()] {
                            let got = inverse_cdf_sample_blocked(
                                &pool,
                                threads,
                                &w,
                                u,
                                &mut partials,
                                simd,
                            );
                            assert_eq!(
                                got, expect,
                                "v={v} case={case} u={u} threads={threads} simd={simd}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn multi_block_sampling_parity_across_methods_threads_chunks() {
        // the b=1 blocked-prefix-sum path inside the full step: v spans
        // multiple VOCAB_CHUNK blocks, so the resample/bonus draw runs
        // the parallel prefix-sum lookup — must stay bit-identical to
        // the scalar oracle for all four methods × threads × chunks
        forall(
            "blocked-cdf step parity",
            Config { cases: 8, ..Config::default() },
            |rng, size| {
                let v = VOCAB_CHUNK + 257 + size * 101;
                let gamma = 1 + (size % 3);
                let case = make_case(rng, 1, gamma, v);
                let expect = run_oracle(&case);
                for threads in [2usize, 3, 8] {
                    for chunk in [64usize, VOCAB_CHUNK] {
                        let mut cfg = force_parallel(KernelConfig::with_threads(threads));
                        cfg.chunk = chunk;
                        let got = run_ws(&case, cfg);
                        if got != expect {
                            return Err(format!(
                                "threads={threads} chunk={chunk} γ={gamma} v={v}: \
                                 {got:?} != {expect:?}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    struct RaggedCase {
        b: usize,
        v: usize,
        gammas: Vec<usize>,
        q_off: Vec<usize>,
        p_off: Vec<usize>,
        z_p: Vec<f32>,
        z_q: Vec<f32>,
        draft: Vec<i32>,
        u_acc: Vec<f32>,
        u_res: Vec<f32>,
        u_bonus: Vec<f32>,
        methods: Vec<Method>,
    }

    fn make_ragged_case(rng: &mut Pcg32, gammas: &[usize], v: usize) -> RaggedCase {
        let pool = [
            Method::Baseline,
            Method::Exact,
            Method::sigmoid(-1e3, 1e3),
            Method::sigmoid16(-1e3, 1e3),
            Method::sigmoid16(-1e5, 1e5),
        ];
        let b = gammas.len();
        let (mut q_off, mut p_off) = (vec![0usize], vec![0usize]);
        for &g in gammas {
            q_off.push(q_off.last().unwrap() + g);
            p_off.push(p_off.last().unwrap() + if g > 0 { g + 1 } else { 0 });
        }
        let (tq, tp) = (q_off[b], p_off[b]);
        RaggedCase {
            b,
            v,
            gammas: gammas.to_vec(),
            q_off,
            p_off,
            z_p: randn(rng, tp * v, 3.0),
            z_q: randn(rng, tq * v, 3.0),
            draft: (0..tq).map(|_| rng.below(v as u32) as i32).collect(),
            u_acc: (0..tq).map(|_| rng.uniform_f32()).collect(),
            u_res: (0..b).map(|_| rng.uniform_f32()).collect(),
            u_bonus: (0..b).map(|_| rng.uniform_f32()).collect(),
            methods: (0..b)
                .map(|_| pool[rng.below(pool.len() as u32) as usize])
                .collect(),
        }
    }

    fn run_ragged_ws(case: &RaggedCase, cfg: KernelConfig) -> (Vec<i32>, Vec<i32>) {
        let mut ws = VerifyWorkspace::new(cfg);
        let (mut accept, mut tokens) = (Vec::new(), Vec::new());
        spec_step_ragged_ws(
            &mut ws,
            &case.z_p,
            &case.z_q,
            case.b,
            &case.gammas,
            &case.q_off,
            &case.p_off,
            case.v,
            &case.draft,
            &case.u_acc,
            &case.u_res,
            &case.u_bonus,
            &case.methods,
            &mut accept,
            &mut tokens,
            None,
        );
        (accept, tokens)
    }

    /// The scalar oracle run per slot on its ragged slices.
    fn run_ragged_oracle(case: &RaggedCase) -> (Vec<i32>, Vec<i32>) {
        let v = case.v;
        let mut accept = vec![0i32; case.b];
        let mut tokens = vec![-1i32; case.p_off[case.b]];
        for i in 0..case.b {
            let g = case.gammas[i];
            if g == 0 {
                continue;
            }
            let (q0, p0) = (case.q_off[i], case.p_off[i]);
            let out = crate::sampling::verify::spec_step(
                &case.z_p[p0 * v..(p0 + g + 1) * v],
                &case.z_q[q0 * v..(q0 + g) * v],
                v,
                &case.draft[q0..q0 + g],
                &case.u_acc[q0..q0 + g],
                case.u_res[i],
                case.u_bonus[i],
                case.methods[i],
                None,
            );
            accept[i] = out.accept_len as i32;
            tokens[p0..p0 + out.tokens.len()].copy_from_slice(&out.tokens);
        }
        (accept, tokens)
    }

    #[test]
    fn ragged_kernel_bit_identical_to_per_slot_oracle() {
        // mixed per-slot γ (incl. empty slots) × mixed methods × thread
        // counts: the ragged step must equal the scalar oracle run on
        // each slot's slices
        forall(
            "ragged kernel parity",
            Config { cases: 40, ..Config::default() },
            |rng, size| {
                let v = 4 + size * 3;
                let b = 1 + (size % 5);
                let gammas: Vec<usize> = (0..b)
                    .map(|_| match rng.below(8) {
                        0 => 0, // empty slot
                        k => 1 + (k as usize % 6),
                    })
                    .collect();
                let case = make_ragged_case(rng, &gammas, v);
                let expect = run_ragged_oracle(&case);
                for threads in [1usize, 2, 8] {
                    let cfg = force_parallel(KernelConfig::with_threads(threads));
                    let got = run_ragged_ws(&case, cfg);
                    if got != expect {
                        return Err(format!(
                            "threads={threads} γs={gammas:?} v={v}: {got:?} != {expect:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ragged_uniform_layout_delegates_to_rectangular_path() {
        // all slots at the same γ: the ragged entry point must produce
        // exactly the rectangular kernel's output (same layout, same
        // schedules) — the engine relies on this for shared-γ parity
        let mut rng = Pcg32::seeded(83);
        for (b, g, v) in [(1usize, 3usize, 40usize), (3, 2, 24), (4, 5, 16)] {
            let gammas = vec![g; b];
            let case = make_ragged_case(&mut rng, &gammas, v);
            let rect = Case {
                b,
                gamma: g,
                v,
                z_p: case.z_p.clone(),
                z_q: case.z_q.clone(),
                draft: case.draft.clone(),
                u_acc: case.u_acc.clone(),
                u_res: case.u_res.clone(),
                u_bonus: case.u_bonus.clone(),
                methods: case.methods.clone(),
            };
            for threads in [1usize, 4] {
                let cfg = force_parallel(KernelConfig::with_threads(threads));
                assert_eq!(run_ragged_ws(&case, cfg), run_ws(&rect, cfg), "b={b} γ={g}");
            }
        }
    }

    #[test]
    fn profiler_scopes_are_preserved_one_to_one() {
        let profiler = Profiler::new();
        let mut rng = Pcg32::seeded(80);
        let case = make_case(&mut rng, 2, 3, 32);
        let mut ws = VerifyWorkspace::new(KernelConfig::scalar());
        let (mut accept, mut tokens) = (Vec::new(), Vec::new());
        spec_step_batch_ws(
            &mut ws,
            &case.z_p,
            &case.z_q,
            case.b,
            case.gamma,
            case.v,
            &case.draft,
            &case.u_acc,
            &case.u_res,
            &case.u_bonus,
            &case.methods,
            &mut accept,
            &mut tokens,
            Some(&profiler),
        );
        for scope in [
            "verify/partition",
            "verify/softmax",
            "verify/kernel",
            "verify/finish",
        ] {
            assert_eq!(profiler.get(scope).calls, 1, "{scope}");
        }
    }

    #[test]
    fn config_from_env_defaults_are_sane() {
        let cfg = KernelConfig::default();
        assert!(cfg.threads >= 1);
        assert_eq!(cfg.chunk, VOCAB_CHUNK);
        assert_eq!(cfg.simd, simd::SimdMode::Auto);
        assert!(KernelConfig::scalar().threads == 1);
        assert_eq!(KernelConfig::with_threads(0).threads, 1);
    }

    #[test]
    fn malformed_env_overrides_warn_and_fall_back() {
        // the parser itself: empty means unset, junk means default
        assert_eq!(parse_env_usize("SPECD_VERIFY_THREADS", "3"), Some(3));
        assert_eq!(parse_env_usize("SPECD_VERIFY_THREADS", " 7 "), Some(7));
        assert_eq!(parse_env_usize("SPECD_VERIFY_THREADS", ""), None);
        assert_eq!(parse_env_usize("SPECD_VERIFY_THREADS", "lots"), None);
        assert_eq!(parse_env_usize("SPECD_VERIFY_CHUNK", "-4"), None);
        assert_eq!(parse_env_usize("SPECD_VERIFY_CHUNK", "4k"), None);
        // a malformed environment yields the defaults, not a panic or a
        // silently wrong config (malformed → default also means any
        // test running concurrently observes defaults, nothing else)
        std::env::set_var("SPECD_VERIFY_THREADS", "many");
        std::env::set_var("SPECD_VERIFY_CHUNK", "4k");
        std::env::set_var("SPECD_VERIFY_PIN", "sideways");
        std::env::set_var("SPECD_SIMD", "fast");
        let cfg = KernelConfig::from_env();
        std::env::remove_var("SPECD_VERIFY_THREADS");
        std::env::remove_var("SPECD_VERIFY_CHUNK");
        std::env::remove_var("SPECD_VERIFY_PIN");
        std::env::remove_var("SPECD_SIMD");
        let def = KernelConfig::default();
        assert_eq!(cfg.threads, def.threads);
        assert_eq!(cfg.chunk, def.chunk);
        assert_eq!(cfg.pin_cores, def.pin_cores);
        assert_eq!(cfg.simd, simd::SimdMode::Auto);
    }

    #[test]
    fn lane_tail_parity_at_ragged_vocab_sizes() {
        // V not a multiple of LANE or VOCAB_CHUNK: the lane tails and
        // the ragged final block must stay bit-identical to the scalar
        // oracle on every schedule × lane path. 4095/4097 straddle the
        // chunk boundary; 32771 is a prime-ish production-scale vocab
        // (8 full blocks + a 3-element tail block).
        let mut rng = Pcg32::seeded(90);
        for v in [4095usize, 4097, 32771] {
            for method in [
                Method::Baseline,
                Method::Exact,
                Method::sigmoid(-1e3, 1e3),
                Method::sigmoid16(-1e3, 1e3),
            ] {
                let mut case = make_case(&mut rng, 1, 2, v);
                case.methods = vec![method];
                let expect = run_oracle(&case);
                for mode in [simd::SimdMode::Off, simd::SimdMode::On] {
                    for threads in [1usize, 4] {
                        let mut cfg = force_parallel(KernelConfig::with_threads(threads));
                        cfg.simd = mode;
                        let got = run_ws(&case, cfg);
                        assert_eq!(
                            got,
                            expect,
                            "v={v} method={} simd={mode:?} threads={threads}",
                            method.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn f16_ingestion_is_fused_and_matches_widen_then_construct() {
        // Logits::F16 must equal widening to f32 first and running the
        // f32 constructors — bit for bit, including across the chunk
        // boundary and for the SIMD-dispatched f32 entry point
        let mut rng = Pcg32::seeded(91);
        for v in [33usize, VOCAB_CHUNK + 17] {
            let z = randn(&mut rng, v, 8.0);
            let h: Vec<u16> = z.iter().map(|&x| verify::f32_to_f16_bits(x)).collect();
            let wide: Vec<f32> = h.iter().map(|&b| verify::f16_bits_to_f32(b)).collect();
            for method in [
                Method::Baseline,
                Method::Exact,
                Method::sigmoid(-1e3, 1e3),
                Method::sigmoid16(-1e3, 1e3),
            ] {
                let mut a = vec![0.0f32; v];
                let mut b = vec![0.0f32; v];
                construct_prob_row(&wide, &mut a, method);
                construct_prob_row_logits(Logits::F16(&h), &mut b, method);
                let (ab, bb): (Vec<u32>, Vec<u32>) = (
                    a.iter().map(|x| x.to_bits()).collect(),
                    b.iter().map(|x| x.to_bits()).collect(),
                );
                assert_eq!(ab, bb, "v={v} method={}", method.name());
            }
            // the From impls round-trip the slice lengths
            assert_eq!(Logits::from(&h[..]).len(), v);
            assert_eq!(Logits::from(&wide[..]).len(), v);
            assert!(!Logits::from(&h[..]).is_empty());
        }
    }

    #[test]
    fn sigmoid16_overflow_rejects_all_through_f16_ingestion() {
        // the Table 2 fp16-overflow row arriving the production way:
        // logits as raw f16 bit patterns (±inf = 0x7c00/0xfc00, NaN =
        // 0x7e00) through the fused ingestion path; the NaN τ from the
        // overflowed (β−α) must still reject every draft even at u = 0
        let method = Method::sigmoid16(-1e5, 1e5);
        let h: [u16; 8] = [0x7c00, 0xfc00, 0x7e00, 0x3c00, 0x0000, 0x8000, 0x5640, 0xc000];
        let v = h.len();
        let mut p = vec![0.0f32; v];
        let mut q = vec![0.0f32; v];
        construct_prob_row_logits(Logits::F16(&h), &mut p, method);
        construct_prob_row_logits(Logits::F16(&h), &mut q, method);
        for x in 0..v {
            assert!(
                !verify::accept_decision(p[x], q[x], 0.0, method),
                "NaN τ must reject token {x} (p={}, q={})",
                p[x],
                q[x]
            );
        }
    }
}
