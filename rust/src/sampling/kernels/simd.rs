//! Runtime-dispatched AVX2 twins of the scalar lane-graph primitives.
//!
//! The scalar reference in [`crate::sampling::verify`] already executes
//! the [`verify::LANE`]-wide reduction graph (8 independent f32
//! accumulators folded in lane order) and routes every exponential
//! through the fixed polynomial [`verify::exp_approx`]. The functions
//! here re-implement those primitives with `std::arch::x86_64`
//! intrinsics, **operation for operation**:
//!
//! * one ymm register *is* the 8-lane accumulator array — `vaddps` /
//!   `vmaxps` per group of 8 elements are exactly the scalar per-lane
//!   `+=` / compare-and-replace (IEEE single ops are exactly rounded,
//!   so element-wise vectorization cannot change a bit);
//! * block tails (fewer than 8 elements) spill the accumulator to an
//!   array and continue with the *scalar* code, then both paths share
//!   the same lane-order fold (`verify::lane_fold_sum` /
//!   `lane_fold_max`);
//! * [`exp8`] is `exp_approx` transcribed to intrinsics: same clamp,
//!   same magic-number round-to-nearest-even, same Cody–Waite
//!   reduction, same polynomial with plain `mul`/`add` (no FMA — it
//!   rounds differently), same exponent-field bit assembly, and NaN
//!   lanes blended back from the input (the scalar early return);
//! * `maxps` operand order is chosen so NaN never replaces an
//!   accumulator, matching the scalar comparison form.
//!
//! Because the two implementations compute literally the same IEEE
//! operation sequence, SIMD on/off is **bit-identical** by
//! construction, and the kernel parity suites assert it empirically
//! (see `simd_rows_match_scalar_lane_graph_bitwise` and the
//! `SPECD_SIMD` CI parity step).
//!
//! On non-x86-64 targets every entry point falls back to the scalar
//! lane-graph implementation (same results, by the same argument).

#[cfg(not(target_arch = "x86_64"))]
use crate::sampling::verify;

/// SIMD dispatch mode for the kernel layer (`SPECD_SIMD`). Never
/// affects results — only which bit-identical implementation of the
/// lane graph executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdMode {
    /// Use the AVX2 path when the host supports it (default).
    Auto,
    /// Force the scalar lane-graph loops (`SPECD_SIMD=0`).
    Off,
    /// Request the AVX2 path (`SPECD_SIMD=1`); still falls back to
    /// scalar when the host lacks AVX2 — the request cannot change
    /// results, so degrading is safe.
    On,
}

impl SimdMode {
    /// Parse a `SPECD_SIMD` value. Malformed values log a warning and
    /// fall back to [`SimdMode::Auto`] instead of being silently
    /// ignored.
    pub fn parse(raw: &str) -> SimdMode {
        match raw.trim() {
            "" | "auto" => SimdMode::Auto,
            "0" | "off" | "false" => SimdMode::Off,
            "1" | "on" | "true" => SimdMode::On,
            other => {
                crate::warn!("ignoring malformed SPECD_SIMD={other:?} (want 0, 1, or auto); using auto");
                SimdMode::Auto
            }
        }
    }

    /// Resolve the mode against the host: `true` means the AVX2 path
    /// runs, `false` means the scalar lane-graph loops run.
    pub fn active(self) -> bool {
        match self {
            SimdMode::Off => false,
            SimdMode::Auto | SimdMode::On => have_avx2(),
        }
    }
}

/// Runtime AVX2 detection (cached by `std`; never true off x86-64).
pub fn have_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

// --- dispatch wrappers -----------------------------------------------------
//
// Callers (the kernel schedules in `kernels::mod`) resolve SimdMode to
// a bool once per step and route per-block work through these. Each has
// the same contract as its scalar twin in `verify`.

/// AVX2 twin of [`verify::softmax_row_from`].
#[cfg(target_arch = "x86_64")]
pub(crate) fn softmax_row_from(src: &[f32], dst: &mut [f32]) {
    debug_assert!(have_avx2());
    unsafe { avx2::softmax_row_from(src, dst) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn softmax_row_from(src: &[f32], dst: &mut [f32]) {
    verify::softmax_row_from(src, dst);
}

/// AVX2 twin of [`verify::sigmoid_row_from`].
#[cfg(target_arch = "x86_64")]
pub(crate) fn sigmoid_row_from(src: &[f32], dst: &mut [f32], alpha: f32, beta: f32) {
    debug_assert!(have_avx2());
    unsafe { avx2::sigmoid_row_from(src, dst, alpha, beta) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn sigmoid_row_from(src: &[f32], dst: &mut [f32], alpha: f32, beta: f32) {
    verify::sigmoid_row_from(src, dst, alpha, beta);
}

/// AVX2 twin of the scalar block max ([`verify::lane_max`]).
#[cfg(target_arch = "x86_64")]
pub(crate) fn lane_max_block(xs: &[f32]) -> f32 {
    debug_assert!(have_avx2());
    unsafe { avx2::lane_max_block(xs) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn lane_max_block(xs: &[f32]) -> f32 {
    verify::lane_max(xs)
}

/// AVX2 twin of the scalar block sum ([`verify::lane_sum`]).
#[cfg(target_arch = "x86_64")]
pub(crate) fn lane_sum_block(xs: &[f32]) -> f32 {
    debug_assert!(have_avx2());
    unsafe { avx2::lane_sum_block(xs) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn lane_sum_block(xs: &[f32]) -> f32 {
    verify::lane_sum(xs)
}

/// AVX2 twin of [`verify::exp_sub_sum_block`].
#[cfg(target_arch = "x86_64")]
pub(crate) fn exp_sub_sum_block(src: &[f32], dst: &mut [f32], max: f32) -> f32 {
    debug_assert!(have_avx2());
    unsafe { avx2::exp_sub_sum_block(src, dst, max) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn exp_sub_sum_block(src: &[f32], dst: &mut [f32], max: f32) -> f32 {
    verify::exp_sub_sum_block(src, dst, max)
}

/// AVX2 twin of the scalar residual loop `dst = max(p - q, 0)`.
#[cfg(target_arch = "x86_64")]
pub(crate) fn residual_block(p: &[f32], q: &[f32], dst: &mut [f32]) {
    debug_assert!(have_avx2());
    unsafe { avx2::residual_block(p, q, dst) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn residual_block(p: &[f32], q: &[f32], dst: &mut [f32]) {
    for ((r, &pp), &qq) in dst.iter_mut().zip(p).zip(q) {
        *r = (pp - qq).max(0.0);
    }
}

/// AVX2 twin of the scalar scale loop `dst *= inv`.
#[cfg(target_arch = "x86_64")]
pub(crate) fn scale_block(dst: &mut [f32], inv: f32) {
    debug_assert!(have_avx2());
    unsafe { avx2::scale_block(dst, inv) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn scale_block(dst: &mut [f32], inv: f32) {
    for e in dst.iter_mut() {
        *e *= inv;
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use crate::sampling::verify::{
        self, EXP_HI, EXP_LN2_HI, EXP_LN2_LO, EXP_LO, EXP_LOG2E, EXP_P0, EXP_P1, EXP_P2,
        EXP_P3, EXP_P4, EXP_P5, EXP_RND, LANE, VOCAB_CHUNK,
    };
    use std::arch::x86_64::*;

    /// `verify::exp_approx` over 8 lanes, operation for operation: the
    /// scalar `if x.is_nan()` early return becomes the final blend,
    /// everything else is the identical exactly-rounded op sequence.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn exp8(x: __m256) -> __m256 {
        let nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(x, x);
        // x.min(EXP_HI).max(EXP_LO)
        let xc = _mm256_max_ps(
            _mm256_min_ps(x, _mm256_set1_ps(EXP_HI)),
            _mm256_set1_ps(EXP_LO),
        );
        // n = (xc*log2e + RND) - RND  (round-to-nearest-even)
        let rnd = _mm256_set1_ps(EXP_RND);
        let n = _mm256_sub_ps(
            _mm256_add_ps(_mm256_mul_ps(xc, _mm256_set1_ps(EXP_LOG2E)), rnd),
            rnd,
        );
        // r = (xc - n*LN2_HI) - n*LN2_LO
        let r = _mm256_sub_ps(
            _mm256_sub_ps(xc, _mm256_mul_ps(n, _mm256_set1_ps(EXP_LN2_HI))),
            _mm256_mul_ps(n, _mm256_set1_ps(EXP_LN2_LO)),
        );
        let z = _mm256_mul_ps(r, r);
        let mut y = _mm256_set1_ps(EXP_P0);
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P1));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P2));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P3));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P4));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P5));
        y = _mm256_add_ps(_mm256_add_ps(_mm256_mul_ps(y, z), r), _mm256_set1_ps(1.0));
        // pow2 = from_bits((n as i32 + 127) << 23); n is integral, so
        // cvtps (round-to-nearest) equals the scalar truncating cast
        let ni = _mm256_cvtps_epi32(n);
        let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
            ni,
            _mm256_set1_epi32(127),
        )));
        let res = _mm256_mul_ps(y, pow2);
        _mm256_blendv_ps(res, x, nan)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn lane_max_block(xs: &[f32]) -> f32 {
        let n = xs.len();
        let full = n - n % LANE;
        // maxps(x, acc): NaN never replaces the accumulator, ties keep
        // it — the scalar comparison form
        let mut accv = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut k = 0;
        while k < full {
            accv = _mm256_max_ps(_mm256_loadu_ps(xs.as_ptr().add(k)), accv);
            k += LANE;
        }
        let mut acc = [f32::NEG_INFINITY; LANE];
        _mm256_storeu_ps(acc.as_mut_ptr(), accv);
        for (j, &x) in xs[full..].iter().enumerate() {
            if x > acc[j] {
                acc[j] = x;
            }
        }
        verify::lane_fold_max(&acc)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn lane_sum_block(xs: &[f32]) -> f32 {
        let n = xs.len();
        let full = n - n % LANE;
        let mut accv = _mm256_setzero_ps();
        let mut k = 0;
        while k < full {
            accv = _mm256_add_ps(accv, _mm256_loadu_ps(xs.as_ptr().add(k)));
            k += LANE;
        }
        let mut acc = [0.0f32; LANE];
        _mm256_storeu_ps(acc.as_mut_ptr(), accv);
        for (j, &x) in xs[full..].iter().enumerate() {
            acc[j] += x;
        }
        verify::lane_fold_sum(&acc)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn exp_sub_sum_block(src: &[f32], dst: &mut [f32], max: f32) -> f32 {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len();
        let full = n - n % LANE;
        let maxv = _mm256_set1_ps(max);
        let mut accv = _mm256_setzero_ps();
        let mut k = 0;
        while k < full {
            let e = exp8(_mm256_sub_ps(_mm256_loadu_ps(src.as_ptr().add(k)), maxv));
            _mm256_storeu_ps(dst.as_mut_ptr().add(k), e);
            accv = _mm256_add_ps(accv, e);
            k += LANE;
        }
        let mut acc = [0.0f32; LANE];
        _mm256_storeu_ps(acc.as_mut_ptr(), accv);
        for j in 0..(n - full) {
            let e = verify::exp_approx(src[full + j] - max);
            dst[full + j] = e;
            acc[j] += e;
        }
        verify::lane_fold_sum(&acc)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn softmax_row_from(src: &[f32], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), dst.len());
        let max = lane_max_block(src);
        let mut sum = 0.0f32;
        for (sb, db) in src.chunks(VOCAB_CHUNK).zip(dst.chunks_mut(VOCAB_CHUNK)) {
            sum += exp_sub_sum_block(sb, db, max);
        }
        let inv = 1.0 / sum;
        scale_block(dst, inv);
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sigmoid_row_from(src: &[f32], dst: &mut [f32], alpha: f32, beta: f32) {
        debug_assert_eq!(src.len(), dst.len());
        let inv = 1.0 / (beta - alpha);
        let n = src.len();
        let full = n - n % LANE;
        let av = _mm256_set1_ps(alpha);
        let iv = _mm256_set1_ps(inv);
        let one = _mm256_set1_ps(1.0);
        // -z as a sign-bit flip, exactly the scalar unary minus
        let signbit = _mm256_set1_ps(-0.0);
        let mut k = 0;
        while k < full {
            let s = _mm256_loadu_ps(src.as_ptr().add(k));
            let z = _mm256_mul_ps(_mm256_sub_ps(s, av), iv);
            let e = exp8(_mm256_xor_ps(z, signbit));
            let d = _mm256_div_ps(one, _mm256_add_ps(one, e));
            _mm256_storeu_ps(dst.as_mut_ptr().add(k), d);
            k += LANE;
        }
        for j in full..n {
            let z = (src[j] - alpha) * inv;
            dst[j] = 1.0 / (1.0 + verify::exp_approx(-z));
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn residual_block(p: &[f32], q: &[f32], dst: &mut [f32]) {
        debug_assert_eq!(p.len(), dst.len());
        debug_assert_eq!(q.len(), dst.len());
        let n = dst.len();
        let full = n - n % LANE;
        let zero = _mm256_setzero_ps();
        let mut k = 0;
        while k < full {
            let d = _mm256_sub_ps(
                _mm256_loadu_ps(p.as_ptr().add(k)),
                _mm256_loadu_ps(q.as_ptr().add(k)),
            );
            // maxps(diff, 0): a NaN difference (inf - inf, NaN inputs)
            // clamps to 0, the f32::max(NaN, 0.0) semantics
            _mm256_storeu_ps(dst.as_mut_ptr().add(k), _mm256_max_ps(d, zero));
            k += LANE;
        }
        for j in full..n {
            dst[j] = (p[j] - q[j]).max(0.0);
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_block(dst: &mut [f32], inv: f32) {
        let n = dst.len();
        let full = n - n % LANE;
        let iv = _mm256_set1_ps(inv);
        let mut k = 0;
        while k < full {
            let d = _mm256_mul_ps(_mm256_loadu_ps(dst.as_ptr().add(k)), iv);
            _mm256_storeu_ps(dst.as_mut_ptr().add(k), d);
            k += LANE;
        }
        for e in dst[full..].iter_mut() {
            *e *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::verify::{exp_approx, LANE, VOCAB_CHUNK};
    use crate::util::rng::Pcg32;

    fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
    }

    /// Poison a buffer with the special values the contract must
    /// survive: NaN, ±inf, ±0, subnormals.
    fn poison(rng: &mut Pcg32, xs: &mut [f32]) {
        let specials = [
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0,
            0.0,
            f32::MIN_POSITIVE / 2.0,
        ];
        for _ in 0..(xs.len() / 16).max(1) {
            let i = rng.below(xs.len() as u32) as usize;
            xs[i] = specials[rng.below(specials.len() as u32) as usize];
        }
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn simd_mode_parses_and_degrades_safely() {
        assert_eq!(SimdMode::parse("0"), SimdMode::Off);
        assert_eq!(SimdMode::parse("off"), SimdMode::Off);
        assert_eq!(SimdMode::parse("1"), SimdMode::On);
        assert_eq!(SimdMode::parse(" on "), SimdMode::On);
        assert_eq!(SimdMode::parse("auto"), SimdMode::Auto);
        // malformed values warn and fall back to auto, never panic
        assert_eq!(SimdMode::parse("sideways"), SimdMode::Auto);
        assert!(!SimdMode::Off.active());
        // On degrades to scalar off-AVX2 hosts instead of crashing
        assert_eq!(SimdMode::On.active(), have_avx2());
        assert_eq!(SimdMode::Auto.active(), have_avx2());
    }

    #[test]
    fn simd_rows_match_scalar_lane_graph_bitwise() {
        if !have_avx2() {
            return; // the dispatch layer never routes here without AVX2
        }
        let mut rng = Pcg32::seeded(41);
        // lane tails, chunk boundaries, multi-block rows
        for v in [1usize, 7, 8, 9, 64, 97, 4095, 4096, 4097, 2 * VOCAB_CHUNK + 13] {
            let mut src = randn(&mut rng, v, 4.0);
            poison(&mut rng, &mut src);
            let mut a = vec![0.0f32; v];
            let mut b = vec![0.0f32; v];

            crate::sampling::verify::softmax_row_from(&src, &mut a);
            softmax_row_from(&src, &mut b);
            assert_eq!(bits(&a), bits(&b), "softmax v={v}");

            for (alpha, beta) in [(-1e3f32, 1e3f32), (-4.0, 4.0)] {
                crate::sampling::verify::sigmoid_row_from(&src, &mut a, alpha, beta);
                sigmoid_row_from(&src, &mut b, alpha, beta);
                assert_eq!(bits(&a), bits(&b), "sigmoid v={v} α={alpha}");
            }

            assert_eq!(
                crate::sampling::verify::lane_sum(&src).to_bits(),
                lane_sum_block(&src).to_bits(),
                "sum v={v}"
            );
            assert_eq!(
                crate::sampling::verify::lane_max(&src).to_bits(),
                lane_max_block(&src).to_bits(),
                "max v={v}"
            );

            let q = randn(&mut rng, v, 4.0);
            let mut ra = vec![0.0f32; v];
            let mut rb = vec![0.0f32; v];
            for ((r, &pp), &qq) in ra.iter_mut().zip(&src).zip(&q) {
                *r = (pp - qq).max(0.0);
            }
            residual_block(&src, &q, &mut rb);
            assert_eq!(bits(&ra), bits(&rb), "residual v={v}");
        }
    }

    #[test]
    fn simd_exp_matches_scalar_polynomial_bitwise() {
        if !have_avx2() {
            return;
        }
        let mut rng = Pcg32::seeded(42);
        let mut xs = randn(&mut rng, 4096, 30.0);
        xs.extend_from_slice(&[
            0.0,
            -0.0,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            88.0,
            -87.0,
            1000.0,
            -1000.0,
        ]);
        while xs.len() % LANE != 0 {
            xs.push(0.5);
        }
        // exp(x - 0) through the block primitive == scalar exp_approx
        let mut out = vec![0.0f32; xs.len()];
        exp_sub_sum_block(&xs, &mut out, 0.0);
        for (&x, &e) in xs.iter().zip(&out) {
            assert_eq!(
                e.to_bits(),
                exp_approx(x).to_bits(),
                "exp({x}) simd {e} vs scalar {}",
                exp_approx(x)
            );
        }
    }
}
