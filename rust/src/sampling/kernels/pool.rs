//! Scoped fork-join primitives for the segment-parallel verification
//! kernels.
//!
//! Safety model: **no `unsafe`**. Work is partitioned *before* any
//! thread is spawned — each worker receives a disjoint `&mut` span
//! produced by `split_at_mut`, so the borrow checker proves data-race
//! freedom. Threads come from `std::thread::scope`, so tasks can borrow
//! the caller's stack data (logit slices, workspace buffers) without
//! lifetime erasure, and every region joins before returning.
//!
//! Determinism: the partition is a pure function of
//! `(len, unit, threads)` and each task writes only values that are a
//! pure function of its own input segment, so outputs are independent of
//! scheduling, thread count, and span boundaries. Reductions that would
//! reassociate floating-point sums are not performed here at all — the
//! kernel layer folds fixed-order per-chunk partials instead (see
//! [`crate::sampling::verify::VOCAB_CHUNK`]).
//!
//! A parallel region costs one `thread::scope` (a few tens of
//! microseconds for the spawns); [`crate::sampling::kernels::KernelConfig`]
//! gates regions on a minimum problem size so small matrices stay on the
//! scalar path.

/// Unit count of contiguous run `w` when `n_units` are split across
/// `workers` runs (earlier runs absorb the remainder).
fn share(n_units: usize, workers: usize, w: usize) -> usize {
    n_units / workers + usize::from(w < n_units % workers)
}

/// Run `f(first_unit, span)` over disjoint contiguous spans of `data`,
/// split at `unit`-element boundaries (only the final unit may be
/// ragged). `f` runs on up to `threads` scoped threads, the last span on
/// the calling thread; `threads <= 1` degenerates to one inline call.
pub fn for_each_span<T, F>(threads: usize, data: &mut [T], unit: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(unit > 0, "span unit must be positive");
    if data.is_empty() {
        return;
    }
    let n_units = data.len().div_ceil(unit);
    let workers = threads.clamp(1, n_units);
    if workers == 1 {
        f(0, data);
        return;
    }
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = data;
        let mut first = 0usize;
        for w in 0..workers {
            let units = share(n_units, workers, w);
            let take = (units * unit).min(rest.len());
            let (span, tail) = rest.split_at_mut(take);
            rest = tail;
            let start = first;
            first += units;
            if w + 1 == workers {
                f(start, span);
            } else {
                scope.spawn(move || f(start, span));
            }
        }
    });
}

/// Like [`for_each_span`] but over two buffers partitioned in lockstep:
/// unit `i` of `a` (stride `unit_a`) pairs with unit `i` of `b` (stride
/// `unit_b`). Both buffers must contain the same number of units.
pub fn for_each_span2<A, B, F>(
    threads: usize,
    a: &mut [A],
    unit_a: usize,
    b: &mut [B],
    unit_b: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(unit_a > 0 && unit_b > 0, "span units must be positive");
    if a.is_empty() && b.is_empty() {
        return;
    }
    let n_units = a.len().div_ceil(unit_a);
    debug_assert_eq!(n_units, b.len().div_ceil(unit_b), "unit count mismatch");
    let workers = threads.clamp(1, n_units.max(1));
    if workers == 1 {
        f(0, a, b);
        return;
    }
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest_a = a;
        let mut rest_b = b;
        let mut first = 0usize;
        for w in 0..workers {
            let units = share(n_units, workers, w);
            let take_a = (units * unit_a).min(rest_a.len());
            let take_b = (units * unit_b).min(rest_b.len());
            let (span_a, tail_a) = rest_a.split_at_mut(take_a);
            let (span_b, tail_b) = rest_b.split_at_mut(take_b);
            rest_a = tail_a;
            rest_b = tail_b;
            let start = first;
            first += units;
            if w + 1 == workers {
                f(start, span_a, span_b);
            } else {
                scope.spawn(move || f(start, span_a, span_b));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn share_covers_all_units_contiguously() {
        for n in [1usize, 2, 7, 16, 100] {
            for workers in 1..=8 {
                let total: usize = (0..workers).map(|w| share(n, workers, w)).sum();
                assert_eq!(total, n, "n={n} workers={workers}");
                // non-increasing run sizes (remainder goes to early runs)
                for w in 1..workers {
                    assert!(share(n, workers, w) <= share(n, workers, w - 1));
                }
            }
        }
    }

    #[test]
    fn spans_cover_every_element_exactly_once() {
        for threads in [1usize, 2, 3, 8, 17] {
            for (len, unit) in [(12usize, 4usize), (13, 4), (1, 4), (64, 1), (10, 100)] {
                let mut data = vec![0u32; len];
                for_each_span(threads, &mut data, unit, |_first, span| {
                    for e in span.iter_mut() {
                        *e += 1;
                    }
                });
                assert!(data.iter().all(|&x| x == 1), "threads={threads} len={len}");
            }
        }
    }

    #[test]
    fn first_unit_index_matches_span_offset() {
        let len = 23;
        let unit = 4;
        let base = vec![0u8; len];
        let base_ptr = base.as_ptr() as usize;
        let mut data = base;
        for_each_span(4, &mut data, unit, |first, span| {
            let off = span.as_ptr() as usize - base_ptr;
            assert_eq!(off, first * unit);
        });
    }

    #[test]
    fn results_are_thread_count_invariant() {
        let compute = |threads: usize| {
            let mut data: Vec<f64> = (0..997).map(|i| i as f64 * 0.25).collect();
            for_each_span(threads, &mut data, 64, |first, span| {
                for (k, e) in span.iter_mut().enumerate() {
                    *e = (*e + (first * 64 + k) as f64).sqrt();
                }
            });
            data
        };
        let one = compute(1);
        for t in [2, 3, 8] {
            assert_eq!(compute(t), one, "threads={t}");
        }
    }

    #[test]
    fn span2_partitions_in_lockstep() {
        // a: 6 units of 8, b: 6 units of 1
        let mut a = vec![1u32; 48];
        let mut b = vec![0u32; 6];
        for_each_span2(3, &mut a, 8, &mut b, 1, |first, sa, sb| {
            for (k, out) in sb.iter_mut().enumerate() {
                let blk = &sa[k * 8..(k + 1) * 8];
                *out = blk.iter().sum::<u32>() + (first + k) as u32;
            }
        });
        for (i, &x) in b.iter().enumerate() {
            assert_eq!(x, 8 + i as u32);
        }
        assert!(a.iter().all(|&x| x == 1));
    }

    #[test]
    fn runs_on_multiple_threads_when_asked() {
        // with enough units, more than one OS thread actually
        // participates (each worker records its ThreadId)
        let calls = AtomicUsize::new(0);
        let tids = std::sync::Mutex::new(std::collections::HashSet::new());
        let mut data = vec![0u8; 1024];
        for_each_span(4, &mut data, 1, |_, _span| {
            calls.fetch_add(1, Ordering::Relaxed);
            tids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(calls.load(Ordering::Relaxed), 4, "one call per worker span");
        assert!(
            tids.lock().unwrap().len() > 1,
            "parallel region must spawn real worker threads"
        );
    }
}
