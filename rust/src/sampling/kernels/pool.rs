//! Persistent worker pool for the segment-parallel verification kernels.
//!
//! PR 3's scoped fork-join spawned OS threads for every parallel region —
//! the CPU analogue of per-step kernel-launch overhead the paper's §3
//! kernels exist to avoid. This module replaces it with a
//! [`WorkerPool`]: long-lived workers spawned **once** (lazily, on the
//! verifier's first parallel region), parked on a condvar between
//! regions, and woken by an epoch ticket per dispatch. A steady-state
//! parallel region costs two condvar transitions instead of N
//! `thread::spawn`s, so softmax/sigmoid construction, residual building
//! and inverse-CDF sampling reuse the same threads across the whole
//! decode loop. Workers shut down (and are joined) when the pool — and
//! therefore the owning verifier — is dropped; a verifier that never
//! enters a parallel region never spawns any.
//!
//! Two further pieces of thread substrate live here:
//!
//! * [`DispatchLane`] — a single long-lived thread executing owned
//!   FIFO jobs, used by the engine's pipelined decode scheduler to keep
//!   a **model dispatch** (draft/score executable calls) in flight while
//!   the engine thread runs **verify regions** on the [`WorkerPool`].
//!   The lane is *not* a pool lane and never dispatches pool regions,
//!   so the pool's single-dispatcher invariant (below) is preserved by
//!   construction: at any instant the pool has at most one dispatching
//!   thread (the engine thread), and the lane's in-flight job touches
//!   only buffers it owns.
//! * opt-in **core affinity** ([`WorkerPool::with_affinity`], surfaced
//!   as `SPECD_VERIFY_PIN=1`): workers pin themselves to distinct CPUs
//!   at spawn — drawn from the process's *allowed* affinity mask, so
//!   cpuset-restricted containers pin correctly — so steady-state
//!   verify regions stop migrating between cores (and away from their
//!   warmed caches). Pinning is best-effort — a no-op on non-Linux
//!   targets or when the mask cannot be read — and never affects
//!   results.
//!
//! ## Safety model
//!
//! Unlike the scoped implementation, a persistent pool cannot let the
//! borrow checker prove task lifetimes, so this module contains the
//! crate's only `unsafe` apart from the affinity syscall below — three
//! narrow, invariant-guarded uses:
//!
//! 1. **lifetime erasure** of the dispatched closure reference
//!    ([`WorkerPool::run`]): sound because `run` blocks until every
//!    worker has retired the epoch before returning, so the erased
//!    `&dyn Fn` never outlives the caller's borrow (a panicking task
//!    still retires its epoch via the bookkeeping in the worker loop,
//!    and the caller's own share runs under `catch_unwind` so workers
//!    are always drained before unwinding past the borrowed data);
//! 2. **span derivation** in [`for_each_span`] / [`for_each_span2`]:
//!    each task index reconstructs its disjoint `&mut` span from a base
//!    pointer using the same pure partition arithmetic as PR 3's
//!    `split_at_mut` chain (`share` / `first_unit` cover every unit
//!    exactly once), so no two tasks alias;
//! 3. `Send`/`Sync` assertions for the erased job pointer and the span
//!    base pointer, justified by (1) and (2).
//!
//! ## Determinism
//!
//! Unchanged from PR 3, and load-bearing for the bit-identical claim:
//! the partition is a pure function of `(len, unit, threads)` — not of
//! the pool width or scheduling — and each task writes only values that
//! are a pure function of its own input segment. Reductions that would
//! reassociate floating-point sums are never performed here; the kernel
//! layer folds fixed-order per-chunk partials instead (see
//! [`crate::sampling::verify::VOCAB_CHUNK`]).
//!
//! Regions must not nest: a task must not call back into
//! [`WorkerPool::run`] on the same pool (debug-asserted). The kernel
//! layer only ever runs its regions sequentially.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

// ---------------------------------------------------------------------------
// core affinity (opt-in, best-effort)

/// glibc's cpu_set_t: 1024 bits. Declared directly so the vendored
/// crate set stays libc-free; std already links libc.
#[cfg(target_os = "linux")]
#[repr(C)]
struct CpuSet {
    bits: [u64; 16],
}

#[cfg(target_os = "linux")]
extern "C" {
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut CpuSet) -> i32;
}

/// The CPU ids this thread is allowed to run on, in ascending order —
/// the pin targets are drawn from this set, so pinning works inside
/// cgroup/cpuset-restricted containers whose allowed CPUs are not
/// contiguous from 0 (e.g. `--cpuset-cpus=4,5`). Empty when the mask
/// cannot be read (and on non-Linux targets), which disables pinning.
#[cfg(target_os = "linux")]
pub(crate) fn allowed_cpus() -> Vec<usize> {
    let mut set = CpuSet { bits: [0; 16] };
    // SAFETY: `set` is a properly-sized, initialised mask buffer and
    // outlives the call; pid 0 addresses the calling thread.
    let ok = unsafe { sched_getaffinity(0, std::mem::size_of::<CpuSet>(), &mut set) == 0 };
    if !ok {
        return Vec::new();
    }
    let mut cpus = Vec::new();
    for (blk, &bits) in set.bits.iter().enumerate() {
        for bit in 0..64 {
            if bits & (1u64 << bit) != 0 {
                cpus.push(blk * 64 + bit);
            }
        }
    }
    cpus
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn allowed_cpus() -> Vec<usize> {
    Vec::new()
}

/// Pin the calling thread to one CPU id (an id from [`allowed_cpus`]).
/// Returns whether the pin took effect. Linux-only (via
/// `sched_setaffinity(0, …)`, which targets the calling *thread*); a
/// strict no-op elsewhere and on syscall failure, so enabling the
/// option can never break a run — only co-locate it.
#[cfg(target_os = "linux")]
pub(crate) fn pin_current_thread(cpu: usize) -> bool {
    let cpu = cpu % (16 * 64);
    let mut set = CpuSet { bits: [0; 16] };
    set.bits[cpu / 64] |= 1u64 << (cpu % 64);
    // SAFETY: `set` is a properly-initialised cpu_set_t-sized mask and
    // outlives the call; pid 0 addresses the calling thread.
    unsafe { sched_setaffinity(0, std::mem::size_of::<CpuSet>(), &set) == 0 }
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn pin_current_thread(_cpu: usize) -> bool {
    false
}

/// A dispatched region: a lifetime-erased task closure plus the task
/// count. Held in the shared state only while [`WorkerPool::run`] is
/// blocked, which is what makes the erasure sound.
#[derive(Clone, Copy)]
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    tasks: usize,
}

// SAFETY: the pointee is `Sync` (it is a `&dyn Fn(usize) + Sync`), and
// `WorkerPool::run` guarantees it stays alive until every worker has
// retired the epoch that carries this job.
unsafe impl Send for Job {}

struct State {
    /// bumped once per dispatched region; workers run a job exactly once
    epoch: u64,
    job: Option<Job>,
    /// workers that have not yet retired the current epoch
    remaining: usize,
    /// a worker's task panicked during the current epoch
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// workers park here between regions
    work: Condvar,
    /// the dispatching thread parks here until `remaining == 0`
    done: Condvar,
}

/// Long-lived worker threads executing closure batches over an epoch
/// barrier. Width-`n` pools own `n - 1` OS threads — the dispatching
/// thread always takes a share of the work, so `WorkerPool::new(1)` is
/// the inline (scalar) degenerate case with no threads at all.
///
/// Workers are spawned **lazily, once**, on the first parallel
/// dispatch: an engine whose verifier never enters a parallel region
/// (HLO backend, autoregressive mode, matrices below
/// [`crate::sampling::kernels::KernelConfig::min_parallel_elems`])
/// never pays for parked threads at all.
pub struct WorkerPool {
    /// total lane count (workers + dispatcher) this pool was sized for
    width: usize,
    /// pin workers to distinct CPUs at spawn (best-effort, opt-in)
    pin_cores: bool,
    shared: Arc<Shared>,
    /// spawned on first parallel dispatch, joined on drop
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("width", &self.width())
            .finish()
    }
}

impl WorkerPool {
    /// Create a pool of total width `threads` (the caller counts as one
    /// lane, so `threads - 1` OS threads will serve it; `threads <= 1`
    /// means every [`WorkerPool::run`] call degenerates to an inline
    /// loop). Worker threads are not spawned here — the first parallel
    /// dispatch spawns them, once.
    pub fn new(threads: usize) -> Self {
        Self::with_affinity(threads, false)
    }

    /// Like [`WorkerPool::new`], with opt-in core pinning: each worker
    /// pins itself at spawn to a distinct CPU drawn from the process's
    /// **allowed** affinity mask — lane index modulo the allowed set,
    /// so pinning works inside cpuset-restricted containers whose CPUs
    /// are not contiguous from 0. The dispatching thread — lane 0 — is
    /// the caller and is never pinned (pinning a thread the pool does
    /// not own would leak policy). Best-effort: a no-op where
    /// unsupported. Closes the ROADMAP NUMA/core-pinning follow-up;
    /// surfaced via `SPECD_VERIFY_PIN=1`
    /// ([`crate::sampling::kernels::KernelConfig::from_env`]).
    pub fn with_affinity(threads: usize, pin_cores: bool) -> Self {
        WorkerPool {
            width: threads.max(1),
            pin_cores,
            shared: Arc::new(Shared {
                state: Mutex::new(State {
                    epoch: 0,
                    job: None,
                    remaining: 0,
                    panicked: false,
                    shutdown: false,
                }),
                work: Condvar::new(),
                done: Condvar::new(),
            }),
            handles: Mutex::new(Vec::new()),
        }
    }

    /// Total parallel lanes: owned workers + the dispatching thread.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Spawn the worker threads if this is the first parallel dispatch.
    fn ensure_spawned(&self) {
        let n_workers = self.width - 1;
        let mut handles = self.handles.lock().unwrap();
        if !handles.is_empty() {
            return;
        }
        // pin targets come from the *allowed* CPU mask, so pinning works
        // in cpuset-restricted containers; an unreadable mask (or a
        // non-Linux target) yields an empty set and disables pinning
        let cpus = if self.pin_cores {
            allowed_cpus()
        } else {
            Vec::new()
        };
        handles.extend((0..n_workers).map(|w| {
            let shared = self.shared.clone();
            // worker w serves lane w+1 (lane 0 = the dispatching caller)
            let target = if cpus.is_empty() {
                None
            } else {
                Some(cpus[(w + 1) % cpus.len()])
            };
            thread::Builder::new()
                .name(format!("specd-verify-{w}"))
                .spawn(move || {
                    if let Some(cpu) = target {
                        let _ = pin_current_thread(cpu);
                    }
                    worker_loop(&shared, w, n_workers)
                })
                .expect("spawning verify worker")
        }));
    }

    /// Execute `f(0) .. f(tasks - 1)`, each exactly once, distributed
    /// over the pool's lanes (task `i` runs on lane `i % width`, the
    /// dispatching thread being lane 0). Blocks until every task has
    /// completed. Panics in any task are re-raised here after the whole
    /// region has drained, leaving the pool serviceable.
    ///
    /// One dispatcher at a time: a region must have fully drained before
    /// the next is dispatched, so concurrent `run` calls on the same
    /// pool (or a task calling back into `run`) are a precondition
    /// violation — asserted, in release builds too, because the epoch
    /// protocol (and the closure-lifetime erasure riding on it) would
    /// otherwise be corrupted silently.
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        let n_workers = self.width - 1;
        if n_workers == 0 || tasks <= 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        self.ensure_spawned();

        // SAFETY: the erased reference is only reachable through
        // `State.job`, and this function does not return (or unwind past
        // `f`'s borrow) until `remaining == 0`, i.e. until no worker can
        // touch it anymore.
        let erased: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            let idle = st.job.is_none() && st.remaining == 0;
            if idle {
                st.epoch = st.epoch.wrapping_add(1);
                st.job = Some(Job {
                    task: erased,
                    tasks,
                });
                st.remaining = n_workers;
                self.shared.work.notify_all();
            }
            drop(st);
            // asserted after releasing the guard: panicking while
            // holding it would poison the mutex and turn this clean
            // precondition report into a double-panic abort in Drop
            assert!(
                idle,
                "concurrent or nested WorkerPool::run on the same pool"
            );
        }

        // the dispatcher's own share: lane 0 of `n_workers + 1`
        let caller = catch_unwind(AssertUnwindSafe(|| {
            let stride = n_workers + 1;
            let mut i = 0;
            while i < tasks {
                f(i);
                i += stride;
            }
        }));

        let worker_panicked = {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
            std::mem::replace(&mut st.panicked, false)
        };
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if worker_panicked {
            panic!("verify worker task panicked");
        }
    }

    #[cfg(test)]
    fn shared_weak(&self) -> std::sync::Weak<Shared> {
        Arc::downgrade(&self.shared)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            // poison-tolerant: Drop may run while unwinding from a
            // panic elsewhere, and a second panic here would abort
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        let handles = self.handles.get_mut().unwrap_or_else(|e| e.into_inner());
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, w: usize, n_workers: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("epoch advanced without a job");
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        // SAFETY: the dispatcher blocks until this worker retires the
        // epoch below, so the erased closure is still alive.
        let task = unsafe { &*job.task };
        let res = catch_unwind(AssertUnwindSafe(|| {
            let stride = n_workers + 1;
            let mut i = w + 1; // lane w+1 (lane 0 is the dispatcher)
            while i < job.tasks {
                task(i);
                i += stride;
            }
        }));
        let mut st = shared.state.lock().unwrap();
        if res.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_one();
        }
    }
}

/// An owned job shipped onto the [`DispatchLane`].
pub type LaneJob = Box<dyn FnOnce() + Send + 'static>;

/// A dedicated dispatcher lane: one long-lived thread running owned
/// jobs FIFO. The engine's pipelined decode scheduler ships the *model
/// dispatch* of the next speculative block here (draft + score
/// executable calls into buffers the job owns), so it stays in flight
/// while the engine thread dispatches *verify regions* on the
/// [`WorkerPool`] — the two substrates overlap without ever sharing a
/// dispatcher, which is what keeps the pool's single-dispatcher
/// invariant intact.
///
/// Invariants (documented contract, relied on by the engine):
///
/// * jobs run **in submission order**, one at a time — a second submit
///   queues behind the first;
/// * a panicking job is contained (`catch_unwind`) and the lane keeps
///   serving — the submitter observes the failure through its own
///   result channel going dead, never through a poisoned lane;
/// * jobs must own everything they touch (`'static`) and must **not**
///   dispatch regions on a [`WorkerPool`] that some other thread
///   dispatches to — the pool asserts against concurrent dispatch;
/// * dropping the lane joins the thread after the queue drains.
///
/// The thread spawns lazily on the first [`DispatchLane::submit`], so
/// engines that never pipeline never pay for it.
#[derive(Default)]
pub struct DispatchLane {
    tx: Option<Sender<LaneJob>>,
    handle: Option<thread::JoinHandle<()>>,
}

impl std::fmt::Debug for DispatchLane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DispatchLane")
            .field("spawned", &self.handle.is_some())
            .finish()
    }
}

impl DispatchLane {
    pub fn new() -> Self {
        DispatchLane::default()
    }

    /// Ship a job to the lane (spawning the lane thread on first use).
    /// Returns immediately; completion is signalled by whatever channel
    /// the job itself carries.
    pub fn submit(&mut self, job: LaneJob) {
        if self.tx.is_none() {
            let (tx, rx): (Sender<LaneJob>, Receiver<LaneJob>) = channel();
            let handle = thread::Builder::new()
                .name("specd-dispatch".into())
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        // a panicking job must not kill the lane: the
                        // submitter's result channel reports the failure
                        let _ = catch_unwind(AssertUnwindSafe(job));
                    }
                })
                .expect("spawning dispatch lane");
            self.tx = Some(tx);
            self.handle = Some(handle);
        }
        self.tx
            .as_ref()
            .expect("lane sender")
            .send(job)
            .expect("dispatch lane thread gone");
    }

    /// Whether the lane thread has been spawned (observability/tests).
    pub fn spawned(&self) -> bool {
        self.handle.is_some()
    }
}

impl Drop for DispatchLane {
    fn drop(&mut self) {
        // closing the channel ends the recv loop after queued jobs drain
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Unit count of contiguous run `w` when `n_units` are split across
/// `workers` runs (earlier runs absorb the remainder).
fn share(n_units: usize, workers: usize, w: usize) -> usize {
    n_units / workers + usize::from(w < n_units % workers)
}

/// First unit index of run `w` — the closed form of summing [`share`]
/// over the preceding runs, so every task can locate its span in O(1)
/// without a serial `split_at_mut` chain.
fn first_unit(n_units: usize, workers: usize, w: usize) -> usize {
    w * (n_units / workers) + w.min(n_units % workers)
}

/// Base pointer of a partitioned buffer, smuggled into span tasks.
///
/// SAFETY: tasks derive disjoint spans from it (see [`for_each_span`]),
/// and the pool guarantees all tasks finish before the buffer's borrow
/// ends, so this is the moral equivalent of `split_at_mut` handing each
/// scoped thread its own `&mut` span.
///
/// Tasks must go through [`SendPtr::get`] — naming the raw-pointer
/// field inside a closure would make 2021-edition precise capture grab
/// the bare `*mut T` (which is neither `Send` nor `Sync`) instead of
/// this wrapper.
struct SendPtr<T>(*mut T);

impl<T> SendPtr<T> {
    fn get(self) -> *mut T {
        self.0
    }
}

// manual impls: the derived ones would demand `T: Copy`/`T: Clone`,
// but copying the wrapper never copies the pointee
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SendPtr<T> {}

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Run `f(first_unit, span)` over disjoint contiguous spans of `data`,
/// split at `unit`-element boundaries (only the final unit may be
/// ragged). Up to `threads` spans execute on the pool's lanes, the
/// partition being identical to PR 3's scoped version — a pure function
/// of `(len, unit, threads)`, independent of the pool width.
/// `threads <= 1` or a single span degenerates to one inline call; on a
/// width-1 pool the spans run sequentially on the caller (same
/// partition, same results).
pub fn for_each_span<T, F>(pool: &WorkerPool, threads: usize, data: &mut [T], unit: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(unit > 0, "span unit must be positive");
    if data.is_empty() {
        return;
    }
    let n_units = data.len().div_ceil(unit);
    let workers = threads.clamp(1, n_units);
    if workers == 1 {
        f(0, data);
        return;
    }
    let len = data.len();
    let base = SendPtr(data.as_mut_ptr());
    pool.run(workers, &|w| {
        let first = first_unit(n_units, workers, w);
        let units = share(n_units, workers, w);
        let start = first * unit;
        let end = (start + units * unit).min(len);
        // SAFETY: [first, first + units) ranges are disjoint across `w`
        // and cover [0, n_units) exactly (share/first_unit), so the byte
        // ranges [start, end) never overlap; `base` outlives the region
        // because `pool.run` blocks until every task completes.
        let span =
            unsafe { std::slice::from_raw_parts_mut(base.get().add(start), end - start) };
        f(first, span);
    });
}

/// Like [`for_each_span`] but over two buffers partitioned in lockstep:
/// unit `i` of `a` (stride `unit_a`) pairs with unit `i` of `b` (stride
/// `unit_b`). Both buffers must contain the same number of units.
pub fn for_each_span2<A, B, F>(
    pool: &WorkerPool,
    threads: usize,
    a: &mut [A],
    unit_a: usize,
    b: &mut [B],
    unit_b: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(unit_a > 0 && unit_b > 0, "span units must be positive");
    if a.is_empty() && b.is_empty() {
        return;
    }
    let n_units = a.len().div_ceil(unit_a);
    // hard assert: a mismatched pair would make the span arithmetic
    // below index past `b` (this is a safe pub fn — the precondition
    // must hold in release builds too, and the check is O(1))
    assert_eq!(n_units, b.len().div_ceil(unit_b), "unit count mismatch");
    let workers = threads.clamp(1, n_units.max(1));
    if workers == 1 {
        f(0, a, b);
        return;
    }
    let (len_a, len_b) = (a.len(), b.len());
    let base_a = SendPtr(a.as_mut_ptr());
    let base_b = SendPtr(b.as_mut_ptr());
    pool.run(workers, &|w| {
        let first = first_unit(n_units, workers, w);
        let units = share(n_units, workers, w);
        let start_a = first * unit_a;
        let end_a = (start_a + units * unit_a).min(len_a);
        let start_b = first * unit_b;
        let end_b = (start_b + units * unit_b).min(len_b);
        // SAFETY: as in `for_each_span`, unit ranges are disjoint and
        // covering in both buffers, and the pool blocks until all tasks
        // complete.
        let (span_a, span_b) = unsafe {
            (
                std::slice::from_raw_parts_mut(base_a.get().add(start_a), end_a - start_a),
                std::slice::from_raw_parts_mut(base_b.get().add(start_b), end_b - start_b),
            )
        };
        f(first, span_a, span_b);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn share_covers_all_units_contiguously() {
        for n in [1usize, 2, 7, 16, 100] {
            for workers in 1..=8 {
                let total: usize = (0..workers).map(|w| share(n, workers, w)).sum();
                assert_eq!(total, n, "n={n} workers={workers}");
                // non-increasing run sizes (remainder goes to early runs)
                for w in 1..workers {
                    assert!(share(n, workers, w) <= share(n, workers, w - 1));
                }
                // first_unit is the prefix sum of share
                let mut acc = 0usize;
                for w in 0..workers {
                    assert_eq!(first_unit(n, workers, w), acc, "n={n} workers={workers} w={w}");
                    acc += share(n, workers, w);
                }
            }
        }
    }

    #[test]
    fn run_executes_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        for tasks in [0usize, 1, 2, 3, 4, 5, 17, 100] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "tasks={tasks}"
            );
        }
    }

    #[test]
    fn spans_cover_every_element_exactly_once() {
        let pool = WorkerPool::new(4);
        for threads in [1usize, 2, 3, 8, 17] {
            for (len, unit) in [(12usize, 4usize), (13, 4), (1, 4), (64, 1), (10, 100)] {
                let mut data = vec![0u32; len];
                for_each_span(&pool, threads, &mut data, unit, |_first, span| {
                    for e in span.iter_mut() {
                        *e += 1;
                    }
                });
                assert!(data.iter().all(|&x| x == 1), "threads={threads} len={len}");
            }
        }
    }

    #[test]
    fn first_unit_index_matches_span_offset() {
        let pool = WorkerPool::new(4);
        let len = 23;
        let unit = 4;
        let base = vec![0u8; len];
        let base_ptr = base.as_ptr() as usize;
        let mut data = base;
        for_each_span(&pool, 4, &mut data, unit, |first, span| {
            let off = span.as_ptr() as usize - base_ptr;
            assert_eq!(off, first * unit);
        });
    }

    #[test]
    fn results_are_thread_count_invariant() {
        let pool = WorkerPool::new(8);
        let compute = |threads: usize| {
            let mut data: Vec<f64> = (0..997).map(|i| i as f64 * 0.25).collect();
            for_each_span(&pool, threads, &mut data, 64, |first, span| {
                for (k, e) in span.iter_mut().enumerate() {
                    *e = (*e + (first * 64 + k) as f64).sqrt();
                }
            });
            data
        };
        let one = compute(1);
        for t in [2, 3, 8] {
            assert_eq!(compute(t), one, "threads={t}");
        }
    }

    #[test]
    fn span2_partitions_in_lockstep() {
        let pool = WorkerPool::new(3);
        // a: 6 units of 8, b: 6 units of 1
        let mut a = vec![1u32; 48];
        let mut b = vec![0u32; 6];
        for_each_span2(&pool, 3, &mut a, 8, &mut b, 1, |first, sa, sb| {
            for (k, out) in sb.iter_mut().enumerate() {
                let blk = &sa[k * 8..(k + 1) * 8];
                *out = blk.iter().sum::<u32>() + (first + k) as u32;
            }
        });
        for (i, &x) in b.iter().enumerate() {
            assert_eq!(x, 8 + i as u32);
        }
        assert!(a.iter().all(|&x| x == 1));
    }

    fn participating_ids(pool: &WorkerPool, tasks: usize) -> HashSet<thread::ThreadId> {
        let ids = Mutex::new(HashSet::new());
        pool.run(tasks, &|_| {
            ids.lock().unwrap().insert(thread::current().id());
        });
        ids.into_inner().unwrap()
    }

    #[test]
    fn consecutive_regions_reuse_the_same_worker_threads() {
        // the tentpole regression: a region must NOT spawn fresh OS
        // threads — the same parked workers serve every dispatch
        let pool = WorkerPool::new(4);
        let first = participating_ids(&pool, 16);
        assert_eq!(
            first.len(),
            pool.width(),
            "static lane striding must involve every lane"
        );
        assert!(first.contains(&thread::current().id()));
        for step in 0..3 {
            assert_eq!(participating_ids(&pool, 16), first, "step {step}");
        }
    }

    #[test]
    fn drop_shuts_workers_down_cleanly() {
        let pool = WorkerPool::new(4);
        pool.run(8, &|_| {});
        let weak = pool.shared_weak();
        drop(pool);
        // drop joins the workers, so no thread still holds the shared
        // state afterwards
        assert!(weak.upgrade().is_none(), "worker threads must have exited");
    }

    #[test]
    fn task_panics_propagate_and_leave_the_pool_serviceable() {
        let pool = WorkerPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must surface to the dispatcher");
        // the pool must have drained the epoch and still work
        let calls = AtomicUsize::new(0);
        pool.run(8, &|_| {
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn dispatch_lane_runs_jobs_in_order_and_joins() {
        let mut lane = DispatchLane::new();
        assert!(!lane.spawned(), "lane spawns lazily");
        let log = Arc::new(Mutex::new(Vec::new()));
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        for i in 0..4 {
            let log = log.clone();
            let tx = tx.clone();
            lane.submit(Box::new(move || {
                log.lock().unwrap().push(i);
                let _ = tx.send(());
            }));
        }
        assert!(lane.spawned());
        for _ in 0..4 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(&*log.lock().unwrap(), &[0, 1, 2, 3], "FIFO order");
        drop(lane); // joins cleanly
    }

    #[test]
    fn dispatch_lane_survives_panicking_jobs() {
        let mut lane = DispatchLane::new();
        lane.submit(Box::new(|| panic!("boom")));
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        lane.submit(Box::new(move || {
            let _ = tx.send(7);
        }));
        assert_eq!(
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap(),
            7,
            "lane must keep serving after a job panic"
        );
    }

    #[test]
    fn lane_and_pool_regions_overlap_without_violating_single_dispatcher() {
        // the tentpole invariant: a lane job in flight while this thread
        // dispatches pool regions — both make progress, no assertion trips
        let pool = WorkerPool::new(3);
        let mut lane = DispatchLane::new();
        let (tx, rx) = std::sync::mpsc::channel::<usize>();
        lane.submit(Box::new(move || {
            // an owned, pool-free "model dispatch"
            let s: usize = (0..100_000).sum();
            let _ = tx.send(s);
        }));
        let mut data = vec![0u32; 4096];
        for _ in 0..5 {
            for_each_span(&pool, 3, &mut data, 64, |_, span| {
                for e in span.iter_mut() {
                    *e += 1;
                }
            });
        }
        assert!(data.iter().all(|&x| x == 5));
        assert!(rx.recv_timeout(std::time::Duration::from_secs(5)).is_ok());
    }

    #[test]
    fn pinned_pool_produces_identical_results() {
        // pinning is placement-only: same partition, same bits, clean drop
        let plain = WorkerPool::new(4);
        let pinned = WorkerPool::with_affinity(4, true);
        let run = |pool: &WorkerPool| {
            let mut data: Vec<f64> = (0..777).map(|i| i as f64 * 0.5).collect();
            for_each_span(pool, 4, &mut data, 32, |first, span| {
                for (k, e) in span.iter_mut().enumerate() {
                    *e = (*e + (first * 32 + k) as f64).sqrt();
                }
            });
            data
        };
        assert_eq!(run(&plain), run(&pinned));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn pinning_within_the_allowed_mask_succeeds() {
        // the allowed mask is readable and non-empty (we are running on
        // *some* CPU), and pinning a scratch thread — not the test
        // runner — to a CPU drawn from it succeeds even under
        // restricted cpusets (where CPU 0 may not be allowed at all)
        let cpus = allowed_cpus();
        assert!(!cpus.is_empty(), "sched_getaffinity should succeed");
        let cpu = cpus[0];
        let ok = thread::spawn(move || pin_current_thread(cpu)).join().unwrap();
        assert!(ok, "pinning to allowed CPU {cpu} should succeed");
    }

    #[test]
    fn width_one_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.width(), 1);
        let here = thread::current().id();
        pool.run(5, &|_| assert_eq!(thread::current().id(), here));
        let mut data = vec![0u8; 100];
        for_each_span(&pool, 8, &mut data, 10, |_, span| {
            for e in span.iter_mut() {
                *e += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }
}
