//! Wire protocol: one JSON object per line, both directions.

use anyhow::{anyhow, Context, Result};

use crate::engine::{FinishReason, GenResult};
use crate::util::json::{self, obj, Value};

/// Parsed client request line.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub seed: Option<u64>,
}

pub fn parse_request(line: &str) -> Result<WireRequest> {
    let v = json::parse(line).map_err(|e| anyhow!("{e}"))?;
    Ok(WireRequest {
        id: v
            .req("id")
            .map_err(|e| anyhow!("{e}"))?
            .as_i64()
            .context("id must be an integer")? as u64,
        prompt: v
            .req("prompt")
            .map_err(|e| anyhow!("{e}"))?
            .as_str()
            .context("prompt must be a string")?
            .to_string(),
        max_new_tokens: v
            .get("max_new_tokens")
            .and_then(Value::as_usize)
            .unwrap_or(64),
        temperature: v
            .get("temperature")
            .and_then(Value::as_f64)
            .unwrap_or(0.8) as f32,
        seed: v.get("seed").and_then(Value::as_i64).map(|s| s as u64),
    })
}

/// Server response line.
#[derive(Debug, Clone)]
pub struct WireResponse {
    pub id: u64,
    pub text: String,
    pub result: GenResult,
}

fn finish_str(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::Context => "context",
    }
}

pub fn render_response(resp: &WireResponse) -> String {
    let r = &resp.result;
    obj(vec![
        ("id", (resp.id as i64).into()),
        ("text", resp.text.as_str().into()),
        ("tokens", r.token_ids.len().into()),
        ("steps", r.steps.into()),
        ("accept_rate", Value::Num(r.acceptance_rate())),
        ("tokens_per_step", Value::Num(r.tokens_per_step())),
        ("latency_ms", Value::Num(r.latency * 1e3)),
        ("finish", finish_str(r.finish).into()),
    ])
    .dump()
}

/// Error line for malformed requests.
pub fn render_error(id: Option<u64>, msg: &str) -> String {
    obj(vec![
        ("id", id.map(|i| (i as i64).into()).unwrap_or(Value::Null)),
        ("error", msg.into()),
    ])
    .dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_request() {
        let r = parse_request(
            r#"{"id": 3, "prompt": "hello", "max_new_tokens": 10, "temperature": 0.5, "seed": 9}"#,
        )
        .unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, "hello");
        assert_eq!(r.max_new_tokens, 10);
        assert_eq!(r.seed, Some(9));
    }

    #[test]
    fn defaults_applied() {
        let r = parse_request(r#"{"id": 1, "prompt": "x"}"#).unwrap();
        assert_eq!(r.max_new_tokens, 64);
        assert!((r.temperature - 0.8).abs() < 1e-6);
        assert_eq!(r.seed, None);
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"prompt": "x"}"#).is_err());
        assert!(parse_request(r#"{"id": "x", "prompt": "y"}"#).is_err());
    }

    #[test]
    fn response_round_trips_as_json() {
        let resp = WireResponse {
            id: 5,
            text: "hello \"world\"".into(),
            result: GenResult {
                id: 5,
                token_ids: vec![1, 2, 3],
                finish: FinishReason::Length,
                steps: 2,
                drafted: 10,
                accepted: 5,
                latency: 0.0123,
            },
        };
        let line = render_response(&resp);
        let v = crate::util::json::parse(&line).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64(), Some(5));
        assert_eq!(v.get("tokens").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(v.get("text").unwrap().as_str(), Some("hello \"world\""));
        assert!((v.get("accept_rate").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn error_rendering() {
        let line = render_error(Some(2), "bad prompt");
        let v = crate::util::json::parse(&line).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("bad prompt"));
        let line = render_error(None, "parse failure");
        assert!(crate::util::json::parse(&line)
            .unwrap()
            .get("id")
            .unwrap()
            .is_null());
    }
}
