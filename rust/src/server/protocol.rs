//! Wire protocol: one JSON object per line, both directions.
//!
//! ## v2 (current)
//!
//! Requests are a versioned envelope:
//!
//! ```json
//! {"v":2, "op":"generate", "id":1, "prompt":"...", "stream":true,
//!  "params":{"max_new_tokens":32, "temperature":0.7, "top_p":0.9,
//!            "stop":["\n"], "seed":7, "gamma":3, "gamma_pinned":true,
//!            "method":"exact"}}
//! {"v":2, "op":"cancel", "id":1}
//! {"v":2, "op":"record", "id":2, "enable":true}
//! ```
//!
//! `record` flips the server's trace-recording gate
//! ([`crate::trace::TraceRecorder`]) when the server was started with
//! `--trace`; it is acknowledged with
//! `{"v":2,"event":"record","id":…,"enabled":…}` or rejected with code
//! `no_recorder`.
//!
//! ## Admission queue & SLO metrics
//!
//! Generate requests pass through a bounded server-side admission
//! queue and are submitted to the engine as batch slots free up
//! (mid-flight refill). Overload produces structured error events:
//! code `queue_full` when the queue is at capacity, `shed` when a
//! queued request waited past the configured deadline, and the
//! admission codes forwarded verbatim from
//! [`crate::engine::AdmitError`] (e.g. `method_gamma_conflict`).
//! Cancelling a still-queued request removes it from the queue and
//! answers with a `done` event carrying `"finish":"cancel"` and zero
//! tokens.
//!
//! The `done` event carries a per-request + server-wide SLO block
//! ([`SloStats`]) when the serve loop produced it: `queue_ms` (this
//! request's admission-queue wait), `queue_depth` (queue length at
//! completion), `latency_percentiles_ms` and
//! `queue_wait_percentiles_ms` (p50/p90/p95/p99 over every request
//! finished so far). When the engine decodes with the pipelined
//! scheduler it also carries a `pipeline` block: speculation-window
//! `depth`, chain/block counters, `full_hits`/`partial_hits`, the
//! per-slot `slots_salvaged`/`slots_redone` totals and the resulting
//! `effective_hit_rate`.
//!
//! `params` keys map 1:1 onto [`SamplingParams`] (absent keys take the
//! shared defaults). v2 parsing is strict: unknown envelope or params
//! keys and wrong field types are rejected, never silently defaulted.
//! `method` is a string (`"baseline"` / `"exact"`) or
//! `{"name":"sigmoid","alpha":…,"beta":…}` — honored per-slot on any
//! batch size (the engine dispatches each batch row under its own
//! method); a `method` is rejected at admission (structured
//! `{"event":"error","code":"rejected"}`, or
//! `"code":"method_gamma_conflict"` on the HLO backend when the
//! method's artifacts share no γ with the rest of the batch — the
//! message lists the offending method and both γ sets).
//!
//! Responses are events. A streaming request receives incremental
//! `{"v":2,"event":"delta","id":…,"text":…,"tokens":…}` lines as tokens
//! commit, then a final `{"v":2,"event":"done", …summary…}`; a
//! non-streaming request receives only the `done`. Failures are
//! `{"v":2,"event":"error","id":…,"code":…,"error":…}`. A cancel frees
//! the slot mid-decode and the request finishes with `"finish":"cancel"`.
//!
//! ## v1 (compatibility shim)
//!
//! A line without `"v"` is a one-shot v1 request
//! (`{"id":…,"prompt":…,"max_new_tokens":…,"temperature":…,"seed":…}`),
//! mapped onto [`SamplingParams::default`] and answered with the
//! original single response line — unchanged for old clients.

use crate::engine::{FinishReason, GenResult, PipelineStats, SamplingParams};
use crate::sampling::Method;
use crate::util::json::{self, obj, Value};

/// Parsed generate request (v1 or v2).
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: u64,
    pub prompt: String,
    pub params: SamplingParams,
    /// emit incremental `delta` events (v2 only)
    pub stream: bool,
    /// parsed from a v1 one-shot line — the response must stay v1-shaped
    pub v1: bool,
}

/// One parsed client line.
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    Generate(WireRequest),
    Cancel { id: u64 },
    /// flip the server's trace-recording gate (v2 only; the server must
    /// have been started with a trace sink attached)
    Record { id: u64, enable: bool },
}

/// Structured protocol error: machine-readable code + human message.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub id: Option<u64>,
    pub code: &'static str,
    pub msg: String,
    /// the offending line spoke v1 — answer with a v1-shaped error line
    /// instead of a v2 error event
    pub v1: bool,
}

impl WireError {
    pub fn new(id: Option<u64>, code: &'static str, msg: impl Into<String>) -> Self {
        WireError {
            id,
            code,
            msg: msg.into(),
            v1: false,
        }
    }

    fn for_v1(mut self, v1: bool) -> Self {
        self.v1 = v1;
        self
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code, self.msg)
    }
}

fn bad(id: Option<u64>, msg: impl Into<String>) -> WireError {
    WireError::new(id, "bad_request", msg)
}

// Strict integer readers: the JSON layer carries f64, but "strict typing"
// means 8.9 must not silently floor to 8 (Value::as_i64/as_usize truncate).
fn as_int(v: &Value) -> Option<i64> {
    v.as_f64()
        .filter(|f| f.fract() == 0.0 && f.abs() <= 9e15)
        .map(|f| f as i64)
}

fn as_uint(v: &Value) -> Option<usize> {
    as_int(v).filter(|&i| i >= 0).map(|i| i as usize)
}

/// Parse one client line into a [`WireMsg`].
///
/// Field presence and types are checked strictly — a present-but-wrong
/// typed field is an error, never silently defaulted (requests are
/// validated at admission instead of trusted off the wire).
pub fn parse_line(line: &str) -> Result<WireMsg, WireError> {
    let v = json::parse(line).map_err(|e| WireError::new(None, "parse", e.to_string()))?;
    let ver = match v.get("v") {
        None => 1,
        Some(x) => as_int(x).ok_or_else(|| bad(None, "v must be an integer"))?,
    };
    if ver != 1 && ver != 2 {
        return Err(WireError::new(
            None,
            "unsupported_version",
            format!("protocol version {ver} not supported (server speaks v1 and v2)"),
        ));
    }
    // from here the dialect is known: v1 lines get v1-shaped error replies
    parse_versioned(&v, ver).map_err(|e| e.for_v1(ver == 1))
}

fn parse_versioned(v: &Value, ver: i64) -> Result<WireMsg, WireError> {
    let id = match v.get("id") {
        None => return Err(bad(None, "missing key \"id\"")),
        Some(x) => as_int(x).ok_or_else(|| bad(None, "id must be an integer"))? as u64,
    };
    // v2 envelopes are strict like their params objects (typos must not
    // silently fall back to defaults); v1 keeps its historic leniency
    if ver == 2 {
        if let Value::Obj(fields) = &v {
            for (key, _) in fields {
                if !matches!(
                    key.as_str(),
                    "v" | "op" | "id" | "prompt" | "params" | "stream" | "enable"
                ) {
                    return Err(bad(
                        Some(id),
                        format!("unknown key {key:?} in request envelope"),
                    ));
                }
            }
        }
    }
    let op = match v.get("op") {
        None => "generate",
        Some(x) => x
            .as_str()
            .ok_or_else(|| bad(Some(id), "op must be a string"))?,
    };
    match op {
        "cancel" => {
            if ver < 2 {
                return Err(bad(Some(id), "cancel requires protocol v2"));
            }
            Ok(WireMsg::Cancel { id })
        }
        "record" => {
            if ver < 2 {
                return Err(bad(Some(id), "record requires protocol v2"));
            }
            let enable = match v.get("enable") {
                None => true,
                Some(x) => x
                    .as_bool()
                    .ok_or_else(|| bad(Some(id), "enable must be a boolean"))?,
            };
            Ok(WireMsg::Record { id, enable })
        }
        "generate" => parse_generate(v, ver, id),
        other => Err(WireError::new(
            Some(id),
            "unknown_op",
            format!("unknown op {other:?} (expected \"generate\", \"cancel\" or \"record\")"),
        )),
    }
}

fn parse_generate(v: &Value, ver: i64, id: u64) -> Result<WireMsg, WireError> {
    let prompt = match v.get("prompt") {
        None => return Err(bad(Some(id), "missing key \"prompt\"")),
        Some(x) => x
            .as_str()
            .ok_or_else(|| bad(Some(id), "prompt must be a string"))?
            .to_string(),
    };
    let mut params = SamplingParams::default();
    let mut stream = false;
    if ver == 1 {
        // v1 shim: flat optional fields onto the shared defaults
        if let Some(x) = v.get("max_new_tokens") {
            params.max_new_tokens = as_uint(x)
                .ok_or_else(|| bad(Some(id), "max_new_tokens must be a non-negative integer"))?;
        }
        if let Some(x) = v.get("temperature") {
            params.temperature = x
                .as_f64()
                .ok_or_else(|| bad(Some(id), "temperature must be a number"))?
                as f32;
        }
        if let Some(x) = v.get("seed") {
            params.seed = Some(
                as_int(x).ok_or_else(|| bad(Some(id), "seed must be an integer"))? as u64,
            );
        }
    } else {
        if let Some(pv) = v.get("params") {
            params = parse_params(pv)
                .map_err(|msg| WireError::new(Some(id), "invalid_params", msg))?;
        }
        if let Some(x) = v.get("stream") {
            stream = x
                .as_bool()
                .ok_or_else(|| bad(Some(id), "stream must be a boolean"))?;
        }
    }
    params
        .validate()
        .map_err(|msg| WireError::new(Some(id), "invalid_params", msg))?;
    Ok(WireMsg::Generate(WireRequest {
        id,
        prompt,
        params,
        stream,
        v1: ver == 1,
    }))
}

/// Parse a v2 `params` object onto [`SamplingParams::default`]. Strict:
/// unknown keys and wrong types are errors.
pub fn parse_params(v: &Value) -> Result<SamplingParams, String> {
    let Value::Obj(fields) = v else {
        return Err("params must be an object".into());
    };
    let mut p = SamplingParams::default();
    for (key, val) in fields {
        match key.as_str() {
            "max_new_tokens" => {
                p.max_new_tokens =
                    as_uint(val).ok_or("max_new_tokens must be a non-negative integer")?;
            }
            "temperature" => {
                p.temperature =
                    val.as_f64().ok_or("temperature must be a number")? as f32;
            }
            "draft_temperature" => {
                p.draft_temperature =
                    Some(val.as_f64().ok_or("draft_temperature must be a number")? as f32);
            }
            "top_k" => {
                p.top_k = as_uint(val).ok_or("top_k must be a non-negative integer")?;
            }
            "top_p" => {
                p.top_p = val.as_f64().ok_or("top_p must be a number")? as f32;
            }
            "stop" => {
                let arr = val.as_arr().ok_or("stop must be an array of strings")?;
                p.stop = arr
                    .iter()
                    .map(|s| {
                        s.as_str()
                            .map(String::from)
                            .ok_or("stop entries must be strings".to_string())
                    })
                    .collect::<Result<_, _>>()?;
            }
            "seed" => {
                p.seed = Some(as_int(val).ok_or("seed must be an integer")? as u64);
            }
            "gamma" => {
                p.gamma = Some(as_uint(val).ok_or("gamma must be a positive integer")?);
            }
            "gamma_pinned" => {
                p.gamma_pinned = val.as_bool().ok_or("gamma_pinned must be a boolean")?;
            }
            "method" => {
                p.method = Some(parse_method_value(val)?);
            }
            other => return Err(format!("unknown parameter {other:?}")),
        }
    }
    Ok(p)
}

fn parse_method_value(v: &Value) -> Result<Method, String> {
    if let Some(name) = v.as_str() {
        return match name {
            "baseline" => Ok(Method::Baseline),
            "exact" => Ok(Method::Exact),
            "sigmoid" | "sigmoid16" => Err(format!(
                "method {name:?} needs alpha/beta — use {{\"name\":{name:?},\"alpha\":…,\"beta\":…}}"
            )),
            other => Err(format!("unknown method {other:?}")),
        };
    }
    if v.get("name").is_some() {
        let name = v
            .get("name")
            .and_then(Value::as_str)
            .ok_or("method name must be a string")?;
        return match name {
            "baseline" => Ok(Method::Baseline),
            "exact" => Ok(Method::Exact),
            "sigmoid" | "sigmoid16" => {
                let alpha = v
                    .get("alpha")
                    .and_then(Value::as_f64)
                    .ok_or("sigmoid method needs numeric alpha")?;
                let beta = v
                    .get("beta")
                    .and_then(Value::as_f64)
                    .ok_or("sigmoid method needs numeric beta")?;
                if name == "sigmoid" {
                    Ok(Method::sigmoid(alpha as f32, beta as f32))
                } else {
                    Ok(Method::sigmoid16(alpha as f32, beta as f32))
                }
            }
            other => Err(format!("unknown method {other:?}")),
        };
    }
    Err("method must be a string or an object with \"name\"".into())
}

fn method_value(m: Method) -> Value {
    match m {
        Method::Baseline => "baseline".into(),
        Method::Exact => "exact".into(),
        m => {
            let (a, b) = m.alpha_beta().expect("sigmoid methods carry alpha/beta");
            obj(vec![
                ("name", m.name().into()),
                ("alpha", Value::Num(a as f64)),
                ("beta", Value::Num(b as f64)),
            ])
        }
    }
}

/// Serialize params as a v2 `params` object (non-default fields only, so
/// the server-side defaults stay the single source of truth).
pub fn params_to_json(p: &SamplingParams) -> Value {
    let d = SamplingParams::default();
    let mut fields: Vec<(&str, Value)> = Vec::new();
    if p.max_new_tokens != d.max_new_tokens {
        fields.push(("max_new_tokens", p.max_new_tokens.into()));
    }
    if p.temperature != d.temperature {
        fields.push(("temperature", Value::Num(p.temperature as f64)));
    }
    if let Some(t) = p.draft_temperature {
        fields.push(("draft_temperature", Value::Num(t as f64)));
    }
    if p.top_k != d.top_k {
        fields.push(("top_k", p.top_k.into()));
    }
    if p.top_p != d.top_p {
        fields.push(("top_p", Value::Num(p.top_p as f64)));
    }
    if !p.stop.is_empty() {
        fields.push((
            "stop",
            Value::Arr(p.stop.iter().map(|s| s.as_str().into()).collect()),
        ));
    }
    if let Some(s) = p.seed {
        fields.push(("seed", (s as i64).into()));
    }
    if let Some(g) = p.gamma {
        fields.push(("gamma", g.into()));
        if p.gamma_pinned {
            fields.push(("gamma_pinned", true.into()));
        }
    }
    if let Some(m) = p.method {
        fields.push(("method", method_value(m)));
    }
    obj(fields)
}

/// Client-side: render a v2 generate line.
pub fn render_generate(id: u64, prompt: &str, params: &SamplingParams, stream: bool) -> String {
    let mut fields = vec![
        ("v", 2i64.into()),
        ("op", "generate".into()),
        ("id", (id as i64).into()),
        ("prompt", prompt.into()),
    ];
    let pjson = params_to_json(params);
    if !matches!(&pjson, Value::Obj(f) if f.is_empty()) {
        fields.push(("params", pjson));
    }
    if stream {
        fields.push(("stream", true.into()));
    }
    obj(fields).dump()
}

/// Client-side: render a v2 cancel line.
pub fn render_cancel(id: u64) -> String {
    obj(vec![
        ("v", 2i64.into()),
        ("op", "cancel".into()),
        ("id", (id as i64).into()),
    ])
    .dump()
}

/// Client-side: render a v2 record-toggle line.
pub fn render_record(id: u64, enable: bool) -> String {
    obj(vec![
        ("v", 2i64.into()),
        ("op", "record".into()),
        ("id", (id as i64).into()),
        ("enable", enable.into()),
    ])
    .dump()
}

/// Server-side: acknowledge a record toggle.
pub fn render_record_ack(id: u64, enabled: bool) -> String {
    obj(vec![
        ("v", 2i64.into()),
        ("event", "record".into()),
        ("id", (id as i64).into()),
        ("enabled", enabled.into()),
    ])
    .dump()
}

/// Server response payload (v1 response line / v2 done event).
#[derive(Debug, Clone)]
pub struct WireResponse {
    pub id: u64,
    pub text: String,
    pub result: GenResult,
}

fn finish_str(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::StopSeq => "stop_seq",
        FinishReason::Context => "context",
        FinishReason::Cancelled => "cancel",
    }
}

fn summary_fields(resp: &WireResponse) -> Vec<(&'static str, Value)> {
    let r = &resp.result;
    vec![
        ("id", (resp.id as i64).into()),
        ("text", resp.text.as_str().into()),
        ("tokens", r.token_ids.len().into()),
        ("steps", r.steps.into()),
        ("accept_rate", Value::Num(r.acceptance_rate())),
        ("tokens_per_step", Value::Num(r.tokens_per_step())),
        ("latency_ms", Value::Num(r.latency * 1e3)),
        ("finish", finish_str(r.finish).into()),
    ]
}

/// v1 one-shot response line (unchanged from protocol v1).
pub fn render_response(resp: &WireResponse) -> String {
    obj(summary_fields(resp)).dump()
}

/// Per-request + server-wide SLO block attached to v2 `done` events by
/// the serve loop. Times are seconds; rendering converts to ms.
#[derive(Debug, Clone)]
pub struct SloStats {
    /// this request's wait in the server admission queue
    pub queue_wait: f64,
    /// admission-queue depth when the request finished
    pub queue_depth: usize,
    /// decode-latency percentiles over every request finished so far
    pub latency: crate::util::stats::Summary,
    /// queue-wait percentiles over every request finished so far
    pub queue: crate::util::stats::Summary,
}

fn percentiles_ms(s: &crate::util::stats::Summary) -> Value {
    obj(vec![
        ("n", s.n.into()),
        ("p50", Value::Num(s.p50 * 1e3)),
        ("p90", Value::Num(s.p90 * 1e3)),
        ("p95", Value::Num(s.p95 * 1e3)),
        ("p99", Value::Num(s.p99 * 1e3)),
    ])
}

/// The engine-wide pipelined-scheduler block attached to v2 `done`
/// events when the engine runs with the pipeline on: speculation-window
/// depth, chain/block counters, full and partial barrier hits, and the
/// per-slot salvage totals behind `effective_hit_rate`.
fn pipeline_block(p: &PipelineStats) -> Value {
    obj(vec![
        ("depth", p.per_depth.len().into()),
        ("chains", (p.chains as i64).into()),
        ("blocks", (p.blocks as i64).into()),
        ("full_hits", (p.full_hits as i64).into()),
        ("partial_hits", (p.partial_hits as i64).into()),
        ("slots_salvaged", (p.slots_salvaged as i64).into()),
        ("slots_redone", (p.slots_redone as i64).into()),
        ("effective_hit_rate", Value::Num(p.effective_hit_rate())),
    ])
}

/// v2 final summary event.
pub fn render_done(resp: &WireResponse) -> String {
    render_done_with(resp, None, None)
}

/// v2 final summary event, optionally carrying the serve loop's SLO
/// block (queue wait + queue depth for this request, latency and
/// queue-wait percentiles over every request finished so far) and the
/// engine-wide pipelined-scheduler counters ([`pipeline_block`]).
pub fn render_done_with(
    resp: &WireResponse,
    slo: Option<&SloStats>,
    pipeline: Option<&PipelineStats>,
) -> String {
    let mut fields = vec![("v", 2i64.into()), ("event", "done".into())];
    fields.extend(summary_fields(resp));
    if let Some(s) = slo {
        fields.push(("queue_ms", Value::Num(s.queue_wait * 1e3)));
        fields.push(("queue_depth", s.queue_depth.into()));
        fields.push(("latency_percentiles_ms", percentiles_ms(&s.latency)));
        fields.push(("queue_wait_percentiles_ms", percentiles_ms(&s.queue)));
    }
    if let Some(p) = pipeline {
        fields.push(("pipeline", pipeline_block(p)));
    }
    obj(fields).dump()
}

/// v2 incremental token-chunk event.
pub fn render_delta(id: u64, text: &str, tokens: usize) -> String {
    obj(vec![
        ("v", 2i64.into()),
        ("event", "delta".into()),
        ("id", (id as i64).into()),
        ("text", text.into()),
        ("tokens", tokens.into()),
    ])
    .dump()
}

/// v2 structured error event (also carries the plain `error` key so v1
/// clients that only check for `error` keep working).
pub fn render_error_event(err: &WireError) -> String {
    obj(vec![
        ("v", 2i64.into()),
        ("event", "error".into()),
        (
            "id",
            err.id.map(|i| (i as i64).into()).unwrap_or(Value::Null),
        ),
        ("code", err.code.into()),
        ("error", err.msg.as_str().into()),
    ])
    .dump()
}

/// v1-shaped error line for failures on v1 one-shot requests.
pub fn render_error(id: Option<u64>, msg: &str) -> String {
    obj(vec![
        ("id", id.map(|i| (i as i64).into()).unwrap_or(Value::Null)),
        ("error", msg.into()),
    ])
    .dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn generate(line: &str) -> WireRequest {
        match parse_line(line).unwrap() {
            WireMsg::Generate(r) => r,
            other => panic!("expected generate, got {other:?}"),
        }
    }

    fn err_code(line: &str) -> &'static str {
        parse_line(line).unwrap_err().code
    }

    #[test]
    fn parses_full_v1_request() {
        let r = generate(
            r#"{"id": 3, "prompt": "hello", "max_new_tokens": 10, "temperature": 0.5, "seed": 9}"#,
        );
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, "hello");
        assert_eq!(r.params.max_new_tokens, 10);
        assert!((r.params.temperature - 0.5).abs() < 1e-6);
        assert_eq!(r.params.seed, Some(9));
        assert!(r.v1);
        assert!(!r.stream);
    }

    #[test]
    fn v1_shim_defaults_are_sampling_params_default() {
        // the compatibility shim maps a bare v1 line onto the one shared
        // defaults struct — no protocol-local default values
        let r = generate(r#"{"id": 1, "prompt": "x"}"#);
        assert_eq!(r.params, SamplingParams::default());
        assert!(r.v1);
    }

    #[test]
    fn parses_v2_request_with_params() {
        let r = generate(
            r#"{"v":2,"op":"generate","id":4,"prompt":"p","stream":true,
                "params":{"max_new_tokens":8,"temperature":0.2,"draft_temperature":0.1,
                          "top_k":5,"top_p":0.9,"stop":["\n","."],"seed":11,
                          "gamma":3,"gamma_pinned":true,"method":"exact"}}"#,
        );
        assert!(!r.v1);
        assert!(r.stream);
        assert_eq!(r.params.max_new_tokens, 8);
        assert!((r.params.draft_temp() - 0.1).abs() < 1e-6);
        assert_eq!(r.params.top_k, 5);
        assert_eq!(r.params.stop, vec!["\n".to_string(), ".".to_string()]);
        assert_eq!(r.params.seed, Some(11));
        assert_eq!(r.params.gamma, Some(3));
        assert!(r.params.gamma_pinned);
        assert_eq!(r.params.method, Some(Method::Exact));
    }

    #[test]
    fn v2_without_params_takes_defaults() {
        let r = generate(r#"{"v":2,"id":5,"prompt":"q"}"#);
        assert_eq!(r.params, SamplingParams::default());
        assert!(!r.stream);
        assert!(!r.v1);
    }

    #[test]
    fn parses_method_object_form() {
        let r = generate(
            r#"{"v":2,"id":1,"prompt":"p",
                "params":{"method":{"name":"sigmoid","alpha":-1000,"beta":1000}}}"#,
        );
        assert_eq!(r.params.method, Some(Method::sigmoid(-1e3, 1e3)));
        let r = generate(
            r#"{"v":2,"id":1,"prompt":"p",
                "params":{"method":{"name":"sigmoid16","alpha":-1e3,"beta":1e3}}}"#,
        );
        assert_eq!(r.params.method, Some(Method::sigmoid16(-1e3, 1e3)));
    }

    #[test]
    fn parses_cancel() {
        assert_eq!(
            parse_line(r#"{"v":2,"op":"cancel","id":9}"#).unwrap(),
            WireMsg::Cancel { id: 9 }
        );
        // cancel is a v2 op
        assert_eq!(err_code(r#"{"op":"cancel","id":9}"#), "bad_request");
    }

    #[test]
    fn rejects_malformed_and_missing_fields() {
        assert_eq!(err_code("not json"), "parse");
        assert_eq!(err_code(r#"{"prompt": "x"}"#), "bad_request"); // missing id
        assert_eq!(err_code(r#"{"id": 1}"#), "bad_request"); // missing prompt
        assert_eq!(err_code(r#"{"id": "x", "prompt": "y"}"#), "bad_request");
    }

    #[test]
    fn rejects_wrong_field_types() {
        assert_eq!(err_code(r#"{"id":1,"prompt":7}"#), "bad_request");
        assert_eq!(
            err_code(r#"{"id":1,"prompt":"x","max_new_tokens":"many"}"#),
            "bad_request"
        );
        assert_eq!(
            err_code(r#"{"id":1,"prompt":"x","temperature":"hot"}"#),
            "bad_request"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","stream":"yes"}"#),
            "bad_request"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"top_k":"all"}}"#),
            "invalid_params"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"stop":"\n"}}"#),
            "invalid_params"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":[1]}"#),
            "invalid_params"
        );
    }

    #[test]
    fn v2_envelope_is_strict_v1_stays_lenient() {
        // a typo'd v2 key must not silently fall back to defaults
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","Stream":true}"#),
            "bad_request"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","Params":{"top_k":1}}"#),
            "bad_request"
        );
        // v1 keeps its historic tolerance of extra keys
        let r = generate(r#"{"id":1,"prompt":"x","extra":true}"#);
        assert_eq!(r.params, SamplingParams::default());
    }

    #[test]
    fn rejects_unknown_op_version_and_params() {
        assert_eq!(err_code(r#"{"v":2,"op":"noop","id":1}"#), "unknown_op");
        assert_eq!(err_code(r#"{"v":3,"id":1,"prompt":"x"}"#), "unsupported_version");
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"temprature":0.5}}"#),
            "invalid_params"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"method":"warp"}}"#),
            "invalid_params"
        );
        // sigmoid as a bare string lacks alpha/beta
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"method":"sigmoid"}}"#),
            "invalid_params"
        );
    }

    #[test]
    fn errors_carry_the_request_dialect() {
        // v1 lines must be answered with v1-shaped errors
        assert!(parse_line(r#"{"id":1,"prompt":"x","temperature":-1}"#)
            .unwrap_err()
            .v1);
        assert!(parse_line(r#"{"prompt":"x"}"#).unwrap_err().v1);
        assert!(!parse_line(r#"{"v":2,"id":1,"prompt":"x","params":{"top_p":0}}"#)
            .unwrap_err()
            .v1);
        // dialect unknown: unparseable lines and unsupported versions
        assert!(!parse_line("garbage").unwrap_err().v1);
        assert!(!parse_line(r#"{"v":7,"id":1}"#).unwrap_err().v1);
    }

    #[test]
    fn fractional_integers_are_rejected_not_floored() {
        assert_eq!(err_code(r#"{"id":1.5,"prompt":"x"}"#), "bad_request");
        assert_eq!(
            err_code(r#"{"id":1,"prompt":"x","max_new_tokens":8.9}"#),
            "bad_request"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"gamma":2.5}}"#),
            "invalid_params"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"top_k":1.2}}"#),
            "invalid_params"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"seed":0.5}}"#),
            "invalid_params"
        );
    }

    #[test]
    fn admission_validation_happens_at_parse() {
        assert_eq!(
            err_code(r#"{"id":1,"prompt":"x","temperature":-1}"#),
            "invalid_params"
        );
        assert_eq!(
            err_code(r#"{"id":1,"prompt":"x","max_new_tokens":0}"#),
            "invalid_params"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"top_p":0}}"#),
            "invalid_params"
        );
        assert_eq!(
            err_code(r#"{"v":2,"id":1,"prompt":"x","params":{"gamma":0}}"#),
            "invalid_params"
        );
    }

    #[test]
    fn generate_line_round_trips_through_parse() {
        let params = SamplingParams::default()
            .with_max_new_tokens(12)
            .with_temperature(0.3)
            .with_top_k(7)
            .with_top_p(0.85)
            .with_stop(vec![".".into()])
            .with_seed(99)
            .pin_gamma(2)
            .with_method(Method::sigmoid(-1e4, 1e4));
        let line = render_generate(6, "prompt text", &params, true);
        let r = generate(&line);
        assert_eq!(r.id, 6);
        assert_eq!(r.prompt, "prompt text");
        assert!(r.stream);
        assert_eq!(r.params, params);

        // defaults render to no params object at all
        let line = render_generate(7, "p", &SamplingParams::default(), false);
        assert!(!line.contains("params"), "{line}");
        assert_eq!(generate(&line).params, SamplingParams::default());

        let cancel = render_cancel(6);
        assert_eq!(parse_line(&cancel).unwrap(), WireMsg::Cancel { id: 6 });
    }

    fn sample_response() -> WireResponse {
        WireResponse {
            id: 5,
            text: "hello \"world\"".into(),
            result: GenResult {
                id: 5,
                token_ids: vec![1, 2, 3],
                finish: FinishReason::Length,
                steps: 2,
                drafted: 10,
                accepted: 5,
                latency: 0.0123,
            },
        }
    }

    #[test]
    fn v1_response_round_trips_as_json() {
        let line = render_response(&sample_response());
        let v = json::parse(&line).unwrap();
        assert!(v.get("v").is_none(), "v1 response must stay unversioned");
        assert_eq!(v.get("id").unwrap().as_i64(), Some(5));
        assert_eq!(v.get("tokens").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(v.get("text").unwrap().as_str(), Some("hello \"world\""));
        assert!((v.get("accept_rate").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn v2_events_render() {
        let mut resp = sample_response();
        resp.result.finish = FinishReason::Cancelled;
        let v = json::parse(&render_done(&resp)).unwrap();
        assert_eq!(v.get("v").unwrap().as_i64(), Some(2));
        assert_eq!(v.get("event").unwrap().as_str(), Some("done"));
        assert_eq!(v.get("finish").unwrap().as_str(), Some("cancel"));

        let v = json::parse(&render_delta(4, "chunk", 3)).unwrap();
        assert_eq!(v.get("event").unwrap().as_str(), Some("delta"));
        assert_eq!(v.get("text").unwrap().as_str(), Some("chunk"));
        assert_eq!(v.get("tokens").unwrap().as_usize(), Some(3));

        let v = json::parse(&render_error_event(&WireError::new(
            Some(2),
            "invalid_params",
            "bad temperature",
        )))
        .unwrap();
        assert_eq!(v.get("event").unwrap().as_str(), Some("error"));
        assert_eq!(v.get("code").unwrap().as_str(), Some("invalid_params"));
        assert_eq!(v.get("error").unwrap().as_str(), Some("bad temperature"));
        assert_eq!(v.get("id").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn v1_error_rendering() {
        let line = render_error(Some(2), "bad prompt");
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("bad prompt"));
        let line = render_error(None, "parse failure");
        assert!(json::parse(&line).unwrap().get("id").unwrap().is_null());
    }

    #[test]
    fn parses_record_toggle() {
        assert_eq!(
            parse_line(r#"{"v":2,"op":"record","id":1,"enable":false}"#).unwrap(),
            WireMsg::Record { id: 1, enable: false }
        );
        // enable defaults to true
        assert_eq!(
            parse_line(r#"{"v":2,"op":"record","id":2}"#).unwrap(),
            WireMsg::Record { id: 2, enable: true }
        );
        // v2-only, strictly typed
        assert_eq!(err_code(r#"{"op":"record","id":1}"#), "bad_request");
        assert_eq!(
            err_code(r#"{"v":2,"op":"record","id":1,"enable":"yes"}"#),
            "bad_request"
        );
        // round trip through the client renderer
        assert_eq!(
            parse_line(&render_record(3, true)).unwrap(),
            WireMsg::Record { id: 3, enable: true }
        );
        let v = json::parse(&render_record_ack(3, true)).unwrap();
        assert_eq!(v.get("event").unwrap().as_str(), Some("record"));
        assert_eq!(v.get("enabled").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn done_event_carries_slo_block() {
        let mut latency = crate::util::stats::Series::new();
        let mut queue = crate::util::stats::Series::new();
        for i in 1..=100 {
            latency.push(i as f64 * 1e-3);
            queue.push(i as f64 * 1e-4);
        }
        let slo = SloStats {
            queue_wait: 0.002,
            queue_depth: 7,
            latency: latency.summary(),
            queue: queue.summary(),
        };
        let line = render_done_with(&sample_response(), Some(&slo), None);
        let v = json::parse(&line).unwrap();
        assert!((v.get("queue_ms").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
        assert_eq!(v.get("queue_depth").unwrap().as_usize(), Some(7));
        let lp = v.get("latency_percentiles_ms").expect("latency percentiles");
        assert_eq!(lp.get("n").unwrap().as_usize(), Some(100));
        let p99 = lp.get("p99").unwrap().as_f64().unwrap();
        let p50 = lp.get("p50").unwrap().as_f64().unwrap();
        assert!(p99 > p50, "p99 {p99} should exceed p50 {p50}");
        let qp = v.get("queue_wait_percentiles_ms").expect("queue percentiles");
        assert_eq!(qp.get("n").unwrap().as_usize(), Some(100));
        // plain render_done stays SLO-free
        let plain = render_done(&sample_response());
        assert!(!plain.contains("latency_percentiles"));
        assert!(!plain.contains("queue_ms"));
    }

    #[test]
    fn done_event_carries_pipeline_block() {
        let stats = PipelineStats {
            chains: 4,
            blocks: 9,
            full_hits: 6,
            partial_hits: 2,
            misses: 1,
            slots_salvaged: 15,
            slots_redone: 5,
            per_depth: vec![Default::default(); 2],
            ..PipelineStats::default()
        };
        let line = render_done_with(&sample_response(), None, Some(&stats));
        let v = json::parse(&line).unwrap();
        let p = v.get("pipeline").expect("pipeline block");
        assert_eq!(p.get("depth").unwrap().as_usize(), Some(2));
        assert_eq!(p.get("chains").unwrap().as_i64(), Some(4));
        assert_eq!(p.get("full_hits").unwrap().as_i64(), Some(6));
        assert_eq!(p.get("partial_hits").unwrap().as_i64(), Some(2));
        assert_eq!(p.get("slots_salvaged").unwrap().as_i64(), Some(15));
        assert_eq!(p.get("slots_redone").unwrap().as_i64(), Some(5));
        let eff = p.get("effective_hit_rate").unwrap().as_f64().unwrap();
        assert!((eff - 0.75).abs() < 1e-9);
        // a serial engine renders no pipeline block
        let plain = render_done_with(&sample_response(), None, None);
        assert!(!plain.contains("\"pipeline\""));
    }

    #[test]
    fn stop_seq_finish_reason_renders() {
        let mut resp = sample_response();
        resp.result.finish = FinishReason::StopSeq;
        let v = json::parse(&render_response(&resp)).unwrap();
        assert_eq!(v.get("finish").unwrap().as_str(), Some("stop_seq"));
    }
}
