//! Threaded TCP server with a single-engine continuous-batching loop.
//!
//! Topology: one listener thread accepting connections, one reader thread
//! per connection parsing JSON lines, one engine thread owning the
//! [`Engine`] and stepping it while work exists. Responses are written by
//! the engine thread through per-connection cloned `TcpStream`s, so the
//! hot loop never blocks on a slow client for longer than one write.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::engine::{Engine, GenRequest};
use crate::tokenizer::Tokenizer;

use super::protocol::{parse_request, render_error, render_response, WireResponse};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7077".into(),
        }
    }
}

struct Job {
    engine_id: u64,
    wire_id: u64,
    stream: TcpStream,
    request: GenRequest,
}

/// The serving front-end. Owns the engine on a dedicated thread.
pub struct Server {
    addr: std::net::SocketAddr,
    listener: TcpListener,
    job_tx: Sender<Job>,
    engine_handle: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind and spawn the engine thread. `addr` may use port 0 for an
    /// ephemeral port (tests); the bound address is available via
    /// [`Server::addr`].
    pub fn start(engine: Engine, tokenizer: Tokenizer, cfg: ServerConfig) -> Result<Self> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let (job_tx, job_rx) = channel::<Job>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let engine_handle = {
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("specd-engine".into())
                .spawn(move || engine_loop(engine, tokenizer, job_rx, shutdown))
                .context("spawning engine thread")?
        };
        crate::info!("server listening on {addr}");
        Ok(Server {
            addr,
            listener,
            job_tx,
            engine_handle: std::sync::Mutex::new(Some(engine_handle)),
            shutdown,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Accept connections until `shutdown` is set (blocks the caller).
    pub fn serve_forever(&self) -> Result<()> {
        let next_id = AtomicU64::new(1);
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::Relaxed) {
                break;
            }
            let stream = stream.context("accept")?;
            let tx = self.job_tx.clone();
            let id_base = next_id.fetch_add(1 << 20, Ordering::Relaxed);
            std::thread::spawn(move || {
                if let Err(e) = connection_loop(stream, tx, id_base) {
                    crate::debug!("connection ended: {e:#}");
                }
            });
        }
        Ok(())
    }

    /// Signal shutdown (in-flight requests finish; accept loop exits on
    /// the next connection attempt).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // unblock the accept loop
        let _ = TcpStream::connect(self.addr);
        let _ = self.engine_handle.lock().unwrap().take();
    }
}

fn connection_loop(stream: TcpStream, tx: Sender<Job>, id_base: u64) -> Result<()> {
    let peer = stream.peer_addr()?;
    crate::debug!("connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut n = 0u64;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Ok(wire) => {
                n += 1;
                let engine_id = id_base + n;
                let request = GenRequest {
                    id: engine_id,
                    prompt_ids: Vec::new(), // encoded by the engine thread
                    prompt_text: Some(wire.prompt),
                    max_new_tokens: wire.max_new_tokens,
                    temperature: wire.temperature,
                    draft_temperature: wire.temperature,
                    seed: wire.seed.unwrap_or(wire.id),
                };
                tx.send(Job {
                    engine_id,
                    wire_id: wire.id,
                    stream: stream.try_clone()?,
                    request,
                })
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            }
            Err(e) => {
                let mut s = stream.try_clone()?;
                let _ = writeln!(s, "{}", render_error(None, &format!("{e:#}")));
            }
        }
    }
    Ok(())
}

fn engine_loop(
    mut engine: Engine,
    tokenizer: Tokenizer,
    rx: Receiver<Job>,
    shutdown: Arc<AtomicBool>,
) {
    let mut inflight: HashMap<u64, (u64, TcpStream)> = HashMap::new();
    loop {
        if shutdown.load(Ordering::Relaxed) && inflight.is_empty() {
            break;
        }
        // admit everything queued; block briefly when idle
        let mut got = false;
        loop {
            let job = if engine.active() == 0 && inflight.is_empty() && !got {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(j) => j,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(_) => break,
                }
            };
            got = true;
            let mut req = job.request;
            if let Some(text) = req.prompt_text.take() {
                req.prompt_ids = tokenizer.encode(&text);
            }
            inflight.insert(job.engine_id, (job.wire_id, job.stream));
            engine.submit(req);
        }

        if engine.active() == 0 && engine.pending() == 0 {
            continue;
        }
        if let Err(e) = engine.step() {
            crate::error!("engine step failed: {e:#}");
            // fail all in-flight requests
            for (_eid, (wid, mut stream)) in inflight.drain() {
                let _ = writeln!(stream, "{}", render_error(Some(wid), "engine failure"));
            }
            continue;
        }
        for result in engine.take_results() {
            if let Some((wire_id, mut stream)) = inflight.remove(&result.id) {
                let resp = WireResponse {
                    id: wire_id,
                    text: tokenizer.decode_until_stop(&result.token_ids),
                    result,
                };
                let _ = writeln!(stream, "{}", render_response(&resp));
            }
        }
    }
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request and wait for its response line.
    pub fn request(
        &mut self,
        id: u64,
        prompt: &str,
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<crate::util::json::Value> {
        let line = crate::util::json::obj(vec![
            ("id", (id as i64).into()),
            ("prompt", prompt.into()),
            ("max_new_tokens", max_new_tokens.into()),
            ("temperature", crate::util::json::Value::Num(temperature as f64)),
        ])
        .dump();
        writeln!(self.stream, "{line}")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        crate::util::json::parse(&resp).map_err(|e| anyhow::anyhow!("{e}"))
    }
}
